"""SWIFT / cost model / clustering / mobility — incl. property-based
invariants with hypothesis."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_config
from repro.sched import swift as SW
from repro.sched.clustering import (TrainingTask, availability_split,
                                    cluster_fleet, form_cluster)
from repro.sched.costmodel import (CostParams, JETSON_AGX, JETSON_NANO,
                                   Unit, Vehicle, make_fleet, model_units,
                                   partition_feasible, path_time,
                                   vision_encoder_units)
from repro.sched.graph import vision_encoder_graph
from repro.sched.mobility import (future_distribution, make_patterns,
                                  pattern_posterior, sample_trajectory,
                                  stability_score)

CP = CostParams()


def _units(n, cap, cmp_=1e12, com=1e6):
    return [Unit(f"u{i}", cap, cmp_, com) for i in range(n)]


def _fleet(mems, stb=None):
    return make_fleet([dict(cmp=1e12, mem=m, com=0.1e9) for m in mems],
                      stb=stb or [1.0] * len(mems))


# ------------------------------------------------------------- properties --
@settings(max_examples=60, deadline=None)
@given(
    n_units=st.integers(1, 24),
    cap=st.floats(0.1e9, 2e9),
    mems=st.lists(st.floats(0.5e9, 16e9), min_size=2, max_size=8),
)
def test_greedy_pipeline_respects_memory(n_units, cap, mems):
    units = _units(n_units, cap)
    fleet = _fleet(mems)
    pipe = SW.phase1_greedy(fleet, units, CP)
    if pipe is None:     # infeasible is a legal outcome
        assert sum(v.mem for v in fleet) < n_units * cap or \
            max(v.mem for v in fleet) < cap or True
        return
    # c1: complete partition; c2: memory; c4: no repeated vehicles;
    # c5: disjoint partitions (by construction of contiguous ranges)
    assert sum(len(p) for p in pipe.partition) == n_units
    assert partition_feasible(pipe.path, pipe.partition)
    vids = [v.vid for v in pipe.path]
    assert len(vids) == len(set(vids))
    assert pipe.time == pytest.approx(
        path_time(pipe.path, pipe.partition, CP))


@settings(max_examples=40, deadline=None)
@given(mems=st.lists(st.floats(1e9, 32e9), min_size=2, max_size=6),
       stb=st.lists(st.floats(0, 1), min_size=6, max_size=6))
def test_phase1_orders_by_stability(mems, stb):
    stb = stb[:len(mems)]
    fleet = _fleet(mems, stb=stb)
    units = _units(8, 0.4e9)
    pipe = SW.phase1_greedy(fleet, units, CP)
    if pipe is None or len(pipe.path) < 2:
        return
    s = [v.stb for v in pipe.path]
    assert all(a >= b for a, b in zip(s, s[1:]))


def test_swift_beats_or_matches_greedy_when_feasible():
    rng = np.random.default_rng(3)
    units = _units(12, 0.9e9, cmp_=2e12, com=50e6)
    fleet = make_fleet(
        [dict(cmp=rng.uniform(0.3, 4) * 1e12, mem=rng.uniform(2, 9) * 1e9,
              com=0.1e9) for _ in range(6)],
        stb=rng.uniform(0, 1, 6))
    res = SW.swift(fleet, units, cp=CP)
    assert res.initial is not None
    assert len(res.essential) >= len(fleet) - 1
    for pipe in res.essential.values():
        assert partition_feasible(pipe.path, pipe.partition)
        assert sum(len(p) for p in pipe.partition) == len(units)
    # phase 1 is fast (quick start property, Fig. 5a)
    assert res.phase1_s < 0.5


def test_greedy_fails_where_capacity_tight():
    """Paper Fig. 6: the single-resource baseline goes infeasible when the
    model outgrows the in-order prefix of vehicle memory."""
    units = _units(10, 1.0e9)
    # arrival order puts tiny vehicles first
    fleet = _fleet([0.5e9, 0.5e9, 0.5e9])
    assert SW.greedy_matching(fleet, units, CP) is None


def test_vision_units_topo_order():
    cfg = get_config("flad_vision")
    g = vision_encoder_graph(cfg)
    order = [n.name for n in g.topo_sorted()]
    assert order.index("rgb_backbone") < order.index("enc0")
    assert order.index(f"enc{cfg.num_layers-1}") < order.index("decoder")
    units = vision_encoder_units(cfg)
    assert len(units) == cfg.num_layers + 3


def test_model_units_match_param_scale():
    cfg = get_config("qwen3_14b")
    units = model_units(cfg, seq_len=4096)
    total_cap = sum(u.cap for u in units)
    # ~10 bytes/param training state over the block params
    assert total_cap == pytest.approx(
        10 * (cfg.param_count() - 2 * cfg.vocab_size * cfg.d_model
              - cfg.d_model), rel=0.15)


# ------------------------------------------- scheduler -> runtime bridge --
def test_units_to_layer_template_folds_overflow():
    """Regression: pipelines with more stages than the SPMD width used to
    silently drop units (counts[:stages]); overflow now folds into the
    last stage and the sum invariant always holds."""
    units = _units(5, 1.0e9)
    fleet = _fleet([1.1e9, 1.1e9, 6e9, 1.1e9, 1.1e9])
    pipe = SW.Pipeline(list(fleet), [[u] for u in units], 0.0)
    t = SW.units_to_layer_template(pipe, 3)
    assert t == (1, 1, 3)
    assert sum(t) == len(units)
    # shorter pipelines still pad with zero-layer stages
    assert SW.units_to_layer_template(pipe, 8) == (1, 1, 1, 1, 1, 0, 0, 0)
    assert sum(SW.units_to_layer_template(pipe, 8)) == len(units)
    # folding that overflows the host vehicle's memory must raise, not drop
    cramped = SW.Pipeline([fleet[0], fleet[1], fleet[3], fleet[4]],
                          [[u] for u in units[:4]], 0.0)
    with pytest.raises(ValueError):
        SW.units_to_layer_template(cramped, 2)


def test_window_fleet_keeps_head():
    """Regression: head=min(idx, N_MAX-1) pinned the WRONG vehicle as
    pipeline head for fleets larger than N_MAX."""
    fleet = _fleet([8e9] * (SW.N_MAX + 6))
    for idx in (0, SW.N_MAX // 2, SW.N_MAX + 2, SW.N_MAX + 5):
        win, head = SW.window_fleet(fleet, idx)
        assert len(win) == SW.N_MAX
        assert win[head].vid == fleet[idx].vid
    with pytest.raises(ValueError):
        SW.window_fleet(fleet, len(fleet))


def test_pipeline_env_invalid_slot_penalized():
    fleet = _fleet([8e9] * 3)
    units = _units(6, 0.9e9)
    env = SW.PipelineEnv(fleet, units, CP)
    env.reset()
    # action addressing a vehicle slot beyond the fleet: penalty, no crash
    obs, mask, r, done = env.step((SW.N_MAX - 1) * len(SW.CHUNK_OPTIONS))
    assert r == -5.0 and done
    with pytest.raises(ValueError):
        SW.PipelineEnv(fleet, units, CP, head=5)


def test_swift_agent_on_oversized_fleet():
    """DQN-driven SWIFT over a fleet larger than N_MAX: the fleet is
    windowed (not truncated), no essential pipeline drops units."""
    from repro.sched.dqn import DQNConfig, DoubleDQN
    n = SW.N_MAX + 4
    fleet = _fleet([8e9] * n, stb=list(np.linspace(1.0, 0.2, n)))
    units = _units(8, 0.9e9)
    probe = SW.PipelineEnv(fleet[:SW.N_MAX], units, CP)
    agent = DoubleDQN(DQNConfig(obs_dim=probe.obs_dim,
                                n_actions=probe.n_actions))
    res = SW.swift(fleet, units, agent=agent, cp=CP)
    assert set(res.essential) == {v.vid for v in fleet}
    for pipe in res.essential.values():
        assert sum(len(p) for p in pipe.partition) == len(units)
        assert partition_feasible(pipe.path, pipe.partition)


# ------------------------------------------------------------- clustering --
def test_availability_split_eq2():
    task = TrainingTask(m_cap=10e9, m_cmp=1e15, e_req=1)
    rich = Vehicle(0, cmp=1e15, mem=32e9, com=1e9, dwl=10.0)
    poor = Vehicle(1, cmp=1e12, mem=4e9, com=1e9, dwl=200.0)
    gone = Vehicle(2, cmp=1e12, mem=4e9, com=1e9, dwl=1.0)
    rs, rl, out = availability_split([rich, poor, gone], task)
    assert [v.vid for v in rs] == [0]
    assert [v.vid for v in rl] == [1]
    assert [v.vid for v in out] == [2]


def test_form_cluster_meets_constraints():
    task = TrainingTask(m_cap=10e9, m_cmp=1e13, e_req=1)
    seed = Vehicle(0, 1e12, 4e9, 1e9, stb=0.9, dwl=600)
    nbrs = [Vehicle(i, 1e12, 4e9, 1e9, stb=1 - 0.1 * i, dwl=600)
            for i in range(1, 6)]
    clu = form_cluster(seed, nbrs, task)
    assert clu is not None
    assert sum(v.mem for v in clu) > task.m_cap


def test_cluster_fleet_covers():
    task = TrainingTask(m_cap=10e9, m_cmp=1e13, e_req=1)
    vehicles = [Vehicle(i, 1e12, 4e9, 1e9, stb=np.random.rand(), dwl=600)
                for i in range(9)]
    clusters, leftover = cluster_fleet(vehicles, task)
    seen = [v.vid for c in clusters for v in c] + [v.vid for v in leftover]
    assert sorted(seen) == list(range(9))


# --------------------------------------------------------------- mobility --
def test_dtmc_rows_stochastic():
    world = make_patterns(6, 3, seed=0)
    assert np.allclose(world.patterns.sum(-1), 1.0, atol=1e-9)


def test_future_distribution_normalized():
    world = make_patterns(5, 2, seed=1)
    rng = np.random.default_rng(0)
    hist = sample_trajectory(world, 0, 7, 4, rng).tolist()
    fut = future_distribution(world, hist, horizon=5)
    assert np.allclose(fut.sum(-1), 1.0, atol=1e-8)
    post = pattern_posterior(world, hist)
    assert post.sum() == pytest.approx(1.0)


def test_stability_higher_for_co_moving():
    world = make_patterns(8, 2, seed=2)
    rng = np.random.default_rng(1)
    a = sample_trajectory(world, 0, 9, 5, rng).tolist()
    b = sample_trajectory(world, 0, 10, 5, rng).tolist()      # same pattern
    c = sample_trajectory(world, 1, 54, 5, rng).tolist()      # far + diff
    assert stability_score(world, a, b, 5) > stability_score(world, a, c, 5)
