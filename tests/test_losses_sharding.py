"""chunked CE oracle equivalence + sharding-rule properties (hypothesis)."""
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.train.losses import chunked_ce, head_weight


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 4), s=st.sampled_from([8, 12, 32]),
       d=st.sampled_from([16, 32]), v=st.sampled_from([50, 128]),
       cs=st.sampled_from([4, 8, 1024]))
def test_chunked_ce_matches_naive(b, s, d, v, cs):
    key = jax.random.PRNGKey(b * 100 + s)
    x = jax.random.normal(key, (b, s, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, v))
    labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, v)
    loss, metrics = chunked_ce(x, w, labels, seq_chunk=cs)
    logits = x @ w
    logp = jax.nn.log_softmax(logits, axis=-1)
    naive = -jnp.take_along_axis(logp, labels[..., None], -1).mean()
    assert float(jnp.abs(loss - naive)) < 1e-4


def test_chunked_ce_grads_match_naive():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 16, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 33))
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 33)

    g1 = jax.grad(lambda w: chunked_ce(x, w, labels, seq_chunk=4)[0])(w)
    g2 = jax.grad(lambda w: -jnp.take_along_axis(
        jax.nn.log_softmax(x @ w, -1), labels[..., None], -1).mean())(w)
    assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-5


def test_head_weight_tied_vs_untied():
    p_untied = {"head": {"w": jnp.ones((4, 7))},
                "embed": {"table": jnp.zeros((7, 4))}}
    assert head_weight(p_untied).shape == (4, 7)
    p_tied = {"embed": {"table": jnp.ones((7, 4))}}
    assert head_weight(p_tied).shape == (4, 7)


# ------------------------------------------------------------- sharding ----
def test_param_specs_divisibility(mesh24):
    """Every sharded dim divides its mesh axis (hypothesis-style sweep over
    real model shapes)."""
    from repro.configs import ARCH_IDS, get_config
    from repro.core import sharding as shd
    from repro.core.steps import abstract_params

    for arch in ARCH_IDS[:6]:
        cfg = get_config(arch)
        shapes = abstract_params(cfg)
        specs = shd.param_specs(mesh24, shapes)

        def check(path, leaf, spec):
            for dim, ax in zip(leaf.shape, spec):
                if ax is None:
                    continue
                size = mesh24.shape[ax] if isinstance(ax, str) else \
                    int(jnp.prod(jnp.asarray([mesh24.shape[a] for a in ax])))
                assert dim % size == 0, (arch, path, leaf.shape, spec)

        jax.tree_util.tree_map_with_path(
            lambda p, l, s: check(p, l, s), shapes, specs)


def test_batch_specs_shard_leading(mesh24):
    from repro.core import sharding as shd
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
             "odd": jax.ShapeDtypeStruct((3, 4), jnp.float32)}
    specs = shd.batch_specs(mesh24, batch)
    assert specs["tokens"][0] in ("data", ("data",))
    assert len(specs["odd"]) == 0 or specs["odd"][0] is None


def test_state_specs_kv(mesh24):
    from repro.core import sharding as shd
    st = {"caches": {"k": jax.ShapeDtypeStruct((4, 2, 8, 64, 16),
                                               jnp.bfloat16),
                     "pos": jax.ShapeDtypeStruct((64,), jnp.int32)}}
    specs = shd.state_specs_sharding(mesh24, st)
    k_spec = specs["caches"]["k"]
    assert k_spec[1] in ("data", ("data",))  # batch dim (after stack dim)
    assert k_spec[3] == "model"              # cache sequence dim


def test_act_rules_constrain_noop_without_rules():
    from repro.core.act_sharding import constrain
    x = jnp.ones((4, 8, 2))
    assert constrain(x) is x
