"""repro.api surface: Session x every registered strategy, MeshSpec,
registry errors, hooks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (LoopHooks, MeshSpec, Session, available_strategies,
                       get_strategy)
from repro.config import ShapeConfig

SHAPE = ShapeConfig("api", 16, 8, "train")


def _flat(tree):
    return np.concatenate([np.asarray(x).ravel()
                           for x in jax.tree.leaves(tree)
                           if jnp.issubdtype(jnp.asarray(x).dtype,
                                             jnp.inexact)])


def _session(strategy, mesh, **kw):
    return Session("flad-vision", strategy=strategy, mesh=mesh,
                   shape=SHAPE, learning_rate=2e-3, **kw)


@pytest.mark.parametrize("strategy,options", [
    ("tensor", {}),
    ("pipeline", {}),
    ("fedavg", {"local_steps": 2}),
    ("fl_pipeline", {"local_steps": 2}),
    ("hier_fl", {"local_steps": 2, "topology": "2@nano*2,agx*2",
                 "codec": "int8"}),
])
def test_session_runs_every_strategy(mesh22, strategy, options):
    ses = _session(strategy, mesh22, **options)
    _, (params0, _) = ses.build()
    before = _flat(params0)
    out = ses.run(2, hooks=LoopHooks(log_fn=lambda *a: None))
    last = out["history"][-1]
    # scalar loss for step strategies; per-client vector (recorded whole,
    # not silently averaged) for the client-stacked round strategies
    loss = last.get("loss", last.get("per_client/loss"))
    assert loss is not None and np.isfinite(loss).all()
    after = _flat(ses.state[0])
    assert not np.allclose(before, after), "params did not change"
    # the merged (flat-model) view exists for every strategy layout
    merged = ses.merged_params()
    assert all(np.all(np.isfinite(x)) for x in jax.tree.leaves(merged)
               if jnp.issubdtype(jnp.asarray(x).dtype, jnp.inexact))


def test_registry_lists_strategies():
    names = available_strategies()
    for expected in ("tensor", "pipeline", "fedavg", "fl_pipeline",
                     "swift_pipeline", "hier_fl", "async_hier_fl",
                     "distill_fl"):
        assert expected in names


def test_unknown_strategy_raises_with_valid_names():
    with pytest.raises(ValueError) as ei:
        get_strategy("warp-drive")
    msg = str(ei.value)
    for name in available_strategies():
        assert name in msg


def test_meshspec_parse_and_axes():
    spec = MeshSpec.parse("2,4")
    assert spec.dims == (2, 4)
    assert spec.axis_names == ("data", "model")
    spec3 = MeshSpec.parse((2, 2, 2))
    assert spec3.axis_names == ("pod", "data", "model")
    assert MeshSpec(production=True).size == 256
    assert MeshSpec(production=True, multi_pod=True).size == 512
    with pytest.raises(ValueError):
        MeshSpec.parse("2,2,2,2")


def test_session_accepts_concrete_mesh(mesh24):
    ses = _session("tensor", mesh24)
    assert ses.mesh is mesh24
    assert ses.mesh_spec.dims == (2, 4)


def test_hooks_backup_and_history(mesh22):
    from repro.recovery.backup import EdgeBackup
    backup = EdgeBackup(interval=1)
    ses = _session("tensor", mesh22)
    ses.run(2, hooks=LoopHooks(backup=backup, log_fn=lambda *a: None))
    assert backup.backups_taken == 2
    restored, step = backup.restore()
    assert jax.tree.structure(restored) == \
        jax.tree.structure(ses.state[0])


def test_serve_smoke(mesh22):
    ses = Session("flad-adllm", strategy="tensor", mesh=mesh22)
    out = ses.serve(requests=1, batch=2, context=8, decode_steps=2,
                    log_fn=None)
    assert out["total_tokens"] == 2 * 3  # batch x (1 prefill + 2 decode)
    assert out["sequences"][0].shape == (2, 3)


def test_lower_compiles(mesh22):
    ses = _session("tensor", mesh22)
    compiled = ses.lower().compile()
    assert compiled is not None
