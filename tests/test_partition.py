"""Direct unit tests for :mod:`repro.data.partition` (non-IID fleet
partitioning): Dirichlet limits, determinism, and the empty-vehicle
edge case."""
import numpy as np
import pytest

from repro.data.partition import (dirichlet_mixtures, fleet_datasets,
                                  vehicle_dataset)
from repro.data.synthetic import DrivingDataConfig, TownWorld

DCFG = DrivingDataConfig(n_towns=4, patches=4, feature_dim=16,
                         num_waypoints=3, num_light_classes=4)


def test_dirichlet_rows_are_distributions():
    mix = dirichlet_mixtures(8, 4, beta=0.5, seed=1)
    assert mix.shape == (8, 4)
    assert (mix >= 0).all()
    np.testing.assert_allclose(mix.sum(axis=1), 1.0, atol=1e-12)


def test_dirichlet_beta_to_zero_is_hard_partition():
    """beta -> 0: each vehicle's mixture collapses onto one town."""
    mix = dirichlet_mixtures(16, 4, beta=1e-3, seed=0)
    assert (mix.max(axis=1) > 0.99).all()


def test_dirichlet_beta_to_inf_is_iid():
    """beta -> inf: every vehicle sees the uniform town mixture."""
    mix = dirichlet_mixtures(16, 4, beta=1e6, seed=0)
    np.testing.assert_allclose(mix, 0.25, atol=5e-3)


def test_vehicle_dataset_deterministic_under_seed():
    world = TownWorld(DCFG)
    mix = dirichlet_mixtures(1, DCFG.n_towns, beta=0.5, seed=3)[0]
    a = vehicle_dataset(world, mix, 32, seed=7)
    b = vehicle_dataset(world, mix, 32, seed=7)
    c = vehicle_dataset(world, mix, 32, seed=8)
    assert set(a) == {"rgb", "lidar", "light", "waypoints"}
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    assert any(not np.array_equal(a[k], c[k]) for k in a)


def test_vehicle_dataset_n_zero():
    """n=0 used to IndexError on ``parts[0]``; it must yield an empty
    dataset with the right keys and trailing shapes."""
    world = TownWorld(DCFG)
    mix = np.full(DCFG.n_towns, 1.0 / DCFG.n_towns)
    out = vehicle_dataset(world, mix, 0, seed=0)
    assert set(out) == {"rgb", "lidar", "light", "waypoints"}
    for v in out.values():
        assert len(v) == 0
    assert out["rgb"].shape[1:] == (DCFG.patches, DCFG.feature_dim)
    assert out["waypoints"].shape[1:] == (DCFG.num_waypoints, 2)


def test_fleet_datasets_shapes_and_count():
    ds = fleet_datasets(DCFG, 3, 16, beta=0.5, seed=0)
    assert len(ds) == 3
    for d in ds:
        assert len(d["light"]) == 16
        assert d["rgb"].shape == (16, DCFG.patches, DCFG.feature_dim)
