"""The vehicle->edge->cloud fabric: topology, codecs, two-tier
aggregation, staleness, and the ``hier_fl`` strategy end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hyp import given, settings, st

from repro.comm.codecs import (available_codecs, get_codec,
                               roundtrip_leaf, roundtrip_stacked,
                               tree_nbytes, zero_residual)
from repro.comm.hierarchy import (cloud_merge, edge_aggregate,
                                  hierarchical_mean, staleness_weights)
from repro.comm.topology import Topology, parse_topology
from repro.core.fedavg import fedavg

KEY = jax.random.PRNGKey(0)
TOPO = parse_topology("2@nano*2,agx*2")


def _stacked(c=4, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"a": jax.random.normal(k1, (c, 6, 5)),
            "b": jax.random.normal(k2, (c, 300))}


# ---- topology -------------------------------------------------------------

def test_parse_topology_spec():
    assert TOPO.n_clients == 4 and TOPO.n_edges == 2
    assert TOPO.edges == ((0, 1), (2, 3))
    assert list(TOPO.client_edge) == [0, 0, 1, 1]
    # plain fleet spec = one edge pod; passthrough for instances
    assert parse_topology("nano*3").n_edges == 1
    assert parse_topology(TOPO) is TOPO


def test_topology_rejects_bad_shapes():
    with pytest.raises(ValueError, match="1 <= n_edges"):
        Topology.from_fleet("nano*2", 3)
    with pytest.raises(ValueError, match="integer E"):
        parse_topology("two@nano*2")
    with pytest.raises(ValueError, match="partition"):
        Topology(TOPO.vehicles, ((0, 1), (2,)))


def test_round_stats_link_math():
    topo = parse_topology("2@nano*4", backhaul_bw=1e9,
                          backhaul_latency=0.0)
    nbytes = 125e6                         # 1 s on a nano's 0.125 GB/s V2X
    hier = topo.hier_round_stats(nbytes)
    flat = topo.flat_round_stats(nbytes)
    assert hier["uplink_bytes"] == flat["uplink_bytes"] == 4 * int(nbytes)
    # edges reduce: 2 backhaul payloads vs 4
    assert hier["backhaul_bytes"] == 2 * int(nbytes)
    assert flat["backhaul_bytes"] == 4 * int(nbytes)
    assert hier["round_time_s"] == pytest.approx(1.0 + 0.125)
    assert flat["round_time_s"] == pytest.approx(1.0 + 0.5)


# ---- codecs ---------------------------------------------------------------

def test_codec_registry():
    assert set(available_codecs()) >= {"none", "int8", "topk"}
    with pytest.raises(ValueError, match="unknown codec"):
        get_codec("gzip")


@pytest.mark.parametrize("name,opts", [("none", {}), ("int8", {}),
                                       ("topk", {"k_frac": 0.25})])
def test_codec_wire_bytes(name, opts):
    codec = get_codec(name, **opts)
    n = 1000
    expected = {"none": 4 * n, "int8": n + 4 * 8, "topk": 8 * 250}[name]
    assert codec.nbytes(n) == expected


def test_int8_roundtrip_error_bound():
    codec = get_codec("int8")
    x = jax.random.normal(KEY, (700,)) * 4.0
    dec = codec.decode(codec.encode(x, KEY), x.size)
    # rowwise bound: one quantization step of the row's absmax
    rows = np.asarray(jnp.pad(x, (0, 896 - 700)).reshape(7, 128))
    step = np.abs(rows).max(axis=1, keepdims=True) / 127.0
    err = np.abs(np.asarray(dec) - np.asarray(x))
    assert (err.reshape(-1) <= step.repeat(128, 1).reshape(-1)[:700]
            + 1e-6).all()


def test_topk_exact_support_recovery():
    codec = get_codec("topk", k_frac=0.01)       # k = 10 of 1000
    x = jnp.zeros((1000,)).at[jnp.arange(0, 1000, 100)].set(
        jnp.arange(10.0) + 1.0)
    dec = codec.decode(codec.encode(x, KEY), x.size)
    assert codec.k(1000) == 10
    # the support IS the k largest entries, recovered exactly
    assert jnp.array_equal(dec, x)


def test_error_feedback_telescopes():
    """decoded_t = (x + res_{t-1}) - res_t, so the running sum of decoded
    updates tracks t*x to within one bounded residual."""
    codec = get_codec("topk", k_frac=0.1)
    x = jax.random.normal(KEY, (400,))
    res = jnp.zeros_like(x)
    total = jnp.zeros_like(x)
    for t in range(12):
        dec, res = roundtrip_leaf(codec, x, res,
                                  jax.random.PRNGKey(t))
        total = total + dec
    err = float(jnp.abs(total / 12 - x).max())
    one_shot = float(jnp.abs(
        codec.decode(codec.encode(x, KEY), x.size) - x).max())
    assert err < one_shot / 3, (err, one_shot)


def test_roundtrip_stacked_shapes_and_lossless():
    stacked = _stacked()
    codec = get_codec("none")
    res = zero_residual(stacked)
    dec, res2 = roundtrip_stacked(codec, stacked, res, KEY)
    assert jax.tree.all(jax.tree.map(
        lambda a, b: jnp.allclose(a, b), dec, stacked))
    assert all(float(jnp.abs(r).max()) == 0.0
               for r in jax.tree.leaves(res2))


# ---- hypothesis property tests (skip when hypothesis is absent) -----------

@settings(max_examples=20, deadline=None)
@given(st.integers(1, 500), st.integers(0, 2 ** 31 - 1))
def test_prop_int8_roundtrip_bounded(n, seed):
    codec = get_codec("int8")
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n,)) * (1.0 + seed % 7)
    dec = codec.decode(codec.encode(x, key), n)
    rows = -(-n // 128)
    padded = np.zeros(rows * 128, np.float32)
    padded[:n] = np.asarray(x)
    step = np.abs(padded.reshape(rows, 128)).max(axis=1)
    err = np.abs(np.asarray(dec) - padded[:n])
    assert (err <= step.repeat(128)[:n] / 127.0 + 1e-6).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 300), st.integers(0, 2 ** 31 - 1),
       st.floats(0.05, 1.0))
def test_prop_topk_support(n, seed, k_frac):
    codec = get_codec("topk", k_frac=k_frac)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n,))
    dec = np.asarray(codec.decode(codec.encode(x, key), n))
    k = codec.k(n)
    assert (dec != 0).sum() <= k
    kept = np.abs(np.asarray(x))[dec != 0]
    if kept.size:
        # every kept magnitude >= every dropped magnitude
        assert kept.min() >= np.abs(np.asarray(x))[dec == 0].max() - 1e-6
    np.testing.assert_allclose(dec[dec != 0],
                               np.asarray(x)[dec != 0], rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(["int8", "topk"]), st.integers(0, 2 ** 31 - 1))
def test_prop_error_feedback_converges(name, seed):
    """Repeated EF rounds on a constant update: the mean transmitted
    value converges to the true update (residual stays bounded)."""
    codec = get_codec(name, **({} if name == "int8" else
                               {"k_frac": 0.2}))
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (256,))
    res = jnp.zeros_like(x)
    total = jnp.zeros_like(x)
    rounds = 16
    for t in range(rounds):
        dec, res = roundtrip_leaf(codec, x, res, jax.random.PRNGKey(t))
        total = total + dec
    assert float(jnp.abs(res).max()) < 10.0           # residual bounded
    err = float(jnp.abs(total / rounds - x).max())
    assert err <= float(jnp.abs(res).max()) / rounds + 1e-5


# ---- hierarchy ------------------------------------------------------------

def test_two_tier_equals_flat_mean():
    stacked = _stacked()
    w = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    flat = fedavg(stacked, weights=w)
    hier = fedavg(stacked, weights=w, topology=TOPO)
    for k in flat:
        assert jnp.allclose(flat[k], hier[k], atol=1e-5)
    uni = fedavg(stacked)
    hier_u = hierarchical_mean(stacked, None, TOPO)
    for k in uni:
        assert jnp.allclose(uni[k], hier_u[k], atol=1e-5)


def test_edge_aggregate_weights():
    stacked = {"a": jnp.stack([jnp.zeros(3), jnp.ones(3),
                               jnp.full(3, 2.0), jnp.full(3, 4.0)])}
    w = jnp.asarray([1.0, 3.0, 1.0, 1.0])
    edge_tree, edge_w = edge_aggregate(stacked, w, TOPO)
    assert jnp.allclose(edge_tree["a"][0], 0.75)      # (0*1 + 1*3) / 4
    assert jnp.allclose(edge_tree["a"][1], 3.0)       # (2 + 4) / 2
    assert jnp.allclose(edge_w, jnp.asarray([4.0, 2.0]))
    with pytest.raises(ValueError, match="topology declares"):
        edge_aggregate({"a": jnp.zeros((3, 2))}, None, TOPO)


def test_cloud_merge_staleness_downweights():
    edge_tree = {"a": jnp.stack([jnp.zeros(4), jnp.ones(4)])}
    w = jnp.asarray([1.0, 1.0])
    fresh = cloud_merge(edge_tree, w)
    assert jnp.allclose(fresh["a"], 0.5)
    stale = cloud_merge(edge_tree, w, staleness=jnp.asarray([1.0, 0.25]))
    assert jnp.allclose(stale["a"], 0.2)              # 0.25 / 1.25


def test_staleness_weights_lag():
    s = staleness_weights([0.5, 1.0, 1.5, 3.2], 1.0, decay=0.5)
    np.testing.assert_allclose(s, [1.0, 1.0, 0.5, 0.125])
    with pytest.raises(ValueError, match="deadline"):
        staleness_weights([1.0], 0.0)
    with pytest.raises(ValueError, match="decay"):
        staleness_weights([1.0], 1.0, decay=1.5)


# ---- hier_fl strategy end-to-end ------------------------------------------

def _session(codec="none", **kw):
    from repro.api import Session
    return Session("flad-vision", strategy="hier_fl", mesh=(1,),
                   shape="16x8", topology=TOPO, codec=codec,
                   local_steps=2, **kw)


def test_hier_fl_trains_and_reports_wire_metrics():
    from repro.api import LoopHooks
    seen = []
    hooks = LoopHooks(log_every=1, log_fn=lambda *a, **k: None,
                      on_round=lambda r, m: seen.append((r, m)))
    ses = _session(codec="int8")
    out = ses.run(2, hooks=hooks)
    assert len(out["history"]) == 2
    # per-client losses are recorded whole under per_client/, not
    # np.mean-flattened into a misleading scalar
    assert all(np.isfinite(h["per_client/loss"]).all()
               for h in out["history"])
    assert all(h["per_client/loss"].shape == (TOPO.n_clients,)
               for h in out["history"])
    assert all("loss" not in h for h in out["history"])
    assert [r for r, _ in seen] == [0, 1]
    stats = ses.strategy.comm_stats
    for _, m in seen:
        assert m["comm_bytes_up"] == stats["uplink_bytes"]
        assert m["sim_round_s"] == pytest.approx(stats["round_time_s"])
    # int8 wire format ~3.9x smaller per client than fp32
    fp32 = tree_nbytes(get_codec("none"),
                       ses.merged_params())
    assert fp32 / stats["bytes_per_client"] > 3.5
    merged = ses.merged_params()
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(merged))


def test_hier_fl_identity_codec_matches_flat_fedavg():
    """With the lossless codec and uniform weights, the fabric round is
    the flat FedAvg round (two-tier mean == flat mean on deltas)."""
    from repro.api import LoopHooks, Session
    quiet = LoopHooks(log_every=10 ** 9, log_fn=lambda *a, **k: None)
    hier = _session(codec="none")
    hier.run(2, hooks=quiet)
    flat = Session("flad-vision", strategy="fedavg", mesh=(1,),
                   shape="16x8", clients=TOPO.n_clients, local_steps=2)
    flat.run(2, hooks=quiet)
    a = jax.tree.leaves(hier.merged_params())
    b = jax.tree.leaves(flat.merged_params())
    for x, y in zip(a, b):
        assert jnp.allclose(x, y, atol=1e-5), \
            float(jnp.abs(x - y).max())


def test_hier_fl_async_staleness_mode():
    ses = _session(codec="int8", async_decay=0.5)
    step = ses.strategy.make_step(ses.cfg, ses.shape, ses.mesh)
    assert step is not None
    stats = ses.strategy.comm_stats
    assert stats["staleness"] is not None
    assert stats["staleness"].shape == (TOPO.n_edges,)
    assert (stats["staleness"] <= 1.0).all()
    assert (stats["staleness"] > 0.0).all()


# ---- review regressions ---------------------------------------------------

def test_edge_pod_zero_weights_raise():
    """Weights passing the global sum check but zeroing out one pod used
    to 0/0 that edge's partial average and NaN the global params."""
    stacked = _stacked()
    w = jnp.asarray([0.0, 0.0, 1.0, 1.0])       # pod 0 sums to zero
    with pytest.raises(ValueError, match="edge pod 0"):
        fedavg(stacked, weights=w, topology=TOPO)


def test_topk_edge_payload_pays_for_support_union():
    codec = get_codec("topk", k_frac=0.05)
    n = 1000                                     # k = 50
    assert codec.nbytes(n) == 8 * 50
    assert codec.edge_nbytes(n, 2) == 8 * 100    # union of 2 members
    # union saturating the leaf falls back to dense fp32
    assert codec.edge_nbytes(n, 50) == 4 * n
    # dense codecs aggregate to the client wire format
    assert get_codec("int8").edge_nbytes(n, 4) == \
        get_codec("int8").nbytes(n)


def test_hier_round_stats_per_edge_bytes():
    topo = parse_topology("2@nano*4", backhaul_bw=1e9,
                          backhaul_latency=0.0)
    stats = topo.hier_round_stats(125e6, [1e9, 2e9])
    assert stats["backhaul_bytes"] == 3_000_000_000
    np.testing.assert_allclose(stats["edge_arrival_s"], [2.0, 3.0])


def test_async_deadline_requires_decay():
    from repro.api import get_strategy
    with pytest.raises(ValueError, match="async_decay"):
        get_strategy("hier_fl", async_deadline=1.0)


def test_hier_fl_rounding_stream_is_seedable():
    """The codec's stochastic-rounding stream derives from the init key:
    same key -> same stream (reproducible re-init), different keys ->
    different streams."""
    from repro.api import get_strategy
    ses = _session(codec="int8")
    ses.build()
    k0 = ses.strategy._key
    assert k0 is not None
    s2 = get_strategy("hier_fl", topology=TOPO, codec="int8")
    s2.init(ses.cfg, ses.shape, ses.mesh, ses.prng())
    assert jnp.array_equal(k0, s2._key)
    s2.init(ses.cfg, ses.shape, ses.mesh, jax.random.PRNGKey(123))
    assert not jnp.array_equal(k0, s2._key)
