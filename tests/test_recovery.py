"""Recovery: template pregeneration, diff-based redistribution, backup."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.recovery.backup import EdgeBackup
from repro.recovery.failures import sample_failures
from repro.recovery.recover import recover, run_failure_sequence
from repro.recovery.templates import (full_redistribution_bytes,
                                      pregenerate, redistribution_bytes)
from repro.sched.costmodel import (CostParams, JETSON_AGX, JETSON_NANO,
                                   Unit, make_fleet)

CP = CostParams()


def _setup(n_units=12, cap=0.8e9):
    rng = np.random.default_rng(0)
    units = [Unit(f"u{i}", cap, 1e12, 1e6) for i in range(n_units)]
    fleet = make_fleet([dict(JETSON_NANO)] * 4 + [dict(JETSON_AGX)] * 2,
                       stb=rng.uniform(0, 1, 6),
                       dwl=rng.uniform(600, 3600, 6))
    return fleet, units


def test_pregenerate_covers_all_departures():
    fleet, units = _setup()
    ts = pregenerate(fleet, units, CP)
    assert set(ts.on_departure) == {v.vid for v in fleet}
    for pipe in ts.on_departure.values():
        assert pipe is not None


def test_diff_moves_less_than_full():
    fleet, units = _setup()
    ts = pregenerate(fleet, units, CP)
    for vid, pipe in ts.on_departure.items():
        assert redistribution_bytes(ts.active, pipe) <= \
            full_redistribution_bytes(pipe) + 1e-6


def test_recovery_ordering():
    """template < elastic < relaunch (paper Fig. 5b: 5s < 30s < 50s)."""
    fleet, units = _setup()
    ts = pregenerate(fleet, units, CP)
    times = {s: recover(s, ts, fleet[0].vid, fleet, units, CP).seconds
             for s in ("template", "elastic", "relaunch")}
    assert times["template"] < times["elastic"] < times["relaunch"]
    assert times["relaunch"] / times["template"] > 3


def test_failure_sequence_template_fastest():
    fleet, units = _setup()
    fails = sample_failures(fleet, 7200, seed=3)
    res = {s: run_failure_sequence(fleet, units, fails, s, CP)
           for s in ("template", "relaunch")}
    assert res["template"]["mean_recovery_s"] < \
        res["relaunch"]["mean_recovery_s"]


def test_edge_backup_roundtrip():
    bk = EdgeBackup(interval=2)
    tree = {"w": jnp.arange(6.0), "b": {"x": jnp.ones((2, 2))}}
    assert bk.maybe_backup(0, tree)
    assert not bk.maybe_backup(1, tree)
    got, step = bk.restore()
    assert step == 0
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_restage_after_failure(mesh24):
    from repro.configs import get_config
    from repro.configs.common import reduced
    from repro.core import pipeline as pl
    from repro.models import build_model
    from repro.recovery.backup import restage

    cfg = reduced(get_config("flad_vision"))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    t_old = {"blocks": (1, 1, 0, 0)}
    t_new = {"blocks": (2, 0, 0, 0)}
    pp = pl.stage_params_from(params, cfg, t_old)
    merged = pl.merge_stage_params(pp, t_old)
    pp2 = restage(merged, cfg, t_new, mesh24)
    merged2 = pl.merge_stage_params(pp2, t_new)
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(merged2)):
        assert jnp.array_equal(a, b)
