"""Data generator non-IID properties + LoRA/distillation behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.partition import dirichlet_mixtures, fleet_datasets
from repro.data.pipeline import Prefetcher, batches, client_round_batches
from repro.data.synthetic import DrivingDataConfig, TownWorld
from repro.distill.lora import (LoRAConfig, init_lora, lora_param_count,
                                merge_lora)

DCFG = DrivingDataConfig(feature_dim=32, patches=8, n_towns=3, seed=1)


def test_dirichlet_mixtures_stochastic():
    mix = dirichlet_mixtures(10, 4, beta=0.5, seed=0)
    assert mix.shape == (10, 4)
    assert np.allclose(mix.sum(-1), 1.0)


def test_light_state_learnable_within_town():
    """The traffic-light label is a linear readout of features — a ridge
    classifier fit on town-0 data beats chance on town 0."""
    world = TownWorld(DCFG)
    rng = np.random.default_rng(0)
    tr = world.sample(0, 512, rng)
    te = world.sample(0, 256, rng)
    X = tr["rgb"].mean(1)
    Y = np.eye(DCFG.num_light_classes)[tr["light"]]
    W = np.linalg.solve(X.T @ X + 1e-1 * np.eye(X.shape[1]), X.T @ Y)
    acc = (te["rgb"].mean(1) @ W).argmax(1) == te["light"]
    assert acc.mean() > 0.5


def test_town_shift_hurts_transfer():
    """The same classifier transfers worse to a rotated town (the non-IID
    property FL exploits)."""
    world = TownWorld(DCFG)
    rng = np.random.default_rng(0)
    tr = world.sample(0, 512, rng)
    X = tr["rgb"].mean(1)
    Y = np.eye(DCFG.num_light_classes)[tr["light"]]
    W = np.linalg.solve(X.T @ X + 1e-1 * np.eye(X.shape[1]), X.T @ Y)
    same = world.sample(0, 256, rng)
    other = world.sample(2, 256, rng)
    acc_same = ((same["rgb"].mean(1) @ W).argmax(1) == same["light"]).mean()
    acc_other = ((other["rgb"].mean(1) @ W).argmax(1)
                 == other["light"]).mean()
    assert acc_same > acc_other


def test_red_light_stops_waypoints():
    world = TownWorld(DCFG)
    rng = np.random.default_rng(0)
    s = world.sample(1, 512, rng)
    red = s["waypoints"][s["light"] == 0]
    green = s["waypoints"][s["light"] != 0]
    if len(red) and len(green):
        assert np.linalg.norm(red[:, -1], axis=-1).mean() < \
            np.linalg.norm(green[:, -1], axis=-1).mean()


def test_batches_cover_epoch():
    data = {"x": np.arange(10), "y": np.arange(10) * 2}
    got = list(batches(data, 3, epochs=1))
    assert len(got) == 3
    seen = np.concatenate([b["x"] for b in got])
    assert len(np.unique(seen)) == 9


def test_round_batches_shape():
    ds = fleet_datasets(DCFG, 3, 32, beta=0.4)
    rb = client_round_batches(ds, local_steps=2, batch_size=4)
    assert rb["rgb"].shape[:3] == (3, 2, 4)


def test_prefetcher_order():
    out = list(Prefetcher(iter(range(7))))
    assert out == list(range(7))


# ------------------------------------------------------------------ lora ---
def test_lora_zero_init_is_identity():
    params = {"attn": {"wq": jnp.ones((8, 8)), "scale": jnp.ones(8)}}
    cfg = LoRAConfig(rank=2)
    lora = init_lora(jax.random.PRNGKey(0), params, cfg)
    merged = merge_lora(params, lora, cfg)
    assert jnp.allclose(merged["attn"]["wq"], params["attn"]["wq"])
    assert lora["attn"]["scale"] is None


def test_apply_lora_matches_merge_and_differentiates():
    """The fused-kernel path (apply_lora -> ops.lora_matmul_ad) computes
    the same adapted linear as merge-then-matmul AND carries gradients to
    the factors (the raw pallas_call has no autodiff rule)."""
    from repro.distill.lora import apply_lora
    cfg = LoRAConfig(rank=4)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (2, 24, 32))
    w = jax.random.normal(ks[1], (32, 16))
    factors = {"A": jax.random.normal(ks[2], (32, 4)),
               "B": jax.random.normal(ks[3], (4, 16))}

    got = apply_lora(x, w, factors, cfg, interpret=True)
    merged = (w + cfg.scale * factors["A"] @ factors["B"]).astype(w.dtype)
    want = x @ merged
    assert float(jnp.max(jnp.abs(got - want))) < 1e-3

    def loss(f):
        return jnp.sum(apply_lora(x, w, f, cfg, interpret=True) ** 2)

    grads = jax.grad(loss)(factors)
    g_ref = jax.grad(lambda f: jnp.sum(
        (x @ (w + cfg.scale * f["A"] @ f["B"])) ** 2))(factors)
    for name in ("A", "B"):
        err = float(jnp.max(jnp.abs(grads[name] - g_ref[name])))
        assert err < 1e-2 * max(1.0, float(jnp.max(jnp.abs(g_ref[name]))))


def test_lora_param_fraction_small():
    from repro.configs import get_config
    from repro.configs.common import reduced
    from repro.models import build_model
    cfg = reduced(get_config("flad_adllm"))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    lora = init_lora(jax.random.PRNGKey(1), params, LoRAConfig(rank=4))
    frac = lora_param_count(lora) / sum(x.size
                                        for x in jax.tree.leaves(params))
    assert frac < 0.1       # paper §2.5: 0.1–1% at full scale


def test_distill_reduces_gap():
    from repro.configs import get_config
    from repro.configs.common import reduced
    from repro.distill.celladapt import (adllm_config, init_adllm,
                                         make_distill_step)
    base = reduced(get_config("flad_adllm"))
    tcfg = adllm_config(base, feature_dim=16, feature_tokens=4,
                        num_waypoints=4)
    scfg = tcfg.replace(num_layers=1, d_ff=64)
    key = jax.random.PRNGKey(0)
    tp = init_adllm(key, tcfg)
    sp = init_adllm(jax.random.PRNGKey(1), scfg)
    step, opt = make_distill_step(tcfg, scfg, lr=2e-3)
    ost = opt.init(sp)
    batch = {"features": jax.random.normal(key, (4, 4, 16)),
             "tokens": jax.random.randint(key, (4, 8), 0, 100)}
    first = None
    for _ in range(8):
        sp, ost, loss = step(sp, ost, tp, batch)
        first = first if first is not None else float(loss)
    assert float(loss) < first
