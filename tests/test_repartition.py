"""Scheduler -> runtime loop: swift_pipeline strategy, live dynamic
repartitioning (Repartitioner), checkpoint template sidecars."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import LoopHooks, Session
from repro.api.session import load_config
from repro.config import ShapeConfig
from repro.recovery.recover import Repartitioner
from repro.sched.costmodel import model_units

SHAPE = ShapeConfig("rep", 16, 8, "train")


def _fleet_for(cfg):
    """Memories sized so the stable vehicle hosts the whole (2-layer
    reduced) model and its departure forces a genuinely different
    template on the survivors."""
    u = model_units(cfg, seq_len=64, num_units=cfg.num_layers)[0].cap
    return [dict(cmp=1e12, mem=2.5 * u, com=0.1e9, stb=0.9),
            dict(cmp=1e12, mem=1.2 * u, com=0.1e9, stb=0.7),
            dict(cmp=1e12, mem=1.2 * u, com=0.1e9, stb=0.6)]


def _leaves_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_swift_pipeline_live_repartition(mesh22, tmp_path):
    from repro.train import checkpoint as ckpt

    cfg = load_config("flad-vision")
    ses = Session(cfg=cfg, strategy="swift_pipeline", mesh=mesh22,
                  shape=SHAPE, learning_rate=2e-3, fleet=_fleet_for(cfg),
                  seq_len=64)
    ses.build()
    strat = ses.strategy
    # acceptance: every template SWIFT hands the runtime covers every unit
    assert sum(sum(t) for t in strat.templates.values()) == len(strat.units)
    assert strat.template_set is not None

    ck = str(tmp_path / "swift_ckpt")
    rep = Repartitioner(ses, {0: strat.active_pipeline.path[0].vid},
                        log_fn=None)
    out = ses.run(2, hooks=LoopHooks(log_fn=lambda *a: None,
                                     repartition=rep,
                                     checkpoint_path=ck,
                                     checkpoint_every=1))
    assert len(rep.events) == 1
    ev = rep.events[0]
    # the live restage kept the merged model bit-identical and complete
    assert ev.params_identical
    assert ev.new_template != ev.old_template
    assert sum(sum(t) for t in ev.new_template.values()) == len(strat.units)
    # the strategy committed the departure: fleet shrank, template adopted
    assert {k: tuple(v) for k, v in strat.templates.items()} \
        == ev.new_template
    assert ev.vid not in {v.vid for v in strat.vehicles}
    # training continued under the rebuilt step
    assert np.isfinite(out["history"][-1]["loss"])
    merged = ses.merged_params()
    assert all(np.all(np.isfinite(np.asarray(x)))
               for x in jax.tree.leaves(merged)
               if jnp.issubdtype(jnp.asarray(x).dtype, jnp.inexact))
    # the checkpoint sidecar tracked the template switch (saved at step 2)
    meta = ckpt.load_meta(ck)
    assert meta["strategy"] == "swift_pipeline"
    assert {k: tuple(v) for k, v in meta["templates"].items()} \
        == ev.new_template


def test_swift_pipeline_infeasible_fleet_raises(mesh22):
    cfg = load_config("flad-vision")
    u = model_units(cfg, seq_len=64, num_units=cfg.num_layers)[0].cap
    tiny = [dict(cmp=1e12, mem=0.5 * u, com=0.1e9)] * 3   # nothing fits
    ses = Session(cfg=cfg, strategy="swift_pipeline", mesh=mesh22,
                  shape=SHAPE, fleet=tiny, seq_len=64)
    with pytest.raises(ValueError):
        ses.strategy.resolve_templates(ses.cfg, ses.mesh)


def test_checkpoint_sidecar_roundtrip_pipeline(mesh22, tmp_path):
    from repro.core import pipeline as pl
    from repro.train import checkpoint as ckpt

    ses = Session("flad-vision", strategy="pipeline", mesh=mesh22,
                  shape=SHAPE)
    state = ses.strategy.init(ses.cfg, ses.shape, ses.mesh, ses.prng())
    path = str(tmp_path / "pipe_ckpt")
    ckpt.save(path, state[0], step=3, meta=ses._checkpoint_meta())

    meta = ckpt.load_meta(path)
    assert meta["strategy"] == "pipeline"
    templates = {k: tuple(v) for k, v in meta["templates"].items()}
    assert templates == {k: tuple(v)
                         for k, v in ses.strategy.templates.items()}
    restored, step = ckpt.load(path, jax.eval_shape(lambda: state[0]))
    assert step == 3
    # merged views agree exactly — the sidecar alone suffices to restage
    assert _leaves_equal(pl.merge_stage_params(state[0], templates),
                         pl.merge_stage_params(restored, templates))


def test_checkpoint_sidecar_roundtrip_fedavg(mesh22, tmp_path):
    from repro.core.fedavg import fedavg
    from repro.train import checkpoint as ckpt

    ses = Session("flad-vision", strategy="fedavg", mesh=mesh22,
                  shape=SHAPE)
    state = ses.strategy.init(ses.cfg, ses.shape, ses.mesh, ses.prng())
    path = str(tmp_path / "fed_ckpt")
    ckpt.save(path, state[0], step=7, meta=ses._checkpoint_meta())

    meta = ckpt.load_meta(path)
    assert meta["strategy"] == "fedavg"
    assert "templates" not in meta            # flat strategies stay bare
    restored, step = ckpt.load(path, jax.eval_shape(lambda: state[0]))
    assert step == 7
    assert _leaves_equal(state[0], restored)
    assert _leaves_equal(fedavg(state[0]), fedavg(restored))
    # a bare checkpoint (no meta) reports no sidecar
    bare = str(tmp_path / "bare_ckpt")
    ckpt.save(bare, state[0], step=1)
    assert ckpt.load_meta(bare) is None
