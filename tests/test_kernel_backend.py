"""The Pallas attention backend produces the same losses/grads as the XLA
path when enabled (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import ShapeConfig
from repro.configs import get_config
from repro.configs.common import concrete_batch, reduced
from repro.models import blocks as B
from repro.models import build_model


@pytest.fixture
def kernel_backend():
    B.set_kernel_backend(True)
    yield
    B.set_kernel_backend(False)


def test_kernel_backend_matches_xla(kernel_backend):
    cfg = reduced(get_config("qwen3_14b")).replace(param_dtype="float32")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    shape = ShapeConfig("k", 128, 2, "train")
    batch = concrete_batch(cfg, shape, key)

    loss_k, _ = model.loss(params, batch, remat=False)
    B.set_kernel_backend(False)
    loss_x, _ = model.loss(params, batch, remat=False)
    assert abs(float(loss_k) - float(loss_x)) < 1e-4


def test_kernel_backend_grads(kernel_backend):
    cfg = reduced(get_config("qwen3_14b")).replace(param_dtype="float32")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    shape = ShapeConfig("k", 128, 2, "train")
    batch = concrete_batch(cfg, shape, key)

    gk = jax.grad(lambda p: model.loss(p, batch, remat=False)[0])(params)
    B.set_kernel_backend(False)
    gx = jax.grad(lambda p: model.loss(p, batch, remat=False)[0])(params)
    for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(gx)):
        assert float(jnp.max(jnp.abs(a - b))) < 5e-3
