"""The Pallas attention backend produces the same losses/grads as the XLA
path when enabled (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import ShapeConfig
from repro.configs import get_config
from repro.configs.common import concrete_batch, reduced
from repro.models import blocks as B
from repro.models import build_model


@pytest.fixture
def kernel_backend():
    B.set_kernel_backend(True)
    yield
    B.set_kernel_backend(False)


def test_kernel_backend_matches_xla(kernel_backend):
    cfg = reduced(get_config("qwen3_14b")).replace(param_dtype="float32")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    shape = ShapeConfig("k", 128, 2, "train")
    batch = concrete_batch(cfg, shape, key)

    loss_k, _ = model.loss(params, batch, remat=False)
    B.set_kernel_backend(False)
    loss_x, _ = model.loss(params, batch, remat=False)
    assert abs(float(loss_k) - float(loss_x)) < 1e-4


def test_train_path_attention_grad_matches_oracle(kernel_backend):
    """The train-path attention gradient through blocks.attention is
    bit-close to the XLA oracle — including an uneven (non-128-multiple)
    sequence length, which the kernels pad + mask."""
    from repro.config import ModelConfig
    cfg = ModelConfig(name="tiny", family="dense", num_layers=1,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                      vocab_size=64, param_dtype="float32")
    key = jax.random.PRNGKey(0)
    params = B.init_attention(key, cfg)
    ks = jax.random.split(key, 2)
    for s in (128, 160):
        x = jax.random.normal(ks[0], (2, s, cfg.d_model), jnp.float32)
        w = jax.random.normal(ks[1], (2, s, cfg.d_model), jnp.float32)
        pos = jnp.arange(s)

        def loss(p):
            o, _ = B.attention(p, x, cfg, positions=pos)
            return jnp.sum(o * w)

        B.set_kernel_backend(True)
        gk = jax.grad(loss)(params)
        B.set_kernel_backend(False)
        gx = jax.grad(loss)(params)
        for name in params:
            err = float(jnp.max(jnp.abs(gk[name] - gx[name])))
            scale = max(1.0, float(jnp.max(jnp.abs(gx[name]))))
            assert err < 1e-4 * scale, (s, name, err)


def test_noncontiguous_positions_fall_back(kernel_backend):
    """Padded (-1) or non-contiguous position arrays must NOT take the
    Pallas path (its masks assume row i at q_offset + i) — they fall back
    to the XLA paths, so enabling the backend changes nothing."""
    from repro.config import ModelConfig
    cfg = ModelConfig(name="tiny", family="dense", num_layers=1,
                      d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
                      vocab_size=64, param_dtype="float32")
    key = jax.random.PRNGKey(0)
    params = B.init_attention(key, cfg)
    x = jax.random.normal(key, (1, 16, cfg.d_model), jnp.float32)
    padded = jnp.where(jnp.arange(16) < 12, jnp.arange(16), -1)

    assert not B._contiguous_positions(padded)
    assert B._contiguous_positions(jnp.arange(16))

    o_k, _ = B.attention(params, x, cfg, positions=padded)
    B.set_kernel_backend(False)
    o_x, _ = B.attention(params, x, cfg, positions=padded)
    assert float(jnp.max(jnp.abs(o_k - o_x))) == 0.0


def test_kernel_backend_grads(kernel_backend):
    cfg = reduced(get_config("qwen3_14b")).replace(param_dtype="float32")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    shape = ShapeConfig("k", 128, 2, "train")
    batch = concrete_batch(cfg, shape, key)

    gk = jax.grad(lambda p: model.loss(p, batch, remat=False)[0])(params)
    B.set_kernel_backend(False)
    gx = jax.grad(lambda p: model.loss(p, batch, remat=False)[0])(params)
    for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(gx)):
        assert float(jnp.max(jnp.abs(a - b))) < 5e-3
