"""FHDP pipeline: equivalence with the single-device model, rotation,
template mechanics, FedAvg round behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ShapeConfig
from repro.configs import get_config
from repro.configs.common import concrete_batch, reduced
from repro.core import pipeline as pl
from repro.core.fhdp import init_fhdp, make_fl_pipeline_round
from repro.models import build_model

SHAPE = ShapeConfig("t", 64, 8, "train")
ARCHS = ["qwen3_14b", "qwen3_moe_30b_a3b", "xlstm_350m", "hymba_1_5b",
         "seamless_m4t_large_v2", "internvl2_2b", "flad_vision"]


@pytest.mark.parametrize("arch", ARCHS)
def test_pipeline_matches_reference_loss(arch, mesh24):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = concrete_batch(cfg, SHAPE, key)
    ref_loss, _ = model.loss(params, batch, remat=False)

    step, h = pl.make_fhdp_train_step(cfg, SHAPE, mesh24)
    pp = pl.stage_params_from(params, cfg, h["templates"])
    opt = pl.zero2_init(pp, mesh24.shape["data"])
    _, _, metrics = jax.jit(step)(pp, opt, batch)
    rel = abs(float(metrics["loss"]) - float(ref_loss)) \
        / max(abs(float(ref_loss)), 1e-6)
    assert rel < 2e-2, (arch, float(metrics["loss"]), float(ref_loss))


def test_training_descends(mesh24):
    cfg = reduced(get_config("flad_vision"))
    key = jax.random.PRNGKey(0)
    step, h = pl.make_fhdp_train_step(cfg, SHAPE, mesh24,
                                      learning_rate=2e-3)
    pp, opt, _ = init_fhdp(cfg, mesh24, key)
    jstep = jax.jit(step)
    batch = concrete_batch(cfg, SHAPE, key)
    first = None
    for _ in range(8):
        pp, opt, m = jstep(pp, opt, batch)
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < first


def test_stage_merge_roundtrip():
    cfg = reduced(get_config("qwen3_14b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tmpl = pl.make_templates(cfg, 4)
    pp = pl.stage_params_from(params, cfg, tmpl)
    merged = pl.merge_stage_params(pp, tmpl)
    for (p1, l1), (p2, l2) in zip(
            jax.tree_util.tree_leaves_with_path(params),
            jax.tree_util.tree_leaves_with_path(merged)):
        assert jnp.array_equal(l1, l2), p1


def test_unequal_templates_match(mesh24):
    """A SWIFT-style unequal split computes the same loss."""
    cfg = reduced(get_config("qwen3_14b"))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = concrete_batch(cfg, SHAPE, key)
    ref_loss, _ = model.loss(params, batch, remat=False)
    tmpl = {"blocks": (2, 0, 0, 0)}     # all layers on stage 0
    step, h = pl.make_fhdp_train_step(cfg, SHAPE, mesh24, templates=tmpl)
    pp = pl.stage_params_from(params, cfg, tmpl)
    opt = pl.zero2_init(pp, mesh24.shape["data"])
    _, _, metrics = jax.jit(step)(pp, opt, batch)
    assert abs(float(metrics["loss"]) - float(ref_loss)) < 1e-2


def test_rotation_preserves_model():
    """Rolling stages then unrolling yields identical parameters."""
    cfg = reduced(get_config("qwen3_14b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tmpl = pl.make_templates(cfg, 4)
    pp = pl.stage_params_from(params, cfg, tmpl)
    rolled = dict(pp, stacks=pl.rotate_stages(pp["stacks"], 1),
                  masks=pl.rotate_stages(pp["masks"], 1))
    back = dict(rolled, stacks=pl.rotate_stages(rolled["stacks"], -1),
                masks=pl.rotate_stages(rolled["masks"], -1))
    for a, b in zip(jax.tree.leaves(pp), jax.tree.leaves(back)):
        assert jnp.array_equal(a, b)


def test_fl_pipeline_round_runs(mesh24):
    cfg = reduced(get_config("flad_vision"))
    key = jax.random.PRNGKey(0)
    fl_round, h = make_fl_pipeline_round(cfg, SHAPE, mesh24, local_steps=2,
                                         learning_rate=1e-3)
    pp, opt, _ = init_fhdp(cfg, mesh24, key, fed_sgd=False)
    b1 = concrete_batch(cfg, SHAPE, key)
    b2 = concrete_batch(cfg, SHAPE, jax.random.PRNGKey(1))
    batches = jax.tree.map(lambda x, y: jnp.stack([x, y]), b1, b2)
    pp, opt, metrics = jax.jit(fl_round)(pp, opt, batches)
    assert jnp.isfinite(metrics["loss"])


def test_balanced_template_properties():
    for L in (1, 3, 7, 24, 40, 64):
        for S in (1, 2, 4, 16):
            t = pl.balanced_template(L, S)
            assert sum(t) == L and len(t) == S
            assert max(t) - min(t) <= 1
