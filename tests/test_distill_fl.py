"""Federated personalized distillation: the ``distill_fl`` strategy,
the fused LoRA forward behind it, adapter-delta codec roundtrips, and
the per-pod serving handoff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.common import reduced
from repro.distill import lora as L
from repro.distill.celladapt import adllm_config, init_adllm
from repro.models import lm

KEY = jax.random.PRNGKey(0)


def _acfg():
    return adllm_config(reduced(get_config("flad_adllm")), feature_dim=32,
                        feature_tokens=8, num_waypoints=6)


@pytest.fixture(scope="module")
def adllm():
    cfg = _acfg()
    return cfg, init_adllm(KEY, cfg)


# -------------------------------------------------- init_lora regression ---
def test_init_lora_no_match_raises(adllm):
    """Regression: targets matching nothing used to return an all-None
    tree — a silent fine-tuning no-op."""
    cfg, params = adllm
    with pytest.raises(ValueError, match="match no parameter leaf"):
        L.init_lora(KEY, params, L.LoRAConfig(targets=("nope",)))
    # the error names what IS adaptable
    with pytest.raises(ValueError, match="wq"):
        L.init_lora(KEY, params, L.LoRAConfig(targets=("bogus",)))


def test_init_merge_determinism(adllm):
    cfg, params = adllm
    lcfg = L.LoRAConfig(rank=4, alpha=8.0)
    f1 = L.init_lora(KEY, params, lcfg)
    f2 = L.init_lora(KEY, params, lcfg)
    for a, b in zip(jax.tree.leaves(f1), jax.tree.leaves(f2)):
        assert jnp.array_equal(a, b)
    m1 = L.merge_lora(params, f1, lcfg)
    m2 = L.merge_lora(params, f2, lcfg)
    for a, b in zip(jax.tree.leaves(m1), jax.tree.leaves(m2)):
        assert jnp.array_equal(a, b)
    # B zero-init: merging a fresh adapter is the identity
    for p, m in zip(jax.tree.leaves(params), jax.tree.leaves(m1)):
        assert jnp.allclose(p, m)


# ----------------------------------- fused adapted forward == merge_lora ---
def test_fused_forward_matches_merged(adllm):
    """lm.forward(lora=...) through the fused base+low-rank kernel must
    match the forward of merge_lora-folded params, and gradients must
    reach every factor."""
    cfg, params = adllm
    lcfg = L.LoRAConfig(rank=4, alpha=8.0)
    factors = L.init_lora(jax.random.fold_in(KEY, 1), params, lcfg)
    # randomize B so the adapter actually perturbs the forward
    factors = jax.tree.map(
        lambda x: x + 0.05 * jax.random.normal(jax.random.fold_in(KEY, 2),
                                               x.shape), factors)
    toks = jax.random.randint(jax.random.fold_in(KEY, 3), (2, 12), 0,
                              cfg.vocab_size)
    ref, _, _ = lm.forward(L.merge_lora(params, factors, lcfg), cfg, toks)
    fused, _, _ = lm.forward(params, cfg, toks, lora=factors,
                             lora_scale=lcfg.scale)
    assert float(jnp.abs(ref - fused).max()) < 1e-3

    def loss(f):
        out, _, _ = lm.forward(params, cfg, toks, lora=f,
                               lora_scale=lcfg.scale)
        return (out ** 2).mean()

    grads = jax.grad(loss)(factors)
    for g in jax.tree.leaves(grads):
        assert float(jnp.abs(g).sum()) > 0.0


def test_fused_forward_rejects_non_block_factors(adllm):
    """Factors outside the scanned block stack (embed/head) have no fused
    path — must fail loudly, not silently ignore the adapter."""
    cfg, params = adllm
    lcfg = L.LoRAConfig(rank=2, targets=("w",))   # head/projector "w" leaves
    factors = L.init_lora(KEY, params, lcfg)
    toks = jnp.zeros((1, 4), jnp.int32)
    with pytest.raises(NotImplementedError, match="block stack"):
        lm.forward(params, cfg, toks, lora=factors, lora_scale=lcfg.scale)


# -------------------------------- adapter deltas through the comm fabric ---
def test_factor_codec_roundtrip_error_feedback(adllm):
    """int8 + error feedback on client-stacked factor trees: one round is
    within the quantization bound, and the residual carries what was
    lost so two half-updates converge to the true sum."""
    from repro.comm.codecs import get_codec, roundtrip_stacked, zero_residual
    cfg, params = adllm
    lcfg = L.LoRAConfig(rank=4)
    factors = L.init_lora(KEY, params, lcfg)
    C = 3
    deltas = jax.tree.map(
        lambda x: 0.1 * jax.random.normal(
            jax.random.fold_in(KEY, 7), (C,) + x.shape, jnp.float32),
        factors)
    codec = get_codec("int8")
    residual = zero_residual(deltas)
    decoded, residual = roundtrip_stacked(codec, deltas, residual, KEY)
    # tree structure survives (None leaves stay None)
    assert jax.tree_util.tree_structure(decoded) == \
        jax.tree_util.tree_structure(deltas)
    for d, x in zip(jax.tree.leaves(decoded), jax.tree.leaves(deltas)):
        step = jnp.abs(x).max() / 127.0
        assert float(jnp.abs(d - x).max()) <= float(step) + 1e-6
    # error feedback: residual == what the wire lost, exactly
    for r, d, x in zip(jax.tree.leaves(residual), jax.tree.leaves(decoded),
                       jax.tree.leaves(deltas)):
        assert jnp.allclose(r, x - d, atol=1e-6)
    # second identical round re-injects the loss: cumulative decode gets
    # closer to the cumulative truth than 2x the one-shot bound
    decoded2, _ = roundtrip_stacked(codec, deltas, residual,
                                    jax.random.fold_in(KEY, 9))
    for d1, d2, x in zip(jax.tree.leaves(decoded), jax.tree.leaves(decoded2),
                         jax.tree.leaves(deltas)):
        step = jnp.abs(x).max() / 127.0
        err = jnp.abs((d1 + d2) - 2 * x).max()
        assert float(err) <= 1.5 * float(step) + 1e-6


def test_pod_slice_broadcast_roundtrip():
    from repro.comm.hierarchy import pod_broadcast, pod_slice
    from repro.comm.topology import parse_topology
    topo = parse_topology("2@nano*2,agx*2")
    edge = {"a": jnp.arange(2 * 3, dtype=jnp.float32).reshape(2, 3),
            "b": None}
    clients = pod_broadcast(edge, topo)
    assert clients["a"].shape == (topo.n_clients, 3)
    for c in range(topo.n_clients):
        e = int(topo.client_edge[c])
        assert jnp.array_equal(clients["a"][c], edge["a"][e])
    back = pod_slice(clients, topo)
    assert jnp.array_equal(back["a"], edge["a"])


# ---------------------------------------------- end-to-end through Session --
@pytest.fixture(scope="module")
def distill_session():
    from repro.api import MeshSpec, Session
    from repro.train.loop import LoopHooks
    quiet = LoopHooks(log_every=1000, log_fn=lambda *a, **k: None)
    sess = Session("flad-adllm", shape="16x8",
                   mesh=MeshSpec.parse("2", devices=2),
                   strategy="distill_fl", learning_rate=3e-2, seed=0,
                   hooks=quiet, topology="2@nano*2", codec="int8",
                   local_steps=2, lora_rank=4, kd_weight=0.1, mix=0.25,
                   warmup_steps=30, beta=0.05, samples_per_vehicle=128,
                   heldout=64)
    out = sess.run(8)
    return sess, out


def test_session_distill_fl_adapter_uplink_20x(distill_session):
    """Adapter-only uplinks must be >= 20x smaller than full-delta
    hier_fl rounds on the same arch/topology/codec."""
    from repro.api.strategies import get_strategy
    sess, out = distill_session
    up = sess.strategy.comm_stats["uplink_bytes"]
    hier = get_strategy("hier_fl", topology="2@nano*2", codec="int8")
    full_up = hier._round_stats(sess.cfg)["uplink_bytes"]
    assert full_up / up >= 20.0, (full_up, up)
    # and the wire metrics ride along in every round's history
    assert out["history"][-1]["comm_bytes_up"] == float(up)
    assert out["history"][-1]["comm_bytes_backhaul"] > 0


def test_session_distill_fl_personalization(distill_session):
    """Each pod's student (base + pod adapter) beats the global model
    (base + cloud-merged adapter) on its own pod's held-out partition."""
    from repro.distill.federated import waypoint_eval
    sess, _ = distill_session
    st = sess.strategy
    acfg = st.adllm_cfg(sess.cfg)
    _, held, _ = st.datasets(sess.cfg, sess.shape)
    global_model = sess.merged_params()
    for e in range(len(held)):
        pod_model = st.pod_params(sess.state, e)
        g = waypoint_eval(global_model, acfg, held[e])
        p = waypoint_eval(pod_model, acfg, held[e])
        assert p < g, (e, p, g)


def test_session_distill_fl_state_and_training(distill_session):
    """Composite state survives the loop: frozen base, per-pod factors
    that actually moved, and a supervised warmup that learned."""
    sess, out = distill_session
    st = sess.strategy
    params_like = sess.state[0]
    assert set(params_like) == {"base", "factors"}
    assert st.warmup_history[-1] < st.warmup_history[0]
    # pod members share an adapter; pods differ (personalization)
    f = params_like["factors"]
    a = jax.tree.leaves(f)[0]
    topo = st.topology
    m0 = np.asarray(topo.member_indices[0])
    m1 = np.asarray(topo.member_indices[1])
    assert jnp.allclose(a[m0[0]], a[m0[-1]])
    assert not jnp.allclose(a[m0[0]], a[m1[0]])
    # factors moved off zero-B init
    assert float(jnp.abs(a[0]).sum()) > 0.0


# ------------------------------------------------- personalized serving ----
def test_pod_serving_matches_merged_oracle(distill_session):
    """A pod's merged adapter serves through PagedEngine with greedy
    streams identical to the merged-params lm.forward oracle."""
    from repro.serve import BlockAllocator, PagedCacheSpec, PagedEngine
    sess, _ = distill_session
    params = sess.strategy.pod_params(sess.state, 0)
    cfg = sess.cfg
    spec = PagedCacheSpec.for_requests(2, 24, block_size=4)
    eng = PagedEngine(cfg, spec, max_context=12, slots=2)
    alloc = BlockAllocator(spec)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 9)]
    n_decode = 4

    pools = eng.init_pools()
    tables = np.zeros((2, spec.max_blocks_per_req), np.int32)
    ctx = np.zeros(2, np.int32)
    pend = np.zeros(2, np.int32)
    for i, p in enumerate(prompts):
        blocks = alloc.alloc(spec.blocks_needed(len(p) + n_decode))
        tables[i, :len(blocks)] = blocks
        toks, length = eng.pad_prompt(p)
        logits, k, v = eng.prefill(params, toks, length)
        pools = eng.write_prefill(pools, k, v, jnp.asarray(tables[i]))
        pend[i] = int(jnp.argmax(logits[0]))
        ctx[i] = len(p)
    streams = [[int(t)] for t in pend]
    for _ in range(n_decode - 1):
        logits, pools = eng.decode(params, pools, jnp.asarray(pend),
                                   jnp.asarray(tables), jnp.asarray(ctx))
        ctx += 1
        pend = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for i in range(2):
            streams[i].append(int(pend[i]))

    for i, p in enumerate(prompts):
        toks = list(p)
        for step in range(n_decode):
            t = jnp.asarray(np.array(toks, np.int32))[None]
            ref, _, _ = lm.forward(params, cfg, t,
                                   positions=jnp.arange(len(toks)))
            want = int(jnp.argmax(ref[0, -1]))
            assert streams[i][step] == want, (i, step)
            toks.append(want)


def test_session_serve_pod_continuous(distill_session):
    """Session.serve(pod=...) hands the personalized model to the
    continuous-batching tier end to end."""
    sess, _ = distill_session
    out = sess.serve(pod=1, scheduler="continuous", requests=2, batch=2,
                     context=16, log_fn=lambda *a, **k: None,
                     max_prompt=8, short_new=(2, 4), long_frac=0.0)
    assert out["requests"] == 2 and out["total_new_tokens"] > 0
    with pytest.raises(ValueError, match="pod"):
        sess.serve(pod=0, params={},
                   log_fn=lambda *a, **k: None)
