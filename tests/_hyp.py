"""Guarded ``hypothesis`` import for the property-based tests.

When hypothesis is installed, re-exports the real ``given``/``settings``/
``strategies``. When it is absent (the default container has no dev
extras), ``@given`` turns into a skip marker so the module still collects
and every non-property test in it runs.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for hypothesis.strategies.* — never actually drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install -e '.[dev]')"
            )(fn)

        return deco
