"""Per-architecture smoke tests (reduced family variants): loss + grads
finite, decode path consistent with the parallel forward."""
import jax
import jax.numpy as jnp
import pytest

from repro.config import ShapeConfig
from repro.configs import ARCH_IDS, get_config
from repro.configs.common import concrete_batch, reduced
from repro.models import build_model

SMOKE = ShapeConfig("smoke", 32, 2, "train")


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_loss_and_grads_finite(arch, key):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(key)
    batch = concrete_batch(cfg, SMOKE, key)
    (loss, metrics), grads = jax.value_and_grad(model.loss,
                                                has_aux=True)(params, batch)
    assert jnp.isfinite(loss), arch
    gnorm = sum(jnp.sum(g.astype(jnp.float32) ** 2)
                for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch, key):
    cfg = reduced(get_config(arch))
    if cfg.family == "vision":
        pytest.skip("vision encoder has no decode path")
    model = build_model(cfg)
    params = model.init(key)
    st = model.init_state(2, 64)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, st = model.decode_step(params, tok, st, 5)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["qwen3_14b", "xlstm_350m", "hymba_1_5b"])
def test_decode_matches_parallel_forward(arch, key):
    """Teacher-forced decode (prefill 1 token at a time) reproduces the
    parallel forward's logits."""
    cfg = reduced(get_config(arch)).replace(param_dtype="float32")
    model = build_model(cfg)
    params = model.init(key)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab_size, jnp.int32)

    from repro.models import lm, xlstm, hymba
    mod = {"dense": lm, "ssm": xlstm, "hybrid": hymba}[cfg.family]
    if cfg.family == "dense":
        full_logits, _, _ = mod.forward(params, cfg, toks)
    else:
        full_logits, _, _ = mod.forward(params, cfg, toks)

    st = model.init_state(1, 16)
    outs = []
    for t in range(toks.shape[1]):
        logits, st = model.decode_step(params, toks[:, t:t + 1], st, t)
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)
    assert jnp.max(jnp.abs(dec - full_logits)) < 2e-2, arch


def test_sliding_window_matches_dense(key):
    """Windowed attention == full attention when window >= seq."""
    cfg = reduced(get_config("qwen3_14b")).replace(param_dtype="float32")
    model = build_model(cfg)
    params = model.init(key)
    batch = concrete_batch(cfg, SMOKE, key)
    l1, _ = model.loss(params, batch, window=None, remat=False)
    l2, _ = model.loss(params, batch, window=SMOKE.seq_len + 1, remat=False)
    assert abs(float(l1) - float(l2)) < 1e-4


def test_chunked_attention_matches_dense(key):
    from repro.models import blocks as B
    q = jax.random.normal(key, (2, 4, 64, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 64, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 2, 64, 16))
    pos = jnp.arange(64)
    a = B.dense_mha(q, k, v, scale=0.25, q_pos=pos, kv_pos=pos,
                    causal=True, window=None)
    b = B.chunked_mha(q, k, v, scale=0.25, q_pos=pos, kv_pos=pos,
                      causal=True, window=None, kv_chunk=16)
    assert jnp.max(jnp.abs(a - b)) < 1e-4


def test_moe_dense_dispatch_treats_all_tokens(key):
    """With enough capacity no token is dropped: MoE output differs from
    zero and aux loss is near the uniform-routing value."""
    cfg = reduced(get_config("qwen3_moe_30b_a3b"))
    from repro.models import blocks as B
    p = B.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    out, aux = B.moe_block(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out))) and float(jnp.abs(out).mean()) > 0
