"""Test fixtures.

8 forced host devices for the mesh/pipeline/FL tests (NOT the 512-device
production flag — that is reserved for launch/dryrun.py, which sets it
itself). Model smoke tests are device-count agnostic.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8"
                           ).strip()

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")


@pytest.fixture(scope="session")
def mesh24():
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh(data=2, model=4)


@pytest.fixture(scope="session")
def mesh22():
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh(data=2, model=2)


@pytest.fixture(scope="session")
def mesh222():
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh(data=2, model=2, pod=2)
