"""Pallas kernels vs pure-jnp oracles, interpret mode, shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _rand(shape, dtype, k):
    x = jax.random.normal(k, shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,sq,skv,d,causal,window",
    [
        (2, 4, 2, 128, 128, 64, True, None),      # GQA causal
        (1, 8, 8, 128, 128, 32, True, 96),        # sliding window
        (2, 2, 1, 64, 192, 64, False, None),      # cross-ish, MQA
        (1, 4, 4, 256, 256, 128, True, None),     # MXU-aligned d
        (1, 4, 2, 160, 160, 64, True, None),      # uneven tail (pad+mask)
        (1, 2, 2, 197, 197, 32, True, 64),        # prime len + window
    ])
def test_flash_attention(dtype, b, hq, hkv, sq, skv, d, causal, window):
    ks = jax.random.split(KEY, 3)
    q = _rand((b, hq, sq, d), dtype, ks[0])
    k = _rand((b, hkv, skv, d), dtype, ks[1])
    v = _rand((b, hkv, skv, d), dtype, ks[2])
    off = skv - sq
    got, lse = ops.flash_attention(q, k, v, causal=causal, window=window,
                                   q_offset=off, block_q=64, block_k=64,
                                   return_lse=True, interpret=True)
    want, lse_want = ref.flash_attention_ref(q, k, v, causal=causal,
                                             window=window, q_offset=off,
                                             return_lse=True)
    tol = 5e-6 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - want.astype(jnp.float32)))) < tol
    assert float(jnp.max(jnp.abs(lse - lse_want))) < tol


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,sq,skv,d,causal,window",
    [
        (2, 4, 2, 128, 128, 64, True, None),      # GQA causal
        (1, 8, 8, 128, 128, 32, True, 96),        # sliding window
        (2, 2, 1, 64, 192, 64, False, None),      # cross-ish, MQA
        (1, 4, 2, 160, 160, 64, True, None),      # uneven tail (pad+mask)
        (1, 2, 2, 197, 197, 32, True, 64),        # prime len + window
    ])
def test_flash_attention_grad(dtype, b, hq, hkv, sq, skv, d, causal,
                              window):
    """The Pallas backward kernels (preprocess/dKV/dQ) vs jax.vjp over
    the O(S^2) reference, across mask x GQA x dtype x uneven tails."""
    ks = jax.random.split(KEY, 4)
    q = _rand((b, hq, sq, d), dtype, ks[0])
    k = _rand((b, hkv, skv, d), dtype, ks[1])
    v = _rand((b, hkv, skv, d), dtype, ks[2])
    g = _rand((b, hq, sq, d), dtype, ks[3])
    off = skv - sq

    _, vjp_kernel = jax.vjp(
        lambda q_, k_, v_: ops.flash_attention_ad(
            q_, k_, v_, None, causal, window, off, block_q=64, block_k=64,
            interpret=True), q, k, v)
    _, vjp_ref = jax.vjp(
        lambda q_, k_, v_: ref.flash_attention_ref(
            q_, k_, v_, causal=causal, window=window, q_offset=off),
        q, k, v)
    for name, got, want in zip("qkv", vjp_kernel(g), vjp_ref(g)):
        want = want.astype(jnp.float32)
        tol = (1e-5 if dtype == jnp.float32 else 5e-2) \
            * max(1.0, float(jnp.max(jnp.abs(want))))
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32) - want)))
        assert err < tol, (name, err, tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,nh,s,dh,chunk", [
    (2, 3, 128, 32, 32),
    (1, 2, 64, 64, 16),
    (1, 1, 96, 16, 96),   # single chunk
])
def test_mlstm_chunked(dtype, b, nh, s, dh, chunk):
    ks = jax.random.split(KEY, 5)
    q = _rand((b, nh, s, dh), dtype, ks[0])
    k = (_rand((b, nh, s, dh), dtype, ks[1]).astype(jnp.float32)
         * dh ** -0.5).astype(dtype)
    v = _rand((b, nh, s, dh), dtype, ks[2])
    ig = _rand((b, nh, s), jnp.float32, ks[3])
    lf = -jax.nn.softplus(-_rand((b, nh, s), jnp.float32, ks[4]) - 2.0)
    h_got, (C1, n1, m1) = ops.mlstm_chunked(q, k, v, ig, lf, chunk=chunk,
                                            interpret=True)
    h_ref, (C2, n2, m2) = ref.mlstm_chunked_ref(q, k, v, ig, lf)
    tol = 5e-4 if dtype == jnp.float32 else 5e-2
    assert float(jnp.max(jnp.abs(h_got.astype(jnp.float32)
                                 - h_ref.astype(jnp.float32)))) < tol
    assert float(jnp.max(jnp.abs(C1 - C2))) < tol
    assert float(jnp.max(jnp.abs(m1 - m2))) < 1e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,r,scale", [
    (128, 256, 192, 8, 0.5),
    (64, 512, 64, 16, 2.0),
    (256, 128, 128, 4, 1.0),
])
def test_lora_matmul(dtype, m, k, n, r, scale):
    ks = jax.random.split(KEY, 4)
    x = _rand((m, k), dtype, ks[0])
    w = _rand((k, n), dtype, ks[1])
    a = _rand((k, r), dtype, ks[2])
    b = _rand((r, n), dtype, ks[3])
    got = ops.lora_matmul(x, w, a, b, scale=scale, block_m=64, block_n=64,
                          block_k=64, interpret=True)
    want = ref.lora_matmul_ref(x, w, a, b, scale=scale)
    tol = 1e-3 if dtype == jnp.float32 else 0.25
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - want.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,r,scale", [
    (128, 256, 192, 8, 0.5),
    (100, 96, 132, 4, 1.0),    # dims not multiples of the tile
])
def test_lora_matmul_grad(dtype, m, k, n, r, scale):
    """lora_matmul_ad's closed-form VJP vs jax.vjp over the oracle (the
    raw pallas_call has no autodiff rule at all)."""
    ks = jax.random.split(KEY, 5)
    x = _rand((m, k), dtype, ks[0])
    w = _rand((k, n), dtype, ks[1])
    a = _rand((k, r), dtype, ks[2])
    b = _rand((r, n), dtype, ks[3])
    g = _rand((m, n), dtype, ks[4])
    out, vjp_kernel = jax.vjp(
        lambda *t: ops.lora_matmul_ad(*t, scale=scale, block_m=64,
                                      block_n=64, block_k=64,
                                      interpret=True), x, w, a, b)
    out_ref, vjp_ref = jax.vjp(
        lambda *t: ref.lora_matmul_ref(*t, scale=scale), x, w, a, b)
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - out_ref.astype(jnp.float32)))) \
        < (1e-3 if dtype == jnp.float32 else 0.25)
    for name, got, want in zip(["dx", "dw", "da", "db"],
                               vjp_kernel(g), vjp_ref(g)):
        want = want.astype(jnp.float32)
        tol = (1e-4 if dtype == jnp.float32 else 5e-2) \
            * max(1.0, float(jnp.max(jnp.abs(want))))
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32) - want)))
        assert err < tol, (name, err, tol)


def test_flash_attention_matches_model_attention():
    """The kernel agrees with the model's chunked XLA path."""
    from repro.models import blocks as B
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 4, 128, 64))
    k = jax.random.normal(ks[1], (2, 2, 128, 64))
    v = jax.random.normal(ks[2], (2, 2, 128, 64))
    pos = jnp.arange(128)
    xla = B.chunked_mha(q, k, v, scale=64 ** -0.5, q_pos=pos, kv_pos=pos,
                        causal=True, window=None, kv_chunk=64)
    pall = ops.flash_attention(q, k, v, causal=True, block_q=64,
                               block_k=64, interpret=True)
    assert float(jnp.max(jnp.abs(xla - pall))) < 5e-5


@pytest.mark.parametrize("m,block_rows", [(8, 256), (520, 256), (96, 32)])
def test_quantize_int8_matches_ref(m, block_rows):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (m, 128), jnp.float32) * 3.0
    x = x.at[min(3, m - 1)].set(0.0)                 # all-zero row
    bits = jax.random.bits(ks[1], (m, 128), jnp.uint32)
    q, s = ops.quantize_int8(x, bits, block_rows=block_rows,
                             interpret=True)
    q_ref, s_ref = ref.quantize_int8_ref(x, bits)
    assert q.dtype == jnp.int8 and s.shape == (m, 1)
    assert jnp.array_equal(q, q_ref)
    assert jnp.allclose(s, s_ref)
    got = ops.dequantize_int8(q, s, block_rows=block_rows, interpret=True)
    want = ref.dequantize_int8_ref(q_ref, s_ref)
    assert jnp.allclose(got, want)


def test_quantize_int8_error_bound_and_zero_rows():
    """Round-trip error < one quantization step per row; zero rows stay
    exactly zero (scale 0 on the wire, not NaN)."""
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (64, 128), jnp.float32) * 10.0
    x = x.at[5].set(0.0)
    bits = jax.random.bits(ks[1], (64, 128), jnp.uint32)
    q, s = ops.quantize_int8(x, bits, interpret=True)
    back = ops.dequantize_int8(q, s, interpret=True)
    step = jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0
    assert float(jnp.max(jnp.abs(back - x) - step)) <= 1e-6
    assert float(jnp.abs(back[5]).max()) == 0.0
    assert float(s[5, 0]) == 0.0


def test_quantize_int8_stochastic_rounding_unbiased():
    """E[dequant(quant(x))] -> x: averaging round-trips over many draws
    shrinks the error well below a single deterministic rounding step."""
    x = jnp.full((8, 128), 0.3456789, jnp.float32)
    x = x.at[:, 0].set(5.0)                  # pins scale = 5/127
    acc = jnp.zeros_like(x)
    n = 64
    for i in range(n):
        bits = jax.random.bits(jax.random.PRNGKey(i), (8, 128),
                               jnp.uint32)
        q, s = ops.quantize_int8(x, bits, interpret=True)
        acc = acc + ops.dequantize_int8(q, s, interpret=True)
    mean_err = float(jnp.abs(acc / n - x)[:, 1:].max())
    step = 5.0 / 127.0
    assert mean_err < 0.25 * step, (mean_err, step)


def _paged_setup(k, b, hkv, nb, bs, d, ctx_list):
    """Random pools + a valid block table for the given context lengths."""
    import numpy as np
    ks = jax.random.split(k, 3)
    kp = jax.random.normal(ks[0], (hkv, nb, bs, d), jnp.float32)
    vp = jax.random.normal(ks[1], (hkv, nb, bs, d), jnp.float32)
    t = max(-(-c // bs) for c in ctx_list) + 1
    tbl = np.zeros((b, t), np.int32)
    free = list(range(1, nb))
    for i, c in enumerate(ctx_list):
        for j in range(-(-c // bs)):
            tbl[i, j] = free.pop()
    return kp, vp, jnp.asarray(tbl), jnp.asarray(ctx_list, jnp.int32)


@pytest.mark.parametrize(
    "b,hq,hkv,d,bs,ctx_list",
    [
        (4, 4, 2, 32, 8, [13, 1, 0, 48]),   # GQA, partial/dead/full blocks
        (2, 8, 8, 64, 16, [16, 31]),        # MHA, exact and off-by-one
        (3, 2, 1, 128, 4, [4, 9, 2]),       # MQA, tiny blocks
    ])
def test_paged_decode_attention(b, hq, hkv, d, bs, ctx_list):
    """Paged single-token decode kernel vs the dense gather oracle,
    including dead lanes (ctx=0 -> exact zeros) and partial last blocks."""
    nb = 1 + sum(-(-c // bs) for c in ctx_list) + 2
    kp, vp, tbl, ctx = _paged_setup(KEY, b, hkv, nb, bs, d, ctx_list)
    q = jax.random.normal(jax.random.fold_in(KEY, 7), (b, hq, d),
                          jnp.float32)
    got = ops.paged_decode_attention(q, kp, vp, tbl, ctx, interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, tbl, ctx)
    assert float(jnp.max(jnp.abs(got - want))) < 5e-6
    for i, c in enumerate(ctx_list):
        if c == 0:
            assert float(jnp.abs(got[i]).max()) == 0.0


def test_paged_decode_attention_int8():
    """int8 pools dequantize in-kernel through per-row scales."""
    b, hq, hkv, d, bs = 3, 4, 2, 32, 8
    ctx_list = [5, 17, 24]
    nb = 1 + sum(-(-c // bs) for c in ctx_list) + 1
    kp, vp, tbl, ctx = _paged_setup(KEY, b, hkv, nb, bs, d, ctx_list)
    ks = jax.random.split(jax.random.fold_in(KEY, 11), 5)
    kq = jax.random.randint(ks[0], kp.shape, -127, 128,
                            jnp.int32).astype(jnp.int8)
    vq = jax.random.randint(ks[1], vp.shape, -127, 128,
                            jnp.int32).astype(jnp.int8)
    ksc = jax.random.uniform(ks[2], kp.shape[:-1] + (1,), jnp.float32,
                             1e-3, 2e-2)
    vsc = jax.random.uniform(ks[3], vp.shape[:-1] + (1,), jnp.float32,
                             1e-3, 2e-2)
    q = jax.random.normal(ks[4], (b, hq, d), jnp.float32)
    got = ops.paged_decode_attention(q, kq, vq, tbl, ctx, k_scales=ksc,
                                     v_scales=vsc, interpret=True)
    want = ref.paged_decode_attention_ref(q, kq, vq, tbl, ctx,
                                          k_scales=ksc, v_scales=vsc)
    assert float(jnp.max(jnp.abs(got - want))) < 5e-6


def _prefill_pool_setup(key, hkv, bs, d, s, spare=2, int8=False):
    """A contiguous K/V stream scattered into shuffled physical blocks,
    plus the block table that maps it back (trailing entries null)."""
    import numpy as np
    t = -(-s // bs)
    nb = 1 + t + spare
    ks = jax.random.split(key, 4)
    k = jax.random.normal(ks[0], (hkv, s, d), jnp.float32)
    v = jax.random.normal(ks[1], (hkv, s, d), jnp.float32)
    pad = t * bs - s
    kb = jnp.pad(k, ((0, 0), (0, pad), (0, 0))).reshape(hkv, t, bs, d)
    vb = jnp.pad(v, ((0, 0), (0, pad), (0, 0))).reshape(hkv, t, bs, d)
    rng = np.random.default_rng(int(jax.random.randint(ks[2], (), 0, 1 << 30)))
    phys = rng.permutation(np.arange(1, nb))[:t]
    kp = jnp.zeros((hkv, nb, bs, d), jnp.float32).at[:, phys].set(kb)
    vp = jnp.zeros((hkv, nb, bs, d), jnp.float32).at[:, phys].set(vb)
    tbl = np.zeros(t + 1, np.int32)
    tbl[:t] = phys
    scales = None
    if int8:
        from repro.serve.kvcache import quantize_rows
        kp, ksc = quantize_rows(kp)
        vp, vsc = quantize_rows(vp)
        scales = (ksc, vsc)
    return k, v, kp, vp, jnp.asarray(tbl), scales


@pytest.mark.parametrize(
    "hq,hkv,d,bs,chunk,ctx,off",
    [
        (4, 2, 32, 8, 8, 21, 0),      # GQA, first chunk
        (4, 2, 32, 8, 8, 21, 8),      # mid chunk over earlier blocks
        (4, 2, 32, 8, 8, 21, 16),     # final partial chunk (5 live rows)
        (8, 8, 64, 16, 16, 16, 0),    # MHA, one exact-fit chunk
        (2, 1, 128, 4, 4, 9, 4),      # MQA, tiny blocks, odd tail
        (4, 2, 32, 8, 16, 37, 16),    # chunk spanning multiple blocks
    ])
def test_paged_prefill_attention(hq, hkv, d, bs, chunk, ctx, off):
    """Chunked paged prefill kernel vs the dense gather oracle: a C-row
    query chunk at q_offset attends causally through the block table."""
    _, _, kp, vp, tbl, _ = _prefill_pool_setup(jax.random.fold_in(KEY, 13),
                                               hkv, bs, d, ctx)
    q = jax.random.normal(jax.random.fold_in(KEY, 17), (hq, chunk, d),
                          jnp.float32)
    got = ops.paged_prefill_attention(q, kp, vp, tbl, off, ctx,
                                      interpret=True)
    want = ref.paged_prefill_attention_ref(q, kp, vp, tbl, off, ctx)
    clen = ctx - off            # rows past the live chunk are garbage
    assert got.shape == (hq, chunk, d)
    err = float(jnp.max(jnp.abs(got[:, :clen] - want[:, :clen])))
    assert err < 5e-6


def test_paged_prefill_attention_int8():
    """int8 pools dequantize in-kernel through per-row scales."""
    hq, hkv, d, bs, chunk, ctx, off = 4, 2, 32, 8, 8, 19, 8
    _, _, kp, vp, tbl, (ksc, vsc) = _prefill_pool_setup(
        jax.random.fold_in(KEY, 19), hkv, bs, d, ctx, int8=True)
    q = jax.random.normal(jax.random.fold_in(KEY, 23), (hq, chunk, d),
                          jnp.float32)
    got = ops.paged_prefill_attention(q, kp, vp, tbl, off, ctx,
                                      k_scales=ksc, v_scales=vsc,
                                      interpret=True)
    want = ref.paged_prefill_attention_ref(q, kp, vp, tbl, off, ctx,
                                           k_scales=ksc, v_scales=vsc)
    clen = ctx - off
    assert float(jnp.max(jnp.abs(got[:, :clen] - want[:, :clen]))) < 5e-6


def test_paged_prefill_dead_blocks_skipped():
    """Table entries beyond the context are never read: pointing them at
    a NaN-poisoned block must not change the output (the kernel's
    dead-block skip, not masking, is what protects the accumulator)."""
    import numpy as np
    hq, hkv, d, bs = 4, 2, 32, 8
    ctx, off = 12, 8                     # 2 live blocks, chunk rows 8..11
    _, _, kp, vp, tbl, _ = _prefill_pool_setup(jax.random.fold_in(KEY, 29),
                                               hkv, bs, d, ctx, spare=2)
    q = jax.random.normal(jax.random.fold_in(KEY, 31), (hq, bs, d),
                          jnp.float32)
    live = -(-ctx // bs)
    poison = int(max(np.asarray(tbl))) + 1      # a spare, unused block
    kp = kp.at[:, poison].set(jnp.nan)
    vp = vp.at[:, poison].set(jnp.nan)
    tbl_nan = np.asarray(tbl).copy()
    tbl_nan[live:] = poison
    got = ops.paged_prefill_attention(q, kp, vp, jnp.asarray(tbl_nan),
                                      off, ctx, interpret=True)
    want = ref.paged_prefill_attention_ref(q, kp, vp, tbl, off, ctx)
    clen = ctx - off
    assert bool(jnp.isfinite(got[:, :clen]).all())
    assert float(jnp.max(jnp.abs(got[:, :clen] - want[:, :clen]))) < 5e-6


def test_paged_prefill_chunks_match_flash():
    """A full causal prefill assembled from sequential fixed-size chunks
    reproduces the dense flash oracle on the contiguous stream."""
    hq, hkv, d, bs, s, chunk = 4, 2, 32, 8, 21, 8
    k, v, kp, vp, tbl, _ = _prefill_pool_setup(jax.random.fold_in(KEY, 37),
                                               hkv, bs, d, s)
    q = jax.random.normal(jax.random.fold_in(KEY, 41), (hq, s, d),
                          jnp.float32)
    outs = []
    for off in range(0, s, chunk):
        clen = min(chunk, s - off)
        qc = jnp.zeros((hq, chunk, d)).at[:, :clen].set(
            q[:, off:off + clen])
        o = ops.paged_prefill_attention(qc, kp, vp, tbl, off, off + clen,
                                        interpret=True)
        outs.append(o[:, :clen])
    got = jnp.concatenate(outs, axis=1)
    want = ref.flash_attention_ref(q[None], k[None], v[None],
                                   causal=True)[0]
    assert float(jnp.max(jnp.abs(got - want))) < 5e-6


def test_paged_decode_matches_contiguous_attention():
    """Scattering a contiguous K/V stream into shuffled physical blocks
    must not change attention output vs the flash kernel on the same
    stream (single query at the last position)."""
    import numpy as np
    b, hq, hkv, d, bs, s = 2, 4, 2, 32, 8, 21
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, 1, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32)
    dense = ref.flash_attention_ref(q, k, v, causal=True, q_offset=s - 1)

    t = -(-s // bs)
    nb = 1 + b * t
    pad = t * bs - s
    kb = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vb = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    rng = np.random.default_rng(3)
    phys = rng.permutation(np.arange(1, nb)).reshape(b, t)
    kp = jnp.zeros((hkv, nb, bs, d), jnp.float32)
    vp = jnp.zeros((hkv, nb, bs, d), jnp.float32)
    for i in range(b):
        kp = kp.at[:, phys[i]].set(
            kb[i].reshape(hkv, t, bs, d))
        vp = vp.at[:, phys[i]].set(
            vb[i].reshape(hkv, t, bs, d))
    ctx = jnp.full((b,), s, jnp.int32)
    got = ops.paged_decode_attention(q[:, :, 0], kp, vp,
                                     jnp.asarray(phys, jnp.int32), ctx,
                                     interpret=True)
    assert float(jnp.max(jnp.abs(got - dense[:, :, 0]))) < 5e-6
