"""Observability layer (repro.obs): the zero-cost contract (tracing
on/off is bitwise invisible to params, event logs, and greedy streams),
byte-deterministic trace JSON, structural validity per
scripts/validate_trace.py, track placement against the event log, the
metrics registry semantics, and the satellite surfaces (history
wall/sim clocks, ``trace_id`` echo, pool-occupancy report stats,
``benchmarks/run.py --list``)."""
import importlib.util
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.comm.events import MobilitySpec, simulate_schedule
from repro.comm.topology import parse_topology
from repro.configs import get_config
from repro.configs.common import reduced
from repro.obs import (Counter, FL_PID, Gauge, Histogram, MetricsRegistry,
                       ProfileOptions, SERVE_PID, Tracer, kernel_cost_args,
                       profiled, resolve_tracer)
from repro.obs.trace import (CLOUD_TID, QUEUE_TID, SPEC_TID, edge_tid,
                             lane_tid, vehicle_tid)
from repro.serve import (PrefillCostModel, ServeRequest,
                         SpecDecodeCostModel, generate_pod_requests,
                         serve_continuous)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TOPO = parse_topology("2@nano*2,agx*2")
QUIET = dict(log_every=1, log_fn=lambda *a, **k: None)

#: the busiest timing-only schedule: clocked merges, stragglers, DTMC
#: migrations — every span/flow/counter emission path fires
SCHED = dict(clock=0.05, compute_flops=5e9, jitter=0.3,
             migrate_every=0.05, rounds=10, seed=0,
             mobility=MobilitySpec(size=5, radius=1, seed=1))


def _load_validator():
    spec = importlib.util.spec_from_file_location(
        "validate_trace", os.path.join(REPO, "scripts", "validate_trace.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


VT = _load_validator()


def _spans(tracer, name=None):
    return [e for e in tracer.events
            if e["ph"] == "X" and (name is None or e["name"] == name)]


# ---- tracer primitives ----------------------------------------------------

def test_tracer_metadata_dedupes_and_flow_ids_increment():
    tr = Tracer()
    tr.process(FL_PID, "fl", sort_index=1)
    tr.process(FL_PID, "fl", sort_index=1)          # second call: no-op
    tr.track(FL_PID, CLOUD_TID, "cloud")
    tr.track(FL_PID, CLOUD_TID, "cloud")
    assert [e["ph"] for e in tr.events] == ["M", "M", "M"]
    assert tr.flow("a", 0.0, FL_PID, 1, 1.0, FL_PID, 2) == 0
    assert tr.flow("b", 1.0, FL_PID, 2, 2.0, FL_PID, 1) == 1
    f = [e for e in tr.events if e["ph"] == "f"]
    assert all(e["bp"] == "e" for e in f)


def test_tracer_span_units_and_clamping():
    tr = Tracer()
    tr.complete("work", 1.5, 2.0, pid=FL_PID, tid=3)
    tr.complete("tick", 2.0, 2.0, pid=FL_PID, tid=3)   # zero-width ok
    a, b = _spans(tr)
    assert a["ts"] == 1.5e6 and a["dur"] == 0.5e6
    assert b["dur"] == 0.0
    assert VT.validate(tr.events) == []


def test_tracer_serializes_numpy_args_deterministically():
    def build():
        tr = Tracer()
        tr.complete("s", 0.0, np.float64(1.0), pid=1, tid=1,
                    args={"n": np.int64(3), "v": np.float32(0.5),
                          "xs": np.arange(2)})
        return tr
    raw = build().to_bytes()
    assert raw == build().to_bytes()
    ev = json.loads(raw)["traceEvents"][0]
    assert ev["args"] == {"n": 3, "v": 0.5, "xs": [0, 1]}


def test_resolve_tracer_forms():
    assert resolve_tracer(None) == (None, None)
    tr = Tracer()
    assert resolve_tracer(tr) == (tr, None)
    got, path = resolve_tracer("/tmp/t.json")
    assert isinstance(got, Tracer) and path == "/tmp/t.json"


# ---- validator negative cases ---------------------------------------------

@pytest.mark.parametrize("events,needle", [
    ([{"ph": "Z", "name": "x", "pid": 1, "tid": 1, "ts": 0}], "unknown ph"),
    ([{"ph": "X", "name": "", "pid": 1, "tid": 1, "ts": 0, "dur": 1}],
     "missing/empty name"),
    ([{"ph": "X", "name": "x", "pid": 1, "tid": 1, "ts": 0, "dur": -1}],
     "bad dur"),
    ([{"ph": "X", "name": "x", "pid": 1, "tid": 1, "ts": -2, "dur": 1}],
     "bad ts"),
    ([{"ph": "X", "name": "x", "pid": "p", "tid": 1, "ts": 0, "dur": 1}],
     "non-integer pid"),
    ([{"ph": "C", "name": "c", "pid": 1, "tid": 0, "ts": 0,
       "args": {"v": "hi"}}], "non-numeric series"),
    ([{"ph": "C", "name": "c", "pid": 1, "tid": 0, "ts": 0, "args": {}}],
     "missing args"),
    ([{"ph": "f", "name": "w", "pid": 1, "tid": 1, "ts": 1, "id": 9,
       "bp": "e"}], "no prior s"),
    ([{"ph": "s", "name": "w", "pid": 1, "tid": 1, "ts": 0, "id": 9},
      {"ph": "s", "name": "w", "pid": 1, "tid": 1, "ts": 1, "id": 9}],
     "reused"),
    ([{"ph": "s", "name": "w", "pid": 1, "tid": 1, "ts": 0, "id": 9}],
     "never finished"),
    ([{"ph": "s", "name": "w", "pid": 1, "tid": 1, "ts": 5, "id": 9},
      {"ph": "f", "name": "w", "pid": 1, "tid": 2, "ts": 1, "id": 9,
       "bp": "e"}], "ends before"),
    ([{"ph": "s", "name": "w", "pid": 1, "tid": 1, "ts": 0, "id": 9},
      {"ph": "f", "name": "w", "pid": 1, "tid": 2, "ts": 1, "id": 9}],
     "bp='e'"),
    ([{"ph": "M", "name": "weird_meta", "pid": 1, "tid": 0, "args": {}}],
     "unknown metadata"),
    ([{"ph": "M", "name": "thread_name", "pid": 1, "tid": 0, "args": {}}],
     "args missing"),
])
def test_validator_rejects(events, needle):
    errors = VT.validate(events)
    assert any(needle in e for e in errors), errors


def test_validator_accepts_empty_and_rejects_bad_top_level(tmp_path):
    assert VT.validate([]) == []
    p = tmp_path / "bad.json"
    p.write_text("[1, 2]")
    assert VT.validate_file(str(p)) == [
        "top level must be an object with 'traceEvents'"]
    assert VT.main([str(p)]) == 1


# ---- event-engine tracing (timing-only schedule) --------------------------

def test_schedule_trace_is_byte_deterministic_and_unobtrusive():
    plain = simulate_schedule(TOPO, **SCHED)
    raws = []
    for _ in range(2):
        tr, reg = Tracer(), MetricsRegistry()
        stats = simulate_schedule(TOPO, tracer=tr, metrics=reg, **SCHED)
        # zero-cost contract: tracing never perturbs the schedule
        assert stats["event_log"] == plain["event_log"]
        assert stats["sim_time_s"] == plain["sim_time_s"]
        raws.append(tr.to_bytes())
    assert raws[0] == raws[1]
    assert VT.validate(json.loads(raws[0])["traceEvents"]) == []
    # fabric metrics rode along
    assert reg.counter("fl_merges").value() == SCHED["rounds"]
    assert reg.histogram("fl_observed_staleness_s").stats()["count"] > 0
    assert reg.counter("fl_uplink_bytes").value(edge="0") > 0


def test_schedule_trace_tracks_match_event_log():
    tr = Tracer()
    stats = simulate_schedule(TOPO, tracer=tr, **SCHED)
    log = stats["event_log"]
    times = {round(t * 1e6, 3) for _, t, *rest in log}

    compute = _spans(tr, "compute")
    assert compute and all(
        e["pid"] == FL_PID and e["tid"] >= vehicle_tid(0) for e in compute)
    # every compute span ends at its LocalStepDone event
    done = {round(t * 1e6, 3) for k, t, *r in log if k == "local_step_done"}
    assert all(round(e["ts"] + e["dur"], 3) in done for e in compute)

    uplink = _spans(tr, "uplink")
    assert uplink and all(e["tid"] >= vehicle_tid(0) for e in uplink)

    backhaul = _spans(tr, "backhaul")
    assert backhaul and all(
        edge_tid(0) <= e["tid"] < vehicle_tid(0) for e in backhaul)

    merges = _spans(tr, "merge")
    assert len(merges) == SCHED["rounds"]
    assert all(e["tid"] == CLOUD_TID and e["dur"] == 0.0 for e in merges)
    assert all(round(e["ts"], 3) in times for e in merges)

    kinds = {e[0] for e in log}
    assert "pod_migration" in kinds
    inst = [e for e in tr.events if e["ph"] == "i"]
    assert {e["name"] for e in inst} >= {"cloud_deadline", "pod_migration"}
    # every emitted flow pairs up and lands on the FL process
    flows = [e for e in tr.events if e["ph"] in ("s", "f")]
    assert flows and all(e["pid"] == FL_PID for e in flows)


# ---- traced model run (async Session) -------------------------------------

def _session(strategy, **kw):
    from repro.api import Session
    return Session("flad-vision", strategy=strategy, mesh=(1,),
                   shape="8x4", topology=TOPO, codec="int8",
                   local_steps=2, seed=3, **kw)


def test_async_run_tracing_is_bitwise_zero_cost():
    """Acceptance: same seed with tracing on/off => identical params and
    event log; same seed traced twice => byte-identical trace JSON; the
    history rides both clocks; the metrics snapshot holds the fabric
    counters next to the loop scalars."""
    from repro.api import LoopHooks
    quiet = LoopHooks(**QUIET)
    opts = dict(clock=0.05, compute_flops=5e9, compute_jitter=0.3,
                migrate_every=0.05,
                mobility=MobilitySpec(size=5, radius=1, seed=1))

    base = _session("async_hier_fl", **opts)
    ref = base.run(8, hooks=quiet)

    runs = []
    for _ in range(2):
        tr, reg = Tracer(), MetricsRegistry()
        ses = _session("async_hier_fl", **opts)
        out = ses.run(8, hooks=quiet, trace=tr, metrics=reg)
        runs.append((ses, out, tr, reg))

    (s1, o1, t1, r1), (_, o2, t2, _) = runs
    assert o1["event_log"] == ref["event_log"] == o2["event_log"]
    for x, y in zip(jax.tree.leaves(base.state[0]),
                    jax.tree.leaves(s1.state[0])):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert t1.to_bytes() == t2.to_bytes()
    assert VT.validate(t1.events) == []
    assert len(_spans(t1, "merge")) == o1["merges"]

    # satellite: history carries wall and simulated clocks
    for h in o1["history"]:
        assert h["t_wall_s"] >= 0.0
        assert h["t_sim_s"] > 0.0
    assert o1["history"][-1]["t_sim_s"] == o1["sim_time_s"]

    snap = r1.snapshot()
    assert snap["schema"] == "repro.obs.metrics/1"
    names = set(snap["metrics"])
    assert {"fl_merges", "fl_uplink_bytes", "fl_backhaul_bytes",
            "fl_observed_staleness_s"} <= names
    assert any(n.startswith("comm_bytes") for n in names)

    # untraced ref run must not have grown a trace/metrics path
    assert "trace_path" not in ref and "trace_path" not in o1


def test_run_trace_rejects_wall_clock_strategies():
    ses = _session("hier_fl")
    with pytest.raises(ValueError, match="async"):
        ses.run(1, trace=Tracer())


# ---- continuous-scheduler tracing -----------------------------------------

@pytest.fixture(scope="module")
def lm_setup():
    from repro.models import lm
    cfg = reduced(get_config("flad_adllm")).replace(param_dtype="float32")
    return cfg, lm.init(jax.random.PRNGKey(0), cfg)


def _serve_opts(cfg):
    """Pod-templated trace (shared prefix, unique suffixes) through the
    chunked + prefix-cache scheduler, with the MAC cost model on the sim
    clock so spans carry ``est_cost_s``."""
    reqs = generate_pod_requests("nano*1,agx*1", num_requests=4, pods=1,
                                 template_len=8, max_suffix=4, seed=0,
                                 short_new=(3, 4), long_new=(5, 6),
                                 long_frac=0.5, vocab_size=cfg.vocab_size)
    return dict(requests=reqs, slots=2, block_size=4, max_context=16,
                prefill="chunked", prefill_chunk=4, prefix_cache=True,
                prefill_cost=PrefillCostModel(), log_fn=None)


def test_serve_tracing_is_bitwise_zero_cost(lm_setup):
    cfg, params = lm_setup
    opts = _serve_opts(cfg)
    plain = serve_continuous(cfg, params=params, **opts)
    raws, reports = [], []
    for _ in range(2):
        tr = Tracer()
        rep = serve_continuous(cfg, params=params, trace=tr, **opts)
        raws.append(tr.to_bytes())
        reports.append(rep)
    rep = reports[0]
    assert rep["sequences"] == plain["sequences"]       # greedy streams
    assert raws[0] == raws[1]

    events = json.loads(raws[0])["traceEvents"]
    assert VT.validate(events) == []
    spans = [e for e in events if e["ph"] == "X"]
    assert spans and all(e["pid"] == SERVE_PID for e in spans)
    by_name = {}
    for e in spans:
        by_name.setdefault(e["name"], []).append(e)
    assert set(by_name) >= {"queued", "prefill_chunk", "decode"}
    assert all(e["tid"] == QUEUE_TID for e in by_name["queued"])
    assert all(e["tid"] >= lane_tid(0) for e in by_name["prefill_chunk"])
    assert len(by_name["queued"]) == len(by_name["decode"]) == 4
    assert [e for e in events if e["ph"] == "i" and e["name"] ==
            "first_token"]

    # trace_id echoes through every request-scoped span, and the chunk
    # spans carry the MAC cost model's annotations
    ids = {e["args"]["trace_id"] for e in by_name["queued"]}
    assert ids == {0, 1, 2, 3}
    for e in by_name["prefill_chunk"]:
        assert e["args"]["trace_id"] in ids
        assert e["args"]["padded_tokens"] > 0
        assert e["args"]["est_cost_s"] > 0.0
    # prefix sharing is annotated where it happened
    assert any(e["args"].get("shared_blocks", 0) > 0
               for e in by_name["queued"])

    # satellite: pool-occupancy stats in the loadgen report
    assert rep["pool_blocks_peak"] >= rep["pool_blocks_mean"] > 0.0
    assert rep["pool_blocks_peak"] == plain["pool_blocks_peak"]
    # and a kv-block counter track sampled alongside
    assert any(e["ph"] == "C" and e["name"] == "kv blocks" for e in events)


def test_speculative_serve_tracing_and_metrics(lm_setup):
    """Speculative mode keeps the zero-cost contract: draft/verify spans
    land on the SPEC_TID track, the trace is byte-deterministic, and the
    traced streams stay bitwise equal to an untraced run. The
    accepted-draft-length histogram and preemption counter are in the
    scheduler's always-on registry snapshot from construction."""
    from repro.serve import ContinuousScheduler, PagedCacheSpec, PagedEngine
    cfg, params = lm_setup
    opts = _serve_opts(cfg)
    opts["prefill_cost"] = SpecDecodeCostModel()
    plain = serve_continuous(cfg, params=params, speculative=True,
                             draft_k=3, **opts)
    raws, rep = [], None
    for _ in range(2):
        tr = Tracer()
        rep = serve_continuous(cfg, params=params, speculative=True,
                               draft_k=3, trace=tr, **opts)
        raws.append(tr.to_bytes())
    assert rep["sequences"] == plain["sequences"]
    assert raws[0] == raws[1]
    events = json.loads(raws[0])["traceEvents"]
    assert VT.validate(events) == []
    spec_spans = [e for e in events
                  if e["ph"] == "X" and e["tid"] == SPEC_TID]
    assert {e["name"] for e in spec_spans} == {"draft", "verify"}
    assert all(e["pid"] == SERVE_PID for e in spec_spans)
    assert sum(e["name"] == "verify" for e in spec_spans) \
        == rep["spec_steps"]
    assert all(e["args"]["forwards"] == 4 for e in spec_spans
               if e["name"] == "draft")
    acc = sum(e["args"]["accepted_drafts"] for e in spec_spans
              if e["name"] == "verify")
    assert acc == rep["accepted_drafts"]
    # the specdec track is named
    assert any(e["ph"] == "M" and e["name"] == "thread_name"
               and e["tid"] == SPEC_TID
               and e["args"]["name"] == "specdec" for e in events)

    # satellite: always-on registry carries the speculative instruments
    # the moment the scheduler is built — no samples needed
    reg = MetricsRegistry()
    pspec = PagedCacheSpec.for_requests(1, 16, block_size=4)
    eng = PagedEngine(cfg, pspec, max_context=8, slots=1)
    sched = ContinuousScheduler(eng, params, speculative=True, draft_k=3,
                                prefix_cache=True, metrics=reg)
    snap = reg.snapshot()["metrics"]
    assert snap["serve_spec_accepted_len"]["type"] == "histogram"
    assert snap["serve_preemptions"]["type"] == "counter"
    # and a drained run populates the histogram
    rng = np.random.default_rng(0)
    sched.run_to_completion(
        [ServeRequest(rid=0,
                      prompt=rng.integers(1, cfg.vocab_size,
                                          (4,)).astype(np.int32),
                      max_new_tokens=6)])
    series = reg.snapshot()["metrics"]["serve_spec_accepted_len"]["series"]
    assert series and series[0]["count"] > 0


def test_serve_request_trace_id_defaults_to_rid():
    prompt = np.zeros(3, np.int32)
    assert ServeRequest(7, prompt, 2).trace_id == 7
    assert ServeRequest(7, prompt, 2, trace_id=41).trace_id == 41


def test_session_serve_trace_needs_continuous_scheduler():
    from repro.api import Session
    ses = Session("flad-adllm", mesh=(1,), shape="8x4")
    with pytest.raises(ValueError, match="continuous"):
        ses.serve(trace=Tracer())


# ---- metrics registry -----------------------------------------------------

def test_counter_is_monotone_and_labeled():
    c = Counter("bytes")
    c.inc(3, edge="0")
    c.inc(4, edge="0")
    c.inc(1, edge="1")
    assert c.value(edge="0") == 7.0 and c.value(edge="1") == 1.0
    assert c.value(edge="9") == 0.0
    with pytest.raises(ValueError):
        c.inc(-1, edge="0")


def test_gauge_tracks_high_watermark():
    g = Gauge("pool")
    for v in (3, 9, 5):
        g.set(v)
    assert g.stats() == {"last": 5.0, "mean": 17.0 / 3, "count": 3,
                         "peak": 9.0, "min": 3.0}
    assert g.stats(other="label") is None


def test_histogram_buckets_and_sum():
    h = Histogram("lat", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 2.0, 3.0):
        h.observe(v)
    s = h.stats()
    assert s["count"] == 4 and s["sum"] == pytest.approx(5.55)
    assert [b["count"] for b in s["buckets"]] == [1, 1, 2]
    assert s["buckets"][-1]["le"] == "inf"


def test_registry_publish_scalars_and_type_conflicts():
    reg = MetricsRegistry()
    reg.publish_scalars({"loss": 0.5, "comm_bytes_uplink": 100,
                         "per_client/loss": np.zeros(4)})
    reg.publish_scalars({"loss": 0.25, "comm_bytes_uplink": 50})
    assert reg.counter("comm_bytes_uplink").value() == 150.0
    assert reg.gauge("loss").stats()["last"] == 0.25
    assert reg.get("per_client/loss") is None          # arrays skipped
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("loss")
    assert len(reg) == 2


def test_registry_snapshot_roundtrips_to_json(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc(2, pod="a")
    reg.gauge("g").set(1.5)
    reg.histogram("h").observe(0.2)
    path = str(tmp_path / "metrics.json")
    reg.save(path)
    with open(path) as f:
        doc = json.load(f)
    assert doc["schema"] == "repro.obs.metrics/1"
    assert doc["metrics"]["c"]["series"] == [
        {"labels": {"pod": "a"}, "value": 2.0}]
    assert doc["metrics"]["g"]["type"] == "gauge"


# ---- profiling hooks ------------------------------------------------------

def test_profiled_disabled_is_a_noop():
    with profiled(None):
        pass
    with profiled(ProfileOptions()):        # jax_trace_dir=None
        pass


def test_kernel_cost_args_prices_through_the_cost_model():
    cm = PrefillCostModel(s_per_token=1e-3, s_per_mac=1e-6)
    args = kernel_cost_args(padded_tokens=10, attn_mac=100, cost_model=cm)
    assert args["padded_tokens"] == 10 and args["attn_mac"] == 100
    assert args["est_cost_s"] == pytest.approx(10 * 1e-3 + 100 * 1e-6)
    assert kernel_cost_args() == {}
    assert kernel_cost_args(flops=5e9) == {"flops": 5e9}
    assert "est_cost_s" not in kernel_cost_args(flops=1.0, cost_model=cm)


# ---- benchmark registry listing -------------------------------------------

def test_benchmarks_list_prints_registry():
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "run.py"),
         "--list"], capture_output=True, text=True, timeout=60, cwd=REPO)
    assert out.returncode == 0, out.stderr
    names = out.stdout.split()
    assert len(names) == 15 and len(set(names)) == 15
    assert {"serving", "prefill", "async", "comm", "specdec"} <= set(names)
