"""Hierarchical FedAvg properties + FL/SGD equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ShapeConfig
from repro.configs import get_config
from repro.configs.common import concrete_batch, reduced
from repro.core.fedavg import (broadcast_round, fedavg, make_fl_round,
                               stack_clients)
from repro.core.steps import make_train_step
from repro.models import build_model
from repro.train.optimizer import Adam

SHAPE = ShapeConfig("t", 16, 8, "train")


def test_fedavg_is_mean():
    tree = {"a": jnp.arange(12.0).reshape(4, 3)}
    avg = fedavg(tree)
    assert jnp.allclose(avg["a"], tree["a"].mean(0))


def test_fedavg_weighted():
    tree = {"a": jnp.stack([jnp.zeros(3), jnp.ones(3)])}
    w = jnp.asarray([1.0, 3.0])
    avg = fedavg(tree, weights=w)
    assert jnp.allclose(avg["a"], 0.75)


def test_broadcast_roundtrip():
    tree = {"a": jnp.arange(6.0).reshape(2, 3)}
    avg = fedavg(tree)
    again = fedavg(broadcast_round(avg, 5))
    assert jnp.allclose(avg["a"], again["a"])


def test_fl_round_single_client_matches_sgd():
    """One client, one local step == plain SGD step."""
    cfg = reduced(get_config("flad_vision"))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt = Adam(lr=1e-3)
    batch = concrete_batch(cfg, SHAPE, key)

    step = jax.jit(make_train_step(cfg, SHAPE, opt, remat=False))
    p_ref, _, _ = step(params, opt.init(params), batch)

    fl_round = jax.jit(make_fl_round(cfg, SHAPE, opt, local_steps=1,
                                     remat=False))
    cp = stack_clients(params, 1)
    co = jax.vmap(opt.init)(cp)
    rb = jax.tree.map(lambda x: x[None, None], batch)   # [C=1, E=1, ...]
    cp2, _, _ = fl_round(cp, co, rb)
    # vmap changes reduction order; grads agree to float32 noise
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(cp2)):
        assert jnp.allclose(a, b[0], atol=1e-4)


def test_fl_round_weighted_matches_manual():
    """Data-volume-weighted aggregation (paper §3.1): the plumbed
    client_weights produce the manual weighted mean of the locally trained
    clients, and uniform weights reduce to the plain mean."""
    cfg = reduced(get_config("flad_vision"))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt = Adam(lr=1e-3)
    b0 = concrete_batch(cfg, SHAPE, jax.random.PRNGKey(1))
    b1 = concrete_batch(cfg, SHAPE, jax.random.PRNGKey(2))

    step = jax.jit(make_train_step(cfg, SHAPE, opt, remat=False))
    p0, _, _ = step(params, opt.init(params), b0)
    p1, _, _ = step(params, opt.init(params), b1)
    w = jnp.asarray([1.0, 3.0])
    manual = jax.tree.map(lambda a, b: (1.0 * a + 3.0 * b) / 4.0, p0, p1)

    cp = stack_clients(params, 2)
    co = jax.vmap(opt.init)(cp)
    rb = jax.tree.map(lambda a, b: jnp.stack([a, b])[:, None], b0, b1)
    fl_w = jax.jit(make_fl_round(cfg, SHAPE, opt, local_steps=1,
                                 remat=False, client_weights=w))
    cw, _, _ = fl_w(cp, co, rb)
    for m, c in zip(jax.tree.leaves(manual), jax.tree.leaves(cw)):
        assert jnp.allclose(m, c[0], atol=1e-4)

    fl_u = jax.jit(make_fl_round(cfg, SHAPE, opt, local_steps=1,
                                 remat=False, client_weights=jnp.ones(2)))
    fl_n = jax.jit(make_fl_round(cfg, SHAPE, opt, local_steps=1,
                                 remat=False))
    au, _, _ = fl_u(cp, co, rb)
    an, _, _ = fl_n(cp, co, rb)
    for x, y in zip(jax.tree.leaves(au), jax.tree.leaves(an)):
        assert jnp.allclose(x, y, atol=1e-5)

    bad = make_fl_round(cfg, SHAPE, opt, local_steps=1, remat=False,
                        client_weights=jnp.ones(3))
    with pytest.raises(ValueError):
        bad(cp, co, rb)


def test_fl_round_clients_average():
    """After a round all clients hold identical (averaged) params."""
    cfg = reduced(get_config("flad_vision"))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt = Adam(lr=1e-3)
    fl_round = jax.jit(make_fl_round(cfg, SHAPE, opt, local_steps=2,
                                     remat=False))
    C = 3
    cp = stack_clients(params, C)
    co = jax.vmap(opt.init)(cp)
    rbs = [concrete_batch(cfg, SHAPE, jax.random.PRNGKey(i))
           for i in range(C * 2)]
    rb = jax.tree.map(
        lambda *xs: jnp.stack(xs).reshape((C, 2) + xs[0].shape), *rbs)
    cp2, _, _ = fl_round(cp, co, rb)
    for leaf in jax.tree.leaves(cp2):
        assert jnp.allclose(leaf[0], leaf[1], atol=1e-5)
        assert jnp.allclose(leaf[0], leaf[2], atol=1e-5)


@pytest.mark.parametrize("weights", [
    np.zeros(3),                      # all-zero
    np.asarray([1.0, -2.0, 0.5]),     # negative sum
    np.asarray([np.inf, 1.0, 1.0]),   # non-finite sum
])
def test_fedavg_degenerate_weights_raise(weights):
    """Regression: degenerate weights used to divide by zero and
    silently NaN the global params through the normalizing division."""
    tree = {"a": jnp.ones((3, 4))}
    with pytest.raises(ValueError, match="degenerate aggregation"):
        fedavg(tree, weights=jnp.asarray(weights))


def test_make_fl_round_degenerate_weights_raise_at_build():
    cfg = reduced(get_config("flad_vision"))
    with pytest.raises(ValueError, match="degenerate aggregation"):
        make_fl_round(cfg, SHAPE, Adam(lr=1e-3),
                      client_weights=np.zeros(2))
