"""The event-time FL engine: queue determinism, split-aggregation
algebra, topology migration, mobility-fed migration events, the
sync-equivalence guarantee, and the dwell-bound property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hyp import given, settings, st

from repro.comm.events import (AsyncHierFLEngine, BackhaulArrived,
                               CloudDeadline, ComputeModel, EventQueue,
                               FleetMobility, LocalStepDone, MobilitySpec,
                               PodMigration, UplinkArrived,
                               simulate_schedule, time_to_migration)
from repro.comm.hierarchy import (cloud_merge, cloud_merge_at,
                                  edge_aggregate, edge_commit)
from repro.comm.topology import parse_topology
from repro.sched.mobility import (in_range_probability, make_patterns,
                                  pattern_posterior, sample_trajectory)

TOPO = parse_topology("2@nano*2,agx*2")
FLOPS = 4.7e11          # ~2.0 s/round on a nano, ~0.25 s on an agx


# ---- event queue ----------------------------------------------------------

def test_event_queue_breaks_ties_by_sequence():
    q = EventQueue()
    evs = [LocalStepDone(1.0, 3), UplinkArrived(1.0, 1, 10),
           CloudDeadline(1.0, 0), LocalStepDone(0.5, 0)]
    for ev in evs:
        q.push(ev)
    # strictly earlier first, then push order among identical timestamps
    assert q.pop() == LocalStepDone(0.5, 0)
    assert q.pop() == LocalStepDone(1.0, 3)
    assert q.pop() == UplinkArrived(1.0, 1, 10)
    assert q.pop() == CloudDeadline(1.0, 0)
    assert q.pop() is None and q.peek_t() == np.inf


# ---- split aggregation algebra -------------------------------------------

def _stacked(c=4, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"a": jax.random.normal(k1, (c, 6, 5)),
            "b": jax.random.normal(k2, (c, 300))}


def test_edge_commit_matches_edge_aggregate():
    stacked = _stacked()
    w = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    edge_tree, edge_w = edge_aggregate(stacked, w, TOPO)
    for e, idx in enumerate(TOPO.member_indices):
        part, total = edge_commit(
            jax.tree.map(lambda x: x[idx], stacked), w[idx])
        assert float(total) == float(edge_w[e])
        for k in part:
            # bitwise: edge_aggregate is built from per-pod edge_commit
            assert jnp.array_equal(part[k].astype(edge_tree[k].dtype),
                                   edge_tree[k][e])


def test_cloud_merge_at_matches_fused_merge():
    stacked = _stacked()
    g = {"a": jnp.ones((6, 5)), "b": jnp.zeros((300,))}
    edge_tree, edge_w = edge_aggregate(stacked, None, TOPO)
    merged = cloud_merge(edge_tree, edge_w)
    fused = jax.tree.map(lambda gl, d: gl + d, g, merged)
    commits = [edge_commit(jax.tree.map(lambda x: x[idx], stacked),
                           jnp.ones(len(idx)))
               for idx in TOPO.member_indices]
    split = cloud_merge_at(g, [c[0] for c in commits],
                           [c[1] for c in commits])
    for k in fused:
        assert jnp.allclose(fused[k], split[k], atol=1e-6)
    # observed staleness down-weights a late commit
    stale = cloud_merge_at(g, [c[0] for c in commits],
                           [c[1] for c in commits],
                           staleness=jnp.asarray([1.0, 0.25]))
    assert not jnp.allclose(stale["a"], split["a"])


# ---- topology transitions -------------------------------------------------

def test_topology_reassign():
    t2 = TOPO.reassign(1, 1)
    assert t2.edges == ((0,), (2, 3, 1))
    assert list(t2.client_edge) == [0, 1, 1, 1]
    assert TOPO.edges == ((0, 1), (2, 3))           # original untouched
    assert TOPO.reassign(1, 0) is TOPO              # no-op move
    with pytest.raises(ValueError, match="last member"):
        t2.reassign(0, 1)
    with pytest.raises(ValueError, match="no vehicle"):
        TOPO.reassign(9, 0)


def test_validate_pod_weights_hoisted():
    """The per-pod degenerate-weight check lives on Topology now (built
    once, not per aggregation call) and still names the pod."""
    with pytest.raises(ValueError, match="edge pod 0"):
        TOPO.validate_pod_weights(np.asarray([0.0, 0.0, 1.0, 1.0]))
    TOPO.validate_pod_weights(np.ones(4))            # fine
    # member indices are cached arrays, not rebuilt per call
    assert TOPO.member_indices is TOPO.member_indices
    assert [list(m) for m in TOPO.member_indices] == [[0, 1], [2, 3]]


def test_hier_round_build_validates_pod_weights():
    from repro.api import Session
    ses = Session("flad-vision", strategy="hier_fl", mesh=(1,),
                  shape="8x4", topology=TOPO,
                  client_weights=[0.0, 0.0, 1.0, 1.0])
    with pytest.raises(ValueError, match="edge pod 0"):
        ses.build(init=False)


# ---- timing-only schedules ------------------------------------------------

def test_simulate_schedule_sync_gated_by_straggler():
    sync = simulate_schedule(TOPO, clock=None, compute_flops=FLOPS,
                             rounds=4)
    # every sync round waits for the slow nano pod (~2 s compute)
    assert len(sync["merges"]) == 4
    assert sync["mean_period_s"] > 1.9
    assert sync["mean_staleness"] == 1.0
    asyn = simulate_schedule(TOPO, clock=0.4, compute_flops=FLOPS,
                             rounds=10)
    # the clocked merge decouples from the stragglers...
    assert asyn["mean_period_s"] < 0.5
    # ...and the nanos' commits land with observed (not predicted) lag
    assert asyn["mean_staleness"] < 1.0
    assert any(m["lag_max"] >= 1 for m in asyn["merges"])


def test_simulate_schedule_replays_identically():
    a = simulate_schedule(TOPO, clock=0.4, compute_flops=FLOPS,
                          jitter=0.3, migrate_every=0.5, rounds=6, seed=7)
    b = simulate_schedule(TOPO, clock=0.4, compute_flops=FLOPS,
                          jitter=0.3, migrate_every=0.5, rounds=6, seed=7)
    assert a == b
    c = simulate_schedule(TOPO, clock=0.4, compute_flops=FLOPS,
                          jitter=0.3, migrate_every=0.5, rounds=6, seed=8)
    # different seed, different jitter/mobility draws
    assert c["event_log"] != a["event_log"]


# ---- full engine: equivalence, determinism, migration ---------------------

def _session(strategy, **kw):
    from repro.api import Session
    return Session("flad-vision", strategy=strategy, mesh=(1,),
                   shape="8x4", topology=TOPO, codec="int8",
                   local_steps=2, seed=3, **kw)


QUIET = dict(log_every=10 ** 9, log_fn=lambda *a, **k: None)


def test_async_sync_mode_bit_identical_to_hier_fl():
    """The acceptance guarantee: with the infinite deadline, zero
    compute jitter, and no migrations, the piecewise-jitted event engine
    reproduces the fused synchronous round bit for bit over >= 3
    rounds (same topology, codec, and seed)."""
    from repro.api import LoopHooks
    quiet = LoopHooks(**QUIET)
    hier = _session("hier_fl")
    hier.run(3, hooks=quiet)
    asyn = _session("async_hier_fl")
    out = asyn.run(3, hooks=quiet)
    assert out["merges"] == 3
    for x, y in zip(jax.tree.leaves(hier.state[0]),
                    jax.tree.leaves(asyn.state[0])):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(hier.state[1]),
                    jax.tree.leaves(asyn.state[1])):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    # the event schedule is the sync barrier: every vehicle uplinks
    # before every merge
    kinds = [e[0] for e in out["event_log"]]
    assert kinds.count("backhaul_arrived") == 3 * TOPO.n_edges
    assert kinds.count("uplink_arrived") == 3 * TOPO.n_clients


def test_async_run_replays_deterministically_with_migration():
    """Determinism satellite + migration acceptance: identical seeds
    replay the exact event log and final params even with jitter,
    clocked merges, and mobility-driven pod migrations — and the
    migrating run stays finite (no NaNs, no shape errors)."""
    from repro.api import LoopHooks
    quiet = LoopHooks(**QUIET)
    opts = dict(clock=0.05, compute_flops=5e9, compute_jitter=0.3,
                migrate_every=0.05,
                mobility=MobilitySpec(size=5, radius=1, seed=1))
    runs = []
    for _ in range(2):
        ses = _session("async_hier_fl", **opts)
        out = ses.run(12, hooks=quiet)
        runs.append((ses, out))
    (s1, o1), (s2, o2) = runs
    assert o1["event_log"] == o2["event_log"]
    for x, y in zip(jax.tree.leaves(s1.state[0]),
                    jax.tree.leaves(s2.state[0])):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    eng = s1.strategy.engine
    kinds = {e[0] for e in o1["event_log"]}
    assert "pod_migration" in kinds and eng.n_migrations > 0
    # the live topology is a valid partition after every reassign (it may
    # even equal the original if migrations round-tripped)
    assert sorted(i for m in eng.topo.edges for i in m) == [0, 1, 2, 3]
    assert all(m for m in eng.topo.edges)        # no pod emptied
    for leaf in jax.tree.leaves(eng.global_params):
        assert np.isfinite(np.asarray(leaf)).all()
    merged = s1.merged_params()                  # engine's global view
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(merged))
    # observed-staleness metrics rode along on each merge
    hist = o1["history"]
    assert all(0.0 < h["staleness_mean"] <= 1.0 for h in hist)
    assert all(np.isfinite(h["per_client/loss"]).any() for h in hist)


# ---- mobility feeding migration events ------------------------------------

def test_fleet_mobility_migrates_out_of_range_vehicles():
    spec = MobilitySpec(size=5, radius=1, seed=1)
    mob = FleetMobility(spec, TOPO)
    assert mob.edge_cells.shape == (TOPO.n_edges,)
    # vehicles start in range of their own pod
    for i in range(TOPO.n_clients):
        assert not mob.out_of_range(i, int(TOPO.client_edge[i]))
    rng = np.random.default_rng(0)
    moved = 0
    for _ in range(40):
        for i in range(TOPO.n_clients):
            mob.advance(i, rng)
            if mob.out_of_range(i, int(TOPO.client_edge[i])):
                moved += 1
                e = mob.nearest_edge(i)
                assert 0 <= e < TOPO.n_edges
    assert moved > 0                 # a radius-1 range does get exited


def test_pattern_posterior_identifies_generating_pattern():
    world = make_patterns(5, 3, seed=4)
    rng = np.random.default_rng(11)
    hits = 0
    for k in range(3):
        for s in range(4):
            traj = sample_trajectory(world, k, rng.integers(world.n_cells),
                                     12, rng)
            hits += int(np.argmax(pattern_posterior(world, traj)) == k)
    assert hits >= 8                 # posterior concentrates on the truth


def test_in_range_probability_monotone_in_horizon():
    world = make_patterns(5, 3, seed=4)
    rng = np.random.default_rng(3)
    h1 = sample_trajectory(world, 0, 12, 4, rng)
    h2 = sample_trajectory(world, 1, 13, 4, rng)
    ps = [in_range_probability(world, h1, h2, h, radius_cells=3)
          for h in (1, 3, 6)]
    assert all(0.0 <= p <= 1.0 for p in ps)
    assert ps[0] >= ps[1] >= ps[2]   # staying in range only gets harder


_DWELL_CACHE = {}


def _dwell_setup():
    if not _DWELL_CACHE:
        from repro.sched.dwell import train_dwell_model
        world = make_patterns(5, 3, seed=2)
        _, predict, mape = train_dwell_model(world, route_len=10,
                                             n_train=256, steps=150,
                                             seed=0)
        _DWELL_CACHE["world"] = world
        _DWELL_CACHE["predict"] = predict
    return _DWELL_CACHE["world"], _DWELL_CACHE["predict"]


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_prop_dwell_upper_bounds_time_to_migration(seed):
    """Predicted dwell (WDR regressor, sched/dwell.py) upper-bounds the
    simulated time-to-migration in expectation over seeds: a vehicle
    cannot, on average, leave its edge pod's comm radius later than its
    predicted dwell in the area (1.25x slack for regression error)."""
    world, predict = _dwell_setup()
    rng = np.random.default_rng(seed)
    routes, speeds, sims = [], [], []
    for _ in range(24):
        k = int(rng.integers(world.patterns.shape[0]))
        start = int(rng.integers(world.n_cells))
        traj = sample_trajectory(world, k, start, 9, rng)
        speed = float(rng.uniform(0.5, 1.5))
        routes.append(traj)
        speeds.append(speed)
        sims.append(time_to_migration(world, traj, speed, radius=2))
    pred = np.asarray(predict(np.stack(routes),
                              np.asarray(speeds, np.float32)))
    assert np.isfinite(pred).all() and (pred > 0).all()
    assert float(np.mean(pred)) * 1.25 >= float(np.mean(sims)), \
        (float(np.mean(pred)), float(np.mean(sims)))


def test_lapped_vehicle_never_double_counted_in_one_commit():
    """A fast vehicle that laps its pod's flush timer (uplinks again
    while its previous update is still buffered) must not appear twice
    in one edge commit — that would double its aggregation weight. The
    engine forwards the pending partial first."""
    topo = parse_topology("2@nano*1,agx*3")     # pod 0 = {nano, agx}
    committed = []

    class Recorder(AsyncHierFLEngine):
        def _commit(self, e, t):
            committed.append(tuple(b.vehicle for b in self.edge_buffers[e]))
            super()._commit(e, t)

    # flush_every > clock: the agx in pod 0 restarts at each 0.4 s tick
    # and uplinks again (~0.67 s) before the 1.17 s flush fires
    eng = Recorder(topo, 2 ** 21, lambda m: 2 ** 21,
                   compute=ComputeModel(flops=4.7e11),
                   clock=0.4, flush_every=0.9)
    eng.reset()
    merges = 0
    for _ in range(10 ** 5):
        if merges >= 8:
            break
        rec = eng.handle(eng.queue.pop())
        merges += rec is not None
    # the lap happened (pod 0's agx committed alone more than once)...
    assert committed.count((1,)) >= 2
    # ...and no commit ever carried the same vehicle twice
    assert all(len(set(c)) == len(c) for c in committed)


# ---- engine guards --------------------------------------------------------

def test_engine_rejects_bad_options():
    with pytest.raises(ValueError, match="clock"):
        AsyncHierFLEngine(TOPO, 100, lambda m: 100, clock=-1.0)
    with pytest.raises(ValueError, match="decay"):
        AsyncHierFLEngine(TOPO, 100, lambda m: 100, decay=0.0)
    with pytest.raises(ValueError, match="edge pod 0"):
        AsyncHierFLEngine(TOPO, 100, lambda m: 100,
                          client_weights=[0.0, 0.0, 1.0, 1.0])


def test_compute_model_jitter_only_slows():
    v = TOPO.vehicles[0]
    cm = ComputeModel(flops=1e12, jitter=0.0)
    rng = np.random.default_rng(0)
    base = cm.time_s(v, rng)
    assert base == pytest.approx(1e12 / (v.cmp * 0.5))
    jittered = ComputeModel(flops=1e12, jitter=0.5)
    ts = [jittered.time_s(v, rng) for _ in range(16)]
    assert all(base <= t <= base * 1.5 for t in ts)
