"""Serving tier: paged KV-cache, continuous batching, int8 cache, chunked
prefill, pod prefix sharing, and the incremental-decode consistency
contract behind them all."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ShapeConfig
from repro.configs import get_config
from repro.configs.common import reduced
from repro.serve import (BlockAllocator, ContinuousScheduler,
                         PagedCacheSpec, PagedEngine, PrefillCostModel,
                         PrefixCache, ServeRequest, drive,
                         generate_fleet_requests, generate_pod_requests,
                         int8_cache_fidelity, serve_continuous)
from repro.serve import kvcache as KC
from tests._hyp import given, settings, st

KEY = jax.random.PRNGKey(0)


def _smoke_cfg(arch="flad_adllm"):
    return reduced(get_config(arch)).replace(param_dtype="float32")


@pytest.fixture(scope="module")
def dense_setup():
    from repro.models import lm
    cfg = _smoke_cfg()
    params = lm.init(KEY, cfg)
    return cfg, params


# ------------------------------------------------------ block allocator ----
def test_block_allocator_semantics():
    spec = PagedCacheSpec(num_blocks=8, block_size=4, max_blocks_per_req=3)
    alloc = BlockAllocator(spec)
    assert alloc.free_blocks == 7          # block 0 never enters the pool
    a = alloc.alloc(3)
    assert a is not None and 0 not in a
    assert alloc.alloc(4) is None          # > max_blocks_per_req
    b = alloc.alloc(3)
    assert alloc.free_blocks == 1
    assert alloc.alloc(2) is None          # all-or-nothing: 1 < 2
    assert alloc.free_blocks == 1          # failed alloc strands nothing
    alloc.release(b)
    assert alloc.free_blocks == 4
    assert alloc.alloc(3) is not None      # released blocks recycle
    with pytest.raises(ValueError):
        alloc.release(a + [a[0]])          # double free in one batch
    with pytest.raises(ValueError):
        alloc.release([0])                 # null block is off-limits
    with pytest.raises(ValueError):
        alloc.release([spec.num_blocks])   # outside the pool


def test_cache_spec_sizing():
    spec = PagedCacheSpec.for_requests(3, max_tokens=20, block_size=8)
    assert spec.max_blocks_per_req == 3 and spec.max_tokens_per_req == 24
    assert spec.num_blocks == 1 + 3 * 3 + 1
    assert spec.blocks_needed(1) == 1 and spec.blocks_needed(17) == 3
    with pytest.raises(ValueError):
        PagedCacheSpec(num_blocks=1, block_size=4, max_blocks_per_req=1)


# ------------------------------------------------- int8 row quantization ---
def test_quantize_rows_deterministic_roundtrip():
    x = jax.random.normal(KEY, (3, 5, 7, 32), jnp.float32)
    q1, s1 = KC.quantize_rows(x)
    q2, s2 = KC.quantize_rows(x)
    assert jnp.array_equal(q1, q2) and jnp.array_equal(s1, s2)
    assert q1.shape == x.shape and s1.shape == x.shape[:-1] + (1,)
    back = KC.dequantize_rows(q1, s1)
    # round-to-nearest: error <= half a quantization step per row
    step = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    assert float(jnp.max(jnp.abs(back - x) - 0.5 * step)) <= 1e-6


# ------------------------------------ incremental decode == full forward ---
@pytest.mark.parametrize("arch", ["flad_adllm", "xlstm_350m", "hymba_1_5b"])
def test_incremental_decode_matches_forward(arch):
    """prefill + N single-token serve steps must reproduce the logits of
    one full-sequence forward, per caching family (ring KV / ssm state /
    hybrid)."""
    from repro.core.steps import make_prefill_step, make_serve_step
    from repro.models import build_model

    cfg = _smoke_cfg(arch)
    batch, context, steps = 2, 8, 4
    shape = ShapeConfig("serve", context + steps, batch, "decode")
    model = build_model(cfg)
    params = model.init(KEY)
    prefill = jax.jit(make_prefill_step(cfg, shape))
    serve = jax.jit(make_serve_step(cfg, shape))
    tokens = jax.random.randint(jax.random.fold_in(KEY, 1),
                                (batch, context + steps), 0,
                                cfg.vocab_size, jnp.int32)

    state = model.init_state(batch, shape.seq_len)
    logits, state = prefill(params, {"tokens": tokens[:, :context]}, state)
    inc = [logits[:, -1]]
    for i in range(steps - 1):
        logits, state = serve(params, tokens[:, context + i:context + i + 1],
                              state, context + i)
        inc.append(logits[:, -1])

    # oracle: a fresh full forward (prefill of the whole prefix) per step
    for i, got in enumerate(inc):
        full, _ = prefill(params, {"tokens": tokens[:, :context + i]},
                          model.init_state(batch, shape.seq_len))
        assert float(jnp.max(jnp.abs(got - full[:, -1]))) < 2e-2, i


# -------------------------------------------- paged engine vs contiguous ---
def test_paged_engine_matches_contiguous(dense_setup):
    from repro.models import lm
    cfg, params = dense_setup
    spec = PagedCacheSpec.for_requests(2, 24, block_size=4)
    eng = PagedEngine(cfg, spec, max_context=12, slots=2)
    alloc = BlockAllocator(spec)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32)
               for n in (5, 9)]
    n_decode = 4

    pools = eng.init_pools()
    tables = np.zeros((2, spec.max_blocks_per_req), np.int32)
    ctx = np.zeros(2, np.int32)
    pend = np.zeros(2, np.int32)
    for i, p in enumerate(prompts):
        blocks = alloc.alloc(spec.blocks_needed(len(p) + n_decode))
        tables[i, :len(blocks)] = blocks
        toks, length = eng.pad_prompt(p)
        logits, k, v = eng.prefill(params, toks, length)
        pools = eng.write_prefill(pools, k, v, jnp.asarray(tables[i]))
        pend[i] = int(jnp.argmax(logits[0]))
        ctx[i] = len(p)
    streams = [[int(t)] for t in pend]
    for _ in range(n_decode - 1):
        logits, pools = eng.decode(params, pools, jnp.asarray(pend),
                                   jnp.asarray(tables), jnp.asarray(ctx))
        ctx += 1
        pend = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for i in range(2):
            streams[i].append(int(pend[i]))

    # contiguous oracle: full forward over prompt + generated prefix
    for i, p in enumerate(prompts):
        toks = list(p)
        for step in range(n_decode):
            t = jnp.asarray(np.array(toks, np.int32))[None]
            logits_ref, _, _ = lm.forward(params, cfg, t,
                                          positions=jnp.arange(len(toks)))
            want = int(jnp.argmax(logits_ref[0, -1]))
            assert streams[i][step] == want, (i, step)
            toks.append(want)
    # and the final-step logits agree numerically per live lane
    t = jnp.asarray(np.array(list(prompts[1]) + streams[1][:-1],
                             np.int32))[None]
    logits_ref, _, _ = lm.forward(params, cfg, t,
                                  positions=jnp.arange(t.shape[1]))
    assert float(jnp.max(jnp.abs(logits[1] - logits_ref[0, -1]))) < 1e-3


def test_paged_engine_rejects_unsupported(dense_setup):
    cfg, _ = dense_setup
    spec = PagedCacheSpec.for_requests(1, 16, block_size=4)
    with pytest.raises(NotImplementedError):
        PagedEngine(_smoke_cfg("xlstm_350m"), spec, max_context=8, slots=1)
    with pytest.raises(ValueError):
        PagedEngine(cfg, spec, max_context=64, slots=1)


# ------------------------------------------------------- int8 cache mode ---
def test_int8_cache_drift_bounds(dense_setup):
    cfg, params = dense_setup
    requests = generate_fleet_requests("nano*1,agx*1", num_requests=3,
                                       max_prompt=6, seed=2,
                                       short_new=(3, 5), long_new=(8, 10),
                                       long_frac=0.4,
                                       vocab_size=cfg.vocab_size)
    rep = serve_continuous(cfg, params=params, slots=2, block_size=4,
                           max_context=12, num_requests=3,
                           fleet="nano*1,agx*1", max_prompt=6,
                           short_new=(3, 5), long_new=(8, 10),
                           long_frac=0.4, log_fn=None)
    fid = int8_cache_fidelity(cfg, params, requests, rep["sequences"],
                              block_size=4, max_context=12)
    # random-init logits are the worst case for argmax flips; the drift
    # bound is the real contract, the flip rate a sanity ceiling
    assert fid["max_logit_drift"] < 0.15
    assert fid["disagreement"] <= 0.15
    assert fid["positions"] == sum(len(s) for s in rep["sequences"].values())


# ---------------------------------------------- scheduler / loadgen -------
def _small_workload(cfg):
    return dict(fleet="nano*1,agx*1", num_requests=4, max_prompt=6,
                short_new=(3, 5), long_new=(9, 12), long_frac=0.5,
                slots=2, block_size=4, max_context=12, log_fn=None)


def test_continuous_equals_rebatch_streams(dense_setup):
    cfg, params = dense_setup
    opts = _small_workload(cfg)
    cont = serve_continuous(cfg, params=params, policy="continuous", **opts)
    reb = serve_continuous(cfg, params=params, policy="rebatch", **opts)
    assert cont["sequences"] == reb["sequences"]
    assert cont["decode_steps"] < reb["decode_steps"]
    assert cont["requests"] == reb["requests"] == 4


def test_scheduler_respects_block_cap(dense_setup):
    cfg, params = dense_setup
    spec = PagedCacheSpec.for_requests(2, 16, block_size=4)
    eng = PagedEngine(cfg, spec, max_context=8, slots=2)
    sched = ContinuousScheduler(eng, params, max_inflight_blocks=4)
    rng = np.random.default_rng(0)
    reqs = [ServeRequest(rid=i,
                         prompt=rng.integers(1, cfg.vocab_size,
                                             (6,)).astype(np.int32),
                         max_new_tokens=6) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    sched.step(0.0)
    # each request needs 3 blocks; the 4-block cap admits exactly one
    assert sched.num_active == 1
    assert sched.allocator.in_use <= 4
    done = []
    for step in range(1, 60):
        sched.step(float(step))
        if sched.idle:
            done = sched.finished
            break
    assert len(done) == 3                  # cap throttles, never starves
    assert all(len(r.tokens) == r.max_new_tokens for r in done)
    assert sched.allocator.in_use == 0     # every block returned


def test_loadgen_deterministic(dense_setup):
    cfg, params = dense_setup
    opts = _small_workload(cfg)
    a = serve_continuous(cfg, params=params, **opts)
    b = serve_continuous(cfg, params=params, **opts)
    assert a["sequences"] == b["sequences"]
    for key in ("decode_steps", "prefills", "p50_latency_s",
                "p99_latency_s", "deadline_hit_rate"):
        assert a[key] == b[key], key


def test_fleet_arrivals_follow_uplink():
    reqs = generate_fleet_requests("nano*1,agx*1", num_requests=2,
                                   max_prompt=8, seed=0)
    # same epoch; the agx's 2x faster V2X link must land no later than
    # the nano's for equal-or-shorter prompts (prompt lengths vary, so
    # compare normalized by payload)
    nano, agx = reqs[0], reqs[1]
    assert nano.arrival_s == pytest.approx(
        len(nano.prompt) * 64 / 0.125e9)
    assert agx.arrival_s == pytest.approx(len(agx.prompt) * 64 / 0.25e9)


# ---------------------------------------------- refcounted sharing --------
def _refcount_walk(alloc, spec, choices):
    """Mirror a random alloc/share/release walk against a pure-python
    refcount model; assert pool accounting after every op."""
    model, held = {}, []
    for op, salt in choices:
        if op == 0:
            n = 1 + salt % spec.max_blocks_per_req
            got = alloc.alloc(n)
            can = n <= (spec.num_blocks - 1) - len(model)
            assert (got is not None) == can
            for b in got or []:
                assert model.get(b, 0) == 0    # handed out from free
                model[b] = 1
                held.append(b)
        elif op == 1 and held:
            picks = [held[(salt + i) % len(held)]
                     for i in range(1 + salt % 3)]
            alloc.share(picks)
            for b in picks:
                model[b] += 1
                held.append(b)
        elif op == 2 and held:
            k = 1 + salt % min(6, len(held))
            idx = sorted({(salt + 7 * i) % len(held) for i in range(k)},
                         reverse=True)
            picks = [held[i] for i in idx]
            for i in idx:
                del held[i]
            alloc.release(picks)
            for b in picks:
                model[b] -= 1
                if model[b] == 0:
                    del model[b]
        assert alloc.free_blocks == (spec.num_blocks - 1) - len(model)
        for b in set(held):
            assert alloc.refcount(b) == model[b]
    # one release too many must raise and mutate nothing
    if held:
        b = held[0]
        extra = [b] * (model[b] + 1)
        free_before, rc_before = alloc.free_blocks, alloc.refcount(b)
        with pytest.raises(ValueError):
            alloc.release(extra)
        assert alloc.free_blocks == free_before
        assert alloc.refcount(b) == rc_before
    free = [b for b in range(1, spec.num_blocks) if b not in model]
    if free:
        with pytest.raises(ValueError):
            alloc.share([free[0]])             # share of a free block


def test_allocator_refcount_random_walk():
    spec = PagedCacheSpec(num_blocks=16, block_size=4, max_blocks_per_req=6)
    rng = np.random.default_rng(0)
    choices = [(int(rng.integers(0, 3)), int(rng.integers(0, 1 << 20)))
               for _ in range(300)]
    _refcount_walk(BlockAllocator(spec), spec, choices)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 1 << 20)),
                max_size=60))
def test_allocator_refcount_property(choices):
    spec = PagedCacheSpec(num_blocks=10, block_size=2, max_blocks_per_req=4)
    _refcount_walk(BlockAllocator(spec), spec, choices)


def test_prefix_cache_match_insert_evict():
    spec = PagedCacheSpec(num_blocks=12, block_size=4, max_blocks_per_req=4)
    alloc = BlockAllocator(spec)
    pc = PrefixCache(alloc)
    prompt = np.arange(1, 11, dtype=np.int32)     # 10 tokens, 2 full blocks
    assert pc.match(prompt) == ([], None, 0)      # cold miss
    blocks = alloc.alloc(3)
    pc.insert(prompt, blocks + [0])
    assert len(pc) == 2 and pc.registered_blocks == 2
    assert alloc.refcount(blocks[0]) == 2         # request + registry
    assert alloc.refcount(blocks[2]) == 1         # partial block never cached

    shared, cow, resume = pc.match(prompt)        # 8 of 10 tokens cached
    assert (shared, cow, resume) == (blocks[:2], None, 8)
    alloc.release(shared)
    div = np.concatenate([prompt[:4], prompt[:6][::-1]])
    shared, cow, resume = pc.match(div)           # diverges after block 0
    assert (shared, cow, resume) == ([blocks[0]], None, 4)
    alloc.release(shared)
    shared, cow, resume = pc.match(prompt[:8])    # whole prompt cached: CoW
    assert (shared, cow, resume) == ([blocks[0]], blocks[1], 7)
    alloc.release(shared + [cow])
    assert (pc.hits, pc.misses, pc.cached_tokens) == (3, 1, 19)

    alloc.release(blocks)                         # the request retires
    assert alloc.refcount(blocks[2]) == 0
    assert pc.evict(1) == 1                       # registry-only -> evictable
    assert pc.evict(10) == 1
    assert len(pc) == 0 and alloc.in_use == 0


# ------------------------------- chunked prefill / stream equivalence -----
def _trace(cfg, n=4, seed=0, max_prompt=6):
    return generate_fleet_requests("nano*1,agx*1", num_requests=n,
                                   max_prompt=max_prompt, seed=seed,
                                   short_new=(3, 5), long_new=(9, 12),
                                   long_frac=0.5, vocab_size=cfg.vocab_size)


def _assert_streams_greedy_consistent(cfg, params, requests, sequences):
    """Each stream must be self-consistent under ONE full lm.forward over
    prompt + generated tokens (exact for greedy by the prefix property)."""
    from repro.models import lm
    for r in requests:
        stream = sequences[r.rid]
        toks = np.concatenate([r.prompt, np.asarray(stream, np.int32)])
        logits, _, _ = lm.forward(params, cfg, jnp.asarray(toks)[None],
                                  positions=jnp.arange(len(toks)))
        plen = len(r.prompt)
        for i, tok in enumerate(stream):
            assert int(jnp.argmax(logits[0, plen - 1 + i])) == tok, \
                (r.rid, i)


def test_chunked_equals_monolithic_and_oracle(dense_setup):
    cfg, params = dense_setup
    reqs = _trace(cfg)
    base = dict(params=params, slots=2, block_size=4, max_context=12,
                requests=reqs, log_fn=None)
    mono = serve_continuous(cfg, prefill="monolithic", **base)
    assert mono["prefills"] > 0 and mono["prefill_chunks"] == 0
    for chunk in (3, 16):        # uneven chunking and one-shot chunking
        ch = serve_continuous(cfg, prefill="chunked", prefill_chunk=chunk,
                              **base)
        assert ch["sequences"] == mono["sequences"], chunk
        assert ch["prefills"] == 0 and ch["prefill_chunks"] > 0
    _assert_streams_greedy_consistent(cfg, params, reqs, mono["sequences"])


def test_chunked_int8_fidelity(dense_setup):
    """The int8 drift contract holds through the chunked prefill path."""
    cfg, params = dense_setup
    reqs = _trace(cfg, n=3, seed=2)
    rep = serve_continuous(cfg, params=params, prefill="chunked",
                           prefill_chunk=4, requests=reqs, slots=2,
                           block_size=4, max_context=12, log_fn=None)
    fid = int8_cache_fidelity(cfg, params, reqs, rep["sequences"],
                              block_size=4, max_context=12,
                              prefill="chunked", prefill_chunk=4)
    assert fid["max_logit_drift"] < 0.15
    assert fid["disagreement"] <= 0.15


def test_prefill_burst_keeps_decode_lanes_live(dense_setup):
    """8-request burst: at most ONE prefill unit per step in either mode,
    decode lanes keep emitting while later arrivals are still
    prefilling, and the two modes agree on every stream."""
    cfg, params = dense_setup
    spec = PagedCacheSpec.for_requests(4, 16, block_size=4)
    eng = PagedEngine(cfg, spec, max_context=8, slots=4)

    def mk():
        rng = np.random.default_rng(1)
        return [ServeRequest(rid=i,
                             prompt=rng.integers(1, cfg.vocab_size,
                                                 (6,)).astype(np.int32),
                             max_new_tokens=8) for i in range(8)]

    streams = {}
    for mode, kw in (("chunked", dict(prefill_chunk=2)), ("monolithic", {})):
        sched = ContinuousScheduler(eng, params, prefill=mode, **kw)
        for r in mk():
            sched.submit(r)
        overlap, prev_units = 0, 0
        for step in range(400):
            emitted = sched.step(float(step))
            units = sched.prefills_run + sched.prefill_chunks_run
            assert units - prev_units <= 1, (mode, step)
            prev_units = units
            still = any(sched.active[i] is not None
                        and not sched.prefill_done[i]
                        for i in range(sched.slots))
            if emitted > 0 and still:
                overlap += 1
            if sched.idle:
                break
        assert sched.idle and len(sched.finished) == 8
        assert overlap > 0, mode           # decode ran during the burst
        assert sched.allocator.in_use == 0
        streams[mode] = {r.rid: list(r.tokens) for r in sched.finished}
    assert streams["chunked"] == streams["monolithic"]


def test_chunked_lifts_max_context_submit_limit(dense_setup):
    """Chunked mode accepts prompts beyond the monolithic prefill bucket
    (bounded only by table capacity) and still streams correctly."""
    cfg, params = dense_setup
    spec = PagedCacheSpec.for_requests(1, 24, block_size=4)
    eng = PagedEngine(cfg, spec, max_context=8, slots=1)
    rng = np.random.default_rng(5)
    long_prompt = rng.integers(1, cfg.vocab_size, (14,)).astype(np.int32)

    mono = ContinuousScheduler(eng, params, prefill="monolithic")
    with pytest.raises(ValueError):        # 14 > max_context=8
        mono.submit(ServeRequest(rid=0, prompt=long_prompt,
                                 max_new_tokens=4))
    ch = ContinuousScheduler(eng, params, prefill="chunked",
                             prefill_chunk=8)
    with pytest.raises(ValueError):        # 22+4 > 24-token table
        ch.submit(ServeRequest(rid=1,
                               prompt=rng.integers(
                                   1, cfg.vocab_size,
                                   (22,)).astype(np.int32),
                               max_new_tokens=4))
    req = ServeRequest(rid=0, prompt=long_prompt, max_new_tokens=4)
    done = ch.run_to_completion([req])
    assert len(done) == 1 and len(done[0].tokens) == 4
    _assert_streams_greedy_consistent(cfg, params, [req],
                                      {0: list(done[0].tokens)})


def test_moe_family_through_scheduler():
    """MoE configs serve through the chunked continuous scheduler (smoke
    + determinism only: capacity routing is cross-token, so chunked-vs-
    monolithic equivalence is pinned to the dense family)."""
    from repro.models import lm
    cfg = _smoke_cfg("qwen3_moe_30b_a3b")
    params = lm.init(KEY, cfg)
    reqs = _trace(cfg, n=3, seed=1)
    kw = dict(params=params, prefill="chunked", prefill_chunk=4,
              requests=reqs, slots=2, block_size=4, max_context=12,
              log_fn=None)
    a = serve_continuous(cfg, **kw)
    b = serve_continuous(cfg, **kw)
    assert a["requests"] == 3 and a["total_new_tokens"] > 0
    assert a["sequences"] == b["sequences"]


# ----------------------------------------- pod prefix-cache sharing -------
def test_prefix_sharing_streams_and_block_immutability(dense_setup):
    """Prefix sharing must not change any stream, and registered template
    blocks must be bit-identical after other requests mapped them
    (shared blocks are read-only; the CoW path covers the whole-prompt
    case)."""
    cfg, params = dense_setup
    rng = np.random.default_rng(9)
    template = rng.integers(1, cfg.vocab_size, (8,)).astype(np.int32)
    sfx = [rng.integers(1, cfg.vocab_size, (2,)).astype(np.int32)
           for _ in range(2)]

    def mk():
        return [
            ServeRequest(rid=0, prompt=np.concatenate([template, sfx[0]]),
                         max_new_tokens=4),
            ServeRequest(rid=1, prompt=np.concatenate([template, sfx[1]]),
                         max_new_tokens=4),
            ServeRequest(rid=2, prompt=template.copy(),   # CoW: whole
                         max_new_tokens=4),                # prompt cached
        ]

    spec = PagedCacheSpec.for_requests(2, 16, block_size=4, headroom=4)
    eng = PagedEngine(cfg, spec, max_context=12, slots=2)

    base = ContinuousScheduler(eng, params, prefill="chunked",
                               prefill_chunk=4)
    want = {r.rid: list(r.tokens)
            for r in base.run_to_completion(mk())}

    sched = ContinuousScheduler(eng, params, prefill="chunked",
                                prefill_chunk=4, prefix_cache=True)
    reqs = mk()
    first = sched.run_to_completion([reqs[0]])
    assert sched.prefix.registered_blocks == 2    # template = 2 full blocks
    reg = sorted(set(sched.prefix._map.values()))
    snap = np.asarray(sched.pools["k"])[:, :, reg].copy()

    rest = sched.run_to_completion(reqs[1:])
    got = {r.rid: list(r.tokens) for r in first + rest}
    assert got == want
    assert sched.prefix.hits >= 2                 # rid 1 shares, rid 2 CoWs
    assert sched.prefix.shared_blocks > 0
    # registered template blocks were mapped, never rewritten
    assert np.array_equal(snap, np.asarray(sched.pools["k"])[:, :, reg])
    # after drain only the registry holds blocks
    assert sched.allocator.in_use == sched.prefix.registered_blocks


def test_pod_trace_prefix_report(dense_setup):
    cfg, params = dense_setup
    reqs = generate_pod_requests("nano*1,agx*1", num_requests=6, pods=1,
                                 template_len=8, max_suffix=4, seed=0,
                                 short_new=(3, 4), long_new=(5, 6),
                                 long_frac=0.5, vocab_size=cfg.vocab_size)
    base = dict(params=params, prefill="chunked", prefill_chunk=4,
                requests=reqs, slots=2, block_size=4, max_context=16,
                log_fn=None)
    on = serve_continuous(cfg, prefix_cache=True, **base)
    off = serve_continuous(cfg, prefix_cache=False, **base)
    assert on["sequences"] == off["sequences"]
    assert on["prefix_hits"] > 0 and on["prefix_blocks_saved"] > 0
    assert 0 < on["prefix_hit_rate"] <= 1
    assert "prefix_hits" not in off
    # sharing strictly reduces the prefill work actually issued
    assert on["prefill_padded_tokens"] < off["prefill_padded_tokens"]


def test_ttft_and_queue_wait_in_report(dense_setup):
    cfg, params = dense_setup
    rep = serve_continuous(cfg, params=params, requests=_trace(cfg),
                           slots=2, block_size=4, max_context=12,
                           prefill_cost=PrefillCostModel(), log_fn=None)
    assert 0 < rep["p50_ttft_s"] <= rep["p50_latency_s"]
    assert rep["p99_ttft_s"] >= rep["p50_ttft_s"]
    assert rep["p99_queue_wait_s"] >= rep["p50_queue_wait_s"] >= 0
    assert rep["p50_ttft_s"] >= rep["p50_queue_wait_s"]


# ------------------------------------- speculative decoding (draft-verify)
def _spec_trace(cfg, n=6, seed=3):
    return generate_pod_requests("nano*1,agx*1", num_requests=n, pods=2,
                                 template_len=8, max_suffix=4, seed=seed,
                                 short_new=(3, 6), long_new=(8, 12),
                                 long_frac=0.4, vocab_size=cfg.vocab_size)


@pytest.mark.parametrize("cache", ["fp32", "int8"])
def test_speculative_streams_bit_identical(dense_setup, cache):
    """Draft-verify speculation must not change a single emitted token —
    self-drafting (acceptance 1.0) and an unrelated random draft
    (acceptance ~0, every speculative step rolls back) both reproduce
    the non-speculative greedy streams bitwise, in fp32 AND int8 cache
    mode, while speculation still wins sim time at high acceptance."""
    from repro.models import lm
    from repro.serve import SpecDecodeCostModel
    cfg, params = dense_setup
    common = dict(params=params, slots=2, block_size=4, max_context=16,
                  prefill="chunked", prefill_chunk=4, prefix_cache=True,
                  cache=cache, requests=_spec_trace(cfg), log_fn=None,
                  warm_passes=1)
    base = serve_continuous(cfg, prefill_cost=PrefillCostModel(), **common)
    spec = serve_continuous(cfg, speculative=True, draft_k=3,
                            prefill_cost=SpecDecodeCostModel(), **common)
    assert spec["sequences"] == base["sequences"]
    assert spec["spec_steps"] > 0
    assert spec["acceptance_rate"] == 1.0       # self-draft agrees always
    assert spec["decode_steps"] < base["decode_steps"]
    assert spec["sim_time_s"] < base["sim_time_s"]
    # unrelated draft weights: every draft rejected, rollback must leave
    # the pools indistinguishable from never having drafted -> streams
    # still bitwise equal (a single corrupt K/V row would cascade)
    rej = serve_continuous(cfg, speculative=True, draft_k=3,
                           draft_params=lm.init(jax.random.PRNGKey(7), cfg),
                           prefill_cost=SpecDecodeCostModel(), **common)
    assert rej["sequences"] == base["sequences"]
    assert rej["acceptance_rate"] < 0.2
    assert rej["proposed_drafts"] > 0


def test_speculative_validation(dense_setup):
    cfg, params = dense_setup
    spec = PagedCacheSpec.for_requests(1, 16, block_size=4)
    eng = PagedEngine(cfg, spec, max_context=8, slots=1)
    with pytest.raises(ValueError):             # greedy-only by definition
        ContinuousScheduler(eng, params, speculative=True,
                            sampling="temperature")
    with pytest.raises(ValueError):             # resume needs chunked
        ContinuousScheduler(eng, params, prefill="monolithic",
                            preemption=True)
    with pytest.raises(ValueError):             # draft_k >= 1
        ContinuousScheduler(eng, params, speculative=True, draft_k=0)
    # speculative + monolithic is allowed, preemption just defaults off
    s = ContinuousScheduler(eng, params, speculative=True,
                            prefill="monolithic")
    assert s.speculative and not s.preemption


def _rollback_cycle(salt, quantized):
    """Draft-append-then-reject cycle must restore the pools bitwise
    (fp32 and int8 — codes AND scales); a partial accept restores
    exactly the rejected tail while leaving accepted rows."""
    from repro.config import ModelConfig
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=8,
                      num_heads=2, num_kv_heads=1, d_ff=16, vocab_size=32,
                      param_dtype="float32")
    spec = PagedCacheSpec(num_blocks=5, block_size=4, max_blocks_per_req=4,
                          quantized=quantized)
    rng = np.random.default_rng(salt)
    pools = KC.init_pools(cfg, spec)
    if quantized:
        pools = {
            "k": jnp.asarray(rng.integers(-127, 128, pools["k"].shape),
                             jnp.int8),
            "v": jnp.asarray(rng.integers(-127, 128, pools["v"].shape),
                             jnp.int8),
            "k_scale": jnp.asarray(rng.random(pools["k_scale"].shape),
                                   jnp.float32),
            "v_scale": jnp.asarray(rng.random(pools["v_scale"].shape),
                                   jnp.float32)}
    else:
        pools = {k: jnp.asarray(rng.standard_normal(p.shape), p.dtype)
                 for k, p in pools.items()}
    before = {k: np.asarray(p).copy() for k, p in pools.items()}

    # a draft window somewhere in blocks 1..4
    w = int(rng.integers(1, 9))
    start = int(rng.integers(0, 16 - w))
    pos = np.arange(start, start + w)
    phys = jnp.asarray(1 + pos // spec.block_size, jnp.int32)
    off = jnp.asarray(pos % spec.block_size, jnp.int32)

    saved = KC.gather_rows(pools, phys, off)
    garbage = {k: jnp.asarray(rng.standard_normal(r.shape), r.dtype)
               if not np.issubdtype(np.asarray(r).dtype, np.integer)
               else jnp.asarray(rng.integers(-127, 128, r.shape), r.dtype)
               for k, r in saved.items()}
    pools = KC.scatter_rows(pools, garbage, phys, off)   # the draft append
    assert any(not np.array_equal(np.asarray(pools[k]), before[k])
               for k in pools)

    accepted = int(rng.integers(0, w + 1))
    # kept positions redirect to the null block: garbage lands in block 0
    keep = np.arange(w) < accepted
    r_phys = jnp.asarray(np.where(keep, 0, np.asarray(phys)), jnp.int32)
    r_off = jnp.asarray(np.where(keep, 0, np.asarray(off)), jnp.int32)
    pools = KC.scatter_rows(pools, saved, r_phys, r_off)
    for k in pools:
        got = np.asarray(pools[k])
        # expected pool: pristine everywhere except the accepted rows,
        # which keep the drafted values (their tokens were emitted)
        want = before[k].copy()
        if accepted:
            ap, ao = np.asarray(phys)[:accepted], np.asarray(off)[:accepted]
            want[:, :, ap, ao] = np.asarray(garbage[k])[:, :, :accepted]
        # block 0 is garbage by contract; everything else must be exact
        assert np.array_equal(got[:, :, 1:], want[:, :, 1:]), k


@pytest.mark.parametrize("quantized", [False, True])
def test_draft_rollback_bitwise_walk(quantized):
    rng = np.random.default_rng(11)
    for _ in range(20):
        _rollback_cycle(int(rng.integers(0, 1 << 20)), quantized)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 1 << 20), st.booleans())
def test_draft_rollback_bitwise_property(salt, quantized):
    _rollback_cycle(salt, quantized)


def test_prefix_evict_never_drops_shared_blocks():
    """Satellite regression: ``PrefixCache.evict`` must skip any block a
    live request still holds (refcount > 1) — evicting it would hand a
    mapped, readable block back to the allocator for reuse."""
    spec = PagedCacheSpec(num_blocks=12, block_size=4, max_blocks_per_req=4)
    alloc = BlockAllocator(spec)
    pc = PrefixCache(alloc)
    prompt = np.arange(1, 9, dtype=np.int32)       # 2 full blocks
    blocks = alloc.alloc(2)
    pc.insert(prompt, blocks + [0, 0])
    shared, cow, resume = pc.match(prompt[:8])     # CoW hold on block 1
    held = shared + [cow]
    assert alloc.refcount(blocks[0]) == 3          # request+registry+match
    assert pc.evict(10) == 0                       # all entries are shared
    assert len(pc) == 2 and alloc.refcount(blocks[0]) == 3
    alloc.release(held)
    alloc.release(blocks)                          # the request retires
    assert pc.evict(10) == 2                       # now registry-only
    assert alloc.in_use == 0


def test_preemption_resume_exact(dense_setup):
    """A tight pool + a later-but-tighter-deadline arrival preempts the
    live lane; the victim's resume replays through the prefix cache and
    its stream stays bit-identical to an unpressured run."""
    cfg, params = dense_setup
    rng = np.random.default_rng(4)
    pa = rng.integers(1, cfg.vocab_size, (6,)).astype(np.int32)
    pb = rng.integers(1, cfg.vocab_size, (6,)).astype(np.int32)

    def mk():
        return [ServeRequest(rid=0, prompt=pa.copy(), max_new_tokens=8,
                             deadline_s=100.0),
                ServeRequest(rid=1, prompt=pb.copy(), max_new_tokens=4,
                             deadline_s=1.0)]

    spec = PagedCacheSpec.for_requests(2, 16, block_size=4)
    eng = PagedEngine(cfg, spec, max_context=8, slots=2)
    oracle = ContinuousScheduler(eng, params, prefill="chunked",
                                 prefill_chunk=4, prefix_cache=True)
    want = {r.rid: list(r.tokens) for r in oracle.run_to_completion(mk())}

    # each request needs 4 blocks; a 5-block cap cannot host both
    sched = ContinuousScheduler(eng, params, prefill="chunked",
                                prefill_chunk=4, prefix_cache=True,
                                preemption=True, max_inflight_blocks=5)
    ra, rb = mk()
    sched.submit(ra)
    for step in range(4):               # admit + prefill A, decode a bit
        sched.step(float(step))
        sched.flush_trace(step + 1.0)
    assert len(ra.tokens) > 0 and not sched.idle
    sched.submit(rb)
    steps = 4
    while not sched.idle:
        sched.step(float(steps))
        sched.flush_trace(steps + 1.0)
        steps += 1
        assert steps < 200
    got = {r.rid: list(r.tokens) for r in sched.finished}
    assert got == want
    assert sched.preemptions == 1
    assert [r.rid for r in sched.finished] == [1, 0]   # B jumped the line
    # the victim's re-registered chain is what remains allocated
    assert sched.allocator.in_use == sched.prefix.registered_blocks
    m = sched.metrics.snapshot()["metrics"]
    assert m["serve_preemptions"]["series"][0]["value"] == 1.0
    # without a strictly-lower-priority victim nothing is preempted: the
    # same pressure with deadlines flipped just queues the newcomer
    s2 = ContinuousScheduler(eng, params, prefill="chunked",
                             prefill_chunk=4, prefix_cache=True,
                             preemption=True, max_inflight_blocks=5)
    ra2 = ServeRequest(rid=0, prompt=pa.copy(), max_new_tokens=8,
                       deadline_s=1.0)
    rb2 = ServeRequest(rid=1, prompt=pb.copy(), max_new_tokens=4,
                       deadline_s=100.0)
    s2.submit(ra2)
    for step in range(4):
        s2.step(float(step))
        s2.flush_trace(step + 1.0)
    s2.submit(rb2)
    steps = 4
    while not s2.idle:
        s2.step(float(steps))
        s2.flush_trace(steps + 1.0)
        steps += 1
        assert steps < 200
    assert s2.preemptions == 0
    assert [r.rid for r in s2.finished] == [0, 1]


def test_unstarted_request_report_none(dense_setup):
    """Satellite: a request that never produced a token reports None for
    ttft/queue-wait (not stale zeros), and the loadgen's deadline hit
    rate scores only requests that started."""
    cfg, params = dense_setup
    r = ServeRequest(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                     max_new_tokens=2)
    assert r.ttft_s is None and r.queue_wait_s is None
    assert r.latency_s is None and not r.met_deadline
    rep = serve_continuous(cfg, params=params, requests=_trace(cfg),
                           slots=2, block_size=4, max_context=12,
                           prefill_cost=PrefillCostModel(), log_fn=None)
    assert rep["unstarted_requests"] == 0       # a drained trace all ran
    assert 0 <= rep["deadline_hit_rate"] <= 1


# ----------------------------------------------------- session plumbing ---
def test_session_serve_continuous_smoke():
    from repro.api import MeshSpec, Session
    ses = Session("flad-adllm", strategy="tensor",
                  mesh=MeshSpec((1,), axes=("data",), devices=1))
    out = ses.serve(scheduler="continuous", requests=3, batch=2,
                    context=12, block_size=4, max_prompt=6,
                    short_new=(3, 4), long_new=(6, 8), log_fn=None)
    assert out["requests"] == 3
    assert out["total_new_tokens"] > 0
    assert out["warm_tokens_per_s"] > 0
    spec = ses.serve(scheduler="continuous", requests=3, batch=2,
                     context=12, block_size=4, max_prompt=6,
                     short_new=(3, 4), long_new=(6, 8),
                     speculative=True, draft_k=2, log_fn=None)
    assert spec["sequences"] == out["sequences"]  # bit-identical via API too
    assert spec["acceptance_rate"] == 1.0         # default self-draft
    with pytest.raises(ValueError):
        ses.serve(scheduler="bogus")
    with pytest.raises(ValueError):
        ses.serve(speculative=True)               # legacy can't speculate
    with pytest.raises(ValueError):
        ses.serve(scheduler="continuous", draft_pod=0)  # needs speculative
    with pytest.raises(ValueError):               # tensor has no pod view
        ses.serve(scheduler="continuous", speculative=True, draft_pod=0)


def test_legacy_serve_sampling():
    from repro.api.serving import serve_requests
    cfg = _smoke_cfg()
    kw = dict(batch=2, context=8, decode_steps=3, requests=1, log_fn=None)
    g1 = serve_requests(cfg, key=jax.random.PRNGKey(5), **kw)
    g2 = serve_requests(cfg, key=jax.random.PRNGKey(5), **kw)
    assert jnp.array_equal(g1["sequences"][0], g2["sequences"][0])
    t1 = serve_requests(cfg, key=jax.random.PRNGKey(5),
                        sampling="temperature", temperature=1.5, **kw)
    t2 = serve_requests(cfg, key=jax.random.PRNGKey(5),
                        sampling="temperature", temperature=1.5, **kw)
    t3 = serve_requests(cfg, key=jax.random.PRNGKey(6),
                        sampling="temperature", temperature=1.5, **kw)
    assert jnp.array_equal(t1["sequences"][0], t2["sequences"][0])
    assert not jnp.array_equal(t1["sequences"][0], t3["sequences"][0])
    assert "warm_tokens_per_s" in g1 and g1["warm_tokens_per_s"] > 0
    with pytest.raises(ValueError):
        serve_requests(cfg, sampling="nucleus", **kw)
