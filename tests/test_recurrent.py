"""Chunked recurrent cells vs step-by-step references (hypothesis sweeps)."""
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.configs import get_config
from repro.configs.common import reduced
from repro.models import recurrent as R


@pytest.fixture(scope="module")
def xcfg():
    return reduced(get_config("xlstm_350m"))


@pytest.fixture(scope="module")
def hcfg():
    return reduced(get_config("hymba_1_5b"))


@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([16, 48, 96]), chunk=st.sampled_from([8, 16, 256]),
       seed=st.integers(0, 5))
def test_mlstm_chunked_matches_stepwise(s, chunk, seed):
    cfg = reduced(get_config("xlstm_350m"))
    key = jax.random.PRNGKey(seed)
    p = R.init_mlstm(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 10),
                          (2, s, cfg.d_model), jnp.float32)
    st_ = R.init_mlstm_state(cfg, 2)
    ys = []
    ref_state = st_
    for t in range(s):
        y, ref_state = R.apply_mlstm_step(p, x[:, t:t + 1], ref_state, cfg)
        ys.append(y)
    ref = jnp.concatenate(ys, axis=1)
    got, fin = R.apply_mlstm_seq(p, x, cfg, chunk=chunk)
    assert float(jnp.max(jnp.abs(got - ref))) < 5e-5
    assert float(jnp.max(jnp.abs(fin["C"] - ref_state["C"]))) < 5e-6


@settings(max_examples=6, deadline=None)
@given(s=st.sampled_from([16, 64]), chunk=st.sampled_from([8, 64]),
       seed=st.integers(0, 3))
def test_mamba_chunked_matches_stepwise(s, chunk, seed):
    cfg = reduced(get_config("hymba_1_5b"))
    key = jax.random.PRNGKey(seed)
    p = R.init_mamba(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 20),
                          (2, s, cfg.d_model), jnp.float32)
    stt = R.init_mamba_state(cfg, 2)
    ys = []
    for t in range(s):
        y, stt = R.apply_mamba_step(p, x[:, t:t + 1], stt, cfg)
        ys.append(y)
    ref = jnp.concatenate(ys, axis=1)
    got, fin = R.apply_mamba_seq(p, x, cfg, chunk=chunk)
    assert float(jnp.max(jnp.abs(got - ref))) < 5e-5
    assert float(jnp.max(jnp.abs(fin["h"] - stt["h"]))) < 1e-6


def test_slstm_seq_matches_stepwise(xcfg):
    key = jax.random.PRNGKey(0)
    p = R.init_slstm(key, xcfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, xcfg.d_model))
    stt = R.init_slstm_state(xcfg, 2)
    ys = []
    for t in range(32):
        y, stt = R.apply_slstm_step(p, x[:, t:t + 1], stt, xcfg)
        ys.append(y)
    ref = jnp.concatenate(ys, axis=1)
    got, fin = R.apply_slstm_seq(p, x, xcfg, chunk=8)
    assert float(jnp.max(jnp.abs(got - ref))) < 5e-5
    assert float(jnp.max(jnp.abs(fin["c"] - stt["c"]))) < 1e-4


def test_mlstm_state_carryover(xcfg):
    """Processing [a; b] equals processing a then b with carried state."""
    key = jax.random.PRNGKey(0)
    p = R.init_mlstm(key, xcfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, xcfg.d_model))
    full, _ = R.apply_mlstm_seq(p, x, xcfg, chunk=16)
    h1, st1 = R.apply_mlstm_seq(p, x[:, :32], xcfg, chunk=16)
    h2, _ = R.apply_mlstm_seq(p, x[:, 32:], xcfg, state=st1, chunk=16)
    err = jnp.max(jnp.abs(jnp.concatenate([h1, h2], 1) - full))
    assert float(err) < 5e-5
