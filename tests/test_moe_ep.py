"""Expert-parallel MoE (shard_map + all_to_all) vs the dense GSPMD oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.common import reduced
from repro.core.moe_ep import moe_block_ep
from repro.models import blocks as B


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("qwen3_moe_30b_a3b"))
    key = jax.random.PRNGKey(0)
    p = B.init_moe(key, cfg)
    return cfg, p


def test_ep_matches_dense_dispatch(setup, mesh24):
    """With ample capacity both dispatches route every token to the same
    experts with the same gates -> identical outputs."""
    cfg, p = setup
    cfg = cfg.replace(moe=cfg.moe.__class__(
        num_experts=4, top_k=2, d_expert=64, capacity_factor=8.0))
    key = jax.random.PRNGKey(1)
    p = B.init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, cfg.d_model),
                          jnp.float32)
    ref, ref_aux = B.moe_block(p, x, cfg)
    got, aux = jax.jit(
        lambda p, x: moe_block_ep(p, x, cfg, mesh=mesh24,
                                  seq_sharded=True))(p, x)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4
    # aux is a per-shard mean of means; same ballpark, not bit-equal
    assert abs(float(aux) - float(ref_aux)) < 0.1


def test_ep_grads_flow(setup, mesh24):
    cfg, p = setup
    cfg = cfg.replace(moe=cfg.moe.__class__(
        num_experts=4, top_k=2, d_expert=64, capacity_factor=4.0))
    p = B.init_moe(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, cfg.d_model),
                          jnp.float32)

    def loss(p):
        y, aux = moe_block_ep(p, x, cfg, mesh=mesh24, seq_sharded=True)
        return (y ** 2).mean() + aux

    g = jax.grad(loss)(p)
    gn = sum(float(jnp.sum(v.astype(jnp.float32) ** 2))
             for v in jax.tree.leaves(g))
    assert gn > 0 and jnp.isfinite(gn)


def test_ep_decode_shape(setup, mesh24):
    """Tiny token counts (decode) still route without dropping (capacity
    floor)."""
    cfg, p = setup
    cfg = cfg.replace(moe=cfg.moe.__class__(
        num_experts=4, top_k=2, d_expert=64, capacity_factor=1.25))
    p = B.init_moe(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 1, cfg.d_model),
                          jnp.float32)
    ref, _ = B.moe_block(p, x, cfg)
    got, _ = jax.jit(
        lambda p, x: moe_block_ep(p, x, cfg, mesh=mesh24,
                                  seq_sharded=False))(p, x)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-4
