"""Trace an asynchronous FL run onto the simulated clock (repro.obs).

Runs a few clocked ``async_hier_fl`` rounds — compute jitter on, DTMC
mobility on — with a :class:`repro.obs.Tracer` attached, and writes a
Chrome trace-event / Perfetto JSON file plus a metrics-registry
snapshot. Load the trace at https://ui.perfetto.dev (or
``chrome://tracing``): one track per vehicle (compute + uplink spans),
one per edge pod (backhaul spans), one for the cloud (merge marks and
deadline ticks), with flow arrows following each update from the
vehicle through its pod commit into the cloud merge.

Timestamps are the engine's simulated seconds — the same numbers as the
event log — so the picture shows straggler gaps and comm/compute overlap
exactly as the timing models scored them. Attaching the tracer does not
perturb the run: params and event log are bitwise those of an untraced
run (tests/test_obs.py pins this).

Runs on CPU in ~1 minute:
    PYTHONPATH=src python examples/traced_async_round.py
"""
import argparse
import json
import os

from repro.api import LoopHooks, Session

#: keep the committed sample loadable at a glance — a few rounds of a
#: 4-vehicle fleet is ~10 KB; anything near this cap means runaway spans
MAX_TRACE_BYTES = 256 * 1024


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--clock", type=float, default=0.4,
                    help="cloud merge period (simulated s)")
    ap.add_argument("--out", default="/tmp/traced_async_round.json",
                    help="trace output path (metrics snapshot lands "
                         "next to it as *.metrics.json)")
    args = ap.parse_args()

    hooks = LoopHooks(log_every=1, log_fn=lambda *a, **k: None)
    session = Session("flad-vision", strategy="async_hier_fl", mesh=(1,),
                      shape="8x8", topology="2@nano*2,agx*2", codec="int8",
                      local_steps=2, clock=args.clock, compute_jitter=0.2,
                      migrate_every=1.0, seed=7)
    metrics_path = os.path.splitext(args.out)[0] + ".metrics.json"
    out = session.run(args.rounds, hooks=hooks, trace=args.out,
                      metrics=metrics_path)

    size = os.path.getsize(out["trace_path"])
    if size > MAX_TRACE_BYTES:
        raise SystemExit(f"trace grew to {size} bytes "
                         f"(cap {MAX_TRACE_BYTES}) — span emission is "
                         f"leaking")
    with open(out["trace_path"]) as f:
        events = json.load(f)["traceEvents"]
    spans = sum(e["ph"] == "X" for e in events)
    flows = sum(e["ph"] == "s" for e in events)
    print(f"{out['merges']} merges in {out['sim_time_s']:.2f}s simulated "
          f"({session.strategy.engine.n_migrations} pod migrations)")
    print(f"trace: {out['trace_path']} — {len(events)} events "
          f"({spans} spans, {flows} flow arrows, {size} bytes)")
    print(f"metrics snapshot: {out['metrics_path']}")
    print("open https://ui.perfetto.dev and drop the trace file in")


if __name__ == "__main__":
    main()
