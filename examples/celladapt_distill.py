"""CELLAdapt demo (paper §5.2 / Fig. 10): distill the edge AD-LLM teacher
into a compact ADM student on waypoint outputs, then LoRA-personalize the
teacher to one region's data. Device setup goes through repro.api.

    PYTHONPATH=src python examples/celladapt_distill.py
"""
import argparse

from repro.api import ensure_host_devices

ensure_host_devices(1)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.common import reduced
from repro.data.synthetic import DrivingDataConfig, TownWorld, make_tokens
from repro.distill.celladapt import (adllm_config, adllm_waypoints,
                                     init_adllm, make_distill_step,
                                     make_finetune_step, waypoint_l1)
from repro.distill.lora import lora_param_count


def make_batch(world, dcfg, cfg, town, n, seed):
    rng = np.random.default_rng(seed)
    s = world.sample(town, n, rng)
    feats = s["rgb"][:, :cfg.prefix_tokens, :]
    toks = make_tokens(s["light"], town, 32, cfg.vocab_size, rng)
    return {"features": jnp.asarray(feats), "tokens": jnp.asarray(toks),
            "waypoints": jnp.asarray(s["waypoints"])}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    base = reduced(get_config("flad-adllm"))
    tcfg = adllm_config(base, feature_dim=64, feature_tokens=16,
                        num_waypoints=10)
    scfg = tcfg.replace(num_layers=1, d_ff=128)   # the compact ADM
    dcfg = DrivingDataConfig(feature_dim=64, patches=16, num_waypoints=10)
    world = TownWorld(dcfg)

    key = jax.random.PRNGKey(0)
    teacher = init_adllm(key, tcfg)
    student = init_adllm(jax.random.PRNGKey(1), scfg)

    # give the teacher some waypoint skill first (supervised warmup)
    from repro.train.optimizer import Adam
    topt = Adam(lr=2e-3)
    tstate = topt.init(teacher)

    @jax.jit
    def tstep(tp, st, batch):
        def loss(tp):
            wp = adllm_waypoints(tp, tcfg, batch["features"],
                                 batch["tokens"])
            return waypoint_l1(wp, batch["waypoints"])
        l, g = jax.value_and_grad(loss)(tp)
        tp, st = topt.update(g, st, tp)
        return tp, st, l

    for i in range(args.steps):
        b = make_batch(world, dcfg, tcfg, town=i % 2, n=16, seed=i)
        teacher, tstate, tl = tstep(teacher, tstate, b)
    print(f"teacher waypoint L1 after warmup: {float(tl):.4f}")

    # 1) edge distillation: teacher -> student on waypoint outputs
    dstep, dopt = make_distill_step(tcfg, scfg, lr=2e-3)
    dstate = dopt.init(student)
    for i in range(args.steps):
        b = make_batch(world, dcfg, tcfg, town=i % 2, n=16, seed=1000 + i)
        student, dstate, dl = dstep(student, dstate, teacher, b)
    print(f"student/teacher waypoint L1 after distillation: {float(dl):.4f}")

    # student quality vs ground truth
    b = make_batch(world, dcfg, tcfg, town=0, n=64, seed=7)
    s_wp = adllm_waypoints(student, scfg, b["features"], b["tokens"])
    print(f"student ground-truth L1: "
          f"{float(waypoint_l1(s_wp, b['waypoints'])):.4f}")

    # 2) LoRA personalization of the teacher to town 3 (unseen region)
    fstep, lora, fopt = make_finetune_step(tcfg, teacher, lr=5e-3)
    fstate = fopt.init(lora)
    b3 = make_batch(world, dcfg, tcfg, town=3, n=64, seed=11)
    wp_pre = adllm_waypoints(teacher, tcfg, b3["features"], b3["tokens"])
    pre = float(waypoint_l1(wp_pre, b3["waypoints"]))
    for i in range(args.steps):
        bt = make_batch(world, dcfg, tcfg, town=3, n=16, seed=2000 + i)
        lora, fstate, fl = fstep(lora, fstate, bt)
    from repro.distill.lora import LoRAConfig, merge_lora
    merged = merge_lora(teacher, lora, LoRAConfig())
    wp_post = adllm_waypoints(merged, tcfg, b3["features"], b3["tokens"])
    post = float(waypoint_l1(wp_post, b3["waypoints"]))
    n_lora = lora_param_count(lora)
    n_full = sum(x.size for x in jax.tree.leaves(teacher))
    print(f"LoRA personalization (town 3): L1 {pre:.4f} -> {post:.4f} "
          f"training {n_lora}/{n_full} = {100*n_lora/n_full:.2f}% of params")


if __name__ == "__main__":
    main()
