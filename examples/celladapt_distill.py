"""CELLAdapt demo (paper §3.3/§5.2, Fig. 10): federated personalized
distillation through the ``distill_fl`` Session strategy — the same code
path the launcher, tests, and benchmarks run.

One Session stands up the whole loop: supervised warmup of the cloud
AD-LLM (which then freezes as the teacher), per-pod LoRA students
trained with the KD loss on their pod's non-IID town partition, and
int8-compressed (A, B) adapter deltas riding the vehicle->edge->cloud
fabric. Afterwards the demo compares each pod's personalized model
against the cloud-merged global model on that pod's held-out split, and
prints what a round actually put on the wire.

    PYTHONPATH=src python examples/celladapt_distill.py
"""
import argparse

from repro.api import ensure_host_devices

ensure_host_devices(2)

import jax

from repro.api import LoopHooks, Session
from repro.api.strategies import get_strategy
from repro.distill.federated import waypoint_eval
from repro.distill.lora import lora_param_count


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--mix", type=float, default=0.25,
                    help="blend toward the cloud merge (1 = global "
                         "FedAvg-of-adapters, 0 = fully local)")
    args = ap.parse_args()

    sess = Session("flad-adllm", shape="16x8", mesh=(2,),
                   strategy="distill_fl", learning_rate=3e-2, seed=0,
                   hooks=LoopHooks(log_every=2), topology="2@nano*2",
                   codec="int8", local_steps=2, lora_rank=4,
                   kd_weight=0.1, mix=args.mix, warmup_steps=30,
                   beta=0.05, samples_per_vehicle=128, heldout=64)
    sess.run(args.rounds)
    st = sess.strategy

    wh = st.warmup_history
    print(f"\nteacher warmup: supervised waypoint L1 "
          f"{wh[0]:.4f} -> {wh[-1]:.4f} over {len(wh)} steps (frozen)")

    # adapter size: what each vehicle trains and uplinks vs the full model
    factors0 = jax.tree.map(lambda x: x[0], sess.state[0]["factors"])
    n_lora = lora_param_count(factors0)
    n_full = sum(x.size for x in jax.tree.leaves(sess.state[0]["base"]))
    cs = st.comm_stats
    full = get_strategy("hier_fl", topology="2@nano*2",
                        codec="int8")._round_stats(sess.cfg)
    print(f"adapter: {n_lora}/{n_full} params "
          f"({100 * n_lora / n_full:.2f}%), uplink "
          f"{cs['uplink_bytes']} B/round vs {full['uplink_bytes']} B "
          f"full-delta (x{full['uplink_bytes'] / cs['uplink_bytes']:.1f} "
          f"smaller)")

    # personalization: pod student vs cloud-merged global, per pod
    acfg = st.adllm_cfg(sess.cfg)
    _, held, mixtures = st.datasets(sess.cfg, sess.shape)
    global_model = sess.merged_params()
    for e in range(len(held)):
        g = waypoint_eval(global_model, acfg, held[e])
        p = waypoint_eval(st.pod_params(sess.state, e), acfg, held[e])
        towns = ", ".join(f"{m:.2f}" for m in mixtures[e])
        print(f"pod {e} (town mix [{towns}]): waypoint L1 "
              f"global {g:.4f} -> personalized {p:.4f} "
              f"({'+' if g >= p else ''}{g - p:.4f})")


if __name__ == "__main__":
    main()
