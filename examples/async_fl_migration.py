"""Event-driven asynchronous FL with live vehicle migration.

Runs the same non-IID fleet task twice over the vehicle->edge->cloud
fabric:

  * synchronously — the cloud merges when every pod has reported, so
    every round is gated by the slowest vehicle (the two `nano`
    stragglers are ~8x slower than the `agx` pair);
  * asynchronously — the cloud merges on a fixed clock, edge pods flush
    partial aggregates instead of waiting, late commits are
    down-weighted by their **observed** staleness lag, and vehicles
    migrate between edge pods mid-run along DTMC mobility trajectories.

Both runs go through the same discrete-event engine
(`repro.comm.events`), so the simulated times are comparable: the async
run reaches the sync run's final training loss in a fraction of the
simulated time.

Runs on CPU in ~2 minutes:
    PYTHONPATH=src python examples/async_fl_migration.py
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.api import LoopHooks, Session, load_config
from repro.comm.topology import parse_topology
from repro.config import ShapeConfig
from repro.data.partition import fleet_datasets
from repro.data.pipeline import client_round_batches

TOPOLOGY = "2@nano*2,agx*2"     # pod 0 = straggler nanos, pod 1 = fast agx
COMPUTE_FLOPS = 4.7e11          # ~2.0 s/round on a nano, ~0.25 s on an agx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6,
                    help="sync rounds (the async run gets the same "
                         "simulated-time budget)")
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--clock", type=float, default=0.4,
                    help="async cloud merge period (simulated s)")
    args = ap.parse_args()

    cfg = load_config("flad-vision")
    from repro.data.synthetic import DrivingDataConfig
    dcfg = DrivingDataConfig(feature_dim=cfg.prefix_dim,
                             patches=cfg.prefix_tokens or 8,
                             num_waypoints=cfg.num_waypoints,
                             num_light_classes=cfg.num_light_classes)
    topo = parse_topology(TOPOLOGY)
    shape = ShapeConfig("async", dcfg.patches, 16, "train")
    datasets = fleet_datasets(dcfg, topo.n_clients, 256, beta=1.0)

    def round_batches(r):
        rb = client_round_batches(datasets, args.local_steps, 16,
                                  round_idx=r)
        return {k: jnp.asarray(v) for k, v in rb.items()}

    quiet = LoopHooks(log_every=1, log_fn=lambda *a, **k: None)
    sync = Session(cfg=cfg, strategy="async_hier_fl", mesh=(1,),
                   shape=shape, topology=topo, codec="int8",
                   local_steps=args.local_steps, learning_rate=2e-3,
                   compute_flops=COMPUTE_FLOPS)
    sync_out = sync.run(args.rounds, batches=round_batches, hooks=quiet)
    t_budget = sync_out["sim_time_s"]
    sync_loss = float(np.nanmean(
        sync_out["history"][-1]["per_client/loss"]))
    print(f"sync : {sync_out['merges']} rounds in {t_budget:6.2f}s "
          f"simulated (every round gated by the nano stragglers), "
          f"train loss {sync_loss:.4f}")

    # async: same time budget, merge clock + mobility-driven migration
    events = []
    hooks = LoopHooks(log_every=1, log_fn=lambda *a, **k: None,
                      on_event=lambda ev: events.append(ev.kind))
    asy = Session(cfg=cfg, strategy="async_hier_fl", mesh=(1,),
                  shape=shape, topology=topo, codec="int8",
                  local_steps=args.local_steps, learning_rate=2e-3,
                  compute_flops=COMPUTE_FLOPS, clock=args.clock,
                  compute_jitter=0.1, migrate_every=1.0, decay=0.7)
    step, (params, opt) = asy.build()
    from repro.train.loop import async_fl_loop
    out = async_fl_loop(step, params, opt, round_batches,
                        rounds=10 ** 6, hooks=hooks,
                        until_time=t_budget)
    eng = asy.strategy.engine
    losses = [float(np.nanmean(h["per_client/loss"]))
              for h in out["history"]]
    hit = next((h["t_sim"] for h, l in zip(out["history"], losses)
                if l <= sync_loss), None)
    print(f"async: {out['merges']} merges in {out['sim_time_s']:6.2f}s "
          f"simulated, {eng.n_migrations} pod migrations, "
          f"final topology {eng.topo.edges}, "
          f"train loss {losses[-1]:.4f}")
    if hit is not None:
        print(f"async reached the sync final loss at t={hit:.2f}s "
              f"simulated — {t_budget / hit:.1f}x faster than the "
              f"synchronous {t_budget:.2f}s")
    kinds = sorted(set(events))
    print(f"event kinds seen: {kinds}")


if __name__ == "__main__":
    main()
