"""Hierarchical FL over the explicit vehicle->edge->cloud fabric.

Declares a 2-edge x 2-vehicle topology from the SWIFT fleet presets,
trains FedAvg rounds three ways on the same non-IID driving data — flat
fp32, hierarchical + int8 stochastic quantization, hierarchical + top-k
sparsification — and prints what each round put on the wire and how long
the link models say it took.

Runs on CPU in ~2 minutes:
    PYTHONPATH=src python examples/hier_fl_round.py
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.api import LoopHooks, Session, load_config
from repro.comm.codecs import get_codec, tree_nbytes
from repro.comm.topology import parse_topology
from repro.config import ShapeConfig
from repro.data.partition import fleet_datasets
from repro.data.pipeline import client_round_batches
from repro.data.synthetic import DrivingDataConfig

TOPOLOGY = "2@nano*2,agx*2"       # 2 edge pods, 2 vehicles each


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--local-steps", type=int, default=2)
    args = ap.parse_args()

    cfg = load_config("flad-vision")
    dcfg = DrivingDataConfig(feature_dim=cfg.prefix_dim,
                             patches=cfg.prefix_tokens or 8,
                             num_waypoints=cfg.num_waypoints,
                             num_light_classes=cfg.num_light_classes)
    topo = parse_topology(TOPOLOGY)
    print(f"topology: {topo.n_clients} vehicles under {topo.n_edges} "
          f"edge pods {topo.edges}; backhaul "
          f"{topo.backhaul_bw / 1e9:.2f} GB/s")

    shape = ShapeConfig("hier", dcfg.patches, 16, "train")
    datasets = fleet_datasets(dcfg, topo.n_clients, 256, beta=0.3)

    def round_batches(r):
        rb = client_round_batches(datasets, args.local_steps, 16,
                                  round_idx=r)
        return {k: jnp.asarray(v) for k, v in rb.items()}

    for codec, options in (("none", {}), ("int8", {}),
                           ("topk", {"k_frac": 0.05})):
        wire = []
        hooks = LoopHooks(
            log_every=1, log_fn=lambda *a, **k: None,
            on_round=lambda r, m: wire.append(
                (float(m["comm_bytes_up"]),
                 float(m["comm_bytes_backhaul"]),
                 float(m["sim_round_s"]))))
        ses = Session(cfg=cfg, strategy="hier_fl", mesh=(1,), shape=shape,
                      topology=topo, codec=codec, codec_options=options,
                      local_steps=args.local_steps, learning_rate=2e-3)
        out = ses.run(args.rounds, batches=round_batches, hooks=hooks)
        up, bh, secs = wire[-1]
        fp32 = tree_nbytes(get_codec("none"), ses.merged_params())
        loss = float(np.mean(out["history"][-1]["per_client/loss"]))
        print(f"codec {codec:5s}: loss {loss:.4f}  "
              f"uplink {up / 1e6:7.3f} MB + backhaul {bh / 1e6:7.3f} MB "
              f"per round ({topo.n_clients * fp32 / 1e6:.3f} MB raw), "
              f"simulated round {secs * 1e3:.1f} ms")


if __name__ == "__main__":
    main()
