"""FHDP fault tolerance end to end (paper §4.2 / Fig. 5b).

Train the vision encoder through the FHDP pipeline, inject a stage
failure mid-training, recover via a pre-generated template (re-staging
the backup under a new layer split), and keep training — loss continues
to descend. Also prints the analytic recovery-time comparison.

    PYTHONPATH=src python examples/fhdp_recovery.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ShapeConfig
from repro.configs import get_config
from repro.configs.common import concrete_batch, reduced
from repro.core import pipeline as pl
from repro.core.fhdp import init_fhdp
from repro.launch.mesh import make_test_mesh
from repro.recovery.backup import EdgeBackup, restage


def main():
    mesh = make_test_mesh(data=2, model=4)
    cfg = reduced(get_config("flad-vision"))
    shape = ShapeConfig("rec", 16, 16, "train")
    key = jax.random.PRNGKey(0)

    # active template: 4 stages; preventive template: stage 2's layers
    # re-homed when its host fails (paper: template pre-generation)
    active = {"blocks": (1, 1, 0, 0)}
    after_failure = {"blocks": (2, 0, 0, 0)}

    step, h = pl.make_fhdp_train_step(cfg, shape, mesh, templates=active,
                                      learning_rate=2e-3)
    pp, opt, _ = init_fhdp(cfg, mesh, key, templates=active)
    jstep = jax.jit(step)
    backup = EdgeBackup(interval=5)

    rngs = iter(jax.random.split(key, 100))
    losses = []
    for i in range(10):
        batch = concrete_batch(cfg, shape, next(rngs))
        pp, opt, m = jstep(pp, opt, batch)
        backup.maybe_backup(i, pl.merge_stage_params(pp, active))
        losses.append(float(m["loss"]))
    print(f"pre-failure loss: {losses[0]:.4f} -> {losses[-1]:.4f}")

    # ---- stage-1 host departs: restore backup under the new template ----
    print("injecting failure of stage-1 host; deploying template",
          after_failure)
    merged, at_step = backup.restore()
    pp2 = restage(merged, cfg, after_failure, mesh)
    step2, _ = pl.make_fhdp_train_step(cfg, shape, mesh,
                                       templates=after_failure,
                                       learning_rate=2e-3)
    opt2 = pl.zero2_init(pp2, mesh.shape["data"])
    jstep2 = jax.jit(step2)
    post = []
    for i in range(10):
        batch = concrete_batch(cfg, shape, next(rngs))
        pp2, opt2, m = jstep2(pp2, opt2, batch)
        post.append(float(m["loss"]))
    print(f"post-recovery loss (from backup at step {at_step}): "
          f"{post[0]:.4f} -> {post[-1]:.4f}")
    assert post[-1] < losses[0], "training did not continue descending"

    # ---- analytic recovery-time comparison (paper Fig. 5b) ----
    from repro.recovery.recover import recover
    from repro.recovery.templates import pregenerate
    from repro.sched.costmodel import (CostParams, JETSON_AGX, JETSON_NANO,
                                       make_fleet, vision_encoder_units)
    cp = CostParams()
    units = vision_encoder_units(get_config("flad-vision"))
    rng = np.random.default_rng(0)
    fleet = make_fleet([dict(JETSON_NANO)] * 4 + [dict(JETSON_AGX)],
                       stb=rng.uniform(0, 1, 5),
                       dwl=rng.uniform(600, 3600, 5))
    ts = pregenerate(fleet, units, cp)
    for strat in ("template", "elastic", "relaunch"):
        out = recover(strat, ts, fleet[1].vid, fleet, units, cp)
        print(f"  {strat:9s}: {out.seconds:6.2f}s "
              f"(moved {out.moved_bytes/1e6:.1f} MB)")


if __name__ == "__main__":
    main()
