"""FHDP fault tolerance end to end (paper §4.2 / Fig. 5b), on the API.

Train the vision encoder through an FHDP :class:`repro.api.Session`,
inject a stage failure mid-training, recover via a pre-generated template
(re-staging the edge backup under a new layer split), and keep training —
loss continues to descend. Also prints the analytic recovery-time
comparison.

    PYTHONPATH=src python examples/fhdp_recovery.py
"""
import numpy as np

from repro.api import LoopHooks, MeshSpec, Session
from repro.config import ShapeConfig
from repro.configs.common import concrete_batch
from repro.core import pipeline as pl
from repro.recovery.backup import EdgeBackup, restage


def main():
    shape = ShapeConfig("rec", 16, 16, "train")
    mesh_spec = MeshSpec((2, 4))

    # active template: 4 stages; preventive template: stage 2's layers
    # re-homed when its host fails (paper: template pre-generation)
    active = {"blocks": (1, 1, 0, 0)}
    after_failure = {"blocks": (2, 0, 0, 0)}

    backup = EdgeBackup(interval=5)
    session = Session("flad-vision", strategy="pipeline", shape=shape,
                      mesh=mesh_spec, learning_rate=2e-3,
                      templates=dict(active))
    # one batch stream continues across failure + recovery (build the
    # session first: MeshSpec must force devices before other jax use)
    session.build()
    import jax
    rngs = iter(jax.random.split(jax.random.PRNGKey(0), 100))

    def batch_stream():
        while True:
            yield concrete_batch(session.cfg, shape, next(rngs))

    # the edge snapshots the MERGED model so any template can redeploy it
    hooks = LoopHooks(backup=backup, log_every=5,
                      backup_view=lambda pp: pl.merge_stage_params(
                          pp, active))
    out = session.run(10, batches=batch_stream(), hooks=hooks)
    losses = [h["loss"] for h in out["history"]]
    print(f"pre-failure loss: {losses[0]:.4f} -> {losses[-1]:.4f}")

    # ---- stage-1 host departs: restore backup under the new template ----
    print("injecting failure of stage-1 host; deploying template",
          after_failure)
    merged, at_step = backup.restore()
    mesh = session.mesh
    pp2 = restage(merged, session.cfg, after_failure, mesh)
    session2 = Session(cfg=session.cfg, strategy="pipeline", shape=shape,
                      mesh=mesh, learning_rate=2e-3,
                      templates=dict(after_failure))
    opt2 = pl.zero2_init(pp2, mesh.shape["data"])
    # passing state skips session2's own init entirely
    out2 = session2.run(10, state=(pp2, opt2), batches=batch_stream(),
                        hooks=LoopHooks(log_every=5))
    post = [h["loss"] for h in out2["history"]]
    print(f"post-recovery loss (from backup at step {at_step}): "
          f"{post[0]:.4f} -> {post[-1]:.4f}")
    assert post[-1] < losses[0], "training did not continue descending"

    # ---- analytic recovery-time comparison (paper Fig. 5b) ----
    from repro.configs import get_config
    from repro.recovery.recover import recover
    from repro.recovery.templates import pregenerate
    from repro.sched.costmodel import (CostParams, JETSON_AGX, JETSON_NANO,
                                       make_fleet, vision_encoder_units)
    cp = CostParams()
    units = vision_encoder_units(get_config("flad-vision"))
    rng = np.random.default_rng(0)
    fleet = make_fleet([dict(JETSON_NANO)] * 4 + [dict(JETSON_AGX)],
                       stb=rng.uniform(0, 1, 5),
                       dwl=rng.uniform(600, 3600, 5))
    ts = pregenerate(fleet, units, cp)
    for strat in ("template", "elastic", "relaunch"):
        res = recover(strat, ts, fleet[1].vid, fleet, units, cp)
        print(f"  {strat:9s}: {res.seconds:6.2f}s "
              f"(moved {res.moved_bytes/1e6:.1f} MB)")


if __name__ == "__main__":
    main()
