"""Quickstart: FHDP-train the FLAD vision encoder on synthetic driving
data over a (data=2, model=4) mesh — FL clients x pipeline stages — then
decode waypoints with the edge AD-LLM.

Runs on CPU in ~2 minutes:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ShapeConfig
from repro.configs import get_config
from repro.configs.common import reduced
from repro.core import pipeline as pl
from repro.core.fhdp import init_fhdp
from repro.data.partition import fleet_datasets
from repro.data.synthetic import DrivingDataConfig
from repro.launch.mesh import make_test_mesh


def main():
    mesh = make_test_mesh(data=2, model=4)
    cfg = reduced(get_config("flad-vision"))
    dcfg = DrivingDataConfig(feature_dim=cfg.prefix_dim,
                             patches=cfg.prefix_tokens or 8,
                             num_waypoints=cfg.num_waypoints,
                             num_light_classes=cfg.num_light_classes)
    # 2 FL clients (the mesh's data axis), town-non-IID
    datasets = fleet_datasets(dcfg, 2, 256, beta=0.3)
    shape = ShapeConfig("quickstart", dcfg.patches, 16, "train")

    step, helpers = pl.make_fhdp_train_step(cfg, shape, mesh,
                                            learning_rate=2e-3)
    print("stage templates:", helpers["templates"])
    pp, opt, _ = init_fhdp(cfg, mesh, jax.random.PRNGKey(0))
    jstep = jax.jit(step)

    rng = np.random.default_rng(0)
    for i in range(30):
        idx = rng.integers(0, 256, 16)
        batch = {k: jnp.asarray(np.concatenate(
            [d[k][idx[:8]] for d in datasets], axis=0))
            for k in datasets[0]}
        pp, opt, metrics = jstep(pp, opt, batch)
        if i % 5 == 0:
            print(f"step {i:3d} loss={float(metrics['loss']):.4f}")
    print("final loss:", float(metrics["loss"]))

    # stage rotation (paper §4: vehicles rotate through pipeline roles)
    pp["stacks"] = pl.rotate_stages(pp["stacks"], 1)
    pp["masks"] = pl.rotate_stages(pp["masks"], 1)
    pp, opt, metrics = jstep(pp, opt, batch)
    print("after stage rotation, loss:", float(metrics["loss"]))


if __name__ == "__main__":
    main()
