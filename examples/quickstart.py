"""Quickstart: FHDP-train the FLAD vision encoder on synthetic driving
data over a (data=2, model=4) mesh — FL clients x pipeline stages — then
rotate pipeline roles, all through :class:`repro.api.Session`.

Runs on CPU in ~2 minutes:
    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.api import MeshSpec, Session, load_config
from repro.config import ShapeConfig
from repro.core import pipeline as pl
from repro.data.partition import fleet_datasets
from repro.data.synthetic import DrivingDataConfig


def main():
    cfg = load_config("flad-vision")
    dcfg = DrivingDataConfig(feature_dim=cfg.prefix_dim,
                             patches=cfg.prefix_tokens or 8,
                             num_waypoints=cfg.num_waypoints,
                             num_light_classes=cfg.num_light_classes)
    # 2 FL clients (the mesh's data axis), town-non-IID
    datasets = fleet_datasets(dcfg, 2, 256, beta=0.3)

    session = Session(cfg=cfg, strategy="pipeline", learning_rate=2e-3,
                      mesh=MeshSpec((2, 4)),
                      shape=ShapeConfig("quickstart", dcfg.patches, 16,
                                        "train"))
    rng = np.random.default_rng(0)

    def batches():
        while True:
            idx = rng.integers(0, 256, 16)
            yield {k: jnp.asarray(np.concatenate(
                [d[k][idx[:8]] for d in datasets], axis=0))
                for k in datasets[0]}

    step, _ = session.build()
    print("stage templates:", session.strategy.templates)
    out = session.run(30, batches=batches())
    print("final loss:", out["history"][-1]["loss"])

    # stage rotation (paper §4: vehicles rotate through pipeline roles)
    pp, opt = session.state
    pp["stacks"] = pl.rotate_stages(pp["stacks"], 1)
    pp["masks"] = pl.rotate_stages(pp["masks"], 1)
    pp, opt, metrics = step(pp, opt, next(batches()))
    print("after stage rotation, loss:", float(metrics["loss"]))


if __name__ == "__main__":
    main()
