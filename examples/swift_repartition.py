"""SWIFT scheduling wired into the live runtime (paper §4.1.3 + §4.2).

A heterogeneous vehicle fleet is declared, SWIFT partitions the model over
it, and the winning pipeline becomes the FHDP stage template of a
:class:`repro.api.Session`. Mid-training a vehicle DEPARTS: the
:class:`repro.recovery.recover.Repartitioner` hook looks up the
pre-generated departure template, merges the live stage params, restages
them under the new template, rebuilds the jitted step, and training
continues — merged params bit-identical across the boundary, loss still
descending.

    PYTHONPATH=src python examples/swift_repartition.py [--dry-run]
"""
import argparse

from repro.api import LoopHooks, MeshSpec, Session
from repro.api.session import load_config
from repro.config import ShapeConfig
from repro.recovery.recover import Repartitioner
from repro.sched.costmodel import demo_fleet, model_units


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="few steps; CI smoke of the scheduler->runtime path")
    ap.add_argument("--depart-vid", type=int, default=1,
                    help="vehicle that departs mid-run")
    args = ap.parse_args()

    pre = 4 if args.dry_run else 10
    post = 10 if args.dry_run else 14
    cfg = load_config("flad-vision").replace(num_layers=4)
    unit_cap = model_units(cfg, seq_len=64, num_units=4)[0].cap
    fleet = demo_fleet(unit_cap)

    session = Session(cfg=cfg, strategy="swift_pipeline",
                      mesh=MeshSpec((2, 4)), learning_rate=2e-3,
                      shape=ShapeConfig("swift", 16, 16, "train"),
                      fleet=fleet, seq_len=64)
    session.build()
    strat = session.strategy
    res = strat.swift_result
    print(f"SWIFT: phase1 {res.phase1_s * 1e3:.2f} ms, phase2 "
          f"{res.phase2_s * 1e3:.2f} ms, {len(res.essential)} essential "
          f"pipelines over {len(strat.vehicles)} vehicles")
    print(f"active pipeline: vehicles "
          f"{[v.vid for v in strat.active_pipeline.path]}, stage template "
          f"{strat.templates}")
    print("pre-generated departure templates:",
          {vid: (p.template() if p else None)
           for vid, p in strat.template_set.on_departure.items()})

    # a small FIXED batch set (cycled) so the loss visibly descends across
    # the departure instead of chasing fresh random labels every step
    import itertools

    import jax

    from repro.configs.common import concrete_batch
    fixed = [concrete_batch(session.cfg, session.shape, jax.random.PRNGKey(i))
             for i in range(4)]

    rep = Repartitioner(session, {pre - 1: args.depart_vid})
    out = session.run(pre + post, batches=itertools.cycle(fixed),
                      hooks=LoopHooks(log_every=1, repartition=rep))
    losses = [h["loss"] for h in out["history"]]

    assert rep.events, "the scheduled departure never fired"
    ev = rep.events[0]
    assert ev.params_identical, \
        "merged params changed across the restage boundary"
    total_layers = sum(sum(t) for t in ev.new_template.values())
    assert total_layers == cfg.num_layers, \
        f"template dropped layers: {ev.new_template}"
    import numpy as np
    early, late = np.mean(losses[:3]), np.mean(losses[-3:])
    assert late < early, \
        f"loss did not continue descending: {early:.4f} -> {late:.4f}"
    print(f"departure of vehicle {ev.vid}: template {ev.old_template} -> "
          f"{ev.new_template}")
    print(f"repartition wall time {ev.total_s * 1e3:.1f} ms "
          f"(lookup {ev.lookup_s * 1e3:.2f}, restage "
          f"{ev.restage_s * 1e3:.1f}, step rebuild "
          f"{ev.rebuild_s * 1e3:.1f})")
    print(f"loss: {early:.4f} -> {late:.4f} across the departure; "
          f"params bit-identical across restage: {ev.params_identical}")


if __name__ == "__main__":
    main()
