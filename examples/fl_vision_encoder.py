"""End-to-end driver: federated training of the FLAD vision encoder
(paper Fig. 1 training procedure / Fig. 8a evaluation), on the API.

8 FL clients with town-non-IID driving data train the vision encoder via
a ``fedavg`` :class:`repro.api.Session` (client -> edge -> cloud = mean
over the data/pod axes). We report held-out traffic-light accuracy of
(a) a model trained on ONE town's data only (the
"centralized-on-local-data" baseline the paper improves over) and (b)
the FL global model — reproducing the direction of Fig. 8a
(79.9% -> 92.66% there).

    PYTHONPATH=src python examples/fl_vision_encoder.py --rounds 20
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.api import LoopHooks, MeshSpec, Session, load_config
from repro.config import ShapeConfig
from repro.data.partition import fleet_datasets
from repro.data.synthetic import DrivingDataConfig, TownWorld
from repro.data.pipeline import batches, client_round_batches


def light_accuracy(model, params, data, batch=64):
    correct = n = 0
    for i in range(0, len(data["light"]) - batch + 1, batch):
        b = {k: jnp.asarray(v[i:i + batch]) for k, v in data.items()}
        _, metrics = model.loss(params, b)
        correct += float(metrics["acc"]) * batch
        n += batch
    return correct / max(n, 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--samples", type=int, default=512)
    ap.add_argument("--full", action="store_true",
                    help="full ~100M config (TPU scale; CPU: hours)")
    args = ap.parse_args()

    cfg = load_config("flad-vision", full=args.full)
    dcfg = DrivingDataConfig(feature_dim=cfg.prefix_dim,
                             patches=cfg.prefix_tokens or 8,
                             num_waypoints=cfg.num_waypoints,
                             num_light_classes=cfg.num_light_classes,
                             n_towns=4)
    datasets = fleet_datasets(dcfg, args.clients, args.samples, beta=0.3)
    world = TownWorld(dcfg)
    rng = np.random.default_rng(99)
    heldout = {t: world.sample(t, 256, rng) for t in range(dcfg.n_towns)}
    shape = ShapeConfig("fl", dcfg.patches, args.batch, "train")
    mesh = MeshSpec((8,), axes=("data",))

    # -- baseline: train on client 0's (single-town-skewed) data only
    base = Session(cfg=cfg, strategy="tensor", shape=shape, mesh=mesh,
                   learning_rate=2e-3, remat=False)
    it = batches(datasets[0], args.batch,
                 epochs=args.rounds * args.local_steps + 1)
    base.run(args.rounds * args.local_steps, batches=it,
             hooks=LoopHooks(log_every=10 ** 9, log_fn=lambda *a: None))
    model = base.model
    base_acc = np.mean([light_accuracy(model, base.merged_params(), d)
                        for d in heldout.values()])
    print(f"single-client model: held-out light acc = {base_acc:.3f}")

    # -- FLAD: hierarchical FedAvg over all clients
    fl = Session(cfg=cfg, strategy="fedavg", shape=shape, mesh=mesh,
                 learning_rate=2e-3, seed=0,
                 local_steps=args.local_steps, clients=args.clients,
                 remat=False)

    def round_batches(r):
        rb = client_round_batches(datasets, args.local_steps, args.batch,
                                  round_idx=r)
        return {k: jnp.asarray(v) for k, v in rb.items()}

    fl.run(args.rounds, batches=round_batches,
           hooks=LoopHooks(log_every=5))
    fl_acc = np.mean([light_accuracy(model, fl.merged_params(), d)
                      for d in heldout.values()])
    print(f"FLAD FL model:       held-out light acc = {fl_acc:.3f}")
    print(f"improvement: {base_acc:.3f} -> {fl_acc:.3f} "
          f"(paper Fig. 8a: 0.799 -> 0.927)")


if __name__ == "__main__":
    main()
