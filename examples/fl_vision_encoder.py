"""End-to-end driver: federated training of the FLAD vision encoder
(paper Fig. 1 training procedure / Fig. 8a evaluation).

8 FL clients with town-non-IID driving data train the vision encoder via
hierarchical FedAvg (client -> edge -> cloud = mean over the data/pod
axes). We report held-out traffic-light accuracy of (a) a model trained
on ONE town's data only (the "centralized-on-local-data" baseline the
paper improves over) and (b) the FL global model — reproducing the
direction of Fig. 8a (79.9% -> 92.66% there).

    PYTHONPATH=src python examples/fl_vision_encoder.py --rounds 20
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ShapeConfig
from repro.configs import get_config
from repro.configs.common import reduced
from repro.core.fedavg import client_specs, fedavg, make_fl_round, stack_clients
from repro.data.partition import fleet_datasets
from repro.data.synthetic import DrivingDataConfig, TownWorld
from repro.data.pipeline import client_round_batches
from repro.models import build_model
from repro.train.optimizer import Adam


def light_accuracy(model, params, data, batch=64):
    correct = n = 0
    for i in range(0, len(data["light"]) - batch + 1, batch):
        b = {k: jnp.asarray(v[i:i + batch]) for k, v in data.items()}
        _, metrics = model.loss(params, b)
        correct += float(metrics["acc"]) * batch
        n += batch
    return correct / max(n, 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--samples", type=int, default=512)
    ap.add_argument("--full", action="store_true",
                    help="full ~100M config (TPU scale; CPU: hours)")
    args = ap.parse_args()

    cfg = get_config("flad-vision")
    if not args.full:
        cfg = reduced(cfg)
    dcfg = DrivingDataConfig(feature_dim=cfg.prefix_dim,
                             patches=cfg.prefix_tokens or 8,
                             num_waypoints=cfg.num_waypoints,
                             num_light_classes=cfg.num_light_classes,
                             n_towns=4)
    datasets = fleet_datasets(dcfg, args.clients, args.samples, beta=0.3)
    world = TownWorld(dcfg)
    rng = np.random.default_rng(99)
    heldout = {t: world.sample(t, 256, rng) for t in range(dcfg.n_towns)}

    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params0 = model.init(key)
    opt = Adam(lr=2e-3)
    shape = ShapeConfig("fl", dcfg.patches, args.batch, "train")

    # -- baseline: train on client 0's (single-town-skewed) data only
    from repro.core.steps import make_train_step
    step = jax.jit(make_train_step(cfg, shape, opt, remat=False))
    p, o = params0, opt.init(params0)
    from repro.data.pipeline import batches
    it = batches(datasets[0], args.batch,
                 epochs=args.rounds * args.local_steps + 1)
    for _ in range(args.rounds * args.local_steps):
        p, o, m = step(p, o, next(it))
    base_acc = np.mean([light_accuracy(model, p, d)
                        for d in heldout.values()])
    print(f"single-client model: held-out light acc = {base_acc:.3f}")

    # -- FLAD: hierarchical FedAvg over all clients
    fl_round = jax.jit(make_fl_round(cfg, shape, opt,
                                     local_steps=args.local_steps,
                                     remat=False))
    cp = stack_clients(params0, args.clients)
    co = jax.vmap(opt.init)(cp)
    for r in range(args.rounds):
        rb = client_round_batches(datasets, args.local_steps, args.batch,
                                  round_idx=r)
        rb = {k: jnp.asarray(v) for k, v in rb.items()}
        cp, co, metrics = fl_round(cp, co, rb)
        if (r + 1) % 5 == 0:
            print(f"round {r+1:3d} loss={float(np.mean(metrics['loss'])):.4f}")
    global_params = fedavg(cp)
    fl_acc = np.mean([light_accuracy(model, global_params, d)
                      for d in heldout.values()])
    print(f"FLAD FL model:       held-out light acc = {fl_acc:.3f}")
    print(f"improvement: {base_acc:.3f} -> {fl_acc:.3f} "
          f"(paper Fig. 8a: 0.799 -> 0.927)")


if __name__ == "__main__":
    main()
