"""Flash-attention fwd+bwd — kernel VJP vs reference VJP.

The training hot path (`blocks.attention` -> `ops.flash_attention_ad`)
used to re-linearize the O(Sq·Skv) reference attention on every backward
pass; the Pallas backward kernels recompute p = exp(s - lse) blockwise
from O(S·D) residuals instead. This benchmark sweeps sequence length and
records, for both VJPs:

  * ``fwd_bwd_s`` — median wall seconds of one jitted forward + backward
    (on CPU the kernels run in interpret mode, so the *memory* columns
    are the meaningful trajectory there; wall time is meaningful on TPU);
  * ``peak_temp_bytes`` — a peak-memory proxy: the largest single
    intermediate (jaxpr equation output, recursing into sub-jaxprs)
    anywhere in the fwd+bwd computation;
  * ``temp_over_io`` — that peak normalized by total input+output bytes.
    Flat in S for the kernel VJP; grows linearly (i.e. the raw peak grows
    quadratically) for the reference VJP's score/softmax matrices.

Writes ``BENCH_attention.json`` — the second perf-trajectory entry
(after ``BENCH_repartition.json``); schema gated by
``scripts/validate_bench.py`` in CI.

    PYTHONPATH=src python benchmarks/attention_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import json

import numpy as np

DEFAULT_OUT = "BENCH_attention.json"
BATCH, Q_HEADS, KV_HEADS, HEAD_DIM = 1, 4, 2, 64
BLOCK_Q = BLOCK_K = 128
SEQS_FULL = (128, 256, 512, 1024)
SEQS_QUICK = (128, 512)


def _subjaxprs(val):
    import jax
    if isinstance(val, jax.core.Jaxpr):
        yield val
    elif isinstance(val, jax.core.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, (list, tuple)):
        for item in val:
            yield from _subjaxprs(item)


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in _subjaxprs(val):
                yield from _iter_eqns(sub)


def largest_temp_bytes(fn, *args) -> int:
    """Largest single intermediate of ``fn(*args)`` in bytes — every
    jaxpr equation output, recursing into sub-jaxprs (scan/pjit bodies,
    pallas_call kernel bodies, custom_vjp branches)."""
    import jax
    import jax.numpy as jnp
    closed = jax.make_jaxpr(fn)(*args)
    best = 0
    for eqn in _iter_eqns(closed.jaxpr):
        for var in eqn.outvars:
            aval = var.aval
            if hasattr(aval, "shape") and hasattr(aval, "dtype"):
                size = int(np.prod(aval.shape, dtype=np.int64))
                best = max(best, size * jnp.dtype(aval.dtype).itemsize)
    return best


def run(quick: bool = False, out: str = DEFAULT_OUT) -> dict:
    import jax
    import jax.numpy as jnp

    try:
        from benchmarks.common import time_fn
    except ImportError:          # invoked as `python benchmarks/...py`
        from common import time_fn
    from repro.kernels import ops as kops
    from repro.kernels import ref

    def fwd_bwd_kernel(q, k, v, do):
        o, vjp = jax.vjp(
            lambda q_, k_, v_: kops.flash_attention_ad(
                q_, k_, v_, block_q=BLOCK_Q, block_k=BLOCK_K), q, k, v)
        return o, vjp(do)

    def fwd_bwd_ref(q, k, v, do):
        o, vjp = jax.vjp(
            lambda q_, k_, v_: ref.flash_attention_ref(q_, k_, v_),
            q, k, v)
        return o, vjp(do)

    seqs = SEQS_QUICK if quick else SEQS_FULL
    iters = 3 if quick else 5
    points = []
    for seq in seqs:
        ks = jax.random.split(jax.random.PRNGKey(seq), 4)
        q = jax.random.normal(ks[0], (BATCH, Q_HEADS, seq, HEAD_DIM))
        k = jax.random.normal(ks[1], (BATCH, KV_HEADS, seq, HEAD_DIM))
        v = jax.random.normal(ks[2], (BATCH, KV_HEADS, seq, HEAD_DIM))
        do = jax.random.normal(ks[3], (BATCH, Q_HEADS, seq, HEAD_DIM))
        # inputs (q, k, v, do) + outputs (o, dq, dk, dv)
        io = 2 * sum(x.nbytes for x in (q, k, v, do))

        point = {"seq": seq, "io_bytes": io}
        for name, fn in (("kernel", fwd_bwd_kernel), ("ref", fwd_bwd_ref)):
            peak = largest_temp_bytes(fn, q, k, v, do)
            secs = time_fn(jax.jit(fn), q, k, v, do, iters=iters, warmup=1)
            point[name] = {"fwd_bwd_s": secs, "peak_temp_bytes": peak,
                           "temp_over_io": peak / io}
        points.append(point)
        print(f"attention seq={seq}: kernel peak "
              f"{point['kernel']['peak_temp_bytes']} B "
              f"({point['kernel']['fwd_bwd_s'] * 1e3:.1f} ms), ref peak "
              f"{point['ref']['peak_temp_bytes']} B "
              f"({point['ref']['fwd_bwd_s'] * 1e3:.1f} ms)", flush=True)

    first, last = points[0], points[-1]
    payload = {
        "bench": "attention_fwd_bwd",
        "schema_version": 1,
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "quick": bool(quick),
        "shape": {"batch": BATCH, "q_heads": Q_HEADS, "kv_heads": KV_HEADS,
                  "head_dim": HEAD_DIM, "causal": True, "window": None},
        "block_q": BLOCK_Q,
        "block_k": BLOCK_K,
        "points": points,
        "summary": {
            "seq_ratio": last["seq"] / first["seq"],
            "kernel_temp_growth": (last["kernel"]["peak_temp_bytes"]
                                   / first["kernel"]["peak_temp_bytes"]),
            "ref_temp_growth": (last["ref"]["peak_temp_bytes"]
                                / first["ref"]["peak_temp_bytes"]),
            "ref_over_kernel_peak_at_max_seq": (
                last["ref"]["peak_temp_bytes"]
                / last["kernel"]["peak_temp_bytes"]),
        },
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    s = payload["summary"]
    print(f"attention: over seq x{s['seq_ratio']:.0f}, kernel peak grew "
          f"x{s['kernel_temp_growth']:.1f} vs ref x{s['ref_temp_growth']:.1f}"
          f" (ref/kernel at max seq: "
          f"x{s['ref_over_kernel_peak_at_max_seq']:.1f}) -> {out}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
