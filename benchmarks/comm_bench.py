"""Bytes-on-wire and simulated round time of the comm fabric.

Trains the same 2-edge x 2-vehicle non-IID fleet task three ways —
identical data, seeds, and local-step schedule — and accounts for what
each round puts on the physical links:

  ``flat_fp32``  flat FedAvg, float32 updates, no edge tier: every
                 vehicle's full payload transits its uplink AND the
                 shared edge->cloud backhaul (the seed reproduction's
                 implicit-mean baseline, with link costs now attached)
  ``hier_int8``  hierarchical rounds with the int8 stochastic codec
                 (Pallas kernel pair) + error feedback
  ``hier_topk``  hierarchical rounds with top-k sparsification + error
                 feedback

Per mode: upward bytes per round (vehicle uplinks + backhaul), simulated
round time from the topology's link models, and the held-out loss of the
final merged params on every town — the matched-quality check for the
compression claim. Writes schema-gated ``BENCH_comm.json`` (third
perf-trajectory entry; ``scripts/validate_bench.py`` enforces the >=4x
upward-bytes reduction of int8+hierarchy over flat fp32 at <=5% held-out
loss drift).

    PYTHONPATH=src python benchmarks/comm_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import json

DEFAULT_OUT = "BENCH_comm.json"
TOPOLOGY = "2@nano*2,agx*2"          # 2 edge pods x 2 vehicles each
TOPK_FRAC = 0.05


def _heldout_loss(model, params, heldout, bs=64):
    import jax.numpy as jnp
    import numpy as np
    losses = []
    for data in heldout:
        n = len(data["light"])
        for i in range(0, n - bs + 1, bs):
            b = {k: jnp.asarray(v[i:i + bs]) for k, v in data.items()}
            loss, _ = model.loss(params, b)
            losses.append(float(loss))
    return float(np.mean(losses))


def run(quick: bool = False, out: str = DEFAULT_OUT) -> dict:
    import jax.numpy as jnp
    import numpy as np

    try:
        from benchmarks.common import bench_session, emit
    except ImportError:          # invoked as `python benchmarks/...py`
        from common import bench_session, emit
    from repro.api import LoopHooks, load_config
    from repro.comm.codecs import get_codec, tree_nbytes
    from repro.comm.topology import parse_topology
    from repro.config import ShapeConfig
    from repro.data.partition import fleet_datasets
    from repro.data.pipeline import client_round_batches
    from repro.data.synthetic import DrivingDataConfig, TownWorld

    rounds, locsteps, bs, samples = (4, 2, 16, 256) if quick \
        else (10, 2, 16, 384)
    quiet = LoopHooks(log_every=10 ** 9, log_fn=lambda *a, **k: None)

    cfg = load_config("flad-vision")
    dcfg = DrivingDataConfig(feature_dim=cfg.prefix_dim,
                             patches=cfg.prefix_tokens or 8,
                             num_waypoints=cfg.num_waypoints,
                             num_light_classes=cfg.num_light_classes,
                             n_towns=4)
    shape = ShapeConfig("comm", dcfg.patches, bs, "train")
    topo = parse_topology(TOPOLOGY)
    clients = topo.n_clients
    datasets = fleet_datasets(dcfg, clients, samples, beta=0.3)
    world = TownWorld(dcfg)
    rng = np.random.default_rng(99)
    heldout = [world.sample(t, 128, rng) for t in range(dcfg.n_towns)]

    def round_batches(r):
        rb = client_round_batches(datasets, locsteps, bs, round_idx=r)
        return {k: jnp.asarray(v) for k, v in rb.items()}

    def train(strategy, **options):
        ses = bench_session("flad-vision", mesh=(1,), shape=shape,
                            strategy=strategy, learning_rate=2e-3,
                            local_steps=locsteps, remat=False, **options)
        ses.run(rounds, batches=round_batches, hooks=quiet)
        return ses, _heldout_loss(ses.model, ses.merged_params(), heldout)

    # wire format sizes come from the model's parameter tree
    from repro.core.steps import abstract_params
    ptree = abstract_params(cfg)
    fp32_payload = tree_nbytes(get_codec("none"), ptree)

    modes = []

    # flat fp32 FedAvg: no edge tier, every payload transits the backhaul
    ses, loss = train("fedavg", clients=clients)
    stats = topo.flat_round_stats(fp32_payload)
    modes.append({
        "name": "flat_fp32", "strategy": "fedavg", "codec": "none",
        "bytes_per_client": fp32_payload,
        "uplink_bytes_per_round": stats["uplink_bytes"],
        "backhaul_bytes_per_round": stats["backhaul_bytes"],
        "total_up_bytes_per_round": (stats["uplink_bytes"]
                                     + stats["backhaul_bytes"]),
        "sim_round_s": stats["round_time_s"],
        "final_loss": loss,
    })

    for name, codec, options in (
            ("hier_int8", "int8", {}),
            ("hier_topk", "topk", {"k_frac": TOPK_FRAC})):
        ses, loss = train("hier_fl", topology=topo, codec=codec,
                          codec_options=options)
        st = ses.strategy.comm_stats
        modes.append({
            "name": name, "strategy": "hier_fl", "codec": codec,
            "bytes_per_client": st["bytes_per_client"],
            "uplink_bytes_per_round": st["uplink_bytes"],
            "backhaul_bytes_per_round": st["backhaul_bytes"],
            "total_up_bytes_per_round": (st["uplink_bytes"]
                                         + st["backhaul_bytes"]),
            "sim_round_s": st["round_time_s"],
            "final_loss": loss,
        })

    flat, int8, topk = modes
    payload = {
        "bench": "comm_fabric",
        "schema_version": 1,
        "arch": cfg.name,
        "quick": bool(quick),
        "rounds": rounds,
        "local_steps": locsteps,
        "topology": {
            "spec": TOPOLOGY,
            "edges": topo.n_edges,
            "vehicles": topo.n_clients,
            "backhaul_bw": topo.backhaul_bw,
        },
        "param_fp32_bytes": fp32_payload,
        "modes": modes,
        "summary": {
            "int8_up_reduction": (flat["total_up_bytes_per_round"]
                                  / int8["total_up_bytes_per_round"]),
            "topk_up_reduction": (flat["total_up_bytes_per_round"]
                                  / topk["total_up_bytes_per_round"]),
            "int8_compression": (flat["bytes_per_client"]
                                 / int8["bytes_per_client"]),
            "topk_compression": (flat["bytes_per_client"]
                                 / topk["bytes_per_client"]),
            "int8_loss_drift": abs(int8["final_loss"] / flat["final_loss"]
                                   - 1.0),
            "topk_loss_drift": abs(topk["final_loss"] / flat["final_loss"]
                                   - 1.0),
            "int8_round_speedup": (flat["sim_round_s"]
                                   / int8["sim_round_s"]),
        },
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    s = payload["summary"]
    for m in modes:
        emit(f"comm/{m['name']}/total_up_bytes",
             m["total_up_bytes_per_round"],
             f"loss={m['final_loss']:.4f} sim_round={m['sim_round_s']:.4g}s")
    print(f"comm: int8 x{s['int8_up_reduction']:.1f} up-bytes "
          f"(loss drift {s['int8_loss_drift']:.3f}), topk "
          f"x{s['topk_up_reduction']:.1f} "
          f"(drift {s['topk_loss_drift']:.3f}) -> {out}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
