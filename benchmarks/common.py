"""Shared benchmark utilities (timing, CSV emission, session setup).

``bench_session`` is the single place benchmarks stand up FLAD work — a
thin veneer over :class:`repro.api.Session` so individual benchmark
modules carry no mesh/strategy wiring of their own.
"""
from __future__ import annotations

import time
from typing import Callable

import jax


def bench_session(arch: str = "flad-vision", *,
                  mesh=(4, 2),
                  shape=None,
                  strategy: str = "tensor",
                  learning_rate: float = 1e-3,
                  **strategy_options):
    """Thin veneer over :class:`repro.api.Session` so benchmark modules
    carry no wiring of their own. Bench defaults differ from Session's:
    mesh (4, 2) = 4 FL clients x 2 pipeline ranks (the paper's testbed
    scale) and strategy ``tensor`` (the no-communication baseline most
    benchmarks compare against)."""
    from repro.api import Session
    return Session(arch, shape=shape, mesh=mesh, strategy=strategy,
                   learning_rate=learning_rate, **strategy_options)


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall seconds per call of a jitted fn (blocks on outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, value, derived: str = "") -> None:
    print(f"{name},{value},{derived}", flush=True)
