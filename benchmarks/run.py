"""Benchmark driver — one module per paper table/figure.

  Fig. 5(a) SWIFT optimization time     -> swift_opt
  Fig. 5(b) recovery time               -> recovery_bench
  §4.2 executed (live repartition)      -> repartition_latency
                                           (writes BENCH_repartition.json)
  §Kernels (flash-attn fwd+bwd)         -> attention_bench
                                           (writes BENCH_attention.json)
  §3.1 comm fabric (bytes / round time) -> comm_bench
                                           (writes BENCH_comm.json)
  §3.1 async event-time engine          -> async_bench
                                           (writes BENCH_async.json)
  Fig. 2 serving tier (paged KV +       -> serving_bench
         continuous batching)              (writes BENCH_serving.json)
  Fig. 2 chunked prefill + prefix cache -> prefill_bench
         (TTFT, pod block sharing)         (writes BENCH_prefill.json)
  §3.2 personalized distillation        -> distill_fl_bench
        (adapter uplinks, per-pod wins)    (writes BENCH_distill.json)
  Fig. 2 speculative decoding           -> specdec_bench
        (pod-student drafts, acceptance)   (writes BENCH_specdec.json)
  Fig. 6(a,b) pipeline execution time   -> pipeline_exec
  Fig. 7(a,b) + Table 2 FHDP            -> fhdp_throughput
  Fig. 8(a) FL accuracy                 -> fl_accuracy
  Fig. 10 LLM/distillation quality      -> distill_quality
  §Roofline table (from the dry-run)    -> roofline

Prints ``name,value,derived`` CSV lines. ``--quick`` shrinks sweeps.
"""
import argparse
import os
import sys
import time
import traceback

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
# make `python benchmarks/run.py` work without the repo root on PYTHONPATH
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


#: registered benchmark names, in run order (``--list`` prints these; the
#: jobs table below is asserted against it so the two cannot drift)
JOB_NAMES = ("swift_opt", "pipeline_exec", "recovery", "repartition",
             "attention", "comm", "async", "serving", "prefill",
             "distill_fl", "specdec", "fhdp_throughput", "fl_accuracy",
             "distill_quality", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list of benchmark names (see --list)")
    ap.add_argument("--list", action="store_true",
                    help="print registered benchmark names and exit")
    args = ap.parse_args()

    if args.list:
        # no benchmark imports: listing must stay instant
        for name in JOB_NAMES:
            print(name)
        return

    from benchmarks import (async_bench, attention_bench, comm_bench,
                            distill_fl_bench, distill_quality,
                            fhdp_throughput, fl_accuracy, pipeline_exec,
                            prefill_bench, recovery_bench,
                            repartition_latency, roofline, serving_bench,
                            specdec_bench, swift_opt)

    agent_holder = {}

    def run_swift():
        agent_holder["agent"] = swift_opt.run(quick=args.quick)

    def run_pipeline_exec():
        pipeline_exec.run(quick=args.quick,
                          agent=agent_holder.get("agent"))

    jobs = [
        ("swift_opt", run_swift),
        ("pipeline_exec", run_pipeline_exec),
        ("recovery", lambda: recovery_bench.run(quick=args.quick)),
        ("repartition", lambda: repartition_latency.run(quick=args.quick)),
        ("attention", lambda: attention_bench.run(quick=args.quick)),
        ("comm", lambda: comm_bench.run(quick=args.quick)),
        ("async", lambda: async_bench.run(quick=args.quick)),
        ("serving", lambda: serving_bench.run(quick=args.quick)),
        ("prefill", lambda: prefill_bench.run(quick=args.quick)),
        ("distill_fl", lambda: distill_fl_bench.run(quick=args.quick)),
        ("specdec", lambda: specdec_bench.run(quick=args.quick)),
        ("fhdp_throughput", lambda: fhdp_throughput.run(quick=args.quick)),
        ("fl_accuracy", lambda: fl_accuracy.run(quick=args.quick)),
        ("distill_quality", lambda: distill_quality.run(quick=args.quick)),
        ("roofline", lambda: roofline.run(quick=args.quick)),
    ]
    assert tuple(n for n, _ in jobs) == JOB_NAMES, \
        "jobs table drifted from JOB_NAMES (--list would lie)"
    only = set(args.only.split(",")) if args.only else None
    failures = []
    for name, job in jobs:
        if only and name not in only:
            continue
        print(f"=== {name} ===", flush=True)
        t0 = time.time()
        try:
            job()
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"=== {name} done in {time.time()-t0:.1f}s ===", flush=True)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
