"""Paper Fig. 8(a): traffic-light accuracy — FL-trained vision encoder vs
a single-client (centrally pre-trained) baseline, on held-out data from
every town. Claim reproduced: FL across non-IID towns improves held-out
accuracy (paper: 79.9% -> 92.66%).

Both models train through ``common.bench_session`` (tensor baseline,
``fedavg`` FL rounds)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_session, emit
from repro.api import LoopHooks, load_config
from repro.config import ShapeConfig
from repro.data.partition import fleet_datasets
from repro.data.pipeline import batches, client_round_batches
from repro.data.synthetic import DrivingDataConfig, TownWorld

QUIET = LoopHooks(log_every=10 ** 9, log_fn=lambda *a, **k: None)


def _acc(model, params, data, bs=64):
    correct = n = 0
    for i in range(0, len(data["light"]) - bs + 1, bs):
        b = {k: jnp.asarray(v[i:i + bs]) for k, v in data.items()}
        _, m = model.loss(params, b)
        correct += float(m["acc"]) * bs
        n += bs
    return correct / max(n, 1)


def run(quick: bool = False):
    clients, rounds, locsteps, bs = (4, 6, 2, 16) if quick \
        else (8, 15, 2, 16)
    cfg = load_config("flad-vision")
    dcfg = DrivingDataConfig(feature_dim=cfg.prefix_dim,
                             patches=cfg.prefix_tokens or 8,
                             num_waypoints=cfg.num_waypoints,
                             num_light_classes=cfg.num_light_classes,
                             n_towns=4)
    shape = ShapeConfig("fl", dcfg.patches, bs, "train")
    base_ses = bench_session("flad-vision", mesh=(1,), shape=shape,
                             strategy="tensor", learning_rate=2e-3,
                             remat=False)
    datasets = fleet_datasets(dcfg, clients, 384, beta=0.3)
    world = TownWorld(dcfg)
    rng = np.random.default_rng(99)
    heldout = [world.sample(t, 192, rng) for t in range(dcfg.n_towns)]

    model = base_ses.model
    it = batches(datasets[0], bs, epochs=rounds * locsteps + 1)
    base_ses.run(rounds * locsteps, batches=it, hooks=QUIET)
    base = np.mean([_acc(model, base_ses.merged_params(), d)
                    for d in heldout])
    emit("fl_accuracy/single_client", f"{base:.4f}")

    fl_ses = bench_session("flad-vision", mesh=(1,), shape=shape,
                           strategy="fedavg", learning_rate=2e-3,
                           local_steps=locsteps, clients=clients,
                           remat=False)

    def round_batches(r):
        rb = client_round_batches(datasets, locsteps, bs, round_idx=r)
        return {k: jnp.asarray(v) for k, v in rb.items()}

    fl_ses.run(rounds, batches=round_batches, hooks=QUIET)
    fl_acc = np.mean([_acc(model, fl_ses.merged_params(), d)
                      for d in heldout])
    emit("fl_accuracy/flad_fl", f"{fl_acc:.4f}",
         f"delta=+{fl_acc-base:.4f} (paper: 0.799->0.927)")
