"""Paper Fig. 8(a): traffic-light accuracy — FL-trained vision encoder vs
a single-client (centrally pre-trained) baseline, on held-out data from
every town. Claim reproduced: FL across non-IID towns improves held-out
accuracy (paper: 79.9% -> 92.66%)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.config import ShapeConfig
from repro.configs import get_config
from repro.configs.common import reduced
from repro.core.fedavg import fedavg, make_fl_round, stack_clients
from repro.core.steps import make_train_step
from repro.data.partition import fleet_datasets
from repro.data.pipeline import batches, client_round_batches
from repro.data.synthetic import DrivingDataConfig, TownWorld
from repro.models import build_model
from repro.train.optimizer import Adam


def _acc(model, params, data, bs=64):
    correct = n = 0
    for i in range(0, len(data["light"]) - bs + 1, bs):
        b = {k: jnp.asarray(v[i:i + bs]) for k, v in data.items()}
        _, m = model.loss(params, b)
        correct += float(m["acc"]) * bs
        n += bs
    return correct / max(n, 1)


def run(quick: bool = False):
    cfg = reduced(get_config("flad_vision"))
    dcfg = DrivingDataConfig(feature_dim=cfg.prefix_dim,
                             patches=cfg.prefix_tokens or 8,
                             num_waypoints=cfg.num_waypoints,
                             num_light_classes=cfg.num_light_classes,
                             n_towns=4)
    clients, rounds, locsteps, bs = (4, 6, 2, 16) if quick \
        else (8, 15, 2, 16)
    datasets = fleet_datasets(dcfg, clients, 384, beta=0.3)
    world = TownWorld(dcfg)
    rng = np.random.default_rng(99)
    heldout = [world.sample(t, 192, rng) for t in range(dcfg.n_towns)]

    model = build_model(cfg)
    params0 = model.init(jax.random.PRNGKey(0))
    opt = Adam(lr=2e-3)
    shape = ShapeConfig("fl", dcfg.patches, bs, "train")

    step = jax.jit(make_train_step(cfg, shape, opt, remat=False))
    p, o = params0, opt.init(params0)
    it = batches(datasets[0], bs, epochs=rounds * locsteps + 1)
    for _ in range(rounds * locsteps):
        p, o, _ = step(p, o, next(it))
    base = np.mean([_acc(model, p, d) for d in heldout])
    emit("fl_accuracy/single_client", f"{base:.4f}")

    fl_round = jax.jit(make_fl_round(cfg, shape, opt, local_steps=locsteps,
                                     remat=False))
    cp = stack_clients(params0, clients)
    co = jax.vmap(opt.init)(cp)
    for r in range(rounds):
        rb = client_round_batches(datasets, locsteps, bs, round_idx=r)
        cp, co, _ = fl_round(cp, co,
                             {k: jnp.asarray(v) for k, v in rb.items()})
    fl_acc = np.mean([_acc(model, fedavg(cp), d) for d in heldout])
    emit("fl_accuracy/flad_fl", f"{fl_acc:.4f}",
         f"delta=+{fl_acc-base:.4f} (paper: 0.799->0.927)")
