"""Paper Fig. 5(a): SWIFT optimization time — phase 1 (greedy quick-start)
vs phase 2 (DQN refinement) across cluster sizes. The claim reproduced:
phase 1 is orders of magnitude faster and roughly constant, enabling
immediate pipeline execution while phase 2 refines."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.sched.costmodel import CostParams, make_fleet, model_units
from repro.sched import swift as SW


def _fleet(n, rng):
    return make_fleet(
        [dict(cmp=rng.uniform(0.3, 4) * 1e12,
              mem=rng.uniform(4, 32) * 1e9, com=0.125e9)
         for _ in range(n)],
        stb=rng.uniform(0, 1, n), dwl=rng.uniform(600, 3600, n))


def run(quick: bool = False):
    cp = CostParams()
    units = model_units(get_config("flad_adllm"), seq_len=1024)
    rng = np.random.default_rng(0)

    def sampler():
        return _fleet(int(rng.integers(3, 8)), rng), units

    agent = SW.train_policy(sampler, episodes=30 if quick else 150, cp=cp)

    sizes = (3, 5, 7) if quick else (3, 5, 7, 9, 11)
    for n in sizes:
        p1s, p2s = [], []
        for rep in range(3):
            fleet = _fleet(n, rng)
            res = SW.swift(fleet, units, agent=agent, cp=cp)
            p1s.append(res.phase1_s)
            p2s.append(res.phase2_s)
        emit(f"swift_opt/phase1_s/cluster{n}", f"{np.median(p1s):.5f}")
        emit(f"swift_opt/phase2_s/cluster{n}", f"{np.median(p2s):.5f}",
             f"ratio={np.median(p2s)/max(np.median(p1s),1e-9):.1f}x")
    return agent
