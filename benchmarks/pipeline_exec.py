"""Paper Fig. 6(a,b): SWIFT vs greedy-matching pipeline execution time
under the Eq. 10 cost model — (a) sweep cluster size at fixed model,
(b) sweep model size at cluster 5. Reproduced claims: SWIFT <= greedy
where both are feasible; greedy becomes infeasible at large cluster /
model sizes where SWIFT still solves."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.sched.costmodel import CostParams, Unit, make_fleet, model_units
from repro.sched import swift as SW


def _fleet(n, rng):
    # heterogeneous, arrival-ordered (greedy consumes them in this order)
    return make_fleet(
        [dict(cmp=rng.uniform(0.4, 4) * 1e12,
              mem=rng.uniform(2, 8) * 1e9, com=0.125e9) for _ in range(n)],
        stb=rng.uniform(0, 1, n), dwl=rng.uniform(600, 3600, n))


def _best_swift(res: SW.SwiftResult):
    pipes = list(res.essential.values())
    if res.initial is not None:
        pipes.append(res.initial)
    return min((p.time for p in pipes), default=None)


def run(quick: bool = False, agent=None):
    cp = CostParams()
    rng = np.random.default_rng(1)

    # (a) cluster-size sweep, ~5.5 GB model (paper's smallest)
    units = [Unit(f"u{i}", 0.55e9, 5e13, 4e6) for i in range(10)]
    for n in ((3, 5, 7) if quick else (3, 5, 7, 9)):
        sw, gr = [], []
        for rep in range(5):
            fleet = _fleet(n, rng)
            res = SW.swift(fleet, units, agent=agent, cp=cp)
            g = SW.greedy_matching(fleet, units, cp)
            t = _best_swift(res)
            if t is not None:
                sw.append(t)
            if g is not None:
                gr.append(g.time)
        emit(f"pipeline_exec/swift_s/cluster{n}",
             f"{np.median(sw):.2f}" if sw else "infeasible",
             f"feasible={len(sw)}/5")
        emit(f"pipeline_exec/greedy_s/cluster{n}",
             f"{np.median(gr):.2f}" if gr else "infeasible",
             f"feasible={len(gr)}/5")

    # (b) model-size sweep at cluster 5 (paper: 5.55 / 11.1 / 14.0 GB)
    for gb in (5.55, 11.1, 14.0):
        units_b = [Unit(f"u{i}", gb * 1e9 / 10, 5e13 * gb / 5.55, 4e6)
                   for i in range(10)]
        sw, gr = [], []
        for rep in range(5):
            fleet = _fleet(5, rng)
            res = SW.swift(fleet, units_b, agent=agent, cp=cp)
            g = SW.greedy_matching(fleet, units_b, cp)
            t = _best_swift(res)
            if t is not None:
                sw.append(t)
            if g is not None:
                gr.append(g.time)
        emit(f"pipeline_exec/swift_s/model{gb}GB",
             f"{np.median(sw):.2f}" if sw else "infeasible",
             f"feasible={len(sw)}/5")
        emit(f"pipeline_exec/greedy_s/model{gb}GB",
             f"{np.median(gr):.2f}" if gr else "infeasible",
             f"feasible={len(gr)}/5")
