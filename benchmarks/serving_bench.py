"""Serving-tier throughput/latency bench (paged KV + continuous batching).

Pre-trains the smoke AD-LLM for a few dozen SGD steps on a structured
synthetic token stream (so the served model has peaked, deployment-like
logits rather than flat random-init ones), then pushes the same bimodal
fleet request trace — short control-style replies with a heavy tail of
long plans, arrivals delayed by each vehicle's V2X uplink — through three
serving modes:

  ``continuous_fp32``  paged KV, lanes refilled as requests finish
  ``rebatch_fp32``     naive static rebatching: waves admitted only when
                       every lane is empty (the strawman the speedup
                       gate compares against)
  ``continuous_int8``  continuous batching over int8-quantized KV pools

plus a teacher-forced int8-vs-fp32 cache replay that isolates the
per-position greedy flip rate of cache quantization (a scheduler-level
stream diff would let one early flip cascade).

Writes schema-gated ``BENCH_serving.json`` (fifth perf-trajectory entry;
``scripts/validate_bench.py`` enforces the >=1.5x warm-throughput win of
continuous batching over rebatching, identical greedy streams between
the two policies, and <=2% teacher-forced int8 greedy disagreement).

    PYTHONPATH=src python benchmarks/serving_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import json

DEFAULT_OUT = "BENCH_serving.json"
FLEET = "nano*2,agx*2"
WORKLOAD = dict(max_context=16, max_prompt=8, block_size=8, slots=4,
                short_new=(6, 10), long_new=(64, 96), long_frac=0.3)


def pretrain(cfg, steps: int, *, lr: float = 0.5, batch: int = 8,
             seq: int = 16, noise: float = 0.1, seed: int = 1):
    """Short SGD on the affine stream t+1 = (3t + 7) mod V with label
    noise — enough structure that the served model predicts confidently.
    Uses the XLA attention path (kernel_backend off) for speed; the flag
    is restored before returning."""
    import jax
    import jax.numpy as jnp
    from repro.models import blocks as B
    from repro.models import lm

    params = lm.init(jax.random.PRNGKey(seed), cfg)

    def make_batch(key):
        k1, k2, k3 = jax.random.split(key, 3)
        t0 = jax.random.randint(k1, (batch, 1), 0, cfg.vocab_size)
        toks = [t0]
        for _ in range(seq - 1):
            toks.append((3 * toks[-1] + 7) % cfg.vocab_size)
        toks = jnp.concatenate(toks, 1)
        flip = jax.random.bernoulli(k2, noise, toks.shape)
        rnd = jax.random.randint(k3, toks.shape, 0, cfg.vocab_size)
        return jnp.where(flip, rnd, toks)

    def loss_fn(p, toks):
        logits, _, _ = lm.forward(p, cfg, toks[:, :-1],
                                  positions=jnp.arange(toks.shape[1] - 1))
        lp = jax.nn.log_softmax(logits, -1)
        return -jnp.mean(jnp.take_along_axis(lp, toks[:, 1:, None], -1))

    @jax.jit
    def step(p, toks):
        l, g = jax.value_and_grad(loss_fn)(p, toks)
        return jax.tree.map(lambda a, b: a - lr * b, p, g), l

    was_kernel = B.kernel_backend()
    B.set_kernel_backend(False)
    try:
        key = jax.random.PRNGKey(seed + 1)
        loss = None
        for _ in range(steps):
            key, k = jax.random.split(key)
            params, loss = step(params, make_batch(k))
    finally:
        B.set_kernel_backend(was_kernel)
    return params, float(loss)


def run(quick: bool = False, out: str = DEFAULT_OUT) -> dict:
    try:
        from benchmarks.common import emit
    except ImportError:          # invoked as `python benchmarks/...py`
        from common import emit
    from repro.api.serving import serve_requests
    from repro.configs import get_config
    from repro.configs.common import reduced
    from repro.serve import (generate_fleet_requests, int8_cache_fidelity,
                             serve_continuous)

    num_requests, pre_steps, warm_passes = (12, 40, 2) if quick \
        else (16, 60, 3)
    cfg = reduced(get_config("flad_adllm")).replace(param_dtype="float32")
    params, pre_loss = pretrain(cfg, pre_steps)
    print(f"serving: pretrained {pre_steps} steps, loss {pre_loss:.3f}")

    opts = dict(params=params, fleet=FLEET, num_requests=num_requests,
                warm_passes=warm_passes, **WORKLOAD)
    modes = []
    results = {}
    for name, policy, cache in (("continuous_fp32", "continuous", "fp32"),
                                ("rebatch_fp32", "rebatch", "fp32"),
                                ("continuous_int8", "continuous", "int8")):
        rep = serve_continuous(cfg, policy=policy, cache=cache, **opts)
        results[name] = rep
        modes.append({
            "name": name, "policy": policy, "cache": cache,
            "requests": rep["requests"],
            "total_new_tokens": rep["total_new_tokens"],
            "decode_steps": rep["decode_steps"],
            "prefills": rep["prefills"],
            "tokens_per_s": rep["tokens_per_s"],
            "warm_tokens_per_s": rep["warm_tokens_per_s"],
            "p50_latency_s": rep["p50_latency_s"],
            "p99_latency_s": rep["p99_latency_s"],
            "deadline_hit_rate": rep["deadline_hit_rate"],
        })

    cont, reb = results["continuous_fp32"], results["rebatch_fp32"]
    streams_match = cont["sequences"] == reb["sequences"]

    requests = generate_fleet_requests(
        FLEET, num_requests=num_requests,
        max_prompt=WORKLOAD["max_prompt"],
        short_new=WORKLOAD["short_new"], long_new=WORKLOAD["long_new"],
        long_frac=WORKLOAD["long_frac"], seed=0,
        vocab_size=cfg.vocab_size)
    fid = int8_cache_fidelity(cfg, params, requests, cont["sequences"],
                              block_size=WORKLOAD["block_size"],
                              max_context=WORKLOAD["max_context"])

    legacy = serve_requests(cfg, batch=WORKLOAD["slots"],
                            context=WORKLOAD["max_context"],
                            decode_steps=16, requests=3, params=params,
                            log_fn=None)

    payload = {
        "bench": "serving_tier",
        "schema_version": 1,
        "arch": cfg.name,
        "quick": bool(quick),
        "workload": {
            "fleet": FLEET,
            "num_requests": num_requests,
            "pretrain_steps": pre_steps,
            "pretrain_loss": pre_loss,
            "warm_passes": warm_passes,
            "slots": WORKLOAD["slots"],
            "block_size": WORKLOAD["block_size"],
            "max_context": WORKLOAD["max_context"],
            "max_prompt": WORKLOAD["max_prompt"],
            "short_new": list(WORKLOAD["short_new"]),
            "long_new": list(WORKLOAD["long_new"]),
            "long_frac": WORKLOAD["long_frac"],
        },
        "modes": modes,
        "int8": {
            "teacher_forced_disagreement": fid["disagreement"],
            "positions": fid["positions"],
            "max_logit_drift": fid["max_logit_drift"],
        },
        "legacy": {
            "tokens_per_s": legacy["tokens_per_s"],
            "warm_tokens_per_s": legacy["warm_tokens_per_s"],
        },
        "summary": {
            "continuous_speedup": (cont["warm_tokens_per_s"]
                                   / reb["warm_tokens_per_s"]),
            "decode_step_ratio": (reb["decode_steps"]
                                  / cont["decode_steps"]),
            "streams_match": bool(streams_match),
            "int8_disagreement": fid["disagreement"],
            "int8_warm_tokens_per_s":
                results["continuous_int8"]["warm_tokens_per_s"],
            "p50_latency_improvement": (reb["p50_latency_s"]
                                        / max(cont["p50_latency_s"],
                                              1e-9)),
        },
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    s = payload["summary"]
    for m in modes:
        emit(f"serving/{m['name']}/warm_tokens_per_s",
             m["warm_tokens_per_s"],
             f"decode_steps={m['decode_steps']} "
             f"p50={m['p50_latency_s']:.3f}s p99={m['p99_latency_s']:.3f}s")
    print(f"serving: continuous x{s['continuous_speedup']:.2f} warm tok/s "
          f"vs rebatch (step ratio x{s['decode_step_ratio']:.2f}), int8 "
          f"disagreement {s['int8_disagreement']:.3%} -> {out}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
