"""Chunked-prefill + prefix-cache bench for the serving tier.

Pre-trains the smoke AD-LLM (same pretrain as the serving bench, so the
served model has peaked logits), then measures the two prefill claims of
the serving tier:

  1. **TTFT** — the same mixed short/long fleet trace is served twice,
     once through the monolithic bucketed prefill (every prompt padded to
     ``max_context``, one synchronous prefill per admission) and once
     through chunked paged prefill (one fixed-size chunk per scheduler
     step, interleaved with decode). Time-to-first-token percentiles come
     from the loadgen's *simulated* clock under a
     :class:`repro.serve.PrefillCostModel` that charges each step for the
     prefill compute it actually issued — padded prompt tokens (linear
     work) plus attention score MACs. Wall-clock on this CPU container
     runs interpret-mode Pallas and says nothing about accelerator cost;
     the padded-token and MAC counts are the honest FLOP proxy, and both
     raw totals are reported alongside the sim-time percentiles.
  2. **Prefix sharing** — a pod-templated trace (shared template prefix +
     unique per-vehicle suffix) is served with the prefix cache on and
     off. The cache must produce identical greedy streams while mapping
     template blocks instead of recomputing them (nonzero hit rate,
     measured pool-block savings).

Greedy streams must be identical across all of it — chunked vs
monolithic on the mixed trace, cache on vs off on the pod trace.

Writes schema-gated ``BENCH_prefill.json`` (seventh perf-trajectory
entry; ``scripts/validate_bench.py`` enforces TTFT p50 >= 1.5x better
than monolithic, matching streams, nonzero prefix hit rate and block
savings).

    PYTHONPATH=src python benchmarks/prefill_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import json

DEFAULT_OUT = "BENCH_prefill.json"
FLEET = "nano*2,agx*2"
# dt_step prices the fused decode step the same way PrefillCostModel
# prices prefill work: slots lanes x one token x s_per_token — so the
# chunked path's extra steps are charged consistently, not punitively.
WORKLOAD = dict(max_context=64, max_prompt=24, block_size=8, slots=4,
                prefill_chunk=16, short_new=(6, 10), long_new=(24, 40),
                long_frac=0.3, dt_step=2e-4)
POD = dict(pods=2, template_len=32, max_suffix=8)


def _mode_row(name: str, rep: dict) -> dict:
    return {
        "name": name,
        "requests": rep["requests"],
        "total_new_tokens": rep["total_new_tokens"],
        "decode_steps": rep["decode_steps"],
        "prefills": rep["prefills"],
        "prefill_chunks": rep["prefill_chunks"],
        "prefill_padded_tokens": rep["prefill_padded_tokens"],
        "prefill_attn_mac": rep["prefill_attn_mac"],
        "p50_ttft_s": rep["p50_ttft_s"],
        "p99_ttft_s": rep["p99_ttft_s"],
        "p50_queue_wait_s": rep["p50_queue_wait_s"],
        "p99_queue_wait_s": rep["p99_queue_wait_s"],
        "p50_latency_s": rep["p50_latency_s"],
        "p99_latency_s": rep["p99_latency_s"],
        "sim_time_s": rep["sim_time_s"],
    }


def run(quick: bool = False, out: str = DEFAULT_OUT) -> dict:
    try:
        from benchmarks.common import emit
        from benchmarks.serving_bench import pretrain
    except ImportError:          # invoked as `python benchmarks/...py`
        from common import emit
        from serving_bench import pretrain
    from repro.configs import get_config
    from repro.configs.common import reduced
    from repro.serve import (PrefillCostModel, generate_fleet_requests,
                             generate_pod_requests, serve_continuous)

    num_requests, pre_steps = (12, 40) if quick else (16, 60)
    cfg = reduced(get_config("flad_adllm")).replace(param_dtype="float32")
    params, pre_loss = pretrain(cfg, pre_steps)
    print(f"prefill: pretrained {pre_steps} steps, loss {pre_loss:.3f}")

    cost = PrefillCostModel()
    mixed = generate_fleet_requests(
        FLEET, num_requests=num_requests,
        max_prompt=WORKLOAD["max_prompt"],
        short_new=WORKLOAD["short_new"], long_new=WORKLOAD["long_new"],
        long_frac=WORKLOAD["long_frac"], seed=0,
        vocab_size=cfg.vocab_size)
    base = dict(params=params, slots=WORKLOAD["slots"],
                block_size=WORKLOAD["block_size"],
                max_context=WORKLOAD["max_context"],
                prefill_chunk=WORKLOAD["prefill_chunk"],
                dt_step=WORKLOAD["dt_step"], prefill_cost=cost,
                warm_passes=1, log_fn=None)

    results = {}
    for name, prefill in (("monolithic", "monolithic"),
                          ("chunked", "chunked")):
        results[name] = serve_continuous(cfg, prefill=prefill,
                                         requests=mixed, **base)
    mono, chunk = results["monolithic"], results["chunked"]
    streams_match_mixed = mono["sequences"] == chunk["sequences"]

    pod_requests = generate_pod_requests(
        FLEET, num_requests=num_requests, seed=0,
        vocab_size=cfg.vocab_size, short_new=WORKLOAD["short_new"],
        long_new=WORKLOAD["long_new"], long_frac=WORKLOAD["long_frac"],
        **POD)
    pod = {}
    for name, share in (("off", False), ("on", True)):
        pod[name] = serve_continuous(cfg, prefill="chunked",
                                     prefix_cache=share,
                                     requests=pod_requests, **base)
    streams_match_pod = pod["on"]["sequences"] == pod["off"]["sequences"]

    ttft_p50_speedup = mono["p50_ttft_s"] / max(chunk["p50_ttft_s"], 1e-12)
    ttft_p99_speedup = mono["p99_ttft_s"] / max(chunk["p99_ttft_s"], 1e-12)
    payload = {
        "bench": "prefill_tier",
        "schema_version": 1,
        "arch": cfg.name,
        "quick": bool(quick),
        "workload": {
            "fleet": FLEET,
            "num_requests": num_requests,
            "pretrain_steps": pre_steps,
            "pretrain_loss": pre_loss,
            "slots": WORKLOAD["slots"],
            "block_size": WORKLOAD["block_size"],
            "max_context": WORKLOAD["max_context"],
            "max_prompt": WORKLOAD["max_prompt"],
            "prefill_chunk": WORKLOAD["prefill_chunk"],
            "short_new": list(WORKLOAD["short_new"]),
            "long_new": list(WORKLOAD["long_new"]),
            "long_frac": WORKLOAD["long_frac"],
            "dt_step": WORKLOAD["dt_step"],
            "cost_s_per_token": cost.s_per_token,
            "cost_s_per_mac": cost.s_per_mac,
            "pod": dict(POD),
        },
        "modes": [_mode_row("monolithic", mono),
                  _mode_row("chunked", chunk)],
        "pod": {
            "requests": pod["on"]["requests"],
            "prefix_hits": pod["on"]["prefix_hits"],
            "prefix_misses": pod["on"]["prefix_misses"],
            "prefix_hit_rate": pod["on"]["prefix_hit_rate"],
            "prefix_cached_tokens": pod["on"]["prefix_cached_tokens"],
            "prefix_blocks_saved": pod["on"]["prefix_blocks_saved"],
            "p50_ttft_s_shared": pod["on"]["p50_ttft_s"],
            "p50_ttft_s_unshared": pod["off"]["p50_ttft_s"],
            "prefill_padded_tokens_shared":
                pod["on"]["prefill_padded_tokens"],
            "prefill_padded_tokens_unshared":
                pod["off"]["prefill_padded_tokens"],
            "streams_match": bool(streams_match_pod),
        },
        "summary": {
            "ttft_p50_speedup": ttft_p50_speedup,
            "ttft_p99_speedup": ttft_p99_speedup,
            "padded_token_ratio": (mono["prefill_padded_tokens"]
                                   / max(1, chunk["prefill_padded_tokens"])),
            "attn_mac_ratio": (mono["prefill_attn_mac"]
                               / max(1, chunk["prefill_attn_mac"])),
            "streams_match": bool(streams_match_mixed),
            "prefix_hit_rate": pod["on"]["prefix_hit_rate"],
            "prefix_blocks_saved": pod["on"]["prefix_blocks_saved"],
        },
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    s = payload["summary"]
    emit("prefill/ttft_p50_speedup", s["ttft_p50_speedup"],
         f"mono={mono['p50_ttft_s'] * 1e3:.2f}ms "
         f"chunked={chunk['p50_ttft_s'] * 1e3:.2f}ms sim")
    emit("prefill/padded_token_ratio", s["padded_token_ratio"],
         f"mono={mono['prefill_padded_tokens']} "
         f"chunked={chunk['prefill_padded_tokens']} padded tokens")
    emit("prefill/prefix_hit_rate", s["prefix_hit_rate"],
         f"hits={pod['on']['prefix_hits']} "
         f"blocks_saved={s['prefix_blocks_saved']} "
         f"cached_tokens={pod['on']['prefix_cached_tokens']}")
    print(f"prefill: TTFT p50 x{s['ttft_p50_speedup']:.2f} "
          f"(p99 x{s['ttft_p99_speedup']:.2f}) vs monolithic, padded "
          f"tokens x{s['padded_token_ratio']:.1f} fewer, prefix hit rate "
          f"{s['prefix_hit_rate']:.0%} saving "
          f"{s['prefix_blocks_saved']} pool blocks -> {out}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
