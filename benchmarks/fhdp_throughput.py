"""Paper Fig. 7(a,b) + Table 2: FHDP vs random-partition vs standalone.

Real execution on forced host devices: the FHDP pipelined step (SWIFT
template) vs a random unbalanced template vs single-device training of
the same model (no communication). Reports throughput (samples/s),
per-device stage memory footprint, and per-boundary activation volume
(Table 2's communication characteristics).

Claims reproduced: FHDP >= ~70% of standalone throughput (paper: 75%) and
beats the random split on both memory and throughput.

All model/mesh/strategy wiring goes through ``common.bench_session``."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import bench_session, emit, time_fn
from repro.config import ShapeConfig
from repro.configs.common import concrete_batch


def _stage_bytes(pp):
    per_stage = {}
    for name, st in pp["stacks"].items():
        leaves = jax.tree.leaves(st)
        S = leaves[0].shape[0]
        for s in range(S):
            per_stage[s] = per_stage.get(s, 0) + sum(
                x[s].size * x[s].dtype.itemsize for x in leaves)
    shared = sum(x.size * x.dtype.itemsize
                 for x in jax.tree.leaves(pp["shared"]))
    return {s: b + shared for s, b in per_stage.items()}


def run(quick: bool = False):
    # 2-stage pipelines x 4 FL clients — matches the paper's testbed scale
    # (Fig. 7 uses 2-3 Jetson pipelines); a stage count beyond the layer
    # count would only measure SPMD padding waste.
    shape = ShapeConfig("bench", 32, 16, "train")
    alone = bench_session("flad-vision", mesh=(4, 2), shape=shape,
                          strategy="tensor", remat=False)
    cfg, mesh = alone.cfg, alone.mesh
    batch = concrete_batch(cfg, shape, alone.prng())

    # ---- standalone (single device, no communication) ----
    sstep, (params, opt0) = alone.build()
    t_alone = time_fn(lambda: sstep(params, opt0, batch),
                      iters=3 if quick else 5)
    emit("fhdp/standalone_samples_per_s",
         f"{shape.global_batch / t_alone:.2f}")

    def run_template(tag, tmpl):
        # same init key as the standalone session -> identical params
        ses = bench_session("flad-vision", mesh=mesh, shape=shape,
                            strategy="pipeline", templates=dict(tmpl))
        step, (pp, opt_) = ses.build()
        h = ses.strategy.helpers
        t = time_fn(lambda: step(pp, opt_, batch),
                    iters=3 if quick else 5)
        mem = _stage_bytes(pp)
        emit(f"fhdp/{tag}_samples_per_s", f"{shape.global_batch / t:.2f}",
             f"frac_of_standalone={t_alone / t:.2f}")
        emit(f"fhdp/{tag}_max_stage_MB", f"{max(mem.values())/1e6:.2f}",
             f"mean={np.mean(list(mem.values()))/1e6:.2f}MB")
        # Table 2: per-boundary activation volume per microbatch
        act = shape.seq_len * cfg.d_model * 4 * h["mb"]
        n_bound = sum(1 for c in list(tmpl.values())[0] if c) - 1
        emit(f"fhdp/{tag}_boundary_MB_per_mb", f"{act/1e6:.3f}",
             f"boundaries={max(n_bound, 0)}")
        return t

    t_swift = run_template("swift", {"blocks": (1, 1)})
    t_rand = run_template("random", {"blocks": (2, 0)})
    emit("fhdp/swift_vs_random_speedup", f"{t_rand / t_swift:.2f}x",
         "paper Fig 7b: 1.4x")
