"""§Roofline: render the per-(arch x shape x mesh) roofline table from the
dry-run JSON (results/dryrun_baseline_v4.json by default).

Terms per device (TPU v5e: 197 TF bf16, 819 GB/s HBM, ~50 GB/s ICI):
  compute_s    = HLO dot/conv FLOPs / peak
  memory_s     = HLO operand+result bytes / HBM bw
  collective_s = collective payload bytes / ICI link bw
(all trip-count-corrected by the launch/hlo_parse walker).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

DEFAULT = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_baseline_v4.json")


def run(quick: bool = False, path: str = DEFAULT):
    if not os.path.exists(path):
        emit("roofline/status", "missing",
             "run: python -m repro.launch.dryrun --all --both-meshes "
             "--out results/dryrun_baseline_v4.json")
        return
    rows = json.load(open(path))
    n_ok = sum(r["ok"] for r in rows)
    emit("roofline/pairs_ok", f"{n_ok}/{len(rows)}")
    fits = sum(1 for r in rows if r.get("fits_hbm"))
    emit("roofline/pairs_fit_hbm", f"{fits}/{n_ok}")
    print(f"{'arch':22s} {'shape':12s} {'mesh':8s} {'comp_s':>8s} "
          f"{'mem_s':>8s} {'coll_s':>8s} {'dom':>7s} {'useful':>6s} "
          f"{'peakGiB':>8s}")
    for r in rows:
        if not r["ok"]:
            print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} FAILED")
            continue
        rf = r["roofline"]
        print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
              f"{rf['compute_s']:8.4f} {rf['memory_s']:8.4f} "
              f"{rf['collective_s']:8.4f} {r['dominant'][:7]:>7s} "
              f"{(r['useful_ratio'] or 0):6.2f} "
              f"{r.get('peak_bytes', 0)/2**30:8.1f}")
