"""Paper Fig. 5(b): recovery time — FLAD's template mechanism vs elastic
vs full relaunch over injected failure traces. Claim reproduced: ~10x
faster than relaunch (paper: 5 s vs 50 s), throughput-stable."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.recovery.failures import sample_failures
from repro.recovery.recover import recover, run_failure_sequence
from repro.recovery.templates import pregenerate
from repro.sched.costmodel import (CostParams, JETSON_AGX, JETSON_NANO,
                                   JETSON_NX, make_fleet, model_units)


def run(quick: bool = False):
    cp = CostParams()
    units = model_units(get_config("flad_adllm"), seq_len=512)
    rng = np.random.default_rng(0)
    fleet = make_fleet(
        [dict(JETSON_NANO)] * 4 + [dict(JETSON_NX)] * 2 + [dict(JETSON_AGX)],
        stb=rng.uniform(0, 1, 7), dwl=rng.uniform(900, 5400, 7))

    ts = pregenerate(fleet, units, cp)
    one = {s: recover(s, ts, fleet[0].vid, fleet, units, cp).seconds
           for s in ("template", "elastic", "relaunch")}
    for s, t in one.items():
        emit(f"recovery/single_{s}_s", f"{t:.2f}")
    emit("recovery/speedup_vs_relaunch",
         f"{one['relaunch'] / one['template']:.1f}x",
         "paper: 10x (5s vs 50s)")

    fails = sample_failures(fleet, 7200, seed=2)
    for s in ("template", "elastic", "relaunch"):
        res = run_failure_sequence(fleet, units, fails, s, cp)
        emit(f"recovery/trace_mean_{s}_s", f"{res['mean_recovery_s']:.2f}",
             f"recoveries={res['recoveries']}")
