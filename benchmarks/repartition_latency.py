"""Live repartition latency — the executed counterpart of Fig. 5(b).

Measures the real scheduler->runtime template switch on a running FHDP
session (pre-generated template lookup, live param merge + restage, jitted
step rebuild, recompile) and writes ``BENCH_repartition.json`` — the first
entry of the repo's performance trajectory. ``scripts/validate_bench.py``
gates the schema in CI.

    PYTHONPATH=src python benchmarks/repartition_latency.py [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

DEFAULT_OUT = "BENCH_repartition.json"
MESH = (2, 4)
DEPART_VID = 1


def run(quick: bool = False, out: str = DEFAULT_OUT) -> dict:
    import jax

    from repro.api import LoopHooks, MeshSpec, Session
    from repro.api.session import load_config
    from repro.config import ShapeConfig
    from repro.recovery.recover import Repartitioner, recover
    from repro.sched.costmodel import demo_fleet, model_units

    pre = 2 if quick else 5
    post = 2 if quick else 5
    cfg = load_config("flad-vision").replace(num_layers=4)
    unit_cap = model_units(cfg, seq_len=64, num_units=4)[0].cap
    session = Session(cfg=cfg, strategy="swift_pipeline",
                      mesh=MeshSpec(MESH), learning_rate=2e-3,
                      shape=ShapeConfig("bench", 16, 8, "train"),
                      fleet=demo_fleet(unit_cap), seq_len=64)
    session.build()
    strat = session.strategy

    hooks = LoopHooks(log_every=max(pre, 1))
    out_pre = session.run(pre, hooks=hooks)
    pre_loss = out_pre["history"][-1]["loss"]

    # analytic recovery comparison on the same fleet/templates (Fig. 5b)
    vehicles = list(strat.vehicles)
    analytic = {
        s: recover(s, strat.template_set, DEPART_VID, vehicles,
                   strat.units, strat.cost).seconds
        for s in ("template", "elastic", "relaunch")}

    # the measured departure: lookup -> restage -> rebuild, then recompile
    rep = Repartitioner(session, {})
    params, opt = session.state
    step2, pp2, opt2 = rep.depart(pre, DEPART_VID, params, opt)
    ev = rep.events[0]

    batch = next(session.default_batches())
    t0 = time.perf_counter()
    jax.block_until_ready(step2(pp2, opt2, batch))
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    jax.block_until_ready(step2(pp2, opt2, batch))
    post_step_s = time.perf_counter() - t0

    out_post = session.run(post, hooks=hooks)
    post_loss = out_post["history"][-1]["loss"]

    res = strat.swift_result
    payload = {
        "bench": "repartition_latency",
        "schema_version": 1,
        "arch": cfg.name,
        "mesh": list(MESH),
        "quick": bool(quick),
        "fleet": [dataclasses.asdict(v) for v in vehicles],
        "swift": {"phase1_s": res.phase1_s, "phase2_s": res.phase2_s,
                  "essential_pipelines": len(res.essential)},
        "event": ev.as_dict(),
        "compile_s": compile_s,
        "post_step_s": post_step_s,
        "pre_loss": float(pre_loss),
        "post_loss": float(post_loss),
        "analytic": {f"{k}_s": v for k, v in analytic.items()},
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"repartition: {ev.total_s * 1e3:.1f} ms live switch "
          f"(+{compile_s:.2f} s recompile), analytic template "
          f"{analytic['template']:.2f} s vs relaunch "
          f"{analytic['relaunch']:.2f} s -> {out}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
