"""Paper Fig. 10 (direction): waypoint quality across the federated
distillation stack — warmed cloud teacher, cloud-merged global student,
and per-pod personalized students — all through the ``distill_fl``
Session strategy (the same code path as the launcher and tests; the
offline ``make_distill_step`` pipeline is no longer driven here).

Claims reproduced in direction: the KD term transfers teacher skill into
the adapters (the same schedule with ``kd_weight=0`` is emitted as the
ablation), and per-pod LoRA personalization closes the regional gap at
~1-5% of parameters."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import bench_session, emit


def _session(rounds, kd_weight):
    from repro.api import LoopHooks
    quiet = LoopHooks(log_every=10 ** 9, log_fn=lambda *a, **k: None)
    ses = bench_session("flad-adllm", mesh=(2,), shape="16x8",
                        strategy="distill_fl", learning_rate=3e-2,
                        hooks=quiet, topology="2@nano*2", codec="int8",
                        local_steps=2, lora_rank=4, kd_weight=kd_weight,
                        mix=0.25, warmup_steps=30, beta=0.05,
                        samples_per_vehicle=128, heldout=64)
    ses.run(rounds)
    return ses


def run(quick: bool = False):
    from repro.distill.federated import waypoint_eval
    from repro.distill.lora import lora_param_count

    rounds = 4 if quick else 8
    ses = _session(rounds, kd_weight=0.1)
    st = ses.strategy
    acfg = st.adllm_cfg(ses.cfg)
    _, held, _ = st.datasets(ses.cfg, ses.shape)
    base = ses.state[0]["base"]
    global_model = ses.merged_params()

    # the frozen teacher (cloud AD-LLM after supervised warmup)
    t_l1 = float(np.mean([waypoint_eval(base, acfg, h) for h in held]))
    emit("distill/teacher_L1", f"{t_l1:.4f}",
         f"warmup {st.warmup_history[0]:.4f}->"
         f"{st.warmup_history[-1]:.4f}")

    # cloud-merged global student vs per-pod personalized students
    g_l1 = float(np.mean([waypoint_eval(global_model, acfg, h)
                          for h in held]))
    emit("distill/global_L1", f"{g_l1:.4f}",
         f"teacher better by {g_l1 - t_l1:+.4f}" if g_l1 > t_l1
         else f"beats teacher by {t_l1 - g_l1:.4f}")
    for e in range(len(held)):
        g = waypoint_eval(global_model, acfg, held[e])
        p = waypoint_eval(st.pod_params(ses.state, e), acfg, held[e])
        emit(f"distill/pod{e}_personalized_L1", f"{p:.4f}",
             f"global {g:.4f}, regional gain {g - p:+.4f}")

    # adapter footprint: what personalization actually trains
    factors0 = jax.tree.map(lambda x: x[0], ses.state[0]["factors"])
    n_lora = lora_param_count(factors0)
    n_full = sum(x.size for x in jax.tree.leaves(base))
    emit("distill/lora_param_frac", f"{n_lora / n_full:.4f}",
         f"{n_lora}/{n_full} params")

    # KD ablation: same schedule, students cut off from the teacher
    ses0 = _session(rounds, kd_weight=0.0)
    st0 = ses0.strategy
    _, held0, _ = st0.datasets(ses0.cfg, ses0.shape)
    a_l1 = float(np.mean([waypoint_eval(st0.pod_params(ses0.state, e),
                                        acfg, held0[e])
                          for e in range(len(held0))]))
    p_l1 = float(np.mean([waypoint_eval(st.pod_params(ses.state, e),
                                        acfg, held[e])
                          for e in range(len(held))]))
    emit("distill/no_kd_personalized_L1", f"{a_l1:.4f}",
         f"with KD {p_l1:.4f}, KD contributes {a_l1 - p_l1:+.4f}")
