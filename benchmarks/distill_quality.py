"""Paper Fig. 10 (direction): waypoint quality across LLM configurations —
warmed teacher AD-LLM, distilled student ADM, from-scratch student, and
LoRA-personalized teacher. Claim reproduced: distillation transfers most
of the teacher's waypoint skill into the compact ADM; LoRA closes the
regional gap at ~1-5% of parameters."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.configs.common import reduced
from repro.data.synthetic import DrivingDataConfig, TownWorld, make_tokens
from repro.distill.celladapt import (adllm_config, adllm_waypoints,
                                     init_adllm, make_distill_step,
                                     make_finetune_step, waypoint_l1)
from repro.train.optimizer import Adam


def _batch(world, dcfg, cfg, town, n, seed):
    rng = np.random.default_rng(seed)
    s = world.sample(town, n, rng)
    return {"features": jnp.asarray(s["rgb"][:, :cfg.prefix_tokens]),
            "tokens": jnp.asarray(make_tokens(s["light"], town, 32,
                                              cfg.vocab_size, rng)),
            "waypoints": jnp.asarray(s["waypoints"])}


def run(quick: bool = False):
    steps = 30 if quick else 80
    base = reduced(get_config("flad_adllm"))
    tcfg = adllm_config(base, feature_dim=64, feature_tokens=16,
                        num_waypoints=10)
    scfg = tcfg.replace(num_layers=1, d_ff=128)
    dcfg = DrivingDataConfig(feature_dim=64, patches=16, num_waypoints=10)
    world = TownWorld(dcfg)
    key = jax.random.PRNGKey(0)

    teacher = init_adllm(key, tcfg)
    opt = Adam(lr=2e-3)
    ost = opt.init(teacher)

    @jax.jit
    def sup_step(p, st, batch, cfg_id):
        del cfg_id
        def loss(p):
            wp = adllm_waypoints(p, tcfg, batch["features"],
                                 batch["tokens"])
            return waypoint_l1(wp, batch["waypoints"])
        l, g = jax.value_and_grad(loss)(p)
        p, st = opt.update(g, st, p)
        return p, st, l

    for i in range(steps):
        teacher, ost, tl = sup_step(teacher, ost,
                                    _batch(world, dcfg, tcfg, i % 2, 16, i),
                                    0)
    eval_b = _batch(world, dcfg, tcfg, 0, 128, 999)
    t_l1 = float(waypoint_l1(adllm_waypoints(
        teacher, tcfg, eval_b["features"], eval_b["tokens"]),
        eval_b["waypoints"]))
    emit("distill/teacher_L1", f"{t_l1:.4f}")

    # distilled student
    student = init_adllm(jax.random.PRNGKey(1), scfg)
    dstep, dopt = make_distill_step(tcfg, scfg, lr=2e-3)
    dst = dopt.init(student)
    for i in range(steps):
        student, dst, _ = dstep(student, dst, teacher,
                                _batch(world, dcfg, tcfg, i % 2, 16,
                                       500 + i))
    s_l1 = float(waypoint_l1(adllm_waypoints(
        student, scfg, eval_b["features"], eval_b["tokens"]),
        eval_b["waypoints"]))
    emit("distill/student_distilled_L1", f"{s_l1:.4f}")

    # from-scratch student (no teacher)
    scr = init_adllm(jax.random.PRNGKey(2), scfg)
    sopt = Adam(lr=2e-3)
    sst = sopt.init(scr)

    @jax.jit
    def scr_step(p, st, batch):
        def loss(p):
            wp = adllm_waypoints(p, scfg, batch["features"],
                                 batch["tokens"])
            return waypoint_l1(wp, batch["waypoints"])
        l, g = jax.value_and_grad(loss)(p)
        p, st = sopt.update(g, st, p)
        return p, st, l

    # the paper's setting: labeled local data is scarce at the edge (the
    # teacher's skill came from the cloud corpus) — the from-scratch
    # student sees only a handful of labeled batches
    for i in range(max(steps // 8, 5)):
        scr, sst, _ = scr_step(scr, sst,
                               _batch(world, dcfg, tcfg, i % 2, 16,
                                      900 + i % 3))
    scr_l1 = float(waypoint_l1(adllm_waypoints(
        scr, scfg, eval_b["features"], eval_b["tokens"]),
        eval_b["waypoints"]))
    emit("distill/student_scratch_L1", f"{scr_l1:.4f}",
         f"distilled better by {scr_l1 - s_l1:.4f}")

    # LoRA personalization to an unseen town
    fstep, lora, fopt = make_finetune_step(tcfg, teacher, lr=5e-3)
    fst = fopt.init(lora)
    b3 = _batch(world, dcfg, tcfg, 3, 128, 777)
    pre = float(waypoint_l1(adllm_waypoints(
        teacher, tcfg, b3["features"], b3["tokens"]), b3["waypoints"]))
    for i in range(steps):
        lora, fst, _ = fstep(lora, fst,
                             _batch(world, dcfg, tcfg, 3, 16, 1500 + i))
    from repro.distill.lora import LoRAConfig, merge_lora
    merged = merge_lora(teacher, lora, LoRAConfig())
    post = float(waypoint_l1(adllm_waypoints(
        merged, tcfg, b3["features"], b3["tokens"]), b3["waypoints"]))
    emit("distill/lora_region_L1", f"{pre:.4f}->{post:.4f}",
         "personalization gain")
