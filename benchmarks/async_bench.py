"""Simulated time-to-target-loss: synchronous vs event-driven async FL.

Both modes run through the same discrete-event engine
(:mod:`repro.comm.events`), so their simulated clocks are directly
comparable:

  ``sync``   infinite cloud deadline — the cloud merges when every pod
             has reported, so each round is gated by the slowest
             vehicle (the classic straggler problem the paper's
             parallelized collaborative training targets);
  ``async``  the cloud merges on a fixed clock, edge pods flush partial
             aggregates instead of waiting for stragglers, and late
             commits are down-weighted by their **observed** staleness
             lag.

Two straggler severities (fraction of the fleet that is a ~8x-slower
``nano``): 25% and 50%. Per severity: the sync run's final held-out
loss is the target; the async run gets the same simulated-time budget,
and its speedup is sync-total-time over the first merge at which its
held-out loss reaches the target. Writes schema-gated
``BENCH_async.json`` (fourth perf-trajectory entry;
``scripts/validate_bench.py`` enforces >= 1.5x speedup at the
50%-straggler point with <= 2% held-out loss regression).

    PYTHONPATH=src python benchmarks/async_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import json

DEFAULT_OUT = "BENCH_async.json"
SEVERITIES = (
    (0.25, "2@nano*1,agx*3"),     # 1 straggler in 4, sharing a pod
    (0.50, "2@nano*2,agx*2"),     # a whole straggler pod
)
COMPUTE_FLOPS = 4.7e11            # ~2.0 s/round on a nano, ~0.25 s on agx
CLOCK = 0.4                       # async cloud merge period (simulated s)
DECAY = 0.7                       # observed-staleness decay per lag round


def _make_heldout_loss(model, heldout, bs=64):
    """Jitted held-out evaluator — it runs after every async merge, so
    an eager per-batch loss would dominate the benchmark's wall time."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    loss_fn = jax.jit(lambda p, b: model.loss(p, b)[0])
    batches = []
    for data in heldout:
        n = len(data["light"])
        for i in range(0, n - bs + 1, bs):
            batches.append({k: jnp.asarray(v[i:i + bs])
                            for k, v in data.items()})

    def heldout_loss(params):
        return float(np.mean([float(loss_fn(params, b)) for b in batches]))

    return heldout_loss


def run(quick: bool = False, out: str = DEFAULT_OUT) -> dict:
    import jax.numpy as jnp
    import numpy as np

    try:
        from benchmarks.common import bench_session, emit
    except ImportError:          # invoked as `python benchmarks/...py`
        from common import bench_session, emit
    from repro.api import LoopHooks, load_config
    from repro.comm.topology import parse_topology
    from repro.config import ShapeConfig
    from repro.data.partition import fleet_datasets
    from repro.data.pipeline import client_round_batches
    from repro.data.synthetic import DrivingDataConfig, TownWorld
    from repro.train.loop import async_fl_loop

    rounds, locsteps, bs, samples = (3, 2, 16, 256) if quick \
        else (6, 2, 16, 384)
    quiet = LoopHooks(log_every=10 ** 9, log_fn=lambda *a, **k: None)

    cfg = load_config("flad-vision")
    dcfg = DrivingDataConfig(feature_dim=cfg.prefix_dim,
                             patches=cfg.prefix_tokens or 8,
                             num_waypoints=cfg.num_waypoints,
                             num_light_classes=cfg.num_light_classes,
                             n_towns=4)
    shape = ShapeConfig("async", dcfg.patches, bs, "train")
    world = TownWorld(dcfg)
    rng = np.random.default_rng(99)
    heldout = [world.sample(t, 64 if quick else 128, rng)
               for t in range(dcfg.n_towns)]
    from repro.models import build_model
    heldout_loss = _make_heldout_loss(build_model(cfg), heldout)

    def severity_run(severity, spec):
        topo = parse_topology(spec)
        datasets = fleet_datasets(dcfg, topo.n_clients, samples, beta=1.0)

        def round_batches(r):
            rb = client_round_batches(datasets, locsteps, bs, round_idx=r)
            return {k: jnp.asarray(v) for k, v in rb.items()}

        def session(**options):
            return bench_session(
                "flad-vision", mesh=(1,), shape=shape,
                strategy="async_hier_fl", learning_rate=2e-3,
                local_steps=locsteps, remat=False, topology=topo,
                codec="int8", compute_flops=COMPUTE_FLOPS, **options)

        # ---- sync: infinite deadline, every round gated by stragglers
        ses = session()
        sync_out = ses.run(rounds, batches=round_batches, hooks=quiet)
        t_budget = sync_out["sim_time_s"]
        sync_loss = heldout_loss(ses.merged_params())
        sync_rec = {"rounds": sync_out["merges"],
                    "sim_time_s": t_budget, "final_loss": sync_loss}

        # ---- async: merge clock, same simulated-time budget; evaluate
        # the merged global params at every cloud merge
        asy = session(clock=CLOCK, decay=DECAY)
        engine, (params, opt) = asy.build()
        curve = []
        staleness = []

        def on_round(r, metrics):
            # hooks.on_round sees every merge; the loop's history only
            # records merges that pass the log cadence
            staleness.append(float(metrics["staleness_mean"]))
            curve.append((float(metrics["t_sim"]),
                          heldout_loss(engine.global_params)))

        hooks = LoopHooks(log_every=10 ** 9, log_fn=lambda *a, **k: None,
                          on_round=on_round)
        asy_out = async_fl_loop(engine, params, opt, round_batches,
                                rounds=10 ** 6, hooks=hooks,
                                until_time=t_budget)
        async_loss = curve[-1][1]
        t_hit = next((t for t, l in curve if l <= sync_loss), None)
        if t_hit is None:
            raise SystemExit(
                f"async never reached the sync target loss {sync_loss:.4f}"
                f" within {t_budget:.2f}s simulated (best "
                f"{min(l for _, l in curve):.4f})")
        speedup = t_budget / t_hit
        drift = max(0.0, async_loss / sync_loss - 1.0)
        return {
            "severity": severity,
            "topology": spec,
            "sync": sync_rec,
            "async": {
                "merges": asy_out["merges"],
                "sim_time_s": asy_out["sim_time_s"],
                "final_loss": async_loss,
                "clock": CLOCK,
                "decay": DECAY,
                "t_target_s": t_hit,
                "staleness_mean": float(np.mean(staleness)),
            },
            "speedup": speedup,
            "loss_drift": drift,
        }

    severities = [severity_run(s, spec) for s, spec in SEVERITIES]
    payload = {
        "bench": "async_fabric",
        "schema_version": 1,
        "arch": cfg.name,
        "quick": bool(quick),
        "sync_rounds": rounds,
        "local_steps": locsteps,
        "compute_flops": COMPUTE_FLOPS,
        "severities": severities,
        "summary": {
            f"speedup_{int(s['severity'] * 100)}": s["speedup"]
            for s in severities} | {
            f"drift_{int(s['severity'] * 100)}": s["loss_drift"]
            for s in severities},
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    for s in severities:
        emit(f"async/straggler_{int(s['severity'] * 100)}/speedup",
             round(s["speedup"], 3),
             f"drift={s['loss_drift']:.4f} "
             f"sync={s['sync']['sim_time_s']:.2f}s "
             f"t_target={s['async']['t_target_s']:.2f}s")
    print(f"async: " + ", ".join(
        f"{int(s['severity'] * 100)}% stragglers -> x{s['speedup']:.1f} "
        f"time-to-target (drift {s['loss_drift']:.3f})"
        for s in severities) + f" -> {out}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
