"""Speculative decoding with the distilled pod student as draft model.

Runs ``distill_fl`` end to end (same recipe and round schedule as
``distill_fl_bench``), then serves each edge pod's held-out traffic
through the continuous paged tier three ways, all against the SAME
target — the pod's personalized student (base + pod adapter), i.e. the
model :meth:`repro.api.Session.serve` deploys at that edge:

  * **baseline** — plain one-token-per-step greedy decode;
  * **pod draft** — draft-verify speculative decode where the draft IS
    the pod student (shared weights, no second checkpoint: the
    ``DraftEngine`` reuses the target's compiled forwards and only owns
    its own KV pools);
  * **global draft** — the same machinery drafting with the cloud-merged
    global model, the ablation that prices what personalization buys.

Three claims, schema-gated by ``scripts/validate_bench.py``:

  * greedy streams are **bit-identical** across all three runs on every
    pod — speculation changes the clock, never the tokens;
  * the pod-matched draft sustains >= 1.3x the baseline's sim-time
    tokens/s (FLOP-proxy :class:`~repro.serve.SpecDecodeCostModel`,
    which charges draft forwards and the widened verify chunk);
  * the pod-matched draft's acceptance rate beats the global draft's on
    every pod — the same personalization gap ``BENCH_distill.json``
    measures as waypoint L1, re-measured as accepted draft tokens.

Settings mirror ``tests/test_distill_fl.py`` — the round schedule is
part of the claim, so ``--quick`` shrinks nothing (recorded in the
payload for provenance only). Writes ``BENCH_specdec.json``.

    PYTHONPATH=src python benchmarks/specdec_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

DEFAULT_OUT = "BENCH_specdec.json"
TOPOLOGY = "2@nano*2"               # 2 edge pods x 1 vehicle each
ROUNDS = 8
DRAFT_K = 4
REQUESTS_PER_POD = 8
PROMPT_LEN = 10
MAX_NEW = 10


def _pod_requests(held_pod, n, plen, max_new):
    import numpy as np

    from repro.serve import ServeRequest

    toks = np.asarray(held_pod["tokens"])
    return [ServeRequest(rid=i, prompt=toks[i, :plen].astype(np.int32),
                         max_new_tokens=max_new,
                         arrival_s=0.01 * i, deadline_s=10.0)
            for i in range(n)]


def _spec_stats(report):
    return {
        "acceptance_rate": report["acceptance_rate"],
        "proposed_drafts": report["proposed_drafts"],
        "accepted_drafts": report["accepted_drafts"],
        "spec_steps": report["spec_steps"],
        "draft_forwards": report["draft_forwards"],
        "decode_steps": report["decode_steps"],
        "total_new_tokens": report["total_new_tokens"],
        "sim_time_s": report["sim_time_s"],
    }


def run(quick: bool = False, out: str = DEFAULT_OUT) -> dict:
    try:
        from benchmarks.common import bench_session, emit
    except ImportError:          # invoked as `python benchmarks/...py`
        from common import bench_session, emit

    from repro.api import LoopHooks
    from repro.serve import (PrefillCostModel, SpecDecodeCostModel,
                             serve_continuous)

    quiet = LoopHooks(log_every=10 ** 9, log_fn=lambda *a, **k: None)
    ses = bench_session("flad-adllm", mesh=(2,), shape="16x8",
                        strategy="distill_fl", learning_rate=3e-2,
                        hooks=quiet, topology=TOPOLOGY, codec="int8",
                        local_steps=2, lora_rank=4, kd_weight=0.1,
                        mix=0.25, warmup_steps=30, beta=0.05,
                        samples_per_vehicle=128, heldout=64)
    ses.run(ROUNDS)

    st = ses.strategy
    global_model = ses.merged_params()
    _, held, _ = st.datasets(ses.cfg, ses.shape)

    pods = []
    for e in range(len(held)):
        target = st.pod_params(ses.state, e)
        reqs = _pod_requests(held[e], REQUESTS_PER_POD, PROMPT_LEN,
                             MAX_NEW)
        common = dict(params=target, slots=2, block_size=4,
                      max_context=PROMPT_LEN + MAX_NEW,
                      prefill="chunked", prefill_chunk=8,
                      prefix_cache=True, requests=reqs, log_fn=None,
                      warm_passes=1)
        base = serve_continuous(ses.cfg, prefill_cost=PrefillCostModel(),
                                **common)
        pod_draft = serve_continuous(
            ses.cfg, speculative=True, draft_k=DRAFT_K,
            draft_params=st.pod_params(ses.state, e),
            prefill_cost=SpecDecodeCostModel(), **common)
        glob_draft = serve_continuous(
            ses.cfg, speculative=True, draft_k=DRAFT_K,
            draft_params=global_model,
            prefill_cost=SpecDecodeCostModel(), **common)
        pods.append({
            "pod": e,
            "baseline": {
                "decode_steps": base["decode_steps"],
                "total_new_tokens": base["total_new_tokens"],
                "sim_time_s": base["sim_time_s"],
            },
            "pod_draft": _spec_stats(pod_draft),
            "global_draft": _spec_stats(glob_draft),
            "speedup_pod": base["sim_time_s"] / pod_draft["sim_time_s"],
            "speedup_global": base["sim_time_s"]
            / glob_draft["sim_time_s"],
            "streams_match_pod": pod_draft["sequences"]
            == base["sequences"],
            "streams_match_global": glob_draft["sequences"]
            == base["sequences"],
        })

    topo = st.topology
    payload = {
        "bench": "specdec",
        "schema_version": 1,
        "arch": ses.cfg.name,
        "quick": bool(quick),
        "rounds": ROUNDS,
        "draft_k": DRAFT_K,
        "topology": {
            "spec": TOPOLOGY,
            "edges": topo.n_edges,
            "vehicles": topo.n_clients,
        },
        "workload": {
            "requests_per_pod": REQUESTS_PER_POD,
            "prompt_len": PROMPT_LEN,
            "max_new_tokens": MAX_NEW,
        },
        "pods": pods,
        "summary": {
            "streams_match": all(p["streams_match_pod"]
                                 and p["streams_match_global"]
                                 for p in pods),
            "min_pod_speedup": min(p["speedup_pod"] for p in pods),
            "mean_pod_acceptance": sum(
                p["pod_draft"]["acceptance_rate"] for p in pods)
            / len(pods),
            "mean_global_acceptance": sum(
                p["global_draft"]["acceptance_rate"] for p in pods)
            / len(pods),
            "min_acceptance_gap": min(
                p["pod_draft"]["acceptance_rate"]
                - p["global_draft"]["acceptance_rate"] for p in pods),
        },
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    for p in pods:
        emit(f"specdec/pod{p['pod']}/speedup", p["speedup_pod"],
             f"acc_pod={p['pod_draft']['acceptance_rate']:.3f} "
             f"acc_global={p['global_draft']['acceptance_rate']:.3f}")
    s = payload["summary"]
    print(f"specdec: x{s['min_pod_speedup']:.2f} min sim speedup with "
          f"the pod draft, acceptance gap "
          f">={s['min_acceptance_gap']:+.3f} over the global draft, "
          f"streams_match={s['streams_match']} -> {out}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
