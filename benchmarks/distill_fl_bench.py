"""Adapter-only uplinks and per-edge personalization of ``distill_fl``.

Runs the federated personalized distillation strategy end to end through
:class:`repro.api.Session` — cloud AD-LLM warmup, frozen teacher, per-pod
LoRA students on non-IID town partitions, int8 adapter deltas over the
vehicle->edge->cloud fabric — and accounts for the two claims the
strategy makes:

  * **wire**: an (A, B) adapter delta is >= 20x smaller on the vehicle
    uplink than the full-delta payload a ``hier_fl`` round moves for the
    same arch / topology / codec;
  * **personalization**: each pod's student (base + pod adapter) beats
    the global model (base + cloud-merged adapter) on its own pod's
    held-out partition, measured as waypoint L1.

Settings mirror the acceptance test in ``tests/test_distill_fl.py`` —
the round schedule is part of the claim, so ``--quick`` shrinks nothing
(it is recorded in the payload for provenance only). Writes schema-gated
``BENCH_distill.json`` (sixth perf-trajectory entry;
``scripts/validate_bench.py`` enforces the >= 20x uplink reduction and a
non-negative personalization delta on every pod).

    PYTHONPATH=src python benchmarks/distill_fl_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

DEFAULT_OUT = "BENCH_distill.json"
TOPOLOGY = "2@nano*2"               # 2 edge pods x 1 vehicle each
ROUNDS = 8


def run(quick: bool = False, out: str = DEFAULT_OUT) -> dict:
    try:
        from benchmarks.common import bench_session, emit
    except ImportError:          # invoked as `python benchmarks/...py`
        from common import bench_session, emit

    from repro.api import LoopHooks
    from repro.api.strategies import get_strategy
    from repro.distill.federated import waypoint_eval

    quiet = LoopHooks(log_every=10 ** 9, log_fn=lambda *a, **k: None)
    ses = bench_session("flad-adllm", mesh=(2,), shape="16x8",
                        strategy="distill_fl", learning_rate=3e-2,
                        hooks=quiet, topology=TOPOLOGY, codec="int8",
                        local_steps=2, lora_rank=4, kd_weight=0.1,
                        mix=0.25, warmup_steps=30, beta=0.05,
                        samples_per_vehicle=128, heldout=64)
    outp = ses.run(ROUNDS)

    st = ses.strategy
    acfg = st.adllm_cfg(ses.cfg)
    cs = st.comm_stats
    adapter = {
        "rank": st.lora_cfg.rank,
        "bytes_per_client": cs["bytes_per_client"],
        "uplink_bytes_per_round": cs["uplink_bytes"],
        "backhaul_bytes_per_round": cs["backhaul_bytes"],
        "sim_round_s": cs["round_time_s"],
    }
    # the full-delta comparison: a hier_fl round on the same arch,
    # topology, and codec ships the whole parameter delta per vehicle
    hs = get_strategy("hier_fl", topology=TOPOLOGY,
                      codec="int8")._round_stats(ses.cfg)
    full_delta = {
        "bytes_per_client": hs["bytes_per_client"],
        "uplink_bytes_per_round": hs["uplink_bytes"],
        "backhaul_bytes_per_round": hs["backhaul_bytes"],
        "sim_round_s": hs["round_time_s"],
    }

    _, held, _ = st.datasets(ses.cfg, ses.shape)
    global_model = ses.merged_params()
    pods = []
    for e in range(len(held)):
        g = waypoint_eval(global_model, acfg, held[e])
        p = waypoint_eval(st.pod_params(ses.state, e), acfg, held[e])
        pods.append({"pod": e, "global_l1": g, "pod_l1": p,
                     "delta": g - p})

    topo = st.topology
    deltas = [p["delta"] for p in pods]
    payload = {
        "bench": "distill_fl",
        "schema_version": 1,
        "arch": ses.cfg.name,
        "quick": bool(quick),
        "rounds": ROUNDS,
        "local_steps": st.local_steps,
        "topology": {
            "spec": TOPOLOGY,
            "edges": topo.n_edges,
            "vehicles": topo.n_clients,
        },
        "distill": {
            "kd_weight": st.kd_weight,
            "kd_temp": st.kd_temp,
            "mix": st.mix,
            "warmup_steps": st.warmup_steps,
            "warmup_loss_first": float(st.warmup_history[0]),
            "warmup_loss_last": float(st.warmup_history[-1]),
        },
        "adapter": adapter,
        "full_delta": full_delta,
        "pods": pods,
        "summary": {
            "uplink_reduction": (full_delta["uplink_bytes_per_round"]
                                 / adapter["uplink_bytes_per_round"]),
            "payload_reduction": (full_delta["bytes_per_client"]
                                  / adapter["bytes_per_client"]),
            "mean_personalization_delta": sum(deltas) / len(deltas),
            "min_personalization_delta": min(deltas),
        },
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    s = payload["summary"]
    emit("distill/adapter/uplink_bytes",
         adapter["uplink_bytes_per_round"],
         f"full_delta={full_delta['uplink_bytes_per_round']}")
    for p in pods:
        emit(f"distill/pod{p['pod']}/waypoint_l1", p["pod_l1"],
             f"global={p['global_l1']:.4f} delta={p['delta']:+.4f}")
    print(f"distill_fl: x{s['uplink_reduction']:.1f} fewer uplink bytes "
          f"than full-delta hier_fl, min pod delta "
          f"{s['min_personalization_delta']:+.4f} -> {out}")
    return payload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    run(quick=args.quick, out=args.out)


if __name__ == "__main__":
    main()
