"""Paged-cache prefill/decode forward for the decoder-only LM family.

Splits :func:`repro.models.lm.forward` at the KV boundary so decode runs
against the block pools of :mod:`repro.serve.kvcache` instead of a
per-request contiguous cache:

  * **prefill** reuses the contiguous machinery unchanged — one request
    at a time, prompt padded to a fixed ``max_context`` bucket (one jit
    trace), causal masking keeps the padded tail out of every real
    position's attention — and returns the last true token's logits plus
    the layer-stacked K/V to scatter into pool blocks;
  * **decode** re-implements the block walk as a ``lax.scan`` whose xs
    carry each layer's pool slices: embed -> rms/qkv/rope (positions =
    per-request context lengths) -> append the token's K/V into its
    physical block -> the paged Pallas decode kernel
    (:func:`repro.kernels.ops.paged_decode_attention`) -> wo/ffn. All
    ``slots`` batch lanes run every step; dead lanes point at the null
    block and cost one masked tile.

The numerics match the contiguous path op for op (same rope-after-norm
order, float32 softmax statistics), which is what the paged-vs-contiguous
equivalence test in ``tests/test_serve.py`` pins down.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import blocks as B
from repro.models import lm
from repro.serve import kvcache as KC

_PAGED_FAMILIES = ("dense", "moe")


class PagedEngine:
    """Jitted paged prefill/decode pair for one (cfg, spec, slots)."""

    def __init__(self, cfg: ModelConfig, spec: KC.PagedCacheSpec, *,
                 max_context: int, slots: int):
        if cfg.family not in _PAGED_FAMILIES:
            raise NotImplementedError(
                f"paged serving covers the LM families {_PAGED_FAMILIES}; "
                f"{cfg.family!r} keeps the legacy contiguous path")
        if cfg.window is not None:
            raise NotImplementedError(
                "paged serving assumes full causal attention (window=None)")
        if max_context > spec.max_tokens_per_req:
            raise ValueError(
                f"max_context {max_context} exceeds the table capacity "
                f"{spec.max_tokens_per_req} tokens")
        self.cfg = cfg
        self.spec = spec
        self.max_context = int(max_context)
        self.slots = int(slots)
        self._prefill = jax.jit(self._prefill_impl)
        self._prefill_chunk = jax.jit(self._prefill_chunk_impl)
        self._decode = jax.jit(self._decode_impl)
        self._verify = jax.jit(self._verify_impl)
        self._write = jax.jit(functools.partial(KC.write_prefill, spec=spec))
        self._copy_block = jax.jit(self._copy_block_impl)

    # ---- pools --------------------------------------------------------
    def init_pools(self) -> Dict:
        return KC.init_pools(self.cfg, self.spec)

    # ---- prefill ------------------------------------------------------
    def _prefill_impl(self, params, tokens, length):
        """tokens: [1, max_context] int32 (padded); length: scalar int32.
        Returns (last-token logits [1, V], k [L, Hkv, Smax, D], v)."""
        cfg = self.cfg
        caches = lm.init_cache(cfg, 1, self.max_context)
        x, new_caches, _ = lm.forward(params, cfg, tokens, caches=caches,
                                      hidden_only=True)
        h = x[:, length - 1]                       # [1, d], true last token
        if cfg.tie_embeddings:
            logits = B.unembed(params["embed"], h[:, None])[:, 0]
        else:
            logits = B.linear(params["head"], h).astype(jnp.float32)
        k = new_caches["k"][:, 0]                  # [L, Hkv, Smax, D]
        v = new_caches["v"][:, 0]
        return logits, k, v

    def prefill(self, params, tokens, length) -> Tuple:
        return self._prefill(params, tokens, length)

    def write_prefill(self, pools, k_layers, v_layers, table_row) -> Dict:
        return self._write(pools, k_layers=k_layers, v_layers=v_layers,
                           table_row=table_row)

    # ---- chunked prefill ---------------------------------------------
    def _prefill_chunk_impl(self, params, pools, tokens, table, q_offset,
                            chunk_len):
        """One prompt chunk of ONE request straight into its pool blocks.

        tokens: [C] int32 (rows past ``chunk_len`` are padding); table:
        [T] int32 logical->physical; q_offset/chunk_len: scalar int32
        (chunk covers absolute positions [q_offset, q_offset +
        chunk_len)). No ``[L, Hkv, Smax, D]`` staging buffer and no
        max_context padding: each layer scatters the chunk's K/V into the
        pool (padding rows target the null block) and attends to the
        prior context *plus itself* through the block table via the
        chunked-prefill Pallas kernel. Returns (logits [1, V] of the
        chunk's last true row — only meaningful on the final chunk — and
        the updated pools). Mirrors ``_decode_impl`` op for op so chunked
        and monolithic prefill agree bit-for-bit in greedy streams."""
        cfg, spec = self.cfg, self.spec
        nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
        c = tokens.shape[0]
        scale = hd ** -0.5

        x = B.embed(params["embed"], tokens[None])         # [1, C, d]
        pos = q_offset + jnp.arange(c, dtype=jnp.int32)    # absolute
        positions = pos[None]                              # [1, C]
        rows = jnp.arange(c, dtype=jnp.int32)
        blk = pos // spec.block_size
        phys = jnp.where(rows < chunk_len, table[blk], 0)  # [C]
        off = pos % spec.block_size

        def body(carry, layer):
            h_in = carry
            lp, layer_pools = layer
            ap = lp["attn"]
            h = B.rms_norm(lp["ln1"], h_in, cfg.norm_eps)
            q = h @ ap["wq"]
            k = h @ ap["wk"]
            v = h @ ap["wv"]
            if "bq" in ap:
                q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
            q = B._split_heads(q, nq, hd)                  # [1, Hq, C, D]
            k = B._split_heads(k, nkv, hd)
            v = B._split_heads(v, nkv, hd)
            if "q_norm" in ap:
                q = B._head_rmsnorm(q, ap["q_norm"], cfg.norm_eps)
                k = B._head_rmsnorm(k, ap["k_norm"], cfg.norm_eps)
            q = B.rope(q, positions, cfg.rope_theta)
            k = B.rope(k, positions, cfg.rope_theta)

            new_pools = KC.append_token(layer_pools, spec, k[0], v[0],
                                        phys, off)
            from repro.kernels import ops as kops
            o = kops.paged_prefill_attention(
                q[0], new_pools["k"], new_pools["v"], table,
                q_offset, q_offset + chunk_len, scale=scale,
                k_scales=new_pools.get("k_scale"),
                v_scales=new_pools.get("v_scale"))         # [Hq, C, D]
            h_in = h_in + (o.transpose(1, 0, 2).reshape(1, c, nq * hd)
                           @ ap["wo"]).astype(h_in.dtype)
            hh = B.rms_norm(lp["ln2"], h_in, cfg.norm_eps)
            if "moe" in lp:
                f, _ = B.moe_block(lp["moe"], hh, cfg)
            else:
                f = B.mlp(lp["ffn"], hh)
            return h_in + f, new_pools

        x, new_pools = jax.lax.scan(body, x, (params["blocks"], pools))
        h = B.rms_norm(params["ln_f"], x[:, chunk_len - 1], cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = B.unembed(params["embed"], h[:, None])[:, 0]
        else:
            logits = B.linear(params["head"], h).astype(jnp.float32)
        return logits, new_pools

    def prefill_chunk(self, params, pools, tokens, table, q_offset,
                      chunk_len) -> Tuple:
        return self._prefill_chunk(params, pools, tokens, table,
                                   jnp.int32(q_offset), jnp.int32(chunk_len))

    def _copy_block_impl(self, pools, src, dst):
        """Copy-on-write helper: clone physical block ``src`` into ``dst``
        across every pool tensor (block axis 2 of [L, Hkv, NB, bs, D])."""
        return {k: p.at[:, :, dst].set(p[:, :, src])
                for k, p in pools.items()}

    def copy_block(self, pools, src, dst) -> Dict:
        return self._copy_block(pools, jnp.int32(src), jnp.int32(dst))

    # ---- decode -------------------------------------------------------
    def _decode_impl(self, params, pools, tokens, tables, ctx_lens):
        """One decode step for all slots.

        tokens: [slots] int32 (the pending token per lane); tables:
        [slots, T] int32; ctx_lens: [slots] int32 (KV written so far —
        the pending token's position). Returns (logits [slots, V],
        updated pools)."""
        cfg, spec = self.cfg, self.spec
        nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
        slots = tokens.shape[0]
        scale = hd ** -0.5

        x = B.embed(params["embed"], tokens[:, None])      # [slots, 1, d]
        positions = ctx_lens[:, None].astype(jnp.int32)    # [slots, 1]
        blk = (ctx_lens // spec.block_size)[:, None]
        phys = jnp.take_along_axis(tables, blk, axis=1)[:, 0]   # [slots]
        off = ctx_lens % spec.block_size

        def body(carry, layer):
            h_in = carry
            lp, layer_pools = layer
            ap = lp["attn"]
            h = B.rms_norm(lp["ln1"], h_in, cfg.norm_eps)
            q = h @ ap["wq"]
            k = h @ ap["wk"]
            v = h @ ap["wv"]
            if "bq" in ap:
                q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
            q = B._split_heads(q, nq, hd)                  # [slots,Hq,1,D]
            k = B._split_heads(k, nkv, hd)
            v = B._split_heads(v, nkv, hd)
            if "q_norm" in ap:
                q = B._head_rmsnorm(q, ap["q_norm"], cfg.norm_eps)
                k = B._head_rmsnorm(k, ap["k_norm"], cfg.norm_eps)
            q = B.rope(q, positions, cfg.rope_theta)
            k = B.rope(k, positions, cfg.rope_theta)

            k_tok = k[:, :, 0].transpose(1, 0, 2)          # [Hkv,slots,D]
            v_tok = v[:, :, 0].transpose(1, 0, 2)
            new_pools = KC.append_token(layer_pools, spec, k_tok, v_tok,
                                        phys, off)
            from repro.kernels import ops as kops
            o = kops.paged_decode_attention(
                q[:, :, 0], new_pools["k"], new_pools["v"], tables,
                ctx_lens + 1, scale=scale,
                k_scales=new_pools.get("k_scale"),
                v_scales=new_pools.get("v_scale"))         # [slots,Hq,D]
            h_in = h_in + (o.reshape(slots, 1, nq * hd)
                           @ ap["wo"]).astype(h_in.dtype)
            hh = B.rms_norm(lp["ln2"], h_in, cfg.norm_eps)
            if "moe" in lp:
                f, _ = B.moe_block(lp["moe"], hh, cfg)
            else:
                f = B.mlp(lp["ffn"], hh)
            return h_in + f, new_pools

        x, new_pools = jax.lax.scan(body, x, (params["blocks"], pools))
        x = B.rms_norm(params["ln_f"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = B.unembed(params["embed"], x)[:, 0]
        else:
            logits = B.linear(params["head"], x).astype(jnp.float32)[:, 0]
        return logits, new_pools

    def decode(self, params, pools, tokens, tables, ctx_lens) -> Tuple:
        return self._decode(params, pools, tokens, tables, ctx_lens)

    # ---- speculative verify -------------------------------------------
    def _verify_impl(self, params, pools, tokens, tables, ctx_lens,
                     chunk_lens):
        """Score a draft window of C = k+1 tokens per lane in ONE target
        forward (the speculative-decode verify pass).

        tokens: [slots, C] int32 — column 0 is the lane's pending token,
        columns 1..k its greedy draft proposals; tables: [slots, T];
        ctx_lens: [slots] int32 (KV written so far — column c sits at
        absolute position ctx + c); chunk_lens: [slots] int32 per-lane
        window (rows at or past a lane's chunk_len neither append K/V
        nor produce meaningful logits — they are masked to the
        null-block contract, which also covers dead lanes via ctx 0 /
        table 0 / chunk C). Verification is exactly a chunk of decode
        positions attending through the lane's block table, so the walk
        mirrors ``_prefill_chunk_impl`` batched over lanes (the chunked
        Pallas kernel runs per lane inside the jit via
        :func:`repro.kernels.ops.paged_verify_attention`). Returns
        (logits [slots, C, V], updated pools) — row c of a lane is the
        next-token distribution after draft position c, which the
        scheduler compares against the proposals for exact-match
        acceptance."""
        cfg, spec = self.cfg, self.spec
        nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
        slots, c = tokens.shape
        scale = hd ** -0.5

        x = B.embed(params["embed"], tokens)               # [slots, C, d]
        cols = jnp.arange(c, dtype=jnp.int32)
        positions = ctx_lens[:, None] + cols[None, :]      # [slots, C]
        valid = cols[None, :] < chunk_lens[:, None]
        safe_pos = jnp.where(valid, positions, 0)
        blk = safe_pos // spec.block_size
        phys = jnp.take_along_axis(tables, blk, axis=1)
        phys = jnp.where(valid, phys, 0).reshape(-1)       # [slots*C]
        off = jnp.where(valid, safe_pos % spec.block_size, 0).reshape(-1)

        def body(carry, layer):
            h_in = carry
            lp, layer_pools = layer
            ap = lp["attn"]
            h = B.rms_norm(lp["ln1"], h_in, cfg.norm_eps)
            q = h @ ap["wq"]
            k = h @ ap["wk"]
            v = h @ ap["wv"]
            if "bq" in ap:
                q, k, v = q + ap["bq"], k + ap["bk"], v + ap["bv"]
            q = B._split_heads(q, nq, hd)                  # [slots,Hq,C,D]
            k = B._split_heads(k, nkv, hd)
            v = B._split_heads(v, nkv, hd)
            if "q_norm" in ap:
                q = B._head_rmsnorm(q, ap["q_norm"], cfg.norm_eps)
                k = B._head_rmsnorm(k, ap["k_norm"], cfg.norm_eps)
            q = B.rope(q, positions, cfg.rope_theta)
            k = B.rope(k, positions, cfg.rope_theta)

            k_rows = k.transpose(1, 0, 2, 3).reshape(nkv, slots * c, hd)
            v_rows = v.transpose(1, 0, 2, 3).reshape(nkv, slots * c, hd)
            new_pools = KC.append_token(layer_pools, spec, k_rows, v_rows,
                                        phys, off)
            from repro.kernels import ops as kops
            o = kops.paged_verify_attention(
                q, new_pools["k"], new_pools["v"], tables, ctx_lens,
                chunk_lens, scale=scale,
                k_scales=new_pools.get("k_scale"),
                v_scales=new_pools.get("v_scale"))     # [slots, Hq, C, D]
            h_in = h_in + (o.transpose(0, 2, 1, 3).reshape(slots, c,
                                                           nq * hd)
                           @ ap["wo"]).astype(h_in.dtype)
            hh = B.rms_norm(lp["ln2"], h_in, cfg.norm_eps)
            if "moe" in lp:
                f, _ = B.moe_block(lp["moe"], hh, cfg)
            else:
                f = B.mlp(lp["ffn"], hh)
            return h_in + f, new_pools

        x, new_pools = jax.lax.scan(body, x, (params["blocks"], pools))
        x = B.rms_norm(params["ln_f"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = B.unembed(params["embed"], x)
        else:
            logits = B.linear(params["head"], x).astype(jnp.float32)
        return logits, new_pools

    def verify(self, params, pools, tokens, tables, ctx_lens,
               chunk_lens) -> Tuple:
        return self._verify(params, pools, tokens, tables, ctx_lens,
                            chunk_lens)

    # ---- sampling -----------------------------------------------------
    def make_sampler(self, sampling: str = "greedy",
                     temperature: float = 1.0):
        """Jitted sampler(logits [B, V], key) -> tokens [B] int32."""
        if sampling == "greedy":
            @jax.jit
            def sample(logits, key):
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        elif sampling == "temperature":
            t = float(temperature)

            @jax.jit
            def sample(logits, key):
                return jax.random.categorical(
                    key, logits / t, axis=-1).astype(jnp.int32)
        else:
            raise ValueError(
                f"unknown sampling {sampling!r} (greedy|temperature)")
        return sample

    def pad_prompt(self, prompt) -> Tuple:
        """Host helper: right-pad a [s] prompt to the fixed prefill
        bucket. Returns (tokens [1, max_context] int32, length int32)."""
        import numpy as np
        s = len(prompt)
        if s > self.max_context:
            raise ValueError(f"prompt length {s} > max_context "
                             f"{self.max_context}")
        buf = np.zeros((1, self.max_context), np.int32)
        buf[0, :s] = np.asarray(prompt, np.int32)
        return jnp.asarray(buf), jnp.int32(s)


class DraftEngine:
    """Speculative-decode draft proposer sharing the target's machinery.

    Wraps the *target* :class:`PagedEngine`'s compiled forwards with the
    distilled student's params (base + merged LoRA factors from
    ``DistillFLStrategy.pod_params`` — shared weights, no second
    checkpoint, no second compile) and a parallel set of pool tensors.
    Block tables and context lengths are the scheduler's own: K/V rows
    are a pure function of the token prefix, so the target's logical
    layout — including prefix-shared blocks, which the scheduler mirrors
    into the draft pools at prefill/copy-on-write time — is valid for
    the draft pools verbatim."""

    def __init__(self, engine: PagedEngine, params, *, draft_k: int):
        if draft_k < 1:
            raise ValueError("draft_k must be >= 1")
        self.engine = engine
        self.spec = engine.spec
        self.params = params
        self.draft_k = int(draft_k)
        self.pools = engine.init_pools()

    def propose(self, tokens, tables, ctx_lens, window):
        """Greedily draft up to ``draft_k`` tokens per lane.

        tokens: [slots] int32 pending tokens; tables: [slots, T];
        ctx_lens: [slots]; window: [slots] per-lane draft budget
        (min(draft_k + 1, tokens the lane may still emit); 0 masks a
        lane out entirely). Runs ``draft_k + 1`` batched draft decode
        forwards — forward i deposits token i's K/V at position ctx + i
        and proposes token i+1 — so even after a full accept the draft
        pools hold the true stream's K/V at every position below the new
        context length. A lane is masked to the dead-lane contract for
        forwards at or past its window, keeping appends inside its
        funded blocks. Returns drafts [slots, draft_k] int32 (zeros past
        a lane's window)."""
        import numpy as np
        slots = len(tokens)
        drafts = np.zeros((slots, self.draft_k), np.int32)
        tok = np.asarray(tokens, np.int32)
        tables = np.asarray(tables, np.int32)
        ctx = np.asarray(ctx_lens, np.int32)
        window = np.asarray(window, np.int32)
        for i in range(self.draft_k + 1):
            live = window > i
            t_i = np.where(live, tok, 0).astype(np.int32)
            tab_i = np.where(live[:, None], tables, 0).astype(np.int32)
            c_i = np.where(live, ctx + i, 0).astype(np.int32)
            logits, self.pools = self.engine.decode(
                self.params, self.pools, jnp.asarray(t_i),
                jnp.asarray(tab_i), jnp.asarray(c_i))
            tok = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            if i < self.draft_k:
                drafts[:, i] = np.where(window > i + 1, tok, 0)
        return drafts

    # ---- prefill mirroring (scheduler-driven) -------------------------
    def prefill(self, tokens, length) -> None:
        """Monolithic mirror: run the draft model's bucketed prefill and
        keep only its K/V (the stream samples from the target)."""
        _, k, v = self.engine.prefill(self.params, tokens, length)
        self._mirror_kv = (k, v)

    def write_prefill(self, table_row) -> None:
        k, v = self._mirror_kv
        self.pools = self.engine.write_prefill(self.pools, k, v, table_row)
        self._mirror_kv = None

    def prefill_chunk(self, tokens, table, pos, clen) -> None:
        """Chunked mirror: same chunk, draft params, draft pools."""
        _, self.pools = self.engine.prefill_chunk(
            self.params, self.pools, tokens, table, pos, clen)

    def copy_block(self, src, dst) -> None:
        """Copy-on-write mirror for whole-prompt prefix hits."""
        self.pools = self.engine.copy_block(self.pools, src, dst)
