"""Fleet load generator: vehicle request arrivals -> scheduler -> report.

The serving tier is load-tested through the same event machinery the FL
engine runs on (:class:`repro.comm.events.EventQueue`): each vehicle in a
:func:`repro.sched.costmodel.parse_fleet` fleet emits inference requests
whose *arrival times* are its request epoch plus the V2X uplink time of
the prompt payload (:func:`repro.sched.costmodel.t_uplink`) — an AGX with
a 0.25 GB/s link lands its prompt twice as fast as a Nano. Each request
carries a deadline (arrival + ``deadline_s``) so the report can speak the
paper's latency-SLO language.

Decode lengths are drawn bimodal — mostly short control-style replies
with a heavy tail of long plans — because that mix is both what an AD
workload looks like and what separates continuous batching from naive
rebatching: under rebatching every wave is held open by its longest
request, so the short mode's lanes idle.

The simulated clock advances ``dt_step`` per scheduler step (a fixed
nominal step cost — the *wall-clock* numbers in the bench come from real
timers around the same loop, the simulated clock only orders admissions
and scores deadlines) and jumps to the next arrival when the scheduler
goes idle. When a :class:`PrefillCostModel` is supplied, each step is
additionally charged for the prefill compute it actually ran — padded
prompt tokens (linear qkv/ffn work) plus attention score MACs — which is
what makes TTFT comparisons between chunked and monolithic prefill
honest on this CPU container: interpret-mode Pallas wall-clock says
nothing about accelerator cost, but a monolithic prefill's
``max_context`` padding and ``max_context**2`` score matrix are real
FLOPs a chunked prefill never issues.
"""
from __future__ import annotations

import dataclasses
import math
from typing import ClassVar, Dict, List, Optional, Sequence

import numpy as np

from repro.comm.events import EventQueue
from repro.sched.costmodel import Vehicle, parse_fleet, t_uplink
from repro.serve.scheduler import ContinuousScheduler, ServeRequest

#: serialized prompt-token payload over V2X (int32 id + embedding-free
#: metadata; the KV never leaves the edge)
BYTES_PER_PROMPT_TOKEN = 64


@dataclasses.dataclass(frozen=True)
class RequestArrival:
    """A vehicle's inference request landing at the edge."""
    t: float
    rid: int
    vehicle: int
    kind: ClassVar[str] = "request_arrival"


def generate_fleet_requests(fleet_spec, *, num_requests: int,
                            max_prompt: int, seed: int = 0,
                            period_s: float = 0.05,
                            deadline_s: float = 2.0,
                            short_new: tuple = (4, 8),
                            long_new: tuple = (32, 48),
                            long_frac: float = 0.2,
                            vocab_size: int = 512
                            ) -> List[ServeRequest]:
    """Deterministic request trace for a declarative fleet spec.

    Vehicles round-robin request epochs ``period_s`` apart; each arrival
    is delayed by its prompt's uplink time over that vehicle's V2X link.
    Decode lengths are bimodal (``long_frac`` of requests draw from
    ``long_new``, the rest from ``short_new``)."""
    fleet = parse_fleet(fleet_spec) if isinstance(fleet_spec, str) \
        else list(fleet_spec)
    rng = np.random.default_rng(seed)
    out = []
    for rid in range(num_requests):
        v = fleet[rid % len(fleet)]
        plen = int(rng.integers(2, max_prompt + 1))
        prompt = rng.integers(1, vocab_size, (plen,)).astype(np.int32)
        if rng.random() < long_frac:
            lo, hi = long_new
        else:
            lo, hi = short_new
        max_new = int(rng.integers(lo, hi + 1))
        epoch = (rid // len(fleet)) * period_s
        arrival = epoch + t_uplink(plen * BYTES_PER_PROMPT_TOKEN, v)
        out.append(ServeRequest(rid=rid, prompt=prompt,
                                max_new_tokens=max_new,
                                arrival_s=arrival,
                                deadline_s=arrival + deadline_s))
    return out


def generate_pod_requests(fleet_spec, *, num_requests: int, pods: int = 2,
                          template_len: int = 24, max_suffix: int = 8,
                          seed: int = 0, period_s: float = 0.05,
                          deadline_s: float = 2.0,
                          short_new: tuple = (4, 8),
                          long_new: tuple = (32, 48),
                          long_frac: float = 0.2,
                          vocab_size: int = 512) -> List[ServeRequest]:
    """Pod-templated request trace: shared prefix + unique suffix.

    FLAD's vehicles cluster into geographic pods whose AD prompts share a
    templated scene/instruction preamble; only the tail (ego state, query)
    differs per vehicle. Each of ``pods`` pods draws one fixed
    ``template_len``-token template, and every request from that pod's
    vehicles is ``template + suffix`` with a unique 1..``max_suffix``
    token suffix — exactly the shape the serving tier's prefix cache
    exploits. Arrivals/deadlines/decode lengths follow
    :func:`generate_fleet_requests`."""
    fleet = parse_fleet(fleet_spec) if isinstance(fleet_spec, str) \
        else list(fleet_spec)
    rng = np.random.default_rng(seed)
    templates = [rng.integers(1, vocab_size, (template_len,)).astype(np.int32)
                 for _ in range(pods)]
    out = []
    for rid in range(num_requests):
        v = fleet[rid % len(fleet)]
        pod = (rid % len(fleet)) % pods
        slen = int(rng.integers(1, max_suffix + 1))
        suffix = rng.integers(1, vocab_size, (slen,)).astype(np.int32)
        prompt = np.concatenate([templates[pod], suffix])
        if rng.random() < long_frac:
            lo, hi = long_new
        else:
            lo, hi = short_new
        max_new = int(rng.integers(lo, hi + 1))
        epoch = (rid // len(fleet)) * period_s
        arrival = epoch + t_uplink(len(prompt) * BYTES_PER_PROMPT_TOKEN, v)
        out.append(ServeRequest(rid=rid, prompt=prompt,
                                max_new_tokens=max_new,
                                arrival_s=arrival,
                                deadline_s=arrival + deadline_s))
    return out


@dataclasses.dataclass(frozen=True)
class PrefillCostModel:
    """Sim-time surcharge for the prefill compute a step actually ran.

    ``s_per_token`` prices the linear work (embed/qkv/ffn) of every
    *padded* prompt token the step pushed through the model —
    ``max_context`` for a monolithic prefill, the chunk size for a
    chunked one — and ``s_per_mac`` prices attention score entries
    (query rows x visible keys). The defaults are nominal edge-GPU
    magnitudes; the TTFT gate compares two runs under the SAME model, so
    only the ratio matters."""
    s_per_token: float = 5e-5
    s_per_mac: float = 2e-9

    def step_cost(self, stats: Dict) -> float:
        return (stats.get("prefill_padded_tokens", 0) * self.s_per_token
                + stats.get("prefill_attn_mac", 0) * self.s_per_mac)


@dataclasses.dataclass(frozen=True)
class SpecDecodeCostModel(PrefillCostModel):
    """Sim-time pricing for speculative draft-verify steps.

    A speculative step's target-side cost IS the ``dt_step`` every step
    already pays — the batched verify is one target forward, weight-load
    bound exactly like a plain decode step — so the surcharges here are
    only what speculation ADDS: ``s_per_draft_forward`` per draft-model
    forward (the distilled compact student of the paper's CELLAdapt
    tier, deployed at a fraction of the teacher's cost — the default is
    dt_step/8), plus the verify chunk's extra linear work
    (``verify_tokens`` x ``s_per_token``) and attention score MACs
    (``verify_attn_mac`` x ``s_per_mac``). Draft prefill mirroring is
    charged one draft forward per mirrored unit. What speculation BUYS
    is up to ``draft_k + 1`` tokens per lane out of that single priced
    step instead of one."""
    s_per_draft_forward: float = 0.00125

    def step_cost(self, stats: Dict) -> float:
        return (super().step_cost(stats)
                + stats.get("draft_forwards", 0) * self.s_per_draft_forward
                + stats.get("verify_tokens", 0) * self.s_per_token
                + stats.get("verify_attn_mac", 0) * self.s_per_mac)


def _pct(sorted_vals: List[float], p: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1,
            int(math.ceil(p / 100.0 * len(sorted_vals))) - 1)
    return sorted_vals[max(0, i)]


def drive(scheduler: ContinuousScheduler,
          requests: Sequence[ServeRequest], *,
          dt_step: float = 0.01,
          prefill_cost: Optional[PrefillCostModel] = None,
          max_steps: int = 1_000_000) -> Dict:
    """Push the request trace through the scheduler in event-time order.

    Arrivals enter a :class:`EventQueue`; the simulated clock advances
    ``dt_step`` per scheduler step (plus the step's prefill compute under
    ``prefill_cost``, when given) and jumps forward when the scheduler is
    idle and the next arrival is still in flight. Returns the latency /
    TTFT / deadline report."""
    q = EventQueue()
    by_rid = {}
    for r in requests:
        q.push(RequestArrival(t=r.arrival_s, rid=r.rid, vehicle=0))
        by_rid[r.rid] = r
    t = 0.0
    steps = 0
    pref_tokens = pref_mac = 0
    while len(q) or not scheduler.idle:
        # drain every arrival that has landed by now
        while len(q) and q.peek_t() <= t:
            ev = q.pop()
            scheduler.submit(by_rid[ev.rid])
        if scheduler.idle:
            if not len(q):
                break
            t = q.peek_t()          # nothing in flight: jump to next landing
            continue
        scheduler.step(t)
        pref_tokens += scheduler.last_stats.get("prefill_padded_tokens", 0)
        pref_mac += scheduler.last_stats.get("prefill_attn_mac", 0)
        t_end = t + dt_step
        if prefill_cost is not None:
            t_end += prefill_cost.step_cost(scheduler.last_stats)
        # first-token / completion events happen when the step's compute
        # finishes, not when it is issued — finalize their timestamps to
        # the step's end so a prefill's cost lands in its own TTFT
        for r in scheduler.step_events:
            if r.t_first_token == t:
                r.t_first_token = t_end
            if r.t_done == t:
                r.t_done = t_end
        # deferred spans read the (now final) restamped timestamps
        scheduler.flush_trace(t_end, cost_model=prefill_cost)
        t = t_end
        steps += 1
        if steps > max_steps:
            raise RuntimeError("loadgen failed to drain the request trace")

    done = scheduler.finished
    lats = sorted(r.latency_s for r in done if r.latency_s is not None)
    ttfts = sorted(r.ttft_s for r in done if r.ttft_s is not None)
    waits = sorted(r.queue_wait_s for r in done
                   if r.queue_wait_s is not None)
    # A request that never emitted a token before the drain has no
    # meaningful deadline outcome (its ttft_s/queue_wait_s are None, not
    # stale zeros) — score the SLO only over requests that started.
    scored = [r for r in done if r.t_first_token is not None]

    report = {
        "requests": len(done),
        "unstarted_requests": len(done) - len(scored),
        "total_new_tokens": scheduler.total_new_tokens,
        "decode_steps": scheduler.decode_steps_run,
        "prefills": scheduler.prefills_run,
        "prefill_chunks": scheduler.prefill_chunks_run,
        "prefill_padded_tokens": pref_tokens,
        "prefill_attn_mac": pref_mac,
        "sim_time_s": t,
        "p50_latency_s": _pct(lats, 50.0),
        "p99_latency_s": _pct(lats, 99.0),
        "p50_ttft_s": _pct(ttfts, 50.0),
        "p99_ttft_s": _pct(ttfts, 99.0),
        "p50_queue_wait_s": _pct(waits, 50.0),
        "p99_queue_wait_s": _pct(waits, 99.0),
        "deadline_hit_rate": (sum(r.met_deadline for r in scored)
                              / max(1, len(scored))),
    }
    if scheduler.speculative:
        prop = scheduler.proposed_drafts
        report.update({
            "spec_steps": scheduler.spec_steps_run,
            "draft_forwards": scheduler.draft_forwards_run,
            "proposed_drafts": prop,
            "accepted_drafts": scheduler.accepted_drafts,
            "acceptance_rate": scheduler.accepted_drafts / max(1, prop),
        })
    if scheduler.preemption:
        report["preemptions"] = scheduler.preemptions
    pool = scheduler.metrics.gauge("serve_pool_blocks_in_use").stats()
    if pool is not None:
        report["pool_blocks_mean"] = pool["mean"]
        report["pool_blocks_peak"] = pool["peak"]
    if scheduler.prefix is not None:
        pc = scheduler.prefix
        report.update({
            "prefix_hits": pc.hits,
            "prefix_misses": pc.misses,
            "prefix_hit_rate": pc.hits / max(1, pc.hits + pc.misses),
            "prefix_cached_tokens": pc.cached_tokens,
            "prefix_blocks_saved": pc.shared_blocks,
        })
    return report
