"""Fleet load generator: vehicle request arrivals -> scheduler -> report.

The serving tier is load-tested through the same event machinery the FL
engine runs on (:class:`repro.comm.events.EventQueue`): each vehicle in a
:func:`repro.sched.costmodel.parse_fleet` fleet emits inference requests
whose *arrival times* are its request epoch plus the V2X uplink time of
the prompt payload (:func:`repro.sched.costmodel.t_uplink`) — an AGX with
a 0.25 GB/s link lands its prompt twice as fast as a Nano. Each request
carries a deadline (arrival + ``deadline_s``) so the report can speak the
paper's latency-SLO language.

Decode lengths are drawn bimodal — mostly short control-style replies
with a heavy tail of long plans — because that mix is both what an AD
workload looks like and what separates continuous batching from naive
rebatching: under rebatching every wave is held open by its longest
request, so the short mode's lanes idle.

The simulated clock advances ``dt_step`` per scheduler step (a fixed
nominal step cost — the *wall-clock* numbers in the bench come from real
timers around the same loop, the simulated clock only orders admissions
and scores deadlines) and jumps to the next arrival when the scheduler
goes idle.
"""
from __future__ import annotations

import dataclasses
import math
from typing import ClassVar, Dict, List, Optional, Sequence

import numpy as np

from repro.comm.events import EventQueue
from repro.sched.costmodel import Vehicle, parse_fleet, t_uplink
from repro.serve.scheduler import ContinuousScheduler, ServeRequest

#: serialized prompt-token payload over V2X (int32 id + embedding-free
#: metadata; the KV never leaves the edge)
BYTES_PER_PROMPT_TOKEN = 64


@dataclasses.dataclass(frozen=True)
class RequestArrival:
    """A vehicle's inference request landing at the edge."""
    t: float
    rid: int
    vehicle: int
    kind: ClassVar[str] = "request_arrival"


def generate_fleet_requests(fleet_spec, *, num_requests: int,
                            max_prompt: int, seed: int = 0,
                            period_s: float = 0.05,
                            deadline_s: float = 2.0,
                            short_new: tuple = (4, 8),
                            long_new: tuple = (32, 48),
                            long_frac: float = 0.2,
                            vocab_size: int = 512
                            ) -> List[ServeRequest]:
    """Deterministic request trace for a declarative fleet spec.

    Vehicles round-robin request epochs ``period_s`` apart; each arrival
    is delayed by its prompt's uplink time over that vehicle's V2X link.
    Decode lengths are bimodal (``long_frac`` of requests draw from
    ``long_new``, the rest from ``short_new``)."""
    fleet = parse_fleet(fleet_spec) if isinstance(fleet_spec, str) \
        else list(fleet_spec)
    rng = np.random.default_rng(seed)
    out = []
    for rid in range(num_requests):
        v = fleet[rid % len(fleet)]
        plen = int(rng.integers(2, max_prompt + 1))
        prompt = rng.integers(1, vocab_size, (plen,)).astype(np.int32)
        if rng.random() < long_frac:
            lo, hi = long_new
        else:
            lo, hi = short_new
        max_new = int(rng.integers(lo, hi + 1))
        epoch = (rid // len(fleet)) * period_s
        arrival = epoch + t_uplink(plen * BYTES_PER_PROMPT_TOKEN, v)
        out.append(ServeRequest(rid=rid, prompt=prompt,
                                max_new_tokens=max_new,
                                arrival_s=arrival,
                                deadline_s=arrival + deadline_s))
    return out


def drive(scheduler: ContinuousScheduler,
          requests: Sequence[ServeRequest], *,
          dt_step: float = 0.01, max_steps: int = 1_000_000) -> Dict:
    """Push the request trace through the scheduler in event-time order.

    Arrivals enter a :class:`EventQueue`; the simulated clock advances
    ``dt_step`` per decode step and jumps forward when the scheduler is
    idle and the next arrival is still in flight. Returns the latency /
    deadline report."""
    q = EventQueue()
    by_rid = {}
    for r in requests:
        q.push(RequestArrival(t=r.arrival_s, rid=r.rid, vehicle=0))
        by_rid[r.rid] = r
    t = 0.0
    steps = 0
    while len(q) or not scheduler.idle:
        # drain every arrival that has landed by now
        while len(q) and q.peek_t() <= t:
            ev = q.pop()
            scheduler.submit(by_rid[ev.rid])
        if scheduler.idle:
            if not len(q):
                break
            t = q.peek_t()          # nothing in flight: jump to next landing
            continue
        scheduler.step(t)
        t += dt_step
        steps += 1
        if steps > max_steps:
            raise RuntimeError("loadgen failed to drain the request trace")

    done = scheduler.finished
    lats = sorted(r.latency_s for r in done)

    def pct(p: float) -> float:
        if not lats:
            return 0.0
        i = min(len(lats) - 1, int(math.ceil(p / 100.0 * len(lats))) - 1)
        return lats[max(0, i)]

    return {
        "requests": len(done),
        "total_new_tokens": scheduler.total_new_tokens,
        "decode_steps": scheduler.decode_steps_run,
        "prefills": scheduler.prefills_run,
        "sim_time_s": t,
        "p50_latency_s": pct(50.0),
        "p99_latency_s": pct(99.0),
        "deadline_hit_rate": (sum(r.met_deadline for r in done)
                              / max(1, len(done))),
    }
