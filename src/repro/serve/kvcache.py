"""Paged KV-cache manager: block-table allocation over a fixed pool.

The serving tier's memory model (vLLM-style paging, sized for the edge
AD-LLM of paper Fig. 2): physical KV storage is a fixed pool of
``num_blocks`` blocks of ``block_size`` tokens per (layer, kv-head), and
each in-flight request holds a *logical* view — a row of physical block
ids — so admission/eviction never copies or compacts KV state. Physical
block 0 is reserved as the null block: dead table slots point at it, its
contents are garbage by design, and the paged kernel masks it out via
``ctx_lens``.

Two cache modes share the layout:

  * ``fp32``/model-dtype pools — K/V stored as written;
  * int8 pools — every (token, kv-head) row is quantized through the
    :mod:`repro.kernels.quantize` Pallas pair with a per-row absmax
    scale, stored alongside as [..., 1] float32. Rows are zero-padded to
    the kernel's 128-lane layout (padding cannot change a row's absmax)
    and the random-bits input is pinned to 2**31 — ``floor(x + 0.5)`` —
    so cache quantization is deterministic round-to-nearest rather than
    stochastic: a cache entry must read back identically every step.

Host-side allocation (:class:`BlockAllocator`) is deliberately plain
Python — the scheduler calls it between jitted steps; everything that
touches tensors (:func:`init_pools`, :func:`write_prefill`,
:func:`append_token`) is pure and jit-safe.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops as kops
from repro.kernels.quantize import LANES

#: pinned random-bits word giving u = 0.5 — deterministic round-to-nearest
NEAREST_BITS = jnp.uint32(1 << 31)


@dataclasses.dataclass(frozen=True)
class PagedCacheSpec:
    """Pool geometry: ``num_blocks`` physical blocks (block 0 reserved as
    the null block) of ``block_size`` tokens; request tables are
    ``max_blocks_per_req`` wide; ``quantized`` selects int8 pools."""
    num_blocks: int
    block_size: int
    max_blocks_per_req: int
    quantized: bool = False

    def __post_init__(self):
        if self.num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null block)")
        if self.block_size < 1 or self.max_blocks_per_req < 1:
            raise ValueError("block_size/max_blocks_per_req must be >= 1")

    def blocks_needed(self, tokens: int) -> int:
        return -(-tokens // self.block_size)

    @property
    def max_tokens_per_req(self) -> int:
        return self.max_blocks_per_req * self.block_size

    @classmethod
    def for_requests(cls, slots: int, max_tokens: int, block_size: int = 16,
                     quantized: bool = False, headroom: int = 1
                     ) -> "PagedCacheSpec":
        """A pool sized so ``slots`` concurrent requests of up to
        ``max_tokens`` always fit, plus the null block and ``headroom``
        spare blocks."""
        per_req = -(-max_tokens // block_size)
        return cls(num_blocks=1 + slots * per_req + headroom,
                   block_size=block_size, max_blocks_per_req=per_req,
                   quantized=quantized)


class BlockAllocator:
    """Refcounted free-list allocator over the physical pool (host-side).

    Allocation is all-or-nothing: ``alloc(n)`` returns ``None`` when the
    pool cannot cover the whole request, so admission never strands a
    partially-allocated request. Block 0 never enters the free list.

    Every live block carries a reference count: ``alloc`` hands blocks
    out at refcount 1, ``share`` increments (prefix-cache sharing — a
    second request mapping the same physical template blocks), and
    ``release`` decrements, returning a block to the free list only when
    its count reaches zero. Releasing a block more times than it is
    currently held (in one call or across calls) raises — the double-free
    safety net predates refcounting and survives it. Shared blocks are
    read-only by contract; a writer must drop its share and copy first
    (copy-on-write, orchestrated by the scheduler via
    ``PagedEngine.copy_block``)."""

    def __init__(self, spec: PagedCacheSpec):
        self.spec = spec
        self._free: List[int] = list(range(spec.num_blocks - 1, 0, -1))
        self._refs: Dict[int, int] = {}

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return (self.spec.num_blocks - 1) - len(self._free)

    def refcount(self, block: int) -> int:
        """Current reference count of ``block`` (0 when free)."""
        return self._refs.get(block, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free) or n > self.spec.max_blocks_per_req:
            return None
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self._refs[b] = 1
        return out

    def share(self, blocks: Sequence[int]) -> None:
        """Increment the refcount of already-live blocks (all-or-nothing:
        validates every id before touching any count)."""
        for b in blocks:
            if not 0 < b < self.spec.num_blocks:
                raise ValueError(f"block id {b} outside the pool")
            if self._refs.get(b, 0) < 1:
                raise ValueError(f"share of free block {b}")
        for b in blocks:
            self._refs[b] += 1

    def release(self, blocks: Sequence[int]) -> None:
        counts: Dict[int, int] = {}
        for b in blocks:
            if not 0 < b < self.spec.num_blocks:
                raise ValueError(f"block id {b} outside the pool")
            counts[b] = counts.get(b, 0) + 1
        for b, n in counts.items():
            if n > self._refs.get(b, 0):
                raise ValueError(f"double free of block {b}")
        for b, n in counts.items():
            self._refs[b] -= n
            if self._refs[b] == 0:
                del self._refs[b]
                self._free.append(b)


class PrefixCache:
    """Pod prefix registry: full-block token chains -> physical blocks.

    Fleet prompts are templated per pod (shared prefix + unique suffix),
    so the KV state of the template blocks is identical across a pod's
    requests — K/V rows are a pure function of the token prefix. The
    registry maps each *full* block of a finished prompt, keyed by the
    entire token prefix up to that block boundary (a collision-free
    realization of token-hash chaining: matching key m+1 implies key m
    matched), to the physical block holding its K/V. A later request
    walks its own prompt's chain, maps every hit via
    ``BlockAllocator.share`` instead of recomputing, and resumes chunked
    prefill at the first uncached token.

    Only blocks whose ``block_size`` tokens are all prompt tokens are
    ever registered — decode appends land at position >= len(prompt),
    i.e. in later blocks — so registered blocks are immutable for the
    lifetime of the registration. When a prompt is covered end-to-end by
    cached blocks the model still owes the last token's logits; the last
    matched block is returned as ``cow_src`` for the scheduler to
    copy-on-write (copy to a private block, drop the share) so the
    recompute of that final token never writes into a shared block.

    Entries are LRU-ordered; :meth:`evict` frees registry-only blocks
    (refcount 1) from the cold end when admission runs out of pool."""

    def __init__(self, allocator: BlockAllocator):
        self.allocator = allocator
        self._map: "OrderedDict[tuple, int]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.cached_tokens = 0
        self.shared_blocks = 0     # pool blocks a request mapped vs computed

    def __len__(self) -> int:
        return len(self._map)

    def _chain_keys(self, prompt: Sequence[int]):
        bs = self.allocator.spec.block_size
        for m in range(len(prompt) // bs):
            yield tuple(prompt[:(m + 1) * bs])

    def match(self, prompt: Sequence[int]):
        """Longest registered full-block prefix of ``prompt``.

        Returns ``(shared, cow_src, resume_pos)``: ``shared`` are the
        physical blocks to map read-only into the request's table (each
        already incref'd here), ``cow_src`` is the incref'd block the
        scheduler must copy-on-write when the whole prompt was covered
        (else None), and ``resume_pos`` is the first prompt position
        chunked prefill still has to compute."""
        blocks = []
        for key in self._chain_keys(prompt):
            b = self._map.get(key)
            if b is None:
                break
            blocks.append(b)
            self._map.move_to_end(key)
        if not blocks:
            self.misses += 1
            return [], None, 0
        cow_src = None
        bs = self.allocator.spec.block_size
        resume = len(blocks) * bs
        if resume == len(prompt):
            # Whole prompt cached; recompute only the final token for its
            # logits, through a private copy of its block.
            cow_src = blocks.pop()
            resume = len(prompt) - 1
        self.allocator.share(blocks + ([cow_src] if cow_src is not None
                                       else []))
        self.hits += 1
        self.cached_tokens += resume
        self.shared_blocks += len(blocks)   # the CoW copy is not a saving
        return blocks, cow_src, resume

    def insert(self, prompt: Sequence[int], table: Sequence[int]) -> None:
        """Register ``prompt``'s full blocks out of a finished prefill's
        ``table`` (logical order). Already-registered chains keep their
        existing block; new registrations hold one registry ref."""
        for m, key in enumerate(self._chain_keys(prompt)):
            if key in self._map:
                self._map.move_to_end(key)
                continue
            b = int(table[m])
            self.allocator.share([b])
            self._map[key] = b

    def evict(self, want_blocks: int) -> int:
        """Drop cold registry-only entries (refcount 1 — no live request
        shares them) until ``want_blocks`` blocks were freed or no entry
        is evictable. Returns the number freed."""
        freed = 0
        for key in list(self._map):
            if freed >= want_blocks:
                break
            b = self._map[key]
            if self.allocator.refcount(b) == 1:
                del self._map[key]
                self.allocator.release([b])
                freed += 1
        return freed

    @property
    def registered_blocks(self) -> int:
        return len(set(self._map.values()))


# ---------------------------------------------------------------- pools ----
def init_pools(cfg: ModelConfig, spec: PagedCacheSpec) -> Dict:
    """Layer-stacked physical pools: k/v [L, Hkv, NB, bs, D] (+ float32
    [..., 1] absmax scales in int8 mode)."""
    shape = (cfg.num_layers, cfg.num_kv_heads, spec.num_blocks,
             spec.block_size, cfg.hd)
    if spec.quantized:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
            "v_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
        }
    return {"k": jnp.zeros(shape, cfg.dtype),
            "v": jnp.zeros(shape, cfg.dtype)}


def quantize_rows(x):
    """Deterministic round-to-nearest int8 quantization of the trailing
    axis: x [..., D] float -> (q int8 [..., D], scale float32 [..., 1]).
    Rows are zero-padded to the kernel's 128-lane layout; padding is
    absmax-neutral so the scales are exactly those of the D-wide rows."""
    lead, d = x.shape[:-1], x.shape[-1]
    m = 1
    for n in lead:
        m *= n
    rows = x.reshape(m, d).astype(jnp.float32)
    if d < LANES:
        rows = jnp.pad(rows, ((0, 0), (0, LANES - d)))
    elif d > LANES:
        raise NotImplementedError(f"head_dim {d} > {LANES} lanes")
    bits = jnp.full((m, LANES), NEAREST_BITS, jnp.uint32)
    q, scale = kops.quantize_int8(rows, bits)
    return q[:, :d].reshape(x.shape), scale.reshape(lead + (1,))


def dequantize_rows(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def write_prefill(pools: Dict, spec: PagedCacheSpec, k_layers, v_layers,
                  table_row) -> Dict:
    """Scatter one request's contiguous prefill K/V into its pool blocks.

    k_layers/v_layers: [L, Hkv, S, D] (S is the padded prefill buffer —
    rows past the true context length are garbage and stay masked by
    ``ctx_lens``); table_row: [T] int32, trailing entries null. Blocks
    beyond the request's allocation scatter into the null block, which is
    garbage by contract."""
    l, hkv, s, d = k_layers.shape
    bs = spec.block_size
    pad = (-s) % bs
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0))
        k_layers = jnp.pad(k_layers, widths)
        v_layers = jnp.pad(v_layers, widths)
    nb = (s + pad) // bs
    kb = k_layers.reshape(l, hkv, nb, bs, d)
    vb = v_layers.reshape(l, hkv, nb, bs, d)
    row = table_row[:nb]
    out = dict(pools)
    if spec.quantized:
        kq, ks = quantize_rows(kb)
        vq, vs = quantize_rows(vb)
        out["k"] = pools["k"].at[:, :, row].set(kq)
        out["v"] = pools["v"].at[:, :, row].set(vq)
        out["k_scale"] = pools["k_scale"].at[:, :, row].set(ks)
        out["v_scale"] = pools["v_scale"].at[:, :, row].set(vs)
    else:
        out["k"] = pools["k"].at[:, :, row].set(kb.astype(pools["k"].dtype))
        out["v"] = pools["v"].at[:, :, row].set(vb.astype(pools["v"].dtype))
    return out


def gather_rows(pools: Dict, phys, off) -> Dict:
    """Snapshot pool rows at ``(phys, off)`` token positions (jit-safe).

    ``phys``/``off``: [N] int32 physical block ids and in-block offsets.
    Returns ``{key: [L, Hkv, N, ...]}`` — the exact stored rows (int8
    codes AND their scales in quantized mode), so a later
    :func:`scatter_rows` restores them bitwise. This is the speculative
    decoder's rollback snapshot: taken over a lane's draft window before
    the batched verify appends draft K/V, then written back over the
    rejected tail so the pools are indistinguishable from never having
    drafted."""
    return {key: p[:, :, phys, off] for key, p in pools.items()}


def scatter_rows(pools: Dict, rows: Dict, phys, off) -> Dict:
    """Write :func:`gather_rows` snapshots back at ``(phys, off)``.

    Callers mask a *partial* restore by redirecting kept positions to the
    null block (``phys = where(rejected, phys, 0)``); duplicate scatters
    into block 0 are harmless by the null-block contract."""
    out = dict(pools)
    for key, p in pools.items():
        out[key] = p.at[:, :, phys, off].set(rows[key].astype(p.dtype))
    return out


def append_token(pools: Dict, spec: PagedCacheSpec, k_tok, v_tok, phys, off
                 ) -> Dict:
    """Append one decode token's K/V per request into per-layer pools.

    k_tok/v_tok: [Hkv, B, D] (a single layer's new rows, batch in the
    middle so the scatter value matches ``pools[:, phys, off]``); pools
    here are the [Hkv, NB, bs, D] slices of one layer; phys/off: [B]
    physical block id and in-block offset. Inactive slots point at
    (null, 0) — duplicate scatters there are harmless."""
    out = dict(pools)
    if spec.quantized:
        kq, ks = quantize_rows(k_tok)
        vq, vs = quantize_rows(v_tok)
        out["k"] = pools["k"].at[:, phys, off].set(kq)
        out["v"] = pools["v"].at[:, phys, off].set(vq)
        out["k_scale"] = pools["k_scale"].at[:, phys, off].set(ks)
        out["v_scale"] = pools["v_scale"].at[:, phys, off].set(vs)
    else:
        out["k"] = pools["k"].at[:, phys, off].set(
            k_tok.astype(pools["k"].dtype))
        out["v"] = pools["v"].at[:, phys, off].set(
            v_tok.astype(pools["v"].dtype))
    return out
