"""Paged KV-cache manager: block-table allocation over a fixed pool.

The serving tier's memory model (vLLM-style paging, sized for the edge
AD-LLM of paper Fig. 2): physical KV storage is a fixed pool of
``num_blocks`` blocks of ``block_size`` tokens per (layer, kv-head), and
each in-flight request holds a *logical* view — a row of physical block
ids — so admission/eviction never copies or compacts KV state. Physical
block 0 is reserved as the null block: dead table slots point at it, its
contents are garbage by design, and the paged kernel masks it out via
``ctx_lens``.

Two cache modes share the layout:

  * ``fp32``/model-dtype pools — K/V stored as written;
  * int8 pools — every (token, kv-head) row is quantized through the
    :mod:`repro.kernels.quantize` Pallas pair with a per-row absmax
    scale, stored alongside as [..., 1] float32. Rows are zero-padded to
    the kernel's 128-lane layout (padding cannot change a row's absmax)
    and the random-bits input is pinned to 2**31 — ``floor(x + 0.5)`` —
    so cache quantization is deterministic round-to-nearest rather than
    stochastic: a cache entry must read back identically every step.

Host-side allocation (:class:`BlockAllocator`) is deliberately plain
Python — the scheduler calls it between jitted steps; everything that
touches tensors (:func:`init_pools`, :func:`write_prefill`,
:func:`append_token`) is pure and jit-safe.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops as kops
from repro.kernels.quantize import LANES

#: pinned random-bits word giving u = 0.5 — deterministic round-to-nearest
NEAREST_BITS = jnp.uint32(1 << 31)


@dataclasses.dataclass(frozen=True)
class PagedCacheSpec:
    """Pool geometry: ``num_blocks`` physical blocks (block 0 reserved as
    the null block) of ``block_size`` tokens; request tables are
    ``max_blocks_per_req`` wide; ``quantized`` selects int8 pools."""
    num_blocks: int
    block_size: int
    max_blocks_per_req: int
    quantized: bool = False

    def __post_init__(self):
        if self.num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null block)")
        if self.block_size < 1 or self.max_blocks_per_req < 1:
            raise ValueError("block_size/max_blocks_per_req must be >= 1")

    def blocks_needed(self, tokens: int) -> int:
        return -(-tokens // self.block_size)

    @property
    def max_tokens_per_req(self) -> int:
        return self.max_blocks_per_req * self.block_size

    @classmethod
    def for_requests(cls, slots: int, max_tokens: int, block_size: int = 16,
                     quantized: bool = False, headroom: int = 1
                     ) -> "PagedCacheSpec":
        """A pool sized so ``slots`` concurrent requests of up to
        ``max_tokens`` always fit, plus the null block and ``headroom``
        spare blocks."""
        per_req = -(-max_tokens // block_size)
        return cls(num_blocks=1 + slots * per_req + headroom,
                   block_size=block_size, max_blocks_per_req=per_req,
                   quantized=quantized)


class BlockAllocator:
    """Free-list allocator over the physical pool (host-side).

    Allocation is all-or-nothing: ``alloc(n)`` returns ``None`` when the
    pool cannot cover the whole request, so admission never strands a
    partially-allocated request. Block 0 never enters the free list."""

    def __init__(self, spec: PagedCacheSpec):
        self.spec = spec
        self._free: List[int] = list(range(spec.num_blocks - 1, 0, -1))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return (self.spec.num_blocks - 1) - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free) or n > self.spec.max_blocks_per_req:
            return None
        out = [self._free.pop() for _ in range(n)]
        return out

    def release(self, blocks: Sequence[int]) -> None:
        seen = set(self._free)
        for b in blocks:
            if not 0 < b < self.spec.num_blocks:
                raise ValueError(f"block id {b} outside the pool")
            if b in seen:
                raise ValueError(f"double free of block {b}")
            seen.add(b)
        self._free.extend(blocks)


# ---------------------------------------------------------------- pools ----
def init_pools(cfg: ModelConfig, spec: PagedCacheSpec) -> Dict:
    """Layer-stacked physical pools: k/v [L, Hkv, NB, bs, D] (+ float32
    [..., 1] absmax scales in int8 mode)."""
    shape = (cfg.num_layers, cfg.num_kv_heads, spec.num_blocks,
             spec.block_size, cfg.hd)
    if spec.quantized:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
            "v_scale": jnp.zeros(shape[:-1] + (1,), jnp.float32),
        }
    return {"k": jnp.zeros(shape, cfg.dtype),
            "v": jnp.zeros(shape, cfg.dtype)}


def quantize_rows(x):
    """Deterministic round-to-nearest int8 quantization of the trailing
    axis: x [..., D] float -> (q int8 [..., D], scale float32 [..., 1]).
    Rows are zero-padded to the kernel's 128-lane layout; padding is
    absmax-neutral so the scales are exactly those of the D-wide rows."""
    lead, d = x.shape[:-1], x.shape[-1]
    m = 1
    for n in lead:
        m *= n
    rows = x.reshape(m, d).astype(jnp.float32)
    if d < LANES:
        rows = jnp.pad(rows, ((0, 0), (0, LANES - d)))
    elif d > LANES:
        raise NotImplementedError(f"head_dim {d} > {LANES} lanes")
    bits = jnp.full((m, LANES), NEAREST_BITS, jnp.uint32)
    q, scale = kops.quantize_int8(rows, bits)
    return q[:, :d].reshape(x.shape), scale.reshape(lead + (1,))


def dequantize_rows(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def write_prefill(pools: Dict, spec: PagedCacheSpec, k_layers, v_layers,
                  table_row) -> Dict:
    """Scatter one request's contiguous prefill K/V into its pool blocks.

    k_layers/v_layers: [L, Hkv, S, D] (S is the padded prefill buffer —
    rows past the true context length are garbage and stay masked by
    ``ctx_lens``); table_row: [T] int32, trailing entries null. Blocks
    beyond the request's allocation scatter into the null block, which is
    garbage by contract."""
    l, hkv, s, d = k_layers.shape
    bs = spec.block_size
    pad = (-s) % bs
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0))
        k_layers = jnp.pad(k_layers, widths)
        v_layers = jnp.pad(v_layers, widths)
    nb = (s + pad) // bs
    kb = k_layers.reshape(l, hkv, nb, bs, d)
    vb = v_layers.reshape(l, hkv, nb, bs, d)
    row = table_row[:nb]
    out = dict(pools)
    if spec.quantized:
        kq, ks = quantize_rows(kb)
        vq, vs = quantize_rows(vb)
        out["k"] = pools["k"].at[:, :, row].set(kq)
        out["v"] = pools["v"].at[:, :, row].set(vq)
        out["k_scale"] = pools["k_scale"].at[:, :, row].set(ks)
        out["v_scale"] = pools["v_scale"].at[:, :, row].set(vs)
    else:
        out["k"] = pools["k"].at[:, :, row].set(kb.astype(pools["k"].dtype))
        out["v"] = pools["v"].at[:, :, row].set(vb.astype(pools["v"].dtype))
    return out


def append_token(pools: Dict, spec: PagedCacheSpec, k_tok, v_tok, phys, off
                 ) -> Dict:
    """Append one decode token's K/V per request into per-layer pools.

    k_tok/v_tok: [Hkv, B, D] (a single layer's new rows, batch in the
    middle so the scatter value matches ``pools[:, phys, off]``); pools
    here are the [Hkv, NB, bs, D] slices of one layer; phys/off: [B]
    physical block id and in-block offset. Inactive slots point at
    (null, 0) — duplicate scatters there are harmless."""
    out = dict(pools)
    if spec.quantized:
        kq, ks = quantize_rows(k_tok)
        vq, vs = quantize_rows(v_tok)
        out["k"] = pools["k"].at[:, phys, off].set(kq)
        out["v"] = pools["v"].at[:, phys, off].set(vq)
        out["k_scale"] = pools["k_scale"].at[:, phys, off].set(ks)
        out["v_scale"] = pools["v_scale"].at[:, phys, off].set(vs)
    else:
        out["k"] = pools["k"].at[:, phys, off].set(
            k_tok.astype(pools["k"].dtype))
        out["v"] = pools["v"].at[:, phys, off].set(
            v_tok.astype(pools["v"].dtype))
    return out
