"""repro.serve — the edge serving tier (paper Fig. 2 inference procedure).

Paged KV-cache (:mod:`repro.serve.kvcache`), paged prefill/decode engine
(:mod:`repro.serve.engine`), continuous-batching scheduler
(:mod:`repro.serve.scheduler`) and the fleet load generator
(:mod:`repro.serve.loadgen`). :func:`serve_continuous` wires the four
together behind one call — the function ``Session.serve(scheduler=
"continuous")`` and the serving bench drive.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax

from repro.config import ModelConfig
from repro.serve.engine import DraftEngine, PagedEngine
from repro.serve.kvcache import (BlockAllocator, PagedCacheSpec,
                                 PrefixCache)
from repro.serve.loadgen import (PrefillCostModel, SpecDecodeCostModel,
                                 drive, generate_fleet_requests,
                                 generate_pod_requests)
from repro.serve.scheduler import ContinuousScheduler, ServeRequest

__all__ = ["BlockAllocator", "ContinuousScheduler", "DraftEngine",
           "PagedCacheSpec", "PagedEngine", "PrefillCostModel",
           "PrefixCache", "ServeRequest", "SpecDecodeCostModel", "drive",
           "generate_fleet_requests", "generate_pod_requests",
           "int8_cache_fidelity", "serve_continuous"]


def int8_cache_fidelity(cfg: ModelConfig, params, requests, streams: Dict,
                        *, block_size: int = 8, max_context: int = 32,
                        prefill: str = "monolithic", prefill_chunk: int = 8
                        ) -> Dict:
    """Teacher-forced int8-vs-fp32 cache comparison.

    Replays each request's fp32 greedy ``streams`` (rid -> token list)
    through BOTH a float and an int8-cache engine, feeding the fp32
    token at every step regardless of what either engine would sample —
    so a single early flip cannot cascade, and the reported disagreement
    is the per-position rate at which cache quantization alone changes
    the greedy token. ``prefill`` selects the monolithic bucketed path
    or the chunked paged path (``prefill_chunk`` tokens per chunk) for
    the prompt — the drift contract must hold through either. Returns
    ``{"disagreement", "positions", "max_logit_drift"}``.
    """
    import numpy as np

    if prefill not in ("monolithic", "chunked"):
        raise ValueError(f"prefill must be monolithic|chunked, "
                         f"got {prefill!r}")
    engines = {}
    for name, quant in (("fp32", False), ("int8", True)):
        cap = max(len(r.prompt) + len(streams[r.rid]) for r in requests)
        spec = PagedCacheSpec.for_requests(1, cap, block_size=block_size,
                                           quantized=quant)
        engines[name] = PagedEngine(cfg, spec, max_context=max_context,
                                    slots=1)
    mism = tot = 0
    drift = 0.0
    for r in requests:
        stream = streams[r.rid]
        state = {}
        for name, eng in engines.items():
            alloc = BlockAllocator(eng.spec)
            blocks = alloc.alloc(
                eng.spec.blocks_needed(len(r.prompt) + len(stream)))
            tbl = np.zeros((1, eng.spec.max_blocks_per_req), np.int32)
            tbl[0, :len(blocks)] = blocks
            pools = eng.init_pools()
            if prefill == "chunked":
                pos, plen = 0, len(r.prompt)
                while pos < plen:
                    clen = min(prefill_chunk, plen - pos)
                    buf = np.zeros(prefill_chunk, np.int32)
                    buf[:clen] = np.asarray(r.prompt[pos:pos + clen],
                                            np.int32)
                    logits, pools = eng.prefill_chunk(
                        params, pools, jax.numpy.asarray(buf),
                        jax.numpy.asarray(tbl[0]), pos, clen)
                    pos += clen
            else:
                toks, length = eng.pad_prompt(r.prompt)
                logits, k, v = eng.prefill(params, toks, length)
                pools = eng.write_prefill(pools, k, v,
                                          jax.numpy.asarray(tbl[0]))
            state[name] = [pools, tbl, logits]
        for i in range(len(stream)):
            l32, l8 = state["fp32"][2], state["int8"][2]
            drift = max(drift, float(abs(l32 - l8).max()))
            if int(l32.argmax()) != int(l8.argmax()):
                mism += 1
            tot += 1
            if i == len(stream) - 1:
                break
            tok = jax.numpy.asarray([stream[i]], "int32")
            ctx = jax.numpy.asarray([len(r.prompt) + i], "int32")
            for name, eng in engines.items():
                pools, tbl, _ = state[name]
                logits, pools = eng.decode(params, pools, tok,
                                           jax.numpy.asarray(tbl), ctx)
                state[name] = [pools, tbl, logits]
    return {"disagreement": mism / max(1, tot), "positions": tot,
            "max_logit_drift": drift}


def serve_continuous(cfg: ModelConfig, *, params=None, seed: int = 0,
                     slots: int = 4, block_size: int = 8,
                     max_context: int = 32, cache: str = "fp32",
                     policy: str = "continuous",
                     prefill: str = "chunked", prefill_chunk: int = 16,
                     prefix_cache: bool = False,
                     sampling: str = "greedy",
                     temperature: float = 1.0,
                     fleet: str = "nano*2,agx*2", num_requests: int = 12,
                     max_prompt: Optional[int] = None,
                     deadline_s: float = 4.0,
                     short_new: tuple = (4, 8), long_new: tuple = (32, 48),
                     long_frac: float = 0.2, warm_passes: int = 1,
                     requests=None, dt_step: float = 0.01,
                     prefill_cost=None, trace=None,
                     speculative: bool = False, draft_k: int = 4,
                     draft_params=None, preemption: Optional[bool] = None,
                     log_fn: Optional[Callable] = print) -> Dict:
    """Serve a fleet request trace through the paged engine.

    Runs the trace with identical requests: a cold pass (includes every
    jit trace — the number legacy ``serve_requests`` used to report),
    then ``warm_passes`` passes on fresh schedulers whose best wall time
    defines the steady-state throughput the serving bench gates on
    (best-of-N damps scheduler-exterior noise on shared CI hosts).
    ``prefill`` selects chunked paged prefill (the default — one
    ``prefill_chunk``-token chunk per step, interleaved with decode) or
    the monolithic bucketed baseline; ``prefix_cache`` turns on pod
    prefix-block sharing (chunked only). Pass ``requests`` (a list of
    :class:`ServeRequest` factories is not needed — a plain list is
    re-instantiated per pass) to serve a custom trace, e.g. from
    :func:`generate_pod_requests`, instead of the built-in fleet trace;
    ``dt_step``/``prefill_cost`` feed the loadgen's simulated clock.
    ``trace`` (a :class:`repro.obs.Tracer` or a path) records the FINAL
    warm pass — one clean steady-state pass, not the jit-noisy cold one —
    as sim-time queue/lane spans; a path is saved before returning.
    ``speculative=True`` turns on draft-verify speculative decoding
    (``draft_k`` drafts per lane per step from ``draft_params`` — the
    distilled pod student; defaults to self-drafting with the target
    weights) and, under chunked prefill, block-level preemption
    (override with ``preemption``); greedy streams stay bit-identical
    to non-speculative decode. Pass a
    :class:`repro.serve.loadgen.SpecDecodeCostModel` as ``prefill_cost``
    so the sim clock charges draft forwards and the verify chunk
    instead of k extra target steps.
    Returns the loadgen report plus both throughputs and the per-request
    token streams (greedy streams are deterministic — the equivalence
    tests compare them across policies, prefill modes and cache modes).
    """
    if cache not in ("fp32", "int8"):
        raise ValueError(f"cache must be fp32|int8, got {cache!r}")
    import copy

    from repro.models import lm
    from repro.obs import resolve_tracer

    tracer, trace_path = resolve_tracer(trace)

    if speculative and prefill_cost is None:
        # price draft forwards + the verify chunk instead of silently
        # charging k extra full target steps on the sim clock
        prefill_cost = SpecDecodeCostModel()
    if params is None:
        params = lm.init(jax.random.PRNGKey(seed), cfg)
    max_prompt = max_prompt if max_prompt is not None else max_context // 2
    max_new_cap = max(short_new[1], long_new[1])
    if requests is not None:
        cap_tokens = max(len(r.prompt) + r.max_new_tokens
                         for r in requests)
    else:
        cap_tokens = max_prompt + max_new_cap
    spec = PagedCacheSpec.for_requests(slots, cap_tokens,
                                       block_size=block_size,
                                       quantized=(cache == "int8"))
    engine = PagedEngine(cfg, spec, max_context=max_context, slots=slots)

    def fresh_requests():
        if requests is not None:
            return copy.deepcopy(requests)
        return generate_fleet_requests(
            fleet, num_requests=num_requests, max_prompt=max_prompt,
            seed=seed, deadline_s=deadline_s, short_new=short_new,
            long_new=long_new, long_frac=long_frac,
            vocab_size=cfg.vocab_size)

    def fresh_scheduler(tracer=None):
        return ContinuousScheduler(engine, params, policy=policy,
                                   prefill=prefill,
                                   prefill_chunk=prefill_chunk,
                                   prefix_cache=prefix_cache,
                                   sampling=sampling,
                                   temperature=temperature, seed=seed,
                                   tracer=tracer,
                                   speculative=speculative,
                                   draft_k=draft_k,
                                   draft_params=draft_params,
                                   preemption=preemption)

    t0 = time.time()
    sched = fresh_scheduler()
    drive(sched, fresh_requests(), dt_step=dt_step,
          prefill_cost=prefill_cost)
    cold_s = time.time() - t0
    cold_toks = sched.total_new_tokens

    n_warm = max(1, warm_passes)
    warm_s = float("inf")
    for p in range(n_warm):
        t0 = time.time()
        sched = fresh_scheduler(tracer if p == n_warm - 1 else None)
        report = drive(sched, fresh_requests(), dt_step=dt_step,
                       prefill_cost=prefill_cost)
        warm_s = min(warm_s, time.time() - t0)
    if trace_path is not None:
        tracer.save(trace_path)

    report.update({
        "policy": policy,
        "prefill": prefill,
        "cache": cache,
        "slots": slots,
        "block_size": block_size,
        "seconds_cold": cold_s,
        "tokens_per_s": cold_toks / max(cold_s, 1e-9),
        "seconds_warm": warm_s,
        "warm_tokens_per_s": report["total_new_tokens"]
        / max(warm_s, 1e-9),
        "sequences": {r.rid: list(r.tokens) for r in sched.finished},
    })
    if trace_path is not None:
        report["trace_path"] = trace_path
    if log_fn:
        if speculative:
            log_fn(f"[serve:specdec] k={draft_k} "
                   f"acceptance={report['acceptance_rate']:.2f} "
                   f"({report['accepted_drafts']}/"
                   f"{report['proposed_drafts']} drafts), "
                   f"{report.get('preemptions', 0)} preemptions")
        log_fn(f"[serve:{policy}/{cache}] {report['requests']} requests, "
               f"{report['total_new_tokens']} tokens in "
               f"{report['decode_steps']} decode steps; "
               f"{report['warm_tokens_per_s']:.1f} tok/s warm "
               f"({report['tokens_per_s']:.1f} cold), "
               f"p50 {report['p50_latency_s'] * 1e3:.0f}ms / "
               f"p99 {report['p99_latency_s'] * 1e3:.0f}ms sim latency")
    return report
