"""Continuous-batching scheduler over the paged engine.

Requests stream in (from :mod:`repro.serve.loadgen` or a plain list) and
occupy one of ``slots`` fixed batch lanes. Every decode step runs ONE
fused forward over all lanes; the scheduler's only job is deciding which
request sits in which lane:

  * ``policy="continuous"`` — a lane is refilled the moment its request
    finishes (vLLM-style continuous batching). Short requests never hold
    long ones hostage and the decode batch stays dense.
  * ``policy="rebatch"`` — the naive baseline: a wave of requests is
    admitted only when *all* lanes are empty, then decoded until the
    longest request in the wave finishes. This is the static-batching
    strawman the serving bench compares against; at mixed decode lengths
    most lanes idle for most of each wave.

Prefill is decoupled from admission (Sarathi-style chunked prefill):
``_admit`` only reserves a lane and its blocks — the actual prompt
compute happens inside :meth:`step`, AT MOST ONE prefill unit per step
(one fixed-size chunk in ``prefill="chunked"`` mode, one full bucketed
prefill in ``prefill="monolithic"`` mode), interleaved with the fused
decode over every prefill-complete lane. A burst of N arrivals therefore
costs live decode lanes one chunk of latency per step, not N monolithic
prefills of dead air. Lanes still prefilling are masked out of the decode
batch (table/ctx/token zeroed → they behave exactly like dead lanes
pointing at the null block).

Chunked mode optionally shares pod prompt prefixes: a
:class:`repro.serve.kvcache.PrefixCache` maps previously computed full
prompt blocks into a new request's table via refcounted
``BlockAllocator.share`` (read-only by contract; the whole-prompt-cached
case goes through ``PagedEngine.copy_block`` copy-on-write), and chunked
prefill resumes at the first uncached token. Monolithic prefill cannot
share (``write_prefill`` scatters the full bucket and would clobber
shared blocks), so ``prefix_cache=True`` requires chunked mode.

Admission is gated by the :class:`repro.serve.kvcache.BlockAllocator`
(all-or-nothing block reservation for prompt + max_new_tokens) and by
``max_inflight_blocks`` so a fleet burst cannot overcommit the pool;
when the prefix registry's cold entries are what exhausts the pool they
are LRU-evicted before admission gives up. With ``preemption`` enabled
(the default in speculative mode) admission has one more lever before
giving up: preempt the lowest-priority live lane — latest deadline,
then latest arrival — if it ranks strictly below the incoming request.
The victim's computed K/V chain (prompt, or prompt + emitted stream) is
re-registered in the prefix cache so its resume is a cache hit, its
blocks are released through the refcounted allocator, and it requeues
at the head of the waiting line behind the request that displaced it.
Greedy resume is exact: chunked prefill replays only the uncached tail
of the chain and the stream continues from its recorded last token.

``speculative=True`` replaces the per-step single-token decode with
draft-verify speculative decoding: a :class:`repro.serve.engine.
DraftEngine` (the pod's distilled student — shared base weights plus
merged LoRA factors) proposes up to ``draft_k`` greedy tokens per lane
(``draft_k + 1`` batched draft forwards, so the draft pools stay
stream-complete even on a full accept), then ONE batched target forward
scores every draft position through the paged pools
(:meth:`PagedEngine.verify` — verification is exactly a k+1-token chunk
attending through the lane's block table). Greedy exact-match
acceptance emits the matched prefix plus the target's own next token,
so the output streams are bit-identical to non-speculative greedy
decode; the rejected tail's K/V rows are rolled back bitwise
(:func:`repro.serve.kvcache.gather_rows` snapshot before the verify
append, :func:`repro.serve.kvcache.scatter_rows` restore after) and the
per-lane context rewinds to the accepted length. Lanes near completion
shrink their window to the tokens they may still emit, which keeps
every append inside the blocks reserved at admission.

Determinism: greedy decoding makes the token streams a pure function of
(params, prompts) — per-request streams are bit-identical between the two
policies AND the two prefill modes for the dense family (each lane's
attention only reads its own blocks; prefix-shared blocks hold bitwise
the K/V the request would have computed itself, since K/V rows are a
pure function of the token prefix; MoE capacity routing is cross-token
and would break this, which the equivalence test therefore pins to
dense). Temperature sampling draws from a per-step key folded from a
base key and the step index, so a run is reproducible given its seed.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import kvcache as KC
from repro.serve.engine import DraftEngine, PagedEngine

_POLICIES = ("continuous", "rebatch")
_PREFILL_MODES = ("chunked", "monolithic")


@dataclasses.dataclass
class ServeRequest:
    """One generation request flowing through the scheduler."""
    rid: int
    prompt: np.ndarray                 # [s] int32
    max_new_tokens: int
    arrival_s: float = 0.0
    deadline_s: float = math.inf
    #: stable id echoed in every span this request produces in a trace
    #: (defaults to ``rid``; callers multiplexing several traces can set
    #: their own correlation id)
    trace_id: Optional[int] = None
    # filled by the scheduler:
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_admit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    def __post_init__(self):
        if self.trace_id is None:
            self.trace_id = self.rid

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.arrival_s

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (arrival -> first sampled token)."""
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival_s

    @property
    def queue_wait_s(self) -> Optional[float]:
        """Time spent waiting for a lane (arrival -> admission)."""
        if self.t_admit is None:
            return None
        return self.t_admit - self.arrival_s

    @property
    def met_deadline(self) -> bool:
        return self.t_done is not None and self.t_done <= self.deadline_s


class ContinuousScheduler:
    """Admit/prefill/decode/retire requests against a :class:`PagedEngine`."""

    def __init__(self, engine: PagedEngine, params, *,
                 policy: str = "continuous",
                 prefill: str = "chunked", prefill_chunk: int = 32,
                 prefix_cache: bool = False,
                 max_inflight_blocks: Optional[int] = None,
                 sampling: str = "greedy", temperature: float = 1.0,
                 seed: int = 0, tracer=None, metrics=None,
                 speculative: bool = False, draft_k: int = 4,
                 draft_params=None,
                 preemption: Optional[bool] = None):
        if policy not in _POLICIES:
            raise ValueError(f"unknown policy {policy!r} ({_POLICIES})")
        if prefill not in _PREFILL_MODES:
            raise ValueError(
                f"unknown prefill mode {prefill!r} ({_PREFILL_MODES})")
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if prefix_cache and prefill != "chunked":
            raise ValueError(
                "prefix_cache requires prefill='chunked' (monolithic "
                "write_prefill would clobber shared blocks)")
        if speculative and sampling != "greedy":
            raise ValueError(
                "speculative decoding is defined by greedy exact-match "
                "acceptance; sampling must be 'greedy'")
        if preemption is None:
            # A lane's draft window is funded out of its admission
            # reservation, so speculative mode leans on preemption for
            # pool pressure; chunked prefill is what makes a preempted
            # lane's resume replay only the uncached tail.
            preemption = speculative and prefill == "chunked"
        if preemption and prefill != "chunked":
            raise ValueError(
                "preemption requires prefill='chunked' (a resumed chain "
                "can exceed the monolithic prefill bucket)")
        self.engine = engine
        self.params = params
        self.policy = policy
        self.prefill_mode = prefill
        self.prefill_chunk = int(prefill_chunk)
        self.spec = engine.spec
        self.slots = engine.slots
        self.max_inflight_blocks = (max_inflight_blocks
                                    if max_inflight_blocks is not None
                                    else self.spec.num_blocks - 1)
        self.allocator = KC.BlockAllocator(self.spec)
        self.prefix: Optional[KC.PrefixCache] = (
            KC.PrefixCache(self.allocator) if prefix_cache else None)
        self.sampler = engine.make_sampler(sampling, temperature)
        self._base_key = jax.random.PRNGKey(seed)
        self._sample_step = 0
        self.speculative = bool(speculative)
        self.preemption = bool(preemption)
        self.draft: Optional[DraftEngine] = None
        if self.speculative:
            # No distilled student supplied -> self-draft with the target
            # weights (acceptance 1.0; useful for smokes and plumbing).
            self.draft = DraftEngine(
                engine, params if draft_params is None else draft_params,
                draft_k=draft_k)
        self.draft_k = int(draft_k)

        self.pools = engine.init_pools()
        self.tables = np.zeros((self.slots, self.spec.max_blocks_per_req),
                               np.int32)
        self.ctx = np.zeros(self.slots, np.int32)
        self.pending_tok = np.zeros(self.slots, np.int32)
        self.active: List[Optional[ServeRequest]] = [None] * self.slots
        self.blocks: List[Optional[List[int]]] = [None] * self.slots
        self.prefill_pos = np.zeros(self.slots, np.int32)
        self.prefill_done = np.zeros(self.slots, bool)
        # per-slot prefill token chain: the prompt, or — for a request
        # resumed after preemption — prompt + the emitted stream whose
        # K/V the lane had already computed (all but the pending token)
        self._chain: List[Optional[np.ndarray]] = [None] * self.slots
        self._prefill_queue: Deque[int] = collections.deque()
        self.waiting: Deque[ServeRequest] = collections.deque()
        self.finished: List[ServeRequest] = []
        # counters for the bench report
        self.decode_steps_run = 0
        self.prefills_run = 0            # monolithic full prefills
        self.prefill_chunks_run = 0
        self.total_new_tokens = 0
        self.fresh_blocks_allocated = 0
        self.spec_steps_run = 0
        self.draft_forwards_run = 0
        self.proposed_drafts = 0         # draft tokens verify could use
        self.accepted_drafts = 0
        self.preemptions = 0
        # per-step cost stats for the loadgen's sim clock
        self.last_stats: Dict[str, int] = {}
        # requests stamped (first token / done) during the current step;
        # the loadgen finalizes their timestamps to the step's END time
        # once it knows the step's compute cost, so a prefill's own cost
        # lands in the TTFT of the request that incurred it
        self.step_events: List[ServeRequest] = []
        #: optional :class:`repro.obs.Tracer`: queue/lane spans on the
        #: sim clock. Spans whose end time is the step's END (known only
        #: after the driver prices the step) are deferred as callables
        #: and emitted by :meth:`flush_trace` — mirroring the
        #: ``step_events`` restamping contract above. None -> no
        #: callbacks, bit-identical streams (tests/test_obs.py).
        self.tracer = tracer
        if self.tracer is not None:
            from repro.obs import trace as T
            self.tracer.process(T.SERVE_PID, "serving", sort_index=2)
            self.tracer.track(T.SERVE_PID, T.QUEUE_TID, "queue")
            if self.speculative:
                self.tracer.track(T.SERVE_PID, T.SPEC_TID, "specdec")
            for s in range(self.slots):
                self.tracer.track(T.SERVE_PID, T.lane_tid(s), f"lane {s}")
        self._pending_trace: List = []
        # always-on registry (host-side dict updates only): the bench
        # report reads pool-occupancy stats from it even when no external
        # registry is supplied
        if metrics is None:
            from repro.obs.metrics import MetricsRegistry
            metrics = MetricsRegistry()
        self.metrics = metrics
        # register the speculative instruments eagerly so a spec
        # scheduler's snapshot always carries them, samples or not
        if self.speculative:
            self.metrics.histogram(
                "serve_spec_accepted_len",
                "accepted draft tokens per lane per speculative step",
                buckets=tuple(float(i) for i in range(self.draft_k + 1)))
        if self.preemption:
            self.metrics.counter(
                "serve_preemptions",
                "live lanes preempted to fund a higher-priority admission")

    # ---- bookkeeping --------------------------------------------------
    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.active)

    @property
    def idle(self) -> bool:
        return self.num_active == 0 and not self.waiting

    def submit(self, req: ServeRequest) -> None:
        if len(req.prompt) + req.max_new_tokens > self.engine.spec.max_tokens_per_req:
            raise ValueError(f"request {req.rid} needs "
                             f"{len(req.prompt) + req.max_new_tokens} tokens "
                             f"> table capacity")
        if (self.prefill_mode == "monolithic"
                and len(req.prompt) > self.engine.max_context):
            # Chunked prefill streams arbitrarily long prompts through
            # fixed-size chunks; only the monolithic bucket is bounded.
            raise ValueError(f"request {req.rid} prompt exceeds max_context")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        self.waiting.append(req)

    def _next_key(self):
        k = jax.random.fold_in(self._base_key, self._sample_step)
        self._sample_step += 1
        return k

    def _retire(self, slot: int, t: float) -> None:
        req = self.active[slot]
        req.t_done = t
        self.step_events.append(req)
        if self.tracer is not None:
            def emit(t_end, cost_model, *, req=req, slot=slot):
                from repro.obs import trace as T
                t0 = (req.t_first_token if req.t_first_token is not None
                      else req.t_done)
                self.tracer.complete(
                    "decode", t0, req.t_done, pid=T.SERVE_PID,
                    tid=T.lane_tid(slot), cat="decode",
                    args={"trace_id": req.trace_id, "rid": req.rid,
                          "new_tokens": len(req.tokens),
                          "latency_s": req.latency_s,
                          "met_deadline": req.met_deadline})
            self._pending_trace.append(emit)
        self.finished.append(req)
        self.allocator.release(self.blocks[slot])
        self._clear_slot(slot)

    def _clear_slot(self, slot: int) -> None:
        self.active[slot] = None
        self.blocks[slot] = None
        self.tables[slot] = 0
        self.ctx[slot] = 0
        self.pending_tok[slot] = 0
        self.prefill_pos[slot] = 0
        self.prefill_done[slot] = False
        self._chain[slot] = None

    # ---- admission ----------------------------------------------------
    def _try_alloc(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` fresh blocks under the inflight cap, LRU-evicting
        cold prefix-registry entries once if they are what's in the way."""
        def fits() -> bool:
            return (self.allocator.in_use + n <= self.max_inflight_blocks
                    and n <= self.allocator.free_blocks)
        if not fits() and self.prefix is not None:
            deficit = max(n - self.allocator.free_blocks,
                          self.allocator.in_use + n
                          - self.max_inflight_blocks)
            self.prefix.evict(deficit)
        if not fits():
            return None
        return self.allocator.alloc(n)

    @staticmethod
    def _priority(req: ServeRequest):
        """Scheduling priority key; LARGER sorts lower-priority (latest
        deadline, then latest arrival, then highest rid)."""
        return (req.deadline_s, req.arrival_s, req.rid)

    def _pick_victim(self, incoming: ServeRequest) -> Optional[int]:
        """Lowest-priority live lane ranking strictly below ``incoming``
        (a preempted request can never preempt its displacer back, so
        admission cannot thrash)."""
        worst_slot = None
        worst = None
        for slot in range(self.slots):
            r = self.active[slot]
            if r is None:
                continue
            if worst is None or self._priority(r) > self._priority(worst):
                worst, worst_slot = r, slot
        if worst is None or self._priority(worst) <= self._priority(incoming):
            return None
        return worst_slot

    def _computed_chain(self, slot: int) -> np.ndarray:
        """The token chain whose K/V the lane holds: the prefilled prompt
        prefix, plus — once decoding — every emitted token except the
        pending one (its K/V is written by the NEXT forward)."""
        req = self.active[slot]
        prompt = np.asarray(req.prompt, np.int32)
        if not self.prefill_done[slot]:
            return np.asarray(self._chain[slot],
                              np.int32)[:int(self.prefill_pos[slot])]
        if not req.tokens:
            return prompt
        return np.concatenate(
            [prompt, np.asarray(req.tokens[:-1], np.int32)])

    def _preempt(self, slot: int, t: float) -> None:
        """Evict a live lane to fund a higher-priority admission.

        The lane's computed chain is re-registered in the prefix cache
        (so its resume replays only the uncached tail), its blocks are
        released through the refcounted allocator — registered blocks
        survive on the registry's reference — and the request requeues
        at the head of the waiting line."""
        req = self.active[slot]
        if self.prefix is not None:
            chain = self._computed_chain(slot)
            if len(chain) >= self.spec.block_size:
                self.prefix.insert(chain, self.tables[slot])
        self.preemptions += 1
        self.metrics.counter(
            "serve_preemptions",
            "live lanes preempted to fund a higher-priority admission"
            ).inc()
        if self.tracer is not None:
            from repro.obs import trace as T
            self.tracer.instant(
                "preempted", t, pid=T.SERVE_PID, tid=T.lane_tid(slot),
                cat="preempt",
                args={"trace_id": req.trace_id, "rid": req.rid,
                      "emitted_tokens": len(req.tokens)})
        self.allocator.release(self.blocks[slot])
        self._prefill_queue = collections.deque(
            s for s in self._prefill_queue if s != slot)
        self._clear_slot(slot)
        self.waiting.appendleft(req)

    def _admit(self, t: float) -> None:
        """Reserve lanes + blocks for waiting requests (bookkeeping only —
        prompt compute happens one prefill unit per :meth:`step`)."""
        if self.policy == "rebatch" and self.num_active > 0:
            return                      # wave semantics: drain first
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            # A request resumed after preemption prefills its full
            # computed chain (prompt + emitted stream minus the pending
            # token); greedy replay of the tail is exact.
            if req.tokens:
                chain = np.concatenate(
                    [np.asarray(req.prompt, np.int32),
                     np.asarray(req.tokens[:-1], np.int32)])
            else:
                chain = np.asarray(req.prompt, np.int32)
            need = self.spec.blocks_needed(len(req.prompt)
                                           + req.max_new_tokens)
            shared: List[int] = []
            cow_src: Optional[int] = None
            resume = 0
            if self.prefix is not None:
                shared, cow_src, resume = self.prefix.match(chain)
            fresh_need = need - len(shared)
            fresh = self._try_alloc(fresh_need)
            if fresh is None and self.preemption:
                # Pop the incoming request first so preempted victims
                # requeue BEHIND it at the head of the line.
                self.waiting.popleft()
                while fresh is None:
                    victim = self._pick_victim(req)
                    if victim is None:
                        break
                    self._preempt(victim, t)
                    fresh = self._try_alloc(fresh_need)
                self.waiting.appendleft(req)
            if fresh is None:
                # Undo the prefix refs and keep FIFO order (don't starve
                # the head by admitting a smaller request behind it).
                undo = shared + ([cow_src] if cow_src is not None else [])
                if undo:
                    self.allocator.release(undo)
                break
            self.waiting.popleft()
            self.fresh_blocks_allocated += fresh_need
            if cow_src is not None:
                # Whole chain was cached: clone the last shared block so
                # the final-token recompute writes a private copy.
                dst = fresh[0]
                self.pools = self.engine.copy_block(self.pools, cow_src, dst)
                if self.draft is not None:
                    self.draft.copy_block(cow_src, dst)
                self.allocator.release([cow_src])
            if req.t_admit is None:
                req.t_admit = t
            if self.tracer is not None:
                from repro.obs import trace as T
                self.tracer.complete(
                    "queued", req.arrival_s, t, pid=T.SERVE_PID,
                    tid=T.QUEUE_TID, cat="queue",
                    args={"trace_id": req.trace_id, "rid": req.rid,
                          "slot": slot, "prompt_tokens": len(req.prompt),
                          "shared_blocks": len(shared),
                          "resume_tokens": resume,
                          "cow": cow_src is not None})
            self.active[slot] = req
            self.blocks[slot] = shared + fresh
            self.tables[slot] = 0
            self.tables[slot, :need] = shared + fresh
            self.ctx[slot] = 0
            self.pending_tok[slot] = 0
            self.prefill_pos[slot] = resume
            self.prefill_done[slot] = False
            self._chain[slot] = chain
            self._prefill_queue.append(slot)

    # ---- prefill work -------------------------------------------------
    def _finish_prefill(self, slot: int, logits, t: float) -> None:
        req = self.active[slot]
        chain = self._chain[slot]
        resumed = len(req.tokens) > 0
        if resumed:
            # Preemption resume: the chain's last-token logits reproduce
            # the already-recorded pending token (greedy replay is
            # exact); pin it rather than re-emitting into the stream.
            first = int(req.tokens[-1])
        else:
            first = int(self.sampler(logits, self._next_key())[0])
            req.tokens.append(first)
            req.t_first_token = t
            self.step_events.append(req)
            if self.tracer is not None:
                def emit(t_end, cost_model, *, req=req, slot=slot):
                    from repro.obs import trace as T
                    self.tracer.instant(
                        "first_token", req.t_first_token, pid=T.SERVE_PID,
                        tid=T.lane_tid(slot), cat="ttft",
                        args={"trace_id": req.trace_id, "rid": req.rid,
                              "ttft_s": req.ttft_s})
                self._pending_trace.append(emit)
            self.total_new_tokens += 1
        self.ctx[slot] = len(chain)
        self.pending_tok[slot] = first
        self.prefill_done[slot] = True
        if self.prefix is not None:
            self.prefix.insert(chain, self.tables[slot])
        if len(req.tokens) >= req.max_new_tokens:
            self._retire(slot, t)

    def _run_prefill(self, t: float) -> None:
        """Run AT MOST ONE prefill unit: the oldest admitted lane still
        prefilling gets one chunk (chunked) or its whole bucketed prefill
        (monolithic). In speculative mode every unit is mirrored through
        the draft engine (same chunk, draft params, draft pools) so the
        draft cache tracks the target's logical layout."""
        while self._prefill_queue and (
                self.active[self._prefill_queue[0]] is None
                or self.prefill_done[self._prefill_queue[0]]):
            self._prefill_queue.popleft()
        if not self._prefill_queue:
            return
        slot = self._prefill_queue[0]
        req = self.active[slot]
        chain = self._chain[slot]
        plen = len(chain)
        if self.prefill_mode == "monolithic":
            toks, length = self.engine.pad_prompt(chain)
            logits, k, v = self.engine.prefill(self.params, toks, length)
            self.pools = self.engine.write_prefill(
                self.pools, k, v, jnp.asarray(self.tables[slot]))
            if self.draft is not None:
                self.draft.prefill(toks, length)
                self.draft.write_prefill(jnp.asarray(self.tables[slot]))
                self.last_stats["draft_forwards"] = (
                    self.last_stats.get("draft_forwards", 0) + 1)
            self.prefills_run += 1
            self.prefill_pos[slot] = plen
            mc = self.engine.max_context
            self.last_stats["prefill_padded_tokens"] = mc
            self.last_stats["prefill_attn_mac"] = mc ** 2
            self.last_stats["prefill_wasted_tokens"] = mc - plen
            if self.tracer is not None:
                self._pending_prefill_span(
                    "prefill", t, slot, req, 0, plen, mc, mc ** 2)
            self._prefill_queue.popleft()
            self._finish_prefill(slot, logits, t)
            return
        c = self.prefill_chunk
        pos = int(self.prefill_pos[slot])
        clen = min(c, plen - pos)
        buf = np.zeros(c, np.int32)
        buf[:clen] = np.asarray(chain[pos:pos + clen], np.int32)
        logits, self.pools = self.engine.prefill_chunk(
            self.params, self.pools, jnp.asarray(buf),
            jnp.asarray(self.tables[slot]), pos, clen)
        if self.draft is not None:
            self.draft.prefill_chunk(jnp.asarray(buf),
                                     jnp.asarray(self.tables[slot]),
                                     pos, clen)
            self.last_stats["draft_forwards"] = (
                self.last_stats.get("draft_forwards", 0) + 1)
        self.prefill_chunks_run += 1
        self.prefill_pos[slot] = pos + clen
        self.last_stats["prefill_padded_tokens"] = c
        self.last_stats["prefill_attn_mac"] = c * (pos + clen)
        self.last_stats["prefill_wasted_tokens"] = c - clen
        if self.tracer is not None:
            self._pending_prefill_span("prefill_chunk", t, slot, req,
                                       pos, pos + clen, c, c * (pos + clen))
        if pos + clen == plen:
            self._prefill_queue.popleft()
            self._finish_prefill(slot, logits, t)

    # ---- tracing (repro.obs) ------------------------------------------
    def _pending_prefill_span(self, name: str, t0: float, slot: int, req,
                              tok0: int, tok1: int, padded: int,
                              mac: int) -> None:
        """Defer a prefill span until the driver knows the step's end."""
        def emit(t_end, cost_model, *, name=name, t0=t0, slot=slot,
                 req=req, tok0=tok0, tok1=tok1, padded=padded, mac=mac):
            from repro.obs import trace as T
            from repro.obs.profile import kernel_cost_args
            self.tracer.complete(
                name, t0, t_end, pid=T.SERVE_PID, tid=T.lane_tid(slot),
                cat="prefill",
                args=dict(kernel_cost_args(padded_tokens=padded,
                                           attn_mac=mac,
                                           cost_model=cost_model),
                          trace_id=req.trace_id, rid=req.rid,
                          tokens=[tok0, tok1]))
        self._pending_trace.append(emit)

    def flush_trace(self, t_end: float, cost_model=None) -> None:
        """Emit the step's deferred spans now that its sim-time end (and
        optionally the :class:`repro.serve.loadgen.PrefillCostModel` that
        priced it) is known. Drivers call this AFTER restamping
        ``step_events``, so request timestamps inside spans are final."""
        if self._pending_trace:
            for fn in self._pending_trace:
                fn(t_end, cost_model)
            self._pending_trace = []

    # ---- one step -----------------------------------------------------
    def step(self, t: float = 0.0) -> int:
        """Admit what fits, run at most one prefill unit, then one fused
        decode step across every prefill-complete lane. Returns the
        number of decode tokens emitted this step (``self.last_stats``
        carries the step's prefill cost breakdown for the sim clock)."""
        self.last_stats = {"prefill_padded_tokens": 0, "prefill_attn_mac": 0,
                           "prefill_wasted_tokens": 0}
        self.step_events = []
        self._admit(t)
        self._run_prefill(t)
        ready = np.array([self.active[i] is not None and self.prefill_done[i]
                          for i in range(self.slots)])
        if not ready.any():
            self._sample_metrics(t, 0)
            return 0
        if self.speculative:
            emitted = self._spec_step(ready, t)
            self._sample_metrics(t, emitted)
            return emitted
        # Lanes still prefilling are masked to the dead-lane contract so
        # the fused decode never writes into their (possibly shared)
        # blocks: table 0 -> null block, ctx 0, token 0.
        dec_tables = np.where(ready[:, None], self.tables, 0)
        dec_ctx = np.where(ready, self.ctx, 0).astype(np.int32)
        dec_tok = np.where(ready, self.pending_tok, 0).astype(np.int32)
        logits, self.pools = self.engine.decode(
            self.params, self.pools, jnp.asarray(dec_tok),
            jnp.asarray(dec_tables), jnp.asarray(dec_ctx))
        self.decode_steps_run += 1
        nxt = np.asarray(self.sampler(logits, self._next_key()), np.int32)
        emitted = 0
        for slot in np.flatnonzero(ready):
            req = self.active[slot]
            self.ctx[slot] += 1
            tok = int(nxt[slot])
            req.tokens.append(tok)
            self.pending_tok[slot] = tok
            self.total_new_tokens += 1
            emitted += 1
            if len(req.tokens) >= req.max_new_tokens:
                self._retire(slot, t)
        self._sample_metrics(t, emitted)
        return emitted

    def _spec_step(self, ready: np.ndarray, t: float) -> int:
        """One draft-verify speculative step over every ready lane.

        Drafts up to ``draft_k`` greedy tokens per lane through the
        draft engine, verifies all of them in ONE batched target forward
        (:meth:`PagedEngine.verify`), emits the exact-match prefix plus
        the target's own next token, and rolls the rejected tail's K/V
        back bitwise. Per-lane windows shrink to the tokens a lane may
        still emit, so appends never leave the blocks reserved at
        admission. Greedy streams are bit-identical to non-speculative
        decode: every emitted token is the target's argmax given exactly
        the prefix before it."""
        k = self.draft_k
        c = k + 1
        bs = self.spec.block_size
        remaining = np.array(
            [self.active[s].max_new_tokens - len(self.active[s].tokens)
             if ready[s] else 0 for s in range(self.slots)], np.int32)
        window = np.minimum(c, remaining)               # [slots]
        live = window > 0
        dec_tables = np.where(live[:, None], self.tables, 0).astype(np.int32)
        ctx = np.where(live, self.ctx, 0).astype(np.int32)
        pend = np.where(live, self.pending_tok, 0).astype(np.int32)

        drafts = self.draft.propose(pend, dec_tables, ctx, window)
        self.draft_forwards_run += k + 1
        self.last_stats["draft_forwards"] = (
            self.last_stats.get("draft_forwards", 0) + k + 1)

        # rollback snapshot of every pool row the verify append may touch
        cols = np.arange(c, dtype=np.int32)[None, :]
        positions = ctx[:, None] + cols                 # [slots, C]
        valid = cols < window[:, None]
        safe_pos = np.where(valid, positions, 0)
        phys = np.take_along_axis(dec_tables, safe_pos // bs, axis=1)
        phys = np.where(valid, phys, 0).astype(np.int32)
        off = np.where(valid, safe_pos % bs, 0).astype(np.int32)
        phys_f = jnp.asarray(phys.reshape(-1))
        off_f = jnp.asarray(off.reshape(-1))
        saved = KC.gather_rows(self.pools, phys_f, off_f)

        tokens = np.concatenate([pend[:, None], drafts], axis=1)
        logits, self.pools = self.engine.verify(
            self.params, self.pools, jnp.asarray(tokens),
            jnp.asarray(dec_tables), jnp.asarray(ctx),
            jnp.asarray(window))
        self.decode_steps_run += 1
        self.spec_steps_run += 1
        greedy = np.asarray(jnp.argmax(logits, axis=-1), np.int32)

        # greedy exact-match acceptance (pure; mutations follow rollback)
        accepted = np.zeros(self.slots, np.int32)
        for slot in np.flatnonzero(live):
            w = int(window[slot])
            a = 0
            while a < w - 1 and greedy[slot, a] == drafts[slot, a]:
                a += 1
            accepted[slot] = a

        # roll the rejected tail back to the never-drafted pool state
        restore = valid & (cols > accepted[:, None])
        if restore.any():
            r_phys = jnp.asarray(np.where(restore, phys, 0).reshape(-1))
            r_off = jnp.asarray(np.where(restore, off, 0).reshape(-1))
            self.pools = KC.scatter_rows(self.pools, saved, r_phys, r_off)

        hist = self.metrics.histogram(
            "serve_spec_accepted_len",
            "accepted draft tokens per lane per speculative step",
            buckets=tuple(float(i) for i in range(k + 1)))
        emitted = 0
        for slot in np.flatnonzero(live):
            req = self.active[slot]
            w = int(window[slot])
            a = int(accepted[slot])
            out = [int(x) for x in drafts[slot, :a]] + [int(greedy[slot, a])]
            req.tokens.extend(out)
            self.ctx[slot] = int(ctx[slot]) + a + 1
            self.pending_tok[slot] = out[-1]
            self.total_new_tokens += len(out)
            emitted += len(out)
            self.proposed_drafts += w - 1
            self.accepted_drafts += a
            hist.observe(float(a))
            if len(req.tokens) >= req.max_new_tokens:
                self._retire(slot, t)

        n_live = int(live.sum())
        verify_tokens = int(window.sum())
        verify_mac = int(sum(int(w) * (int(cx) + int(w))
                             for w, cx in zip(window, ctx) if w > 0))
        self.last_stats["verify_tokens"] = (
            self.last_stats.get("verify_tokens", 0) + verify_tokens)
        self.last_stats["verify_attn_mac"] = (
            self.last_stats.get("verify_attn_mac", 0) + verify_mac)
        if self.tracer is not None:
            def emit_spec(t_end, cost_model, *, t0=t, n_live=n_live,
                          verify_tokens=verify_tokens,
                          verify_mac=verify_mac, emitted=emitted,
                          acc=int(accepted.sum())):
                from repro.obs import trace as T
                mid = t0 + (t_end - t0) * 0.5
                self.tracer.complete(
                    "draft", t0, mid, pid=T.SERVE_PID, tid=T.SPEC_TID,
                    cat="spec",
                    args={"forwards": k + 1, "lanes": n_live})
                self.tracer.complete(
                    "verify", mid, t_end, pid=T.SERVE_PID, tid=T.SPEC_TID,
                    cat="spec",
                    args={"tokens": verify_tokens, "attn_mac": verify_mac,
                          "accepted_drafts": acc, "emitted": emitted})
            self._pending_trace.append(emit_spec)
        return emitted

    def _sample_metrics(self, t: float, emitted: int) -> None:
        """Per-step registry samples (host dicts only): pool occupancy +
        its high-watermark, prefill waste, decode tokens, prefix hits."""
        m = self.metrics
        m.gauge("serve_pool_blocks_in_use",
                "KV block-pool occupancy per step (peak = watermark)"
                ).set(self.allocator.in_use)
        m.gauge("serve_pool_blocks_free",
                "free KV blocks per step").set(self.allocator.free_blocks)
        pad = self.last_stats.get("prefill_padded_tokens", 0)
        waste = self.last_stats.get("prefill_wasted_tokens", 0)
        if pad:
            m.counter("serve_prefill_padded_tokens",
                      "padded prompt tokens pushed through prefill"
                      ).inc(pad)
        if waste:
            m.counter("serve_prefill_wasted_tokens",
                      "padding beyond real prompt tokens").inc(waste)
        if emitted:
            m.counter("serve_decode_tokens", "decode tokens emitted"
                      ).inc(emitted)
        if self.prefix is not None:
            m.gauge("serve_prefix_hits", "prefix-cache hits (cumulative)"
                    ).set(self.prefix.hits)
            m.gauge("serve_prefix_misses",
                    "prefix-cache misses (cumulative)"
                    ).set(self.prefix.misses)
        if self.tracer is not None:
            from repro.obs import trace as T
            self.tracer.counter("kv blocks", t,
                                {"in_use": self.allocator.in_use},
                                pid=T.SERVE_PID)

    def run_to_completion(self, requests: Sequence[ServeRequest],
                          max_steps: int = 100_000) -> List[ServeRequest]:
        """Convenience driver: submit everything at t=0 and step until
        drained (the loadgen drives arrivals through real event time)."""
        for r in requests:
            self.submit(r)
        steps = 0
        while not self.idle:
            self.step(float(steps))
            # no cost model here: the step's end is the next integer tick
            self.flush_trace(float(steps) + 1.0)
            steps += 1
            if steps > max_steps:
                raise RuntimeError("scheduler failed to drain")
        return self.finished
