"""Continuous-batching scheduler over the paged engine.

Requests stream in (from :mod:`repro.serve.loadgen` or a plain list) and
occupy one of ``slots`` fixed batch lanes. Every decode step runs ONE
fused forward over all lanes; the scheduler's only job is deciding which
request sits in which lane:

  * ``policy="continuous"`` — a lane is refilled the moment its request
    finishes (vLLM-style continuous batching). Short requests never hold
    long ones hostage and the decode batch stays dense.
  * ``policy="rebatch"`` — the naive baseline: a wave of requests is
    admitted only when *all* lanes are empty, then decoded until the
    longest request in the wave finishes. This is the static-batching
    strawman the serving bench compares against; at mixed decode lengths
    most lanes idle for most of each wave.

Prefill is decoupled from admission (Sarathi-style chunked prefill):
``_admit`` only reserves a lane and its blocks — the actual prompt
compute happens inside :meth:`step`, AT MOST ONE prefill unit per step
(one fixed-size chunk in ``prefill="chunked"`` mode, one full bucketed
prefill in ``prefill="monolithic"`` mode), interleaved with the fused
decode over every prefill-complete lane. A burst of N arrivals therefore
costs live decode lanes one chunk of latency per step, not N monolithic
prefills of dead air. Lanes still prefilling are masked out of the decode
batch (table/ctx/token zeroed → they behave exactly like dead lanes
pointing at the null block).

Chunked mode optionally shares pod prompt prefixes: a
:class:`repro.serve.kvcache.PrefixCache` maps previously computed full
prompt blocks into a new request's table via refcounted
``BlockAllocator.share`` (read-only by contract; the whole-prompt-cached
case goes through ``PagedEngine.copy_block`` copy-on-write), and chunked
prefill resumes at the first uncached token. Monolithic prefill cannot
share (``write_prefill`` scatters the full bucket and would clobber
shared blocks), so ``prefix_cache=True`` requires chunked mode.

Admission is gated by the :class:`repro.serve.kvcache.BlockAllocator`
(all-or-nothing block reservation for prompt + max_new_tokens) and by
``max_inflight_blocks`` so a fleet burst cannot overcommit the pool;
when the prefix registry's cold entries are what exhausts the pool they
are LRU-evicted before admission gives up.

Determinism: greedy decoding makes the token streams a pure function of
(params, prompts) — per-request streams are bit-identical between the two
policies AND the two prefill modes for the dense family (each lane's
attention only reads its own blocks; prefix-shared blocks hold bitwise
the K/V the request would have computed itself, since K/V rows are a
pure function of the token prefix; MoE capacity routing is cross-token
and would break this, which the equivalence test therefore pins to
dense). Temperature sampling draws from a per-step key folded from a
base key and the step index, so a run is reproducible given its seed.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import kvcache as KC
from repro.serve.engine import PagedEngine

_POLICIES = ("continuous", "rebatch")
_PREFILL_MODES = ("chunked", "monolithic")


@dataclasses.dataclass
class ServeRequest:
    """One generation request flowing through the scheduler."""
    rid: int
    prompt: np.ndarray                 # [s] int32
    max_new_tokens: int
    arrival_s: float = 0.0
    deadline_s: float = math.inf
    #: stable id echoed in every span this request produces in a trace
    #: (defaults to ``rid``; callers multiplexing several traces can set
    #: their own correlation id)
    trace_id: Optional[int] = None
    # filled by the scheduler:
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_admit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    def __post_init__(self):
        if self.trace_id is None:
            self.trace_id = self.rid

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.arrival_s

    @property
    def ttft_s(self) -> Optional[float]:
        """Time to first token (arrival -> first sampled token)."""
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival_s

    @property
    def queue_wait_s(self) -> Optional[float]:
        """Time spent waiting for a lane (arrival -> admission)."""
        if self.t_admit is None:
            return None
        return self.t_admit - self.arrival_s

    @property
    def met_deadline(self) -> bool:
        return self.t_done is not None and self.t_done <= self.deadline_s


class ContinuousScheduler:
    """Admit/prefill/decode/retire requests against a :class:`PagedEngine`."""

    def __init__(self, engine: PagedEngine, params, *,
                 policy: str = "continuous",
                 prefill: str = "chunked", prefill_chunk: int = 32,
                 prefix_cache: bool = False,
                 max_inflight_blocks: Optional[int] = None,
                 sampling: str = "greedy", temperature: float = 1.0,
                 seed: int = 0, tracer=None, metrics=None):
        if policy not in _POLICIES:
            raise ValueError(f"unknown policy {policy!r} ({_POLICIES})")
        if prefill not in _PREFILL_MODES:
            raise ValueError(
                f"unknown prefill mode {prefill!r} ({_PREFILL_MODES})")
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if prefix_cache and prefill != "chunked":
            raise ValueError(
                "prefix_cache requires prefill='chunked' (monolithic "
                "write_prefill would clobber shared blocks)")
        self.engine = engine
        self.params = params
        self.policy = policy
        self.prefill_mode = prefill
        self.prefill_chunk = int(prefill_chunk)
        self.spec = engine.spec
        self.slots = engine.slots
        self.max_inflight_blocks = (max_inflight_blocks
                                    if max_inflight_blocks is not None
                                    else self.spec.num_blocks - 1)
        self.allocator = KC.BlockAllocator(self.spec)
        self.prefix: Optional[KC.PrefixCache] = (
            KC.PrefixCache(self.allocator) if prefix_cache else None)
        self.sampler = engine.make_sampler(sampling, temperature)
        self._base_key = jax.random.PRNGKey(seed)
        self._sample_step = 0

        self.pools = engine.init_pools()
        self.tables = np.zeros((self.slots, self.spec.max_blocks_per_req),
                               np.int32)
        self.ctx = np.zeros(self.slots, np.int32)
        self.pending_tok = np.zeros(self.slots, np.int32)
        self.active: List[Optional[ServeRequest]] = [None] * self.slots
        self.blocks: List[Optional[List[int]]] = [None] * self.slots
        self.prefill_pos = np.zeros(self.slots, np.int32)
        self.prefill_done = np.zeros(self.slots, bool)
        self._prefill_queue: Deque[int] = collections.deque()
        self.waiting: Deque[ServeRequest] = collections.deque()
        self.finished: List[ServeRequest] = []
        # counters for the bench report
        self.decode_steps_run = 0
        self.prefills_run = 0            # monolithic full prefills
        self.prefill_chunks_run = 0
        self.total_new_tokens = 0
        self.fresh_blocks_allocated = 0
        # per-step cost stats for the loadgen's sim clock
        self.last_stats: Dict[str, int] = {}
        # requests stamped (first token / done) during the current step;
        # the loadgen finalizes their timestamps to the step's END time
        # once it knows the step's compute cost, so a prefill's own cost
        # lands in the TTFT of the request that incurred it
        self.step_events: List[ServeRequest] = []
        #: optional :class:`repro.obs.Tracer`: queue/lane spans on the
        #: sim clock. Spans whose end time is the step's END (known only
        #: after the driver prices the step) are deferred as callables
        #: and emitted by :meth:`flush_trace` — mirroring the
        #: ``step_events`` restamping contract above. None -> no
        #: callbacks, bit-identical streams (tests/test_obs.py).
        self.tracer = tracer
        if self.tracer is not None:
            from repro.obs import trace as T
            self.tracer.process(T.SERVE_PID, "serving", sort_index=2)
            self.tracer.track(T.SERVE_PID, T.QUEUE_TID, "queue")
            for s in range(self.slots):
                self.tracer.track(T.SERVE_PID, T.lane_tid(s), f"lane {s}")
        self._pending_trace: List = []
        # always-on registry (host-side dict updates only): the bench
        # report reads pool-occupancy stats from it even when no external
        # registry is supplied
        if metrics is None:
            from repro.obs.metrics import MetricsRegistry
            metrics = MetricsRegistry()
        self.metrics = metrics

    # ---- bookkeeping --------------------------------------------------
    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.active)

    @property
    def idle(self) -> bool:
        return self.num_active == 0 and not self.waiting

    def submit(self, req: ServeRequest) -> None:
        if len(req.prompt) + req.max_new_tokens > self.engine.spec.max_tokens_per_req:
            raise ValueError(f"request {req.rid} needs "
                             f"{len(req.prompt) + req.max_new_tokens} tokens "
                             f"> table capacity")
        if (self.prefill_mode == "monolithic"
                and len(req.prompt) > self.engine.max_context):
            # Chunked prefill streams arbitrarily long prompts through
            # fixed-size chunks; only the monolithic bucket is bounded.
            raise ValueError(f"request {req.rid} prompt exceeds max_context")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        self.waiting.append(req)

    def _next_key(self):
        k = jax.random.fold_in(self._base_key, self._sample_step)
        self._sample_step += 1
        return k

    def _retire(self, slot: int, t: float) -> None:
        req = self.active[slot]
        req.t_done = t
        self.step_events.append(req)
        if self.tracer is not None:
            def emit(t_end, cost_model, *, req=req, slot=slot):
                from repro.obs import trace as T
                t0 = (req.t_first_token if req.t_first_token is not None
                      else req.t_done)
                self.tracer.complete(
                    "decode", t0, req.t_done, pid=T.SERVE_PID,
                    tid=T.lane_tid(slot), cat="decode",
                    args={"trace_id": req.trace_id, "rid": req.rid,
                          "new_tokens": len(req.tokens),
                          "latency_s": req.latency_s,
                          "met_deadline": req.met_deadline})
            self._pending_trace.append(emit)
        self.finished.append(req)
        self.allocator.release(self.blocks[slot])
        self.active[slot] = None
        self.blocks[slot] = None
        self.tables[slot] = 0
        self.ctx[slot] = 0
        self.pending_tok[slot] = 0
        self.prefill_pos[slot] = 0
        self.prefill_done[slot] = False

    # ---- admission ----------------------------------------------------
    def _try_alloc(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` fresh blocks under the inflight cap, LRU-evicting
        cold prefix-registry entries once if they are what's in the way."""
        def fits() -> bool:
            return (self.allocator.in_use + n <= self.max_inflight_blocks
                    and n <= self.allocator.free_blocks)
        if not fits() and self.prefix is not None:
            deficit = max(n - self.allocator.free_blocks,
                          self.allocator.in_use + n
                          - self.max_inflight_blocks)
            self.prefix.evict(deficit)
        if not fits():
            return None
        return self.allocator.alloc(n)

    def _admit(self, t: float) -> None:
        """Reserve lanes + blocks for waiting requests (bookkeeping only —
        prompt compute happens one prefill unit per :meth:`step`)."""
        if self.policy == "rebatch" and self.num_active > 0:
            return                      # wave semantics: drain first
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            need = self.spec.blocks_needed(len(req.prompt)
                                           + req.max_new_tokens)
            shared: List[int] = []
            cow_src: Optional[int] = None
            resume = 0
            if self.prefix is not None:
                shared, cow_src, resume = self.prefix.match(req.prompt)
            fresh_need = need - len(shared)
            fresh = self._try_alloc(fresh_need)
            if fresh is None:
                # Undo the prefix refs and keep FIFO order (don't starve
                # the head by admitting a smaller request behind it).
                undo = shared + ([cow_src] if cow_src is not None else [])
                if undo:
                    self.allocator.release(undo)
                break
            self.waiting.popleft()
            self.fresh_blocks_allocated += fresh_need
            if cow_src is not None:
                # Whole prompt was cached: clone the last shared block so
                # the final-token recompute writes a private copy.
                dst = fresh[0]
                self.pools = self.engine.copy_block(self.pools, cow_src, dst)
                self.allocator.release([cow_src])
            req.t_admit = t
            if self.tracer is not None:
                from repro.obs import trace as T
                self.tracer.complete(
                    "queued", req.arrival_s, t, pid=T.SERVE_PID,
                    tid=T.QUEUE_TID, cat="queue",
                    args={"trace_id": req.trace_id, "rid": req.rid,
                          "slot": slot, "prompt_tokens": len(req.prompt),
                          "shared_blocks": len(shared),
                          "resume_tokens": resume,
                          "cow": cow_src is not None})
            self.active[slot] = req
            self.blocks[slot] = shared + fresh
            self.tables[slot] = 0
            self.tables[slot, :need] = shared + fresh
            self.ctx[slot] = 0
            self.pending_tok[slot] = 0
            self.prefill_pos[slot] = resume
            self.prefill_done[slot] = False
            self._prefill_queue.append(slot)

    # ---- prefill work -------------------------------------------------
    def _finish_prefill(self, slot: int, logits, t: float) -> None:
        req = self.active[slot]
        first = int(self.sampler(logits, self._next_key())[0])
        req.tokens.append(first)
        req.t_first_token = t
        self.step_events.append(req)
        if self.tracer is not None:
            def emit(t_end, cost_model, *, req=req, slot=slot):
                from repro.obs import trace as T
                self.tracer.instant(
                    "first_token", req.t_first_token, pid=T.SERVE_PID,
                    tid=T.lane_tid(slot), cat="ttft",
                    args={"trace_id": req.trace_id, "rid": req.rid,
                          "ttft_s": req.ttft_s})
            self._pending_trace.append(emit)
        self.total_new_tokens += 1
        self.ctx[slot] = len(req.prompt)
        self.pending_tok[slot] = first
        self.prefill_done[slot] = True
        if self.prefix is not None:
            self.prefix.insert(req.prompt, self.tables[slot])
        if req.max_new_tokens == 1:
            self._retire(slot, t)

    def _run_prefill(self, t: float) -> None:
        """Run AT MOST ONE prefill unit: the oldest admitted lane still
        prefilling gets one chunk (chunked) or its whole bucketed prefill
        (monolithic)."""
        while self._prefill_queue and (
                self.active[self._prefill_queue[0]] is None
                or self.prefill_done[self._prefill_queue[0]]):
            self._prefill_queue.popleft()
        if not self._prefill_queue:
            return
        slot = self._prefill_queue[0]
        req = self.active[slot]
        plen = len(req.prompt)
        if self.prefill_mode == "monolithic":
            toks, length = self.engine.pad_prompt(req.prompt)
            logits, k, v = self.engine.prefill(self.params, toks, length)
            self.pools = self.engine.write_prefill(
                self.pools, k, v, jnp.asarray(self.tables[slot]))
            self.prefills_run += 1
            self.prefill_pos[slot] = plen
            mc = self.engine.max_context
            self.last_stats["prefill_padded_tokens"] = mc
            self.last_stats["prefill_attn_mac"] = mc ** 2
            self.last_stats["prefill_wasted_tokens"] = mc - plen
            if self.tracer is not None:
                self._pending_prefill_span(
                    "prefill", t, slot, req, 0, plen, mc, mc ** 2)
            self._prefill_queue.popleft()
            self._finish_prefill(slot, logits, t)
            return
        c = self.prefill_chunk
        pos = int(self.prefill_pos[slot])
        clen = min(c, plen - pos)
        buf = np.zeros(c, np.int32)
        buf[:clen] = np.asarray(req.prompt[pos:pos + clen], np.int32)
        logits, self.pools = self.engine.prefill_chunk(
            self.params, self.pools, jnp.asarray(buf),
            jnp.asarray(self.tables[slot]), pos, clen)
        self.prefill_chunks_run += 1
        self.prefill_pos[slot] = pos + clen
        self.last_stats["prefill_padded_tokens"] = c
        self.last_stats["prefill_attn_mac"] = c * (pos + clen)
        self.last_stats["prefill_wasted_tokens"] = c - clen
        if self.tracer is not None:
            self._pending_prefill_span("prefill_chunk", t, slot, req,
                                       pos, pos + clen, c, c * (pos + clen))
        if pos + clen == plen:
            self._prefill_queue.popleft()
            self._finish_prefill(slot, logits, t)

    # ---- tracing (repro.obs) ------------------------------------------
    def _pending_prefill_span(self, name: str, t0: float, slot: int, req,
                              tok0: int, tok1: int, padded: int,
                              mac: int) -> None:
        """Defer a prefill span until the driver knows the step's end."""
        def emit(t_end, cost_model, *, name=name, t0=t0, slot=slot,
                 req=req, tok0=tok0, tok1=tok1, padded=padded, mac=mac):
            from repro.obs import trace as T
            from repro.obs.profile import kernel_cost_args
            self.tracer.complete(
                name, t0, t_end, pid=T.SERVE_PID, tid=T.lane_tid(slot),
                cat="prefill",
                args=dict(kernel_cost_args(padded_tokens=padded,
                                           attn_mac=mac,
                                           cost_model=cost_model),
                          trace_id=req.trace_id, rid=req.rid,
                          tokens=[tok0, tok1]))
        self._pending_trace.append(emit)

    def flush_trace(self, t_end: float, cost_model=None) -> None:
        """Emit the step's deferred spans now that its sim-time end (and
        optionally the :class:`repro.serve.loadgen.PrefillCostModel` that
        priced it) is known. Drivers call this AFTER restamping
        ``step_events``, so request timestamps inside spans are final."""
        if self._pending_trace:
            for fn in self._pending_trace:
                fn(t_end, cost_model)
            self._pending_trace = []

    # ---- one step -----------------------------------------------------
    def step(self, t: float = 0.0) -> int:
        """Admit what fits, run at most one prefill unit, then one fused
        decode step across every prefill-complete lane. Returns the
        number of decode tokens emitted this step (``self.last_stats``
        carries the step's prefill cost breakdown for the sim clock)."""
        self.last_stats = {"prefill_padded_tokens": 0, "prefill_attn_mac": 0,
                           "prefill_wasted_tokens": 0}
        self.step_events = []
        self._admit(t)
        self._run_prefill(t)
        ready = np.array([self.active[i] is not None and self.prefill_done[i]
                          for i in range(self.slots)])
        if not ready.any():
            self._sample_metrics(t, 0)
            return 0
        # Lanes still prefilling are masked to the dead-lane contract so
        # the fused decode never writes into their (possibly shared)
        # blocks: table 0 -> null block, ctx 0, token 0.
        dec_tables = np.where(ready[:, None], self.tables, 0)
        dec_ctx = np.where(ready, self.ctx, 0).astype(np.int32)
        dec_tok = np.where(ready, self.pending_tok, 0).astype(np.int32)
        logits, self.pools = self.engine.decode(
            self.params, self.pools, jnp.asarray(dec_tok),
            jnp.asarray(dec_tables), jnp.asarray(dec_ctx))
        self.decode_steps_run += 1
        nxt = np.asarray(self.sampler(logits, self._next_key()), np.int32)
        emitted = 0
        for slot in np.flatnonzero(ready):
            req = self.active[slot]
            self.ctx[slot] += 1
            tok = int(nxt[slot])
            req.tokens.append(tok)
            self.pending_tok[slot] = tok
            self.total_new_tokens += 1
            emitted += 1
            if len(req.tokens) >= req.max_new_tokens:
                self._retire(slot, t)
        self._sample_metrics(t, emitted)
        return emitted

    def _sample_metrics(self, t: float, emitted: int) -> None:
        """Per-step registry samples (host dicts only): pool occupancy +
        its high-watermark, prefill waste, decode tokens, prefix hits."""
        m = self.metrics
        m.gauge("serve_pool_blocks_in_use",
                "KV block-pool occupancy per step (peak = watermark)"
                ).set(self.allocator.in_use)
        m.gauge("serve_pool_blocks_free",
                "free KV blocks per step").set(self.allocator.free_blocks)
        pad = self.last_stats.get("prefill_padded_tokens", 0)
        waste = self.last_stats.get("prefill_wasted_tokens", 0)
        if pad:
            m.counter("serve_prefill_padded_tokens",
                      "padded prompt tokens pushed through prefill"
                      ).inc(pad)
        if waste:
            m.counter("serve_prefill_wasted_tokens",
                      "padding beyond real prompt tokens").inc(waste)
        if emitted:
            m.counter("serve_decode_tokens", "decode tokens emitted"
                      ).inc(emitted)
        if self.prefix is not None:
            m.gauge("serve_prefix_hits", "prefix-cache hits (cumulative)"
                    ).set(self.prefix.hits)
            m.gauge("serve_prefix_misses",
                    "prefix-cache misses (cumulative)"
                    ).set(self.prefix.misses)
        if self.tracer is not None:
            from repro.obs import trace as T
            self.tracer.counter("kv blocks", t,
                                {"in_use": self.allocator.in_use},
                                pid=T.SERVE_PID)

    def run_to_completion(self, requests: Sequence[ServeRequest],
                          max_steps: int = 100_000) -> List[ServeRequest]:
        """Convenience driver: submit everything at t=0 and step until
        drained (the loadgen drives arrivals through real event time)."""
        for r in requests:
            self.submit(r)
        steps = 0
        while not self.idle:
            self.step(float(steps))
            # no cost model here: the step's end is the next integer tick
            self.flush_trace(float(steps) + 1.0)
            steps += 1
            if steps > max_steps:
                raise RuntimeError("scheduler failed to drain")
        return self.finished
