"""Continuous-batching scheduler over the paged engine.

Requests stream in (from :mod:`repro.serve.loadgen` or a plain list) and
occupy one of ``slots`` fixed batch lanes. Every decode step runs ONE
fused forward over all lanes; the scheduler's only job is deciding which
request sits in which lane:

  * ``policy="continuous"`` — a lane is refilled the moment its request
    finishes (vLLM-style continuous batching). Short requests never hold
    long ones hostage and the decode batch stays dense.
  * ``policy="rebatch"`` — the naive baseline: a wave of requests is
    admitted only when *all* lanes are empty, then decoded until the
    longest request in the wave finishes. This is the static-batching
    strawman the serving bench compares against; at mixed decode lengths
    most lanes idle for most of each wave.

Admission is gated by the :class:`repro.serve.kvcache.BlockAllocator`
(all-or-nothing block reservation for prompt + max_new_tokens) and by
``max_inflight_blocks`` so a fleet burst cannot overcommit the pool.

Determinism: greedy decoding makes the token streams a pure function of
(params, prompts) — per-request streams are bit-identical between the two
policies for the dense family (each lane's attention only reads its own
blocks; MoE capacity routing is cross-token and would break this, which
the equivalence test therefore pins to dense). Temperature sampling draws
from a per-step key folded from a base key and the step index, so a run
is reproducible given its seed.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve import kvcache as KC
from repro.serve.engine import PagedEngine

_POLICIES = ("continuous", "rebatch")


@dataclasses.dataclass
class ServeRequest:
    """One generation request flowing through the scheduler."""
    rid: int
    prompt: np.ndarray                 # [s] int32
    max_new_tokens: int
    arrival_s: float = 0.0
    deadline_s: float = math.inf
    # filled by the scheduler:
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_admit: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.arrival_s

    @property
    def met_deadline(self) -> bool:
        return self.t_done is not None and self.t_done <= self.deadline_s


class ContinuousScheduler:
    """Admit/decode/retire requests against a :class:`PagedEngine`."""

    def __init__(self, engine: PagedEngine, params, *,
                 policy: str = "continuous",
                 max_inflight_blocks: Optional[int] = None,
                 sampling: str = "greedy", temperature: float = 1.0,
                 seed: int = 0):
        if policy not in _POLICIES:
            raise ValueError(f"unknown policy {policy!r} ({_POLICIES})")
        self.engine = engine
        self.params = params
        self.policy = policy
        self.spec = engine.spec
        self.slots = engine.slots
        self.max_inflight_blocks = (max_inflight_blocks
                                    if max_inflight_blocks is not None
                                    else self.spec.num_blocks - 1)
        self.allocator = KC.BlockAllocator(self.spec)
        self.sampler = engine.make_sampler(sampling, temperature)
        self._base_key = jax.random.PRNGKey(seed)
        self._sample_step = 0

        self.pools = engine.init_pools()
        self.tables = np.zeros((self.slots, self.spec.max_blocks_per_req),
                               np.int32)
        self.ctx = np.zeros(self.slots, np.int32)
        self.pending_tok = np.zeros(self.slots, np.int32)
        self.active: List[Optional[ServeRequest]] = [None] * self.slots
        self.blocks: List[Optional[List[int]]] = [None] * self.slots
        self.waiting: Deque[ServeRequest] = collections.deque()
        self.finished: List[ServeRequest] = []
        # counters for the bench report
        self.decode_steps_run = 0
        self.prefills_run = 0
        self.total_new_tokens = 0

    # ---- bookkeeping --------------------------------------------------
    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self.active)

    @property
    def idle(self) -> bool:
        return self.num_active == 0 and not self.waiting

    def submit(self, req: ServeRequest) -> None:
        if len(req.prompt) + req.max_new_tokens > self.engine.spec.max_tokens_per_req:
            raise ValueError(f"request {req.rid} needs "
                             f"{len(req.prompt) + req.max_new_tokens} tokens "
                             f"> table capacity")
        if len(req.prompt) > self.engine.max_context:
            raise ValueError(f"request {req.rid} prompt exceeds max_context")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        self.waiting.append(req)

    def _next_key(self):
        k = jax.random.fold_in(self._base_key, self._sample_step)
        self._sample_step += 1
        return k

    def _retire(self, slot: int, t: float) -> None:
        req = self.active[slot]
        req.t_done = t
        self.finished.append(req)
        self.allocator.release(self.blocks[slot])
        self.active[slot] = None
        self.blocks[slot] = None
        self.tables[slot] = 0
        self.ctx[slot] = 0
        self.pending_tok[slot] = 0

    # ---- admission ----------------------------------------------------
    def _admit(self, t: float) -> None:
        if self.policy == "rebatch" and self.num_active > 0:
            return                      # wave semantics: drain first
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            need = self.spec.blocks_needed(len(req.prompt)
                                           + req.max_new_tokens)
            inflight = self.allocator.in_use
            if inflight + need > self.max_inflight_blocks:
                break                   # FIFO: don't starve the head
            blocks = self.allocator.alloc(need)
            if blocks is None:
                break
            self.waiting.popleft()
            req.t_admit = t
            self.active[slot] = req
            self.blocks[slot] = blocks
            self.tables[slot] = 0
            self.tables[slot, :need] = blocks
            toks, length = self.engine.pad_prompt(req.prompt)
            logits, k, v = self.engine.prefill(self.params, toks, length)
            self.pools = self.engine.write_prefill(
                self.pools, k, v, jnp.asarray(self.tables[slot]))
            self.prefills_run += 1
            first = int(self.sampler(logits, self._next_key())[0])
            req.tokens.append(first)
            self.total_new_tokens += 1
            self.ctx[slot] = len(req.prompt)
            self.pending_tok[slot] = first
            if req.max_new_tokens == 1:
                self._retire(slot, t)

    # ---- one step -----------------------------------------------------
    def step(self, t: float = 0.0) -> int:
        """Admit what fits, then run one fused decode step across all
        lanes. Returns the number of tokens emitted this step."""
        self._admit(t)
        live = [i for i in range(self.slots) if self.active[i] is not None]
        if not live:
            return 0
        logits, self.pools = self.engine.decode(
            self.params, self.pools, jnp.asarray(self.pending_tok),
            jnp.asarray(self.tables), jnp.asarray(self.ctx))
        self.decode_steps_run += 1
        nxt = np.asarray(self.sampler(logits, self._next_key()), np.int32)
        emitted = 0
        for slot in live:
            req = self.active[slot]
            self.ctx[slot] += 1
            tok = int(nxt[slot])
            req.tokens.append(tok)
            self.pending_tok[slot] = tok
            self.total_new_tokens += 1
            emitted += 1
            if len(req.tokens) >= req.max_new_tokens:
                self._retire(slot, t)
        return emitted

    def run_to_completion(self, requests: Sequence[ServeRequest],
                          max_steps: int = 100_000) -> List[ServeRequest]:
        """Convenience driver: submit everything at t=0 and step until
        drained (the loadgen drives arrivals through real event time)."""
        for r in requests:
            self.submit(r)
        steps = 0
        while not self.idle:
            self.step(float(steps))
            steps += 1
            if steps > max_steps:
                raise RuntimeError("scheduler failed to drain")
        return self.finished
