"""Pure-pytree optimizers (no optax dependency): Adam/AdamW + schedules."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class Adam:
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: Optional[float] = 1.0

    def init(self, params) -> AdamState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(step=jnp.zeros((), jnp.int32),
                         m=jax.tree.map(zeros, params),
                         v=jax.tree.map(zeros, params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.grad_clip is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mu, g: b1 * mu + (1 - b1) * g, state.m, grads)
        v = jax.tree.map(lambda nu, g: b2 * nu + (1 - b2) * g * g, state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, mu, nu):
            u = (mu / bc1) / (jnp.sqrt(nu / bc2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamState(step=step, m=m, v=v)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(s < warmup, warm, cos)
    return lr
