"""Loss functions.

``chunked_ce`` computes token cross-entropy scanning over sequence chunks so
the full [B, S, V] float32 logits tensor is never materialized — with V up to
256k and 1M-token global batches that tensor is tens of GB per chip.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.act_sharding import constrain_vocab


def chunked_ce(x: jnp.ndarray, w: jnp.ndarray, labels: jnp.ndarray,
               *, bias: Optional[jnp.ndarray] = None,
               seq_chunk: int = 256):
    """x: [B, S, d] final hidden states; w: [d, V]; labels: [B, S] int32.

    Returns (mean_loss, metrics). Scans over S in chunks; gradients flow
    through the scan.
    """
    b, s, d = x.shape
    # materialize the (d-gathered, vocab-sharded) head weight ONCE outside
    # the rematted chunk scan — otherwise the backward re-all-gathers it
    # for every chunk (measured 3x collective inflation)
    from repro.core.act_sharding import constrain_map
    w = constrain_map(w, {1: "seq"})
    cs = min(seq_chunk, s)
    while s % cs:
        cs -= 1
    nc = s // cs
    xs = x.reshape(b, nc, cs, d).transpose(1, 0, 2, 3)        # [nc, B, cs, d]
    ls = labels.reshape(b, nc, cs).transpose(1, 0, 2)         # [nc, B, cs]

    @jax.checkpoint
    def body(carry, inp):
        # rematted: backward recomputes each chunk's logits rather than
        # storing [nc, B, cs, V] for the whole sequence
        tot, correct = carry
        xc, lc = inp
        logits = jnp.einsum("bsd,dv->bsv", xc, w,
                            preferred_element_type=jnp.float32)
        logits = constrain_vocab(logits)  # vocab-parallel under act rules
        if bias is not None:
            logits = logits + bias
        lse = jax.nn.logsumexp(logits, axis=-1)               # [B, cs]
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        tot = tot + (lse - ll).sum()
        correct = correct + (logits.argmax(-1) == lc).sum()
        return (tot, correct), None

    (tot, correct), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (xs, ls))
    n = b * s
    loss = tot / n
    return loss, {"ce": loss, "acc": correct.astype(jnp.float32) / n}


def head_weight(params: dict) -> jnp.ndarray:
    """Unembedding matrix [d, V] for either tied or separate heads."""
    if "head" in params:
        return params["head"]["w"]
    return params["embed"]["table"].T
