"""Flat-file checkpointing (numpy .npz of path-flattened pytrees).

Used by the training loop and by recovery's edge-aided backup when
persistence across processes is wanted (EdgeBackup keeps snapshots in
memory; this writes them to disk).
"""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict:
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = _SEP.join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree, step: int = 0) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, __step__=np.asarray(step), **flat)


def load(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shapes must match)."""
    with np.load(path if path.endswith(".npz") else path + ".npz") as data:
        step = int(data["__step__"])
        flat = {k: data[k] for k in data.files if k != "__step__"}
    paths = jax.tree_util.tree_leaves_with_path(like)
    leaves = []
    for p, leaf in paths:
        key = _SEP.join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in p)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: {arr.shape} != {leaf.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=getattr(leaf, "dtype",
                                                           arr.dtype)))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
