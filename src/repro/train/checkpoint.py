"""Flat-file checkpointing (numpy .npz of path-flattened pytrees).

Used by the training loop and by recovery's edge-aided backup when
persistence across processes is wanted (EdgeBackup keeps snapshots in
memory; this writes them to disk).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict:
    out = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = _SEP.join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path)
        out[key] = np.asarray(leaf)
    return out


def _npz_path(path: str) -> str:
    return path if path.endswith(".npz") else path + ".npz"


def _meta_path(path: str) -> str:
    return _npz_path(path)[:-len(".npz")] + ".meta.json"


def save(path: str, tree, step: int = 0, meta: Optional[dict] = None) -> None:
    """Save a pytree; ``meta`` (JSON-serializable) is written as a sidecar
    next to the .npz — structured strategies record their stage templates
    there so a checkpoint can be merged/restaged without out-of-band
    knowledge of the layout it was taken under."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, __step__=np.asarray(step), **flat)
    if meta is not None:
        with open(_meta_path(path), "w") as f:
            json.dump(meta, f, indent=1, sort_keys=True)


def load_meta(path: str) -> Optional[dict]:
    """The checkpoint's sidecar metadata, or None if it was saved bare."""
    mp = _meta_path(path)
    if not os.path.exists(mp):
        return None
    with open(mp) as f:
        return json.load(f)


def load(path: str, like) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (shapes must match)."""
    with np.load(path if path.endswith(".npz") else path + ".npz") as data:
        step = int(data["__step__"])
        flat = {k: data[k] for k in data.files if k != "__step__"}
    paths = jax.tree_util.tree_leaves_with_path(like)
    leaves = []
    for p, leaf in paths:
        key = _SEP.join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in p)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: {arr.shape} != {leaf.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=getattr(leaf, "dtype",
                                                           arr.dtype)))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), step
