"""Host-side training loops.

``train_loop`` drives any jitted (params, opt, batch) -> (params, opt,
metrics) step with logging, periodic edge backup, and checkpointing.
``fl_loop`` drives hierarchical FedAvg rounds over per-client datasets
(paper Fig. 1 training procedure) using core/fedavg.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterator, Optional, Sequence

import jax
import numpy as np

from repro.recovery.backup import EdgeBackup


def train_loop(step_fn: Callable, params, opt_state,
               batch_iter: Iterator, *, steps: int,
               log_every: int = 10,
               backup: Optional[EdgeBackup] = None,
               checkpoint_path: Optional[str] = None,
               checkpoint_every: int = 0,
               log_fn: Callable = print) -> Dict:
    hist = []
    t0 = time.time()
    for i in range(steps):
        batch = next(batch_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if backup is not None:
            backup.maybe_backup(i, params)
        if checkpoint_path and checkpoint_every and \
                (i + 1) % checkpoint_every == 0:
            from repro.train.checkpoint import save
            save(checkpoint_path, params, step=i + 1)
        if (i + 1) % log_every == 0 or i == 0:
            m = {k: float(v) for k, v in metrics.items()
                 if np.ndim(v) == 0}
            hist.append(dict(m, step=i + 1))
            rate = (i + 1) / (time.time() - t0)
            log_fn(f"[train] step {i+1:5d} "
                   + " ".join(f"{k}={v:.4f}" for k, v in m.items())
                   + f" ({rate:.2f} it/s)")
    return {"params": params, "opt_state": opt_state, "history": hist}


def fl_loop(fl_round: Callable, client_params, client_opt,
            round_batches_fn: Callable, *, rounds: int,
            log_every: int = 1, log_fn: Callable = print) -> Dict:
    """round_batches_fn(round_idx) -> client-stacked batches [C, E, B, ...]."""
    hist = []
    for r in range(rounds):
        batches = round_batches_fn(r)
        client_params, client_opt, metrics = fl_round(client_params,
                                                      client_opt, batches)
        if (r + 1) % log_every == 0:
            m = {k: float(np.mean(v)) for k, v in metrics.items()}
            hist.append(dict(m, round=r + 1))
            log_fn(f"[fl] round {r+1:4d} "
                   + " ".join(f"{k}={v:.4f}" for k, v in m.items()))
    return {"client_params": client_params, "client_opt": client_opt,
            "history": hist}
