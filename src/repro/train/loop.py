"""Host-side training loops.

``train_loop`` drives any jitted (params, opt, batch) -> (params, opt,
metrics) step; ``fl_loop`` drives hierarchical FedAvg rounds over
per-client datasets (paper Fig. 1 training procedure) using core/fedavg.

Both share a :class:`LoopHooks` struct for logging, periodic edge backup,
and checkpointing — the single place ``repro.api.Session`` (and any other
driver) plugs side effects into the hot loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, Optional

import numpy as np

from repro.recovery.backup import EdgeBackup
from repro.train.checkpoint import save as _save_checkpoint


def _identity(tree):
    return tree


@dataclasses.dataclass
class LoopHooks:
    """Side effects of one training/FL loop, in one place.

    ``backup_view`` maps the loop's raw params (which may be a stage
    container or client-stacked tree) to what EdgeBackup should snapshot.
    None means raw params — except under ``Session.run``, which defaults
    it to ``strategy.merge_params`` so snapshots are redeployable by
    recovery's ``restage`` under any template.
    """

    log_every: int = 10
    log_fn: Callable = print
    backup: Optional[EdgeBackup] = None
    backup_view: Optional[Callable] = None
    checkpoint_path: Optional[str] = None
    checkpoint_every: int = 0
    #: JSON-serializable dict (or zero-arg callable returning one) saved as
    #: a sidecar next to each checkpoint — Session.run defaults it to the
    #: strategy name + live stage templates so structured checkpoints can
    #: be restaged without out-of-band knowledge
    checkpoint_meta: Optional[object] = None
    #: optional user callback (step_or_round_idx, params, metrics) -> None
    on_step: Optional[Callable] = None
    #: FL-round callback (round_idx, metrics) -> None; for the ``hier_fl``
    #: strategy the metrics carry the comm fabric's per-round accounting
    #: (``comm_bytes_up``, ``comm_bytes_backhaul``, ``sim_round_s`` from
    #: the topology's link models)
    on_round: Optional[Callable] = None
    #: live dynamic repartitioning hook (paper §4.2 executed in-loop):
    #: (idx, step_fn, params, opt) -> None to keep going, or a replacement
    #: (step_fn, params, opt) after a template switch
    repartition: Optional[Callable] = None

    def after_step(self, i: int, params, metrics=None) -> None:
        if self.backup is not None:
            view = self.backup_view or _identity
            self.backup.maybe_backup(i, lambda: view(params))
        if self.checkpoint_path and self.checkpoint_every and \
                (i + 1) % self.checkpoint_every == 0:
            meta = self.checkpoint_meta() if callable(self.checkpoint_meta) \
                else self.checkpoint_meta
            _save_checkpoint(self.checkpoint_path, params, step=i + 1,
                             meta=meta)
        if self.on_step is not None:
            self.on_step(i, params, metrics)

    def maybe_repartition(self, i: int, step_fn, params, opt_state):
        """Apply the repartition hook; returns the (possibly swapped)
        loop state."""
        if self.repartition is not None:
            swapped = self.repartition(i, step_fn, params, opt_state)
            if swapped is not None:
                return swapped
        return step_fn, params, opt_state

    def should_log(self, i: int) -> bool:
        return (i + 1) % self.log_every == 0 or i == 0


def train_loop(step_fn: Callable, params, opt_state,
               batch_iter: Iterator, *, steps: int,
               hooks: Optional[LoopHooks] = None) -> Dict:
    hooks = hooks or LoopHooks()
    hist = []
    t0 = time.time()
    for i in range(steps):
        batch = next(batch_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        hooks.after_step(i, params, metrics)
        if hooks.should_log(i):
            m = {k: float(v) for k, v in metrics.items()
                 if np.ndim(v) == 0}
            hist.append(dict(m, step=i + 1))
            rate = (i + 1) / (time.time() - t0)
            hooks.log_fn(f"[train] step {i+1:5d} "
                         + " ".join(f"{k}={v:.4f}" for k, v in m.items())
                         + f" ({rate:.2f} it/s)")
        step_fn, params, opt_state = hooks.maybe_repartition(
            i, step_fn, params, opt_state)
    return {"params": params, "opt_state": opt_state, "history": hist,
            "step_fn": step_fn}


def fl_loop(fl_round: Callable, client_params, client_opt,
            round_batches_fn: Callable, *, rounds: int,
            hooks: Optional[LoopHooks] = None) -> Dict:
    """round_batches_fn(round_idx) -> client-stacked batches [C, E, B, ...].

    Rounds are few and each is expensive, so the default cadence logs
    every round."""
    hooks = hooks or LoopHooks(log_every=1)
    hist = []
    for r in range(rounds):
        batches = round_batches_fn(r)
        client_params, client_opt, metrics = fl_round(client_params,
                                                      client_opt, batches)
        hooks.after_step(r, client_params, metrics)
        if hooks.on_round is not None:
            hooks.on_round(r, metrics)
        if hooks.should_log(r):
            m = {k: float(np.mean(v)) for k, v in metrics.items()}
            hist.append(dict(m, round=r + 1))
            hooks.log_fn(f"[fl] round {r+1:4d} "
                         + " ".join(f"{k}={v:.4f}" for k, v in m.items()))
        fl_round, client_params, client_opt = hooks.maybe_repartition(
            r, fl_round, client_params, client_opt)
    return {"client_params": client_params, "client_opt": client_opt,
            "history": hist, "step_fn": fl_round}
