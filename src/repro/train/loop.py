"""Host-side training loops.

``train_loop`` drives any jitted (params, opt, batch) -> (params, opt,
metrics) step; ``fl_loop`` drives hierarchical FedAvg rounds over
per-client datasets (paper Fig. 1 training procedure) using core/fedavg;
``async_fl_loop`` drives the discrete-event engine of
:mod:`repro.comm.events` — the loop pops timestamped events and the
events drive the jitted compute, inverting ``fl_loop``'s control flow.

All share a :class:`LoopHooks` struct for logging, periodic edge backup,
and checkpointing — the single place ``repro.api.Session`` (and any other
driver) plugs side effects into the hot loop.

History entries keep scalar metrics as floats; non-scalar (per-client)
metrics are recorded verbatim under a ``per_client/`` prefix instead of
being silently averaged into misleading scalars.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, Optional

import numpy as np

from repro.recovery.backup import EdgeBackup
from repro.train.checkpoint import save as _save_checkpoint


def _identity(tree):
    return tree


def _split_metrics(metrics: Dict):
    """(scalars as floats, non-scalars under a ``per_client/`` prefix).

    Arrays are kept whole instead of ``np.mean``-flattened: a per-client
    loss vector averaged into one float silently hides stragglers and
    divergent clients."""
    scalars, arrays = {}, {}
    for k, v in metrics.items():
        if np.ndim(v) == 0:
            scalars[k] = float(v)
        else:
            arrays[f"per_client/{k}"] = np.asarray(v)
    return scalars, arrays


def _fmt_metrics(scalars: Dict, arrays: Dict) -> str:
    """Log-line rendering: scalars verbatim; arrays as explicitly-labeled
    means so nothing is passed off as a scalar metric."""
    parts = [f"{k}={v:.4f}" for k, v in scalars.items()]
    parts += [f"{k.split('/', 1)[1]}[mean]={np.nanmean(v):.4f}"
              for k, v in arrays.items()]
    return " ".join(parts)


@dataclasses.dataclass
class LoopHooks:
    """Side effects of one training/FL loop, in one place.

    ``backup_view`` maps the loop's raw params (which may be a stage
    container or client-stacked tree) to what EdgeBackup should snapshot.
    None means raw params — except under ``Session.run``, which defaults
    it to ``strategy.merge_params`` so snapshots are redeployable by
    recovery's ``restage`` under any template.
    """

    log_every: int = 10
    log_fn: Callable = print
    backup: Optional[EdgeBackup] = None
    backup_view: Optional[Callable] = None
    checkpoint_path: Optional[str] = None
    checkpoint_every: int = 0
    #: JSON-serializable dict (or zero-arg callable returning one) saved as
    #: a sidecar next to each checkpoint — Session.run defaults it to the
    #: strategy name + live stage templates so structured checkpoints can
    #: be restaged without out-of-band knowledge
    checkpoint_meta: Optional[object] = None
    #: optional user callback (step_or_round_idx, params, metrics) -> None
    on_step: Optional[Callable] = None
    #: FL-round callback (round_idx, metrics) -> None; for the ``hier_fl``
    #: strategy the metrics carry the comm fabric's per-round accounting
    #: (``comm_bytes_up``, ``comm_bytes_backhaul``, ``sim_round_s`` from
    #: the topology's link models)
    on_round: Optional[Callable] = None
    #: event-time callback (event) -> None, fired for every event the
    #: ``async_fl_loop`` engine pops (LocalStepDone / UplinkArrived /
    #: BackhaulArrived / CloudDeadline / PodMigration / ...)
    on_event: Optional[Callable] = None
    #: live dynamic repartitioning hook (paper §4.2 executed in-loop):
    #: (idx, step_fn, params, opt) -> None to keep going, or a replacement
    #: (step_fn, params, opt) after a template switch
    repartition: Optional[Callable] = None
    #: optional :class:`repro.obs.Tracer` — ``async_fl_loop`` hands it to
    #: the event engine (sim-time spans per vehicle/edge/cloud track);
    #: the wall-clock loops have no sim timeline and ignore it
    tracer: Optional[object] = None
    #: optional :class:`repro.obs.MetricsRegistry` — every logged round's
    #: scalar metrics are published into it (``comm_bytes*`` as counters,
    #: the rest as gauges); ``async_fl_loop`` also hands it to the engine
    metrics: Optional[object] = None

    def after_step(self, i: int, params, metrics=None) -> None:
        if self.backup is not None:
            view = self.backup_view or _identity
            self.backup.maybe_backup(i, lambda: view(params))
        if self.checkpoint_path and self.checkpoint_every and \
                (i + 1) % self.checkpoint_every == 0:
            meta = self.checkpoint_meta() if callable(self.checkpoint_meta) \
                else self.checkpoint_meta
            _save_checkpoint(self.checkpoint_path, params, step=i + 1,
                             meta=meta)
        if self.on_step is not None:
            self.on_step(i, params, metrics)

    def maybe_repartition(self, i: int, step_fn, params, opt_state):
        """Apply the repartition hook; returns the (possibly swapped)
        loop state."""
        if self.repartition is not None:
            swapped = self.repartition(i, step_fn, params, opt_state)
            if swapped is not None:
                return swapped
        return step_fn, params, opt_state

    def should_log(self, i: int) -> bool:
        return (i + 1) % self.log_every == 0 or i == 0


def train_loop(step_fn: Callable, params, opt_state,
               batch_iter: Iterator, *, steps: int,
               hooks: Optional[LoopHooks] = None) -> Dict:
    hooks = hooks or LoopHooks()
    hist = []
    t0 = time.time()
    for i in range(steps):
        batch = next(batch_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        hooks.after_step(i, params, metrics)
        if hooks.should_log(i):
            m, per_client = _split_metrics(metrics)
            if hooks.metrics is not None:
                hooks.metrics.publish_scalars(m)
            hist.append(dict(m, **per_client, step=i + 1,
                             t_wall_s=time.time() - t0))
            rate = (i + 1) / (time.time() - t0)
            hooks.log_fn(f"[train] step {i+1:5d} "
                         + _fmt_metrics(m, per_client)
                         + f" ({rate:.2f} it/s)")
        step_fn, params, opt_state = hooks.maybe_repartition(
            i, step_fn, params, opt_state)
    return {"params": params, "opt_state": opt_state, "history": hist,
            "step_fn": step_fn}


def fl_loop(fl_round: Callable, client_params, client_opt,
            round_batches_fn: Callable, *, rounds: int,
            hooks: Optional[LoopHooks] = None, teacher=None) -> Dict:
    """round_batches_fn(round_idx) -> client-stacked batches [C, E, B, ...].

    Rounds are few and each is expensive, so the default cadence logs
    every round.

    ``teacher``: the student/teacher split of federated distillation —
    optional frozen params handed to every round as
    ``fl_round(client_params, client_opt, batches, teacher)``. The loop
    only carries (and hands to hooks) the trainable student side, so
    edge backups snapshot adapters, not the immutable backbone."""
    hooks = hooks or LoopHooks(log_every=1)
    extra = () if teacher is None else (teacher,)
    hist = []
    t0 = time.time()
    for r in range(rounds):
        batches = round_batches_fn(r)
        client_params, client_opt, metrics = fl_round(client_params,
                                                      client_opt, batches,
                                                      *extra)
        hooks.after_step(r, client_params, metrics)
        if hooks.on_round is not None:
            hooks.on_round(r, metrics)
        if hooks.should_log(r):
            m, per_client = _split_metrics(metrics)
            if hooks.metrics is not None:
                hooks.metrics.publish_scalars(m)
            hist.append(dict(m, **per_client, round=r + 1,
                             t_wall_s=time.time() - t0))
            hooks.log_fn(f"[fl] round {r+1:4d} "
                         + _fmt_metrics(m, per_client))
        fl_round, client_params, client_opt = hooks.maybe_repartition(
            r, fl_round, client_params, client_opt)
    return {"client_params": client_params, "client_opt": client_opt,
            "history": hist, "step_fn": fl_round}


def async_fl_loop(engine, client_params, client_opt,
                  round_batches_fn: Callable, *, rounds: int,
                  hooks: Optional[LoopHooks] = None,
                  until_time: Optional[float] = None,
                  max_events: int = 2_000_000) -> Dict:
    """Drive an :class:`repro.comm.events.AsyncHierFLEngine` until
    ``rounds`` cloud merges (or simulated ``until_time``) have happened.

    This inverts ``fl_loop``'s control flow: the loop pops timestamped
    events off the engine's priority queue and each event drives the
    jitted compute it stands for (local steps at ``LocalStepDone``, a
    pod's partial aggregate at commit, the staleness-weighted merge at
    ``CloudDeadline``). ``round_batches_fn(wave_idx)`` supplies
    client-stacked batches exactly like ``fl_loop``'s
    ``round_batches_fn`` — in the synchronous special case (no merge
    clock) waves and rounds coincide.

    One history entry per cloud merge; ``hooks.on_event`` sees every
    event, ``hooks.on_round`` every merge.
    """
    hooks = hooks or LoopHooks(log_every=1)
    # observability rides in on the hooks: the engine owns the sim clock,
    # so it (not this loop) emits the spans and fabric metrics
    if hooks.tracer is not None and getattr(engine, "tracer", None) is None:
        engine.tracer = hooks.tracer
    if hooks.metrics is not None and getattr(engine, "metrics", None) is None:
        engine.metrics = hooks.metrics
    engine.reset(client_params, client_opt, round_batches_fn)
    hist = []
    merges = 0
    t0 = time.time()
    for _ in range(max_events):
        if merges >= rounds:
            break
        if until_time is not None and engine.queue.peek_t() > until_time:
            break
        ev = engine.queue.pop()
        if ev is None:
            raise RuntimeError(
                f"event queue drained after {merges} merges "
                f"(wanted {rounds}) — the fabric deadlocked; with "
                f"clock=None every pod must eventually hear from all "
                f"its members")
        rec = engine.handle(ev)
        if hooks.on_event is not None:
            hooks.on_event(ev)
        if rec is None:
            continue
        hooks.after_step(merges, engine.client_params, rec)
        if hooks.on_round is not None:
            hooks.on_round(merges, rec)
        if hooks.should_log(merges):
            m, per_client = _split_metrics(rec)
            if hooks.metrics is not None:
                hooks.metrics.publish_scalars(m)
            hist.append(dict(m, **per_client, round=merges + 1,
                             t_wall_s=time.time() - t0,
                             t_sim_s=float(engine.now)))
            hooks.log_fn(f"[async-fl] merge {merges+1:4d} "
                         f"t={engine.now:9.3f}s "
                         + _fmt_metrics(m, per_client))
        merges += 1
    else:
        raise RuntimeError(
            f"async_fl_loop exceeded max_events={max_events} before "
            f"{rounds} merges — runaway event schedule")
    return {"client_params": engine.client_params,
            "client_opt": engine.client_opt,
            "global_params": engine.global_params,
            "history": hist, "event_log": engine.event_log,
            "sim_time_s": engine.now, "merges": merges,
            "step_fn": engine}
