"""Profiling hooks: optional ``jax.profiler`` capture + static kernel
cost annotations.

Two complementary levels:

  * :func:`profiled` — a context manager wrapping the jitted hot loop in
    a ``jax.profiler`` trace when a capture directory is set (view the
    result in TensorBoard / Perfetto). Zero-cost no-op when disabled or
    when the profiler is unavailable in this jax build.
  * :func:`kernel_cost_args` — static per-kernel cost annotations for
    span ``args``: padded tokens and attention MACs priced through the
    same :class:`repro.serve.loadgen.PrefillCostModel` accounting the
    serving tier's sim clock uses. On an interpret-mode CPU container
    the Pallas wall-clock says nothing about accelerator cost; the MAC
    model is the honest FLOP proxy, so traces carry it on every compute
    span instead of pretending host time is device time.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class ProfileOptions:
    """Where (and whether) to capture a ``jax.profiler`` trace.

    ``jax_trace_dir=None`` disables capture entirely — the context
    manager is then a no-op and the traced run stays bit-identical."""

    jax_trace_dir: Optional[str] = None
    create_perfetto_link: bool = False


@contextlib.contextmanager
def profiled(options: Optional[ProfileOptions] = None):
    """Wrap a block in ``jax.profiler.trace`` when enabled.

    Usage::

        with profiled(ProfileOptions(jax_trace_dir="/tmp/jaxtrace")):
            out = session.run(steps)
    """
    if options is None or options.jax_trace_dir is None:
        yield
        return
    try:
        import jax.profiler as _prof
    except Exception:                      # pragma: no cover - jax stub
        yield
        return
    with _prof.trace(options.jax_trace_dir,
                     create_perfetto_link=options.create_perfetto_link):
        yield


def kernel_cost_args(*, padded_tokens: int = 0, attn_mac: int = 0,
                     flops: float = 0.0, cost_model=None) -> Dict:
    """Static cost annotation dict for a span's ``args``.

    ``padded_tokens`` / ``attn_mac`` follow the scheduler's
    ``last_stats`` accounting (linear work per padded token + attention
    score MACs); ``flops`` is the FL compute model's per-round estimate.
    When a :class:`repro.serve.loadgen.PrefillCostModel` (anything with
    ``step_cost``) is given, the modeled seconds ride along as
    ``est_cost_s`` — the exact surcharge the sim clock charged."""
    args: Dict = {}
    if padded_tokens:
        args["padded_tokens"] = int(padded_tokens)
    if attn_mac:
        args["attn_mac"] = int(attn_mac)
    if flops:
        args["flops"] = float(flops)
    if cost_model is not None and (padded_tokens or attn_mac):
        args["est_cost_s"] = float(cost_model.step_cost(
            {"prefill_padded_tokens": padded_tokens,
             "prefill_attn_mac": attn_mac}))
    return args
