"""Span tracer: Chrome trace-event / Perfetto JSON on the simulated clock.

The emitted file is the `trace-event format
<https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU>`_
(JSON object form, ``{"traceEvents": [...]}``): ``X`` complete spans
with microsecond ``ts``/``dur``, ``i`` instants, ``C`` counter samples,
``s``/``f`` flow arrows, and ``M`` metadata rows naming processes and
threads. Perfetto (https://ui.perfetto.dev) and ``chrome://tracing``
both load it directly.

Timestamps are the **simulated** clock — the same numbers that appear in
the event engine's event log and the loadgen's report — converted to
microseconds. A traced run therefore shows communication/computation
overlap, straggler gaps, and TTFT exactly as the timing models scored
them, independent of host wall time.

Determinism: events are appended in the (deterministic) order the
drivers process them and serialized with sorted keys, so the same seed
produces byte-identical trace files (mirroring the event-log replay
guarantee of :mod:`repro.comm.events`; enforced by
``tests/test_obs.py``).

Track conventions (pid groups tracks; tid orders them):

  * ``FL_PID``     — the FL fabric: one track per vehicle
    (``vehicle_tid``), one per edge pod (``edge_tid``), one for the
    cloud (``CLOUD_TID``).
  * ``SERVE_PID``  — the serving tier: a queue track (``QUEUE_TID``)
    for admission waits, a speculative-decode track (``SPEC_TID``) for
    the per-step draft/verify spans, plus one track per scheduler lane
    (``lane_tid``).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Union

TRACE_SCHEMA = "chrome-trace-event/1"

#: process ids for the two instrumented subsystems
FL_PID = 1
SERVE_PID = 2

#: tid layout inside FL_PID
CLOUD_TID = 1
_EDGE_TID0 = 100
_VEHICLE_TID0 = 1000
#: tid layout inside SERVE_PID
QUEUE_TID = 1
#: draft/verify spans of the speculative decoder (batched across lanes,
#: so they live on their own track rather than any one lane's)
SPEC_TID = 2
_LANE_TID0 = 10


def vehicle_tid(i: int) -> int:
    return _VEHICLE_TID0 + i


def edge_tid(e: int) -> int:
    return _EDGE_TID0 + e


def lane_tid(slot: int) -> int:
    return _LANE_TID0 + slot


class Tracer:
    """Collects trace events in memory; ``save``/``to_bytes`` serialize.

    All ``t``/``t0``/``t1`` arguments are simulated seconds; they are
    stored as microseconds (the trace-event unit). The tracer never
    touches tensors or PRNG state — attaching one cannot perturb a run.
    """

    def __init__(self):
        self.events: List[Dict] = []
        self._named: set = set()
        self._flow_seq = 0

    # ---- metadata -----------------------------------------------------
    def process(self, pid: int, name: str, sort_index: int = 0) -> None:
        key = ("p", pid)
        if key in self._named:
            return
        self._named.add(key)
        self.events.append({"ph": "M", "name": "process_name", "pid": pid,
                            "tid": 0, "args": {"name": name}})
        if sort_index:
            self.events.append({"ph": "M", "name": "process_sort_index",
                                "pid": pid, "tid": 0,
                                "args": {"sort_index": sort_index}})

    def track(self, pid: int, tid: int, name: str) -> None:
        key = ("t", pid, tid)
        if key in self._named:
            return
        self._named.add(key)
        self.events.append({"ph": "M", "name": "thread_name", "pid": pid,
                            "tid": tid, "args": {"name": name}})

    # ---- spans / marks ------------------------------------------------
    def complete(self, name: str, t0: float, t1: float, *, pid: int,
                 tid: int, cat: str = "", args: Optional[Dict] = None
                 ) -> None:
        """One ``X`` complete span covering simulated ``[t0, t1]``."""
        ev = {"ph": "X", "name": name, "pid": pid, "tid": tid,
              "ts": t0 * 1e6, "dur": max(0.0, (t1 - t0) * 1e6)}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, t: float, *, pid: int, tid: int,
                cat: str = "", args: Optional[Dict] = None,
                scope: str = "t") -> None:
        ev = {"ph": "i", "name": name, "pid": pid, "tid": tid,
              "ts": t * 1e6, "s": scope}
        if cat:
            ev["cat"] = cat
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, t: float, values: Dict[str, float], *,
                pid: int, tid: int = 0) -> None:
        """One ``C`` counter sample (rendered as a counter track)."""
        self.events.append({"ph": "C", "name": name, "pid": pid,
                            "tid": tid, "ts": t * 1e6,
                            "args": {k: float(v)
                                     for k, v in values.items()}})

    def flow(self, name: str, t0: float, pid0: int, tid0: int,
             t1: float, pid1: int, tid1: int, cat: str = "flow") -> int:
        """A flow arrow (``s`` -> ``f`` pair) between two tracks; the
        endpoints must lie inside enclosing slices on their tracks.
        Returns the flow id."""
        fid = self._flow_seq
        self._flow_seq += 1
        self.events.append({"ph": "s", "name": name, "cat": cat,
                            "id": fid, "pid": pid0, "tid": tid0,
                            "ts": t0 * 1e6})
        self.events.append({"ph": "f", "name": name, "cat": cat,
                            "id": fid, "pid": pid1, "tid": tid1,
                            "ts": t1 * 1e6, "bp": "e"})
        return fid

    # ---- serialization ------------------------------------------------
    def to_dict(self) -> Dict:
        return {"traceEvents": self.events,
                "displayTimeUnit": "ms",
                "otherData": {"schema": TRACE_SCHEMA,
                              "clock": "simulated-seconds->us"}}

    def to_bytes(self) -> bytes:
        """Canonical serialization: sorted keys, fixed separators — the
        byte-determinism contract the trace tests pin. Numpy scalars in
        span args collapse to plain ints/floats (same rendered bytes)."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"),
                          default=_np_default).encode()

    def save(self, path: str) -> str:
        with open(path, "wb") as f:
            f.write(self.to_bytes())
        return path

    def __len__(self) -> int:
        return len(self.events)


def _np_default(o):
    import numpy as np
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"Object of type {type(o).__name__} "
                    f"is not JSON serializable")


def resolve_tracer(trace: Union[None, str, Tracer]
                   ) -> tuple:
    """Normalize a ``trace=`` option: None -> (None, None), a path ->
    (fresh Tracer, path to save at the end), a Tracer -> (it, None)."""
    if trace is None:
        return None, None
    if isinstance(trace, Tracer):
        return trace, None
    return Tracer(), str(trace)
