"""Metrics registry: labeled counters / gauges / histograms -> JSON.

Every subsystem keeps private accounting (wire bytes in the comm
fabric, TTFT fields in the loadgen, block churn in the allocator); this
registry is the shared layer they publish into so one snapshot can
correlate them:

  * the train loops publish every round's scalar metrics
    (``LoopHooks.metrics``): ``comm_bytes_*`` accumulate as counters,
    everything else samples a gauge;
  * the event engine publishes per-edge uplink/backhaul byte counters,
    the observed-staleness histogram, and the migration counter;
  * the continuous scheduler publishes block-pool occupancy (+ its
    high-watermark, via ``BlockAllocator.free_blocks``), prefix
    hits/misses, decode tokens, and padded-token waste.

Instruments are host-side and allocation-light: a dict update per
publish, no tensors, no PRNG — publishing cannot perturb a run.
``snapshot()`` is JSON-serializable with deterministic key order.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

METRICS_SCHEMA = "repro.obs.metrics/1"

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Instrument:
    kind = ""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help

    def _series(self) -> List[Dict]:  # pragma: no cover - abstract
        raise NotImplementedError

    def snapshot(self) -> Dict:
        return {"type": self.kind, "help": self.help,
                "series": self._series()}


class Counter(_Instrument):
    """Monotone accumulator, one cell per label set."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._cells: Dict[LabelKey, float] = {}

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {value})")
        k = _label_key(labels)
        self._cells[k] = self._cells.get(k, 0.0) + float(value)

    def value(self, **labels) -> float:
        return self._cells.get(_label_key(labels), 0.0)

    def _series(self) -> List[Dict]:
        return [{"labels": dict(k), "value": v}
                for k, v in sorted(self._cells.items())]


class Gauge(_Instrument):
    """Last-value instrument that also tracks mean / peak / min / count,
    so a per-step sample stream (e.g. block-pool occupancy) can report
    its high-watermark without storing every sample."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        # label key -> [last, sum, count, peak, trough]
        self._cells: Dict[LabelKey, List[float]] = {}

    def set(self, value: float, **labels) -> None:
        v = float(value)
        cell = self._cells.get(_label_key(labels))
        if cell is None:
            self._cells[_label_key(labels)] = [v, v, 1, v, v]
        else:
            cell[0] = v
            cell[1] += v
            cell[2] += 1
            cell[3] = max(cell[3], v)
            cell[4] = min(cell[4], v)

    def value(self, **labels) -> Optional[float]:
        cell = self._cells.get(_label_key(labels))
        return None if cell is None else cell[0]

    def stats(self, **labels) -> Optional[Dict]:
        cell = self._cells.get(_label_key(labels))
        if cell is None:
            return None
        last, total, count, peak, trough = cell
        return {"last": last, "mean": total / count, "count": int(count),
                "peak": peak, "min": trough}

    def _series(self) -> List[Dict]:
        return [{"labels": dict(k), **self.stats(**dict(k))}
                for k in sorted(self._cells)]


DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.25, 0.5, 1.0,
                   2.5, 5.0, 10.0)


class Histogram(_Instrument):
    """Fixed-bucket histogram (cumulative counts, Prometheus-style)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")
        # label key -> [bucket counts..., +inf count, sum]
        self._cells: Dict[LabelKey, List[float]] = {}

    def observe(self, value: float, **labels) -> None:
        v = float(value)
        k = _label_key(labels)
        cell = self._cells.setdefault(
            k, [0.0] * (len(self.buckets) + 1) + [0.0])
        for i, b in enumerate(self.buckets):
            if v <= b:
                cell[i] += 1
                break
        else:
            cell[len(self.buckets)] += 1
        cell[-1] += v

    def stats(self, **labels) -> Optional[Dict]:
        cell = self._cells.get(_label_key(labels))
        if cell is None:
            return None
        counts = cell[:-1]
        n = int(sum(counts))
        return {"count": n, "sum": cell[-1],
                "mean": (cell[-1] / n) if n else 0.0,
                "buckets": [{"le": b, "count": int(c)}
                            for b, c in zip(self.buckets, counts)]
                + [{"le": "inf", "count": int(counts[-1])}]}

    def _series(self) -> List[Dict]:
        return [{"labels": dict(k), **self.stats(**dict(k))}
                for k in sorted(self._cells)]


class MetricsRegistry:
    """Get-or-create registry of instruments, snapshotting to JSON."""

    def __init__(self):
        self._metrics: Dict[str, _Instrument] = {}

    def _get(self, cls, name: str, help: str, **kw):
        inst = self._metrics.get(name)
        if inst is None:
            inst = cls(name, help, **kw)
            self._metrics[name] = inst
        elif not isinstance(inst, cls):
            raise ValueError(
                f"metric {name!r} already registered as {inst.kind}, "
                f"requested {cls.kind}")
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_Instrument]:
        return self._metrics.get(name)

    def publish_scalars(self, metrics: Dict, *, prefix: str = "",
                        **labels) -> None:
        """Publish one round/step's scalar metrics dict: ``comm_bytes*``
        keys accumulate as counters (they are per-round byte totals),
        everything else samples a gauge. Non-scalars are skipped — the
        loops record those whole in history instead."""
        import numpy as np
        for k, v in metrics.items():
            if np.ndim(v) != 0:
                continue
            v = float(v)
            name = prefix + k
            if k.startswith("comm_bytes"):
                self.counter(name).inc(v, **labels)
            else:
                self.gauge(name).set(v, **labels)

    def snapshot(self) -> Dict:
        return {"schema": METRICS_SCHEMA,
                "metrics": {name: inst.snapshot()
                            for name, inst in sorted(self._metrics.items())}}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, sort_keys=True, indent=1)
        return path

    def __len__(self) -> int:
        return len(self._metrics)
