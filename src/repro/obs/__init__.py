"""repro.obs — unified observability for the FL engine and serving tier.

Three pieces, all host-side and all zero-cost when disabled:

  * :mod:`repro.obs.trace` — a span tracer emitting Chrome trace-event /
    Perfetto-compatible JSON on the **simulated** clock, with one track
    per vehicle / edge / cloud (FL fabric) and per serving lane
    (continuous scheduler). ``tracer=None`` everywhere means no
    callbacks fire: event logs, params, and greedy streams are bitwise
    identical to an untraced run (enforced by ``tests/test_obs.py``).
  * :mod:`repro.obs.metrics` — a registry of labeled counters / gauges /
    histograms (uplink/backhaul bytes, observed staleness, block-pool
    occupancy + high-watermark, prefix hit rate, padded-token waste)
    that the train loops, the event engine, and the continuous scheduler
    publish into, snapshotting to JSON.
  * :mod:`repro.obs.profile` — optional ``jax.profiler`` trace capture
    around jitted steps plus static per-kernel cost annotations (the
    :class:`repro.serve.PrefillCostModel` MAC accounting) attached to
    spans.

Capture points: ``Session.run(trace=...)`` / ``Session.serve(trace=...)``
and the ``--trace PATH`` flags on ``launch/train.py``,
``launch/serve.py`` and ``launch/dryrun.py``. Validate any emitted file
with ``scripts/validate_trace.py`` and open it at https://ui.perfetto.dev
or ``chrome://tracing``.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry)
from repro.obs.profile import (ProfileOptions, kernel_cost_args, profiled)
from repro.obs.trace import (FL_PID, SERVE_PID, TRACE_SCHEMA, Tracer,
                             resolve_tracer)

__all__ = ["Counter", "FL_PID", "Gauge", "Histogram", "MetricsRegistry",
           "ProfileOptions", "SERVE_PID", "TRACE_SCHEMA", "Tracer",
           "kernel_cost_args", "profiled", "resolve_tracer"]
