"""Double-DQN in pure JAX (paper §4.1.3 "DQN-based Pipeline Generation").

Generic: an environment supplies (state, valid-action mask) vectors; the
agent owns the online/target networks, replay buffer, and the double-DQN
update (action selection by the online net, evaluation by the target —
the paper names a "Double Q Network (DQN)-based scheduler").
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import Adam


@dataclasses.dataclass(frozen=True)
class DQNConfig:
    obs_dim: int
    n_actions: int
    hidden: int = 64
    gamma: float = 0.98
    lr: float = 1e-3
    batch: int = 64
    buffer: int = 20000
    target_update: int = 200
    eps_start: float = 1.0
    eps_end: float = 0.05
    eps_decay_steps: int = 3000


def init_qnet(key, cfg: DQNConfig) -> dict:
    ks = jax.random.split(key, 3)
    h = cfg.hidden
    return {
        "w1": jax.random.normal(ks[0], (cfg.obs_dim, h)) * cfg.obs_dim ** -0.5,
        "b1": jnp.zeros((h,)),
        "w2": jax.random.normal(ks[1], (h, h)) * h ** -0.5,
        "b2": jnp.zeros((h,)),
        "w3": jax.random.normal(ks[2], (h, cfg.n_actions)) * h ** -0.5,
        "b3": jnp.zeros((cfg.n_actions,)),
    }


def q_values(p, obs):
    x = jax.nn.relu(obs @ p["w1"] + p["b1"])
    x = jax.nn.relu(x @ p["w2"] + p["b2"])
    return x @ p["w3"] + p["b3"]


class Replay:
    def __init__(self, cfg: DQNConfig):
        self.cfg = cfg
        self.obs = np.zeros((cfg.buffer, cfg.obs_dim), np.float32)
        self.act = np.zeros(cfg.buffer, np.int32)
        self.rew = np.zeros(cfg.buffer, np.float32)
        self.nxt = np.zeros((cfg.buffer, cfg.obs_dim), np.float32)
        self.nxt_mask = np.zeros((cfg.buffer, cfg.n_actions), np.float32)
        self.done = np.zeros(cfg.buffer, np.float32)
        self.n = 0
        self.i = 0

    def add(self, obs, act, rew, nxt, nxt_mask, done):
        i = self.i
        self.obs[i], self.act[i], self.rew[i] = obs, act, rew
        self.nxt[i], self.nxt_mask[i], self.done[i] = nxt, nxt_mask, done
        self.i = (i + 1) % self.cfg.buffer
        self.n = min(self.n + 1, self.cfg.buffer)

    def sample(self, rng, batch):
        idx = rng.integers(0, self.n, batch)
        return (self.obs[idx], self.act[idx], self.rew[idx],
                self.nxt[idx], self.nxt_mask[idx], self.done[idx])


class DoubleDQN:
    def __init__(self, cfg: DQNConfig, seed: int = 0):
        self.cfg = cfg
        key = jax.random.PRNGKey(seed)
        self.online = init_qnet(key, cfg)
        self.target = jax.tree.map(jnp.copy, self.online)
        self.opt = Adam(lr=cfg.lr, grad_clip=5.0)
        self.opt_state = self.opt.init(self.online)
        self.replay = Replay(cfg)
        self.rng = np.random.default_rng(seed)
        self.step_count = 0

        @jax.jit
        def _update(online, target, opt_state, batch):
            obs, act, rew, nxt, nxt_mask, done = batch

            def loss_fn(p):
                q = q_values(p, obs)
                q_sa = jnp.take_along_axis(q, act[:, None], axis=1)[:, 0]
                # double-DQN target: online argmax, target value
                q_next_online = q_values(p, nxt) + (nxt_mask - 1) * 1e9
                a_star = jnp.argmax(q_next_online, axis=1)
                q_next_t = q_values(target, nxt)
                q_star = jnp.take_along_axis(q_next_t, a_star[:, None],
                                             axis=1)[:, 0]
                tgt = rew + self.cfg.gamma * (1 - done) * \
                    jax.lax.stop_gradient(q_star)
                return jnp.mean((q_sa - tgt) ** 2)

            loss, grads = jax.value_and_grad(loss_fn)(online)
            online, opt_state = self.opt.update(grads, opt_state, online)
            return online, opt_state, loss

        self._update = _update

        @jax.jit
        def _greedy(online, obs, mask):
            q = q_values(online, obs) + (mask - 1) * 1e9
            return jnp.argmax(q)

        self._greedy = _greedy

    def epsilon(self) -> float:
        c = self.cfg
        frac = min(self.step_count / c.eps_decay_steps, 1.0)
        return c.eps_start + (c.eps_end - c.eps_start) * frac

    def act(self, obs: np.ndarray, mask: np.ndarray,
            explore: bool = True) -> int:
        valid = np.flatnonzero(mask > 0)
        if len(valid) == 0:
            return 0
        if explore and self.rng.random() < self.epsilon():
            return int(self.rng.choice(valid))
        return int(self._greedy(self.online, jnp.asarray(obs),
                                jnp.asarray(mask)))

    def record(self, *transition):
        self.replay.add(*transition)

    def learn(self) -> float:
        self.step_count += 1
        if self.replay.n < self.cfg.batch:
            return 0.0
        batch = self.replay.sample(self.rng, self.cfg.batch)
        self.online, self.opt_state, loss = self._update(
            self.online, self.target, self.opt_state,
            tuple(map(jnp.asarray, batch)))
        if self.step_count % self.cfg.target_update == 0:
            self.target = jax.tree.map(jnp.copy, self.online)
        return float(loss)
