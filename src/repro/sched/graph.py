"""Model DAG + topological sort (paper §4.1.3 "systematic model
partitioning": nodes = modules, edges = data dependencies).

The paper traces torch modules with torch.fx; in JAX we build the graph
from the config (the model is declarative), which sidesteps the paper's
dynamic-control-flow tracing failures entirely (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from repro.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Node:
    name: str
    cap: float     # training memory footprint (bytes)
    cmp: float     # FLOPs per sample
    com: float     # output activation bytes per sample
    deps: tuple = ()


@dataclasses.dataclass
class ModelGraph:
    nodes: Dict[str, Node]

    def topo_sorted(self) -> List[Node]:
        """Kahn's algorithm; raises on cycles."""
        indeg = {n: 0 for n in self.nodes}
        for node in self.nodes.values():
            for d in node.deps:
                indeg[node.name] += 1
        ready = sorted([n for n, k in indeg.items() if k == 0])
        out: List[Node] = []
        while ready:
            cur = ready.pop(0)
            out.append(self.nodes[cur])
            for node in sorted(self.nodes.values(), key=lambda x: x.name):
                if cur in node.deps:
                    indeg[node.name] -= 1
                    if indeg[node.name] == 0:
                        ready.append(node.name)
        if len(out) != len(self.nodes):
            raise ValueError("cycle in model graph")
        return out


def vision_encoder_graph(cfg: ModelConfig, *, tokens: int = 256,
                         dtype_bytes: int = 4) -> ModelGraph:
    """The paper's vision encoder DAG: RGB backbone + LiDAR backbone ->
    transformer encoder blocks -> query decoder (Eq. 7 components)."""
    d, f = cfg.d_model, cfg.d_ff
    train_mult = 10 * dtype_bytes / 4  # paper: train state ~10x params
    nodes: Dict[str, Node] = {}

    def add(name, params, flops, out_bytes, deps=()):
        nodes[name] = Node(name, params * train_mult, flops, out_bytes,
                           tuple(deps))

    proj_p = cfg.prefix_dim * d
    add("rgb_backbone", proj_p, 2 * proj_p * tokens,
        tokens * d * dtype_bytes)
    add("lidar_backbone", proj_p, 2 * proj_p * tokens,
        tokens * d * dtype_bytes)
    blk_p = 4 * d * d + 3 * d * f + 2 * d
    t2 = 2 * tokens  # fused multimodal stream
    for i in range(cfg.num_layers):
        deps = ("rgb_backbone", "lidar_backbone") if i == 0 \
            else (f"enc{i-1}",)
        add(f"enc{i}", blk_p,
            6 * blk_p * t2 + 4 * cfg.num_heads * cfg.hd * t2 * t2,
            t2 * d * dtype_bytes, deps)
    dec_p = 4 * d * d + (cfg.num_waypoints + 1) * d
    add("decoder", dec_p, 6 * dec_p * (cfg.num_waypoints + 1),
        (cfg.num_waypoints * 2 + cfg.num_light_classes) * dtype_bytes,
        (f"enc{cfg.num_layers-1}",))
    return ModelGraph(nodes)
