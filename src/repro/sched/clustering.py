"""Availability filtering + capacity/stability clustering (paper §4.1.1–2).

Eq. 1/2 split vehicles into resource-sufficient (train alone — plain FL
clients) and resource-limited (must join a cluster). Eq. 6 forms clusters
greedily by stability, subject to:
  c1: cluster memory  > M_cap,
  c2: cluster compute-over-dwell > e * alpha' * M_cmp,
  c3: cluster size bounded by the in-range neighbor set over the horizon.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.sched.costmodel import Vehicle


@dataclasses.dataclass(frozen=True)
class TrainingTask:
    m_cap: float       # model training footprint (bytes)
    m_cmp: float       # FLOPs per epoch
    e_req: int = 1     # required epochs
    alpha: float = 0.1  # min fraction of the task a participant must carry
    alpha_prime: float = 1.2   # fault-tolerance redundancy (Eq. 6 c2)


def availability_split(vehicles: Sequence[Vehicle], task: TrainingTask
                       ) -> Tuple[List[Vehicle], List[Vehicle], List[Vehicle]]:
    """Eq. 1/2: (resource_sufficient, resource_limited, unavailable)."""
    rs, rl, out = [], [], []
    for v in vehicles:
        # Eq. 1: dwl*cmp >= alpha * M_cmp * e_req to participate at all
        can_contribute = v.dwl * v.cmp >= \
            task.alpha * task.m_cmp * task.e_req
        if not can_contribute:
            out.append(v)
        elif v.dwl * v.cmp >= task.m_cmp * task.e_req and v.mem >= task.m_cap:
            rs.append(v)
        else:
            rl.append(v)
    return rs, rl, out


def form_cluster(seed: Vehicle, neighbors: Sequence[Vehicle],
                 task: TrainingTask, *,
                 stability: Optional[Dict[int, float]] = None,
                 max_size: Optional[int] = None) -> Optional[List[Vehicle]]:
    """Eq. 6: grow ``seed``'s cluster by descending neighbor stability until
    c1 (memory) and c2 (compute-over-dwell) hold; None if infeasible within
    c3 (size cap = in-range neighbor count)."""
    stability = stability or {}
    cand = sorted(neighbors, key=lambda v: -stability.get(v.vid, v.stb))
    cluster = [seed]
    cap = seed.mem
    cmp_dwl = seed.dwl * seed.cmp
    limit = max_size if max_size is not None else len(cand) + 1
    need_cmp = task.e_req * task.alpha_prime * task.m_cmp

    for v in cand:
        if cap > task.m_cap and cmp_dwl > need_cmp:
            break
        if len(cluster) >= limit:
            break
        cluster.append(v)
        cap += v.mem
        cmp_dwl += v.dwl * v.cmp
    if cap > task.m_cap and cmp_dwl > need_cmp and len(cluster) <= limit:
        return cluster
    return None


def cluster_fleet(vehicles: Sequence[Vehicle], task: TrainingTask, *,
                  stability: Optional[Dict[Tuple[int, int], float]] = None,
                  max_size: Optional[int] = None
                  ) -> Tuple[List[List[Vehicle]], List[Vehicle]]:
    """Partition resource-limited vehicles into clusters (each acting as one
    FL client, §4.1.2 end). Returns (clusters, leftover)."""
    rs, rl, _ = availability_split(vehicles, task)
    clusters: List[List[Vehicle]] = [[v] for v in rs]
    remaining = sorted(rl, key=lambda v: -v.stb)
    used: set = set()
    for seed in remaining:
        if seed.vid in used:
            continue
        nbrs = [v for v in remaining
                if v.vid != seed.vid and v.vid not in used]
        stb = None
        if stability is not None:
            stb = {v.vid: stability.get((seed.vid, v.vid), 0.0)
                   for v in nbrs}
        got = form_cluster(seed, nbrs, task, stability=stb,
                           max_size=max_size)
        if got is not None:
            clusters.append(got)
            used.update(v.vid for v in got)
    leftover = [v for v in remaining if v.vid not in used]
    return clusters, leftover
