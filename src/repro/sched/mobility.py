"""DTMC vehicle mobility model + stability scoring (paper §4.1.2, Eq. 3–5).

The area is an R x R grid of unit cells; mobility patterns are Markov
transition matrices over cells; future-position prediction marginalizes
over patterns given a history (Eq. 3); neighbor stability integrates the
expected relative distance over the dwell horizon (Eq. 5 — lower expected
distance => higher stability; we return the negated distance integral so
"bigger is more stable", matching the argmax in Eq. 6).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class GridWorld:
    size: int                     # cells per side
    patterns: np.ndarray          # [K, C, C] row-stochastic transitions

    @property
    def n_cells(self) -> int:
        return self.size * self.size

    def cell_xy(self, c) -> np.ndarray:
        return np.stack([np.asarray(c) // self.size,
                         np.asarray(c) % self.size], axis=-1)

    def cell_dist(self, a, b) -> np.ndarray:
        """Cell-count distance n(c_a -> c_b) (paper's grid metric)."""
        pa, pb = self.cell_xy(a), self.cell_xy(b)
        return np.abs(pa - pb).sum(axis=-1)


def make_patterns(size: int, n_patterns: int, seed: int = 0,
                  persistence: float = 0.55) -> GridWorld:
    """Synthetic mobility patterns: each pattern is a biased random walk
    with a preferred heading (models route classes, e.g. 'north-bound
    arterial'), plus a stay-put mass."""
    rng = np.random.default_rng(seed)
    C = size * size
    pats = np.zeros((n_patterns, C, C))
    headings = rng.uniform(0, 2 * np.pi, n_patterns)
    for k in range(n_patterns):
        dx = int(np.round(np.cos(headings[k])))
        dy = int(np.round(np.sin(headings[k])))
        for c in range(C):
            x, y = divmod(c, size)
            moves = {}
            for (mx, my), w in (((0, 0), persistence),
                                ((dx, dy), 1 - persistence),
                                ((1, 0), .05), ((-1, 0), .05),
                                ((0, 1), .05), ((0, -1), .05)):
                nx, ny = min(max(x + mx, 0), size - 1), \
                    min(max(y + my, 0), size - 1)
                moves[nx * size + ny] = moves.get(nx * size + ny, 0) + w
            total = sum(moves.values())
            for cc, w in moves.items():
                pats[k, c, cc] = w / total
    return GridWorld(size, pats)


def sample_trajectory(world: GridWorld, pattern: int, start: int,
                      steps: int, rng) -> np.ndarray:
    traj = [start]
    c = start
    for _ in range(steps):
        c = rng.choice(world.n_cells, p=world.patterns[pattern, c])
        traj.append(c)
    return np.asarray(traj)


def pattern_posterior(world: GridWorld, history: Sequence[int]) -> np.ndarray:
    """P(m_a | H) by trajectory likelihood under each pattern (Eq. 3's
    mixture weights)."""
    K = world.patterns.shape[0]
    logp = np.zeros(K)
    for k in range(K):
        for a, b in zip(history[:-1], history[1:]):
            logp[k] += np.log(world.patterns[k, a, b] + 1e-12)
    logp -= logp.max()
    p = np.exp(logp)
    return p / p.sum()


def future_distribution(world: GridWorld, history: Sequence[int],
                        horizon: int) -> np.ndarray:
    """Eq. 3: P(c_f at t | H) for t = 1..horizon. Returns [horizon, C]."""
    post = pattern_posterior(world, history)
    cur = np.zeros(world.n_cells)
    cur[history[-1]] = 1.0
    out = np.zeros((horizon, world.n_cells))
    per_pat = np.tile(cur, (world.patterns.shape[0], 1))
    for t in range(horizon):
        per_pat = np.einsum("kc,kcd->kd", per_pat, world.patterns)
        out[t] = post @ per_pat
    return out


def expected_relative_distance(world: GridWorld, hist_v: Sequence[int],
                               hist_nb: Sequence[int], horizon: int
                               ) -> float:
    """E[sum_t RD_nb(t)] via the joint independent-future factorization of
    Eq. 4."""
    fv = future_distribution(world, hist_v, horizon)
    fn = future_distribution(world, hist_nb, horizon)
    cells = np.arange(world.n_cells)
    D = world.cell_dist(cells[:, None], cells[None, :])   # [C, C]
    return float(np.einsum("tc,td,cd->", fv, fn, D))


def stability_score(world: GridWorld, hist_v: Sequence[int],
                    hist_nb: Sequence[int], dwell_steps: int) -> float:
    """Stb_nb (Eq. 5): negated expected cumulative relative distance over
    the dwell horizon, normalized per step (higher = more stable)."""
    rd = expected_relative_distance(world, hist_v, hist_nb, dwell_steps)
    return -rd / max(dwell_steps, 1)


def in_range_probability(world: GridWorld, hist_v, hist_nb, horizon: int,
                         radius_cells: int) -> float:
    """P(neighbour stays within comm radius for the whole horizon) under a
    per-step independence approximation (used by clustering's c3)."""
    fv = future_distribution(world, hist_v, horizon)
    fn = future_distribution(world, hist_nb, horizon)
    cells = np.arange(world.n_cells)
    D = world.cell_dist(cells[:, None], cells[None, :])
    within = (D <= radius_cells).astype(float)
    p = 1.0
    for t in range(horizon):
        p *= float(np.einsum("c,d,cd->", fv[t], fn[t], within))
    return p
