"""Dwell-time prediction (paper §4.1.1): MAPE regression over route
features, solved with a wide-and-deep-recurrent regressor in pure JAX.

The paper cites the WDR travel-time architecture [32]: a wide (linear)
path over cross features, a deep MLP path, and a recurrent path over the
cell sequence of the route. Loss: min_R sum |a_i - R(b_i)| / a_i + Omega(R).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import Adam


@dataclasses.dataclass(frozen=True)
class WDRConfig:
    n_cells: int
    route_len: int
    emb: int = 16
    hidden: int = 32
    l2: float = 1e-4


def init_wdr(key, cfg: WDRConfig) -> dict:
    ks = jax.random.split(key, 6)
    e, h = cfg.emb, cfg.hidden
    return {
        "cell_emb": jax.random.normal(ks[0], (cfg.n_cells, e)) * 0.1,
        "wide_w": jnp.zeros((cfg.n_cells,)),
        "deep_w1": jax.random.normal(ks[1], (e * 2 + 2, h)) * (e * 2 + 2) ** -0.5,
        "deep_b1": jnp.zeros((h,)),
        "deep_w2": jax.random.normal(ks[2], (h, h)) * h ** -0.5,
        "deep_b2": jnp.zeros((h,)),
        "gru_wx": jax.random.normal(ks[3], (e, 3 * h)) * e ** -0.5,
        "gru_wh": jax.random.normal(ks[4], (h, 3 * h)) * h ** -0.5,
        "gru_b": jnp.zeros((3 * h,)),
        "out_w": jax.random.normal(ks[5], (2 * h + 1, 1)) * 0.1,
        "out_b": jnp.zeros((1,)),
    }


def _gru(p, xs, h0):
    def step(h, x):
        z = x @ p["gru_wx"] + h @ p["gru_wh"] + p["gru_b"]
        r, u, c = jnp.split(z, 3, axis=-1)
        r, u = jax.nn.sigmoid(r), jax.nn.sigmoid(u)
        cand = jnp.tanh(c + r * 0)
        h = (1 - u) * h + u * cand
        return h, None

    h, _ = jax.lax.scan(step, h0, xs)
    return h


def wdr_forward(p, routes: jnp.ndarray, speeds: jnp.ndarray) -> jnp.ndarray:
    """routes: [B, L] int cell ids; speeds: [B] avg speed feature.
    Returns predicted dwell [B] (softplus — positive)."""
    emb = p["cell_emb"][routes]                       # [B, L, e]
    wide = p["wide_w"][routes].sum(axis=1)            # [B]
    deep_in = jnp.concatenate(
        [emb[:, 0], emb[:, -1],
         speeds[:, None], jnp.ones_like(speeds)[:, None]], axis=-1)
    deep = jax.nn.relu(deep_in @ p["deep_w1"] + p["deep_b1"])
    deep = jax.nn.relu(deep @ p["deep_w2"] + p["deep_b2"])
    h0 = jnp.zeros((routes.shape[0], p["gru_wh"].shape[0]))
    rec = _gru(p, emb.transpose(1, 0, 2), h0)
    feats = jnp.concatenate([deep, rec, wide[:, None]], axis=-1)
    return jax.nn.softplus(feats @ p["out_w"] + p["out_b"])[:, 0]


def mape_loss(p, routes, speeds, dwell, l2=1e-4):
    pred = wdr_forward(p, routes, speeds)
    mape = jnp.mean(jnp.abs(dwell - pred) / jnp.maximum(dwell, 1e-3))
    reg = sum(jnp.sum(w ** 2) for w in jax.tree.leaves(p))
    return mape + l2 * reg, pred


def synthetic_dwell_data(world, n: int, route_len: int, seed: int = 0
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Routes from the DTMC + ground-truth dwell = f(route length in cells,
    speed) + noise — the 'historical edge server data' of §4.1.1."""
    from repro.sched.mobility import sample_trajectory
    rng = np.random.default_rng(seed)
    K = world.patterns.shape[0]
    routes = np.zeros((n, route_len), np.int32)
    speeds = np.zeros(n, np.float32)
    dwell = np.zeros(n, np.float32)
    for i in range(n):
        k = rng.integers(K)
        start = rng.integers(world.n_cells)
        traj = sample_trajectory(world, k, start, route_len - 1, rng)
        routes[i] = traj
        speed = rng.uniform(0.5, 1.5)
        speeds[i] = speed
        path_cells = len(np.unique(traj))
        dwell[i] = (path_cells * 2.0 / speed) * rng.uniform(0.9, 1.1)
    return routes, speeds, dwell


def train_dwell_model(world, *, route_len: int = 12, n_train: int = 512,
                      steps: int = 300, seed: int = 0):
    """Fit the WDR regressor; returns (params, predict_fn, final_mape)."""
    cfg = WDRConfig(n_cells=world.n_cells, route_len=route_len)
    key = jax.random.PRNGKey(seed)
    params = init_wdr(key, cfg)
    routes, speeds, dwell = synthetic_dwell_data(world, n_train, route_len,
                                                 seed)
    routes, speeds, dwell = map(jnp.asarray, (routes, speeds, dwell))
    opt = Adam(lr=1e-2, grad_clip=1.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        (loss, _), grads = jax.value_and_grad(
            lambda p: mape_loss(p, routes, speeds, dwell), has_aux=True)(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    loss = jnp.inf
    for _ in range(steps):
        params, state, loss = step(params, state)

    def predict(routes_, speeds_):
        return wdr_forward(params, jnp.asarray(routes_), jnp.asarray(speeds_))

    return params, predict, float(loss)
