"""Analytic pipeline cost model (paper Eqs. 7–10), re-parameterized for TPU.

The paper measures per-module computation ``M_cmp`` (FLOPs/sample), memory
footprint ``M_cap`` (bytes, ~10x params for training state), and boundary
activation volume ``M_com``. Timing:

  t_cmp = M_cmp * N_batch * nu / (cmp_v * mu)          (Eq. 8)
  t_com = 2 * M_com * N_batch * nu / com_v             (Eq. 9)
  t_path(p, P) = sum t_cmp + sum t_com (non-final)     (Eq. 10)

``mu`` (GPU utilization 0.3–0.7) and ``nu`` (memory-bandwidth overhead
1.1–1.5) keep the paper's calibration; ``cmp_v``/``com_v`` default to the
TPU v5e constants instead of Jetson numbers. Heterogeneous vehicle specs
(Table 1) are retained for the testbed-replay benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.config import TPU_V5E, ModelConfig

BYTES_PER_PARAM_TRAIN = 10  # activations+grads+optimizer (paper §4.1.1)


@dataclasses.dataclass(frozen=True)
class Vehicle:
    """A participant: compute (FLOP/s), memory (bytes), link (bytes/s),
    stability score (Eq. 5) and predicted dwell time (s)."""
    vid: int
    cmp: float
    mem: float
    com: float
    stb: float = 1.0
    dwl: float = 1e9


# The paper's Jetson testbed (Table 1), used by the replay benchmarks.
JETSON_NX = dict(cmp=0.404e12, mem=8e9, com=0.125e9)
JETSON_NANO = dict(cmp=0.472e12, mem=8e9, com=0.125e9)
JETSON_AGX = dict(cmp=3.85e12, mem=32e9, com=0.25e9)

TPU_CHIP = dict(cmp=TPU_V5E.peak_flops, mem=TPU_V5E.hbm_bytes,
                com=TPU_V5E.ici_bw)

#: named vehicle classes for the declarative fleet spec ("nano*4,agx*2")
FLEET_PRESETS = {"nano": JETSON_NANO, "nx": JETSON_NX, "agx": JETSON_AGX,
                 "tpu": TPU_CHIP}


def make_fleet(specs: Sequence[dict], *, stb: Optional[Sequence[float]] = None,
               dwl: Optional[Sequence[float]] = None) -> List[Vehicle]:
    out = []
    for i, s in enumerate(specs):
        out.append(Vehicle(i, s["cmp"], s["mem"], s["com"],
                           stb[i] if stb is not None else s.get("stb", 1.0),
                           dwl[i] if dwl is not None else s.get("dwl", 1e9)))
    return out


def demo_fleet(unit_cap: float) -> List[dict]:
    """The heterogeneous 5-vehicle fixture the repartition example and
    benchmark share: vehicle memories/compute sized (in units of one model
    unit's training footprint ``unit_cap``) so SWIFT must span multiple
    vehicles — two fast 2-unit hosts, a small 1-unit host, and two
    roomy-but-slow stragglers a single-vehicle pipeline would bottleneck
    on."""
    return [
        dict(cmp=1.0e12, mem=2.2 * unit_cap, com=0.10e9, stb=0.95),
        dict(cmp=0.8e12, mem=2.2 * unit_cap, com=0.10e9, stb=0.85),
        dict(cmp=0.5e12, mem=1.2 * unit_cap, com=0.05e9, stb=0.70),
        dict(cmp=0.3e12, mem=4.5 * unit_cap, com=0.25e9, stb=0.60),
        dict(cmp=0.3e12, mem=4.5 * unit_cap, com=0.25e9, stb=0.50),
    ]


def parse_fleet(spec) -> List[Vehicle]:
    """Coerce a fleet declaration into vehicles.

    Accepts "nano*4,agx*2"-style preset strings (see :data:`FLEET_PRESETS`),
    a sequence of spec dicts (``cmp``/``mem``/``com`` required, ``stb``/
    ``dwl`` optional), or a sequence of :class:`Vehicle` (passed through).
    """
    if isinstance(spec, str):
        dicts = []
        for part in spec.split(","):
            name, _, mult = part.strip().partition("*")
            if name not in FLEET_PRESETS:
                raise ValueError(
                    f"unknown vehicle class {name!r}; presets: "
                    f"{', '.join(sorted(FLEET_PRESETS))}")
            dicts += [dict(FLEET_PRESETS[name])] * (int(mult) if mult else 1)
        return make_fleet(dicts)
    spec = list(spec)
    if all(isinstance(v, Vehicle) for v in spec):
        return spec
    return make_fleet([dict(s) for s in spec])


@dataclasses.dataclass(frozen=True)
class Unit:
    """One partitionable model unit (paper: a DAG node after topo-sort;
    here: a transformer block or frontend module)."""
    name: str
    cap: float        # training memory footprint (bytes)
    cmp: float        # FLOPs per sample (fwd+bwd)
    com: float        # boundary activation bytes per sample


def model_units(cfg: ModelConfig, *, seq_len: int = 1024,
                dtype_bytes: int = 2,
                num_units: Optional[int] = None) -> List[Unit]:
    """Units for an architecture: per-block FLOPs/bytes from the config.

    fwd+bwd FLOPs ~= 6 * params_per_block * tokens (dense); the boundary
    volume is the residual stream [seq, d_model]. ``num_units`` overrides
    the unit count (default: one per layer) while preserving the model's
    total cost — used when the runtime's partitionable unit (e.g. an xLSTM
    super-block) differs from ``cfg.num_layers``.
    """
    d = cfg.d_model
    nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd

    def attn_params():
        return d * nq * hd + 2 * d * nkv * hd + nq * hd * d + 2 * d

    def ffn_params():
        if cfg.moe.num_experts:
            k = cfg.moe.top_k
            return d * cfg.moe.num_experts \
                + k * 3 * d * cfg.moe.d_expert  # active params per token
        return 3 * d * cfg.d_ff

    def ffn_store():
        if cfg.moe.num_experts:
            return d * cfg.moe.num_experts \
                + cfg.moe.num_experts * 3 * d * cfg.moe.d_expert
        return 3 * d * cfg.d_ff

    blk_active = attn_params() + ffn_params()
    blk_store = attn_params() + ffn_store()
    n = num_units or cfg.num_layers
    scale = cfg.num_layers / n
    units = []
    for i in range(n):
        cmp_ = (6 * blk_active * seq_len
                + 4 * nq * hd * seq_len * seq_len) * scale
        units.append(Unit(
            f"block{i}",
            cap=blk_store * dtype_bytes * BYTES_PER_PARAM_TRAIN / 2 * scale,
            cmp=cmp_,
            com=seq_len * d * dtype_bytes))
    return units


def vision_encoder_units(cfg: ModelConfig, *, tokens: int = 256,
                         dtype_bytes: int = 4) -> List[Unit]:
    """The paper's own vision encoder DAG (RGB, LiDAR, Enc, Dec modules).

    §4.1.3: ``M_cmp = M_cmp^* N_batch`` per component; we expose the
    topo-sorted unit list the scheduler partitions.
    """
    from repro.sched.graph import vision_encoder_graph
    g = vision_encoder_graph(cfg, tokens=tokens, dtype_bytes=dtype_bytes)
    return [Unit(n.name, n.cap, n.cmp, n.com) for n in g.topo_sorted()]


@dataclasses.dataclass(frozen=True)
class CostParams:
    mu: float = 0.5      # compute utilization (paper: 0.3–0.7)
    nu: float = 1.3      # memory-bandwidth overhead (paper: 1.1–1.5)
    n_batch: int = 16    # samples per epoch step


def t_cmp(units_cmp: float, v: Vehicle, cp: CostParams) -> float:
    return units_cmp * cp.n_batch * cp.nu / (v.cmp * cp.mu)       # Eq. 8


def t_com(boundary_bytes: float, v: Vehicle, cp: CostParams) -> float:
    return 2.0 * boundary_bytes * cp.n_batch * cp.nu / v.com      # Eq. 9


def t_uplink(nbytes: float, v: Vehicle) -> float:
    """One-way vehicle -> edge transfer of an FL update payload over the
    vehicle's V2X link — the per-link model :mod:`repro.comm.topology`
    builds round times from. Contrast :func:`t_com`, the per-step
    boundary-activation exchange of Eq. 9 (round trip, batch-scaled)."""
    return nbytes / v.com


def path_time(path: Sequence[Vehicle], partition: Sequence[Sequence[Unit]],
              cp: CostParams) -> float:
    """Eq. 10: sum of stage compute plus inter-stage communication."""
    total = 0.0
    for i, (v, units) in enumerate(zip(path, partition)):
        total += t_cmp(sum(u.cmp for u in units), v, cp)
        if i < len(path) - 1 and units:
            total += t_com(units[-1].com, v, cp)
    return total


def partition_feasible(path: Sequence[Vehicle],
                       partition: Sequence[Sequence[Unit]]) -> bool:
    """Eq. 11 c2: every stage fits its vehicle's memory."""
    return all(sum(u.cap for u in units) <= v.mem
               for v, units in zip(path, partition))


def pipeline_throughput(path, partition, cp: CostParams,
                        microbatches: int = 8) -> float:
    """Samples/s under GPipe pipelining: bottleneck-stage-bound with the
    (M + S - 1)/M bubble factor."""
    stage_times = []
    for i, (v, units) in enumerate(zip(path, partition)):
        t = t_cmp(sum(u.cmp for u in units), v, cp) / cp.n_batch
        if i < len(path) - 1 and units:
            t += t_com(units[-1].com, v, cp) / cp.n_batch
        stage_times.append(t)
    bottleneck = max(stage_times) if stage_times else 1e9
    bubble = (microbatches + len(path) - 1) / microbatches
    return 1.0 / (bottleneck * bubble)
