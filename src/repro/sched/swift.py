"""SWIFT — Speedy Weight-based Intelligent Fast Two-phase scheduler
(paper §4.1.3).

Phase 1 (quick start): greedy matching over stability-ordered vehicles —
each vehicle takes the largest contiguous unit range its memory allows.
Stable vehicles sit in EARLY stages (they must persist longest).

Phase 2: for every remaining vehicle v_j (ascending stability) as the
first stage of a new pipeline, a double-DQN jointly picks (vehicle, units)
per stage (Eq. 11's coupled partition+order; reward Eq. 12). This gives
the |V| essential pipelines so every vehicle heads one pipeline — the
data-utilization requirement FHDP is built on.

Also provides :func:`greedy_matching` — the single-resource baseline the
paper compares against (Fig. 6): it optimizes memory fit only, ignoring
the compute/communication balance, and becomes infeasible or bottlenecked
as cluster size / model size grow.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.sched.costmodel import (CostParams, Unit, Vehicle,
                                   partition_feasible, path_time)
from repro.sched.dqn import DQNConfig, DoubleDQN

N_MAX = 12                       # max cluster size the policy supports
CHUNK_OPTIONS = (1, 2, 4, 8, 16, 32)


@dataclasses.dataclass
class Pipeline:
    path: List[Vehicle]
    partition: List[List[Unit]]          # units per stage
    time: float

    def template(self) -> Tuple[int, ...]:
        return tuple(len(u) for u in self.partition)


# --------------------------------------------------------------------------
# Phase 1: greedy stability-ordered quick start
# --------------------------------------------------------------------------
def phase1_greedy(vehicles: Sequence[Vehicle], units: Sequence[Unit],
                  cp: CostParams) -> Optional[Pipeline]:
    """Stability-descending greedy: each stage takes the largest prefix of
    remaining units that fits memory (paper: 'each vehicle receiving the
    maximum partition size that satisfies its memory constraint')."""
    order = sorted(vehicles, key=lambda v: -v.stb)
    remaining = list(units)
    path, partition = [], []
    for v in order:
        if not remaining:
            break
        take, cap = [], 0.0
        while remaining and cap + remaining[0].cap <= v.mem:
            cap += remaining[0].cap
            take.append(remaining.pop(0))
        if not take:
            continue
        path.append(v)
        partition.append(take)
    if remaining:
        return None
    return Pipeline(path, partition, path_time(path, partition, cp))


def greedy_matching(vehicles: Sequence[Vehicle], units: Sequence[Unit],
                    cp: CostParams) -> Optional[Pipeline]:
    """The baseline: memory-greedy in arrival order (single-resource
    optimization, no stability/time awareness)."""
    remaining = list(units)
    path, partition = [], []
    for v in vehicles:
        if not remaining:
            break
        take, cap = [], 0.0
        while remaining and cap + remaining[0].cap <= v.mem:
            cap += remaining[0].cap
            take.append(remaining.pop(0))
        if take:
            path.append(v)
            partition.append(take)
    if remaining:
        return None
    return Pipeline(path, partition, path_time(path, partition, cp))


# --------------------------------------------------------------------------
# Phase 2: DQN pipeline generation
# --------------------------------------------------------------------------
class PipelineEnv:
    """Episode: build one pipeline for a cluster.

    Action a = vehicle_idx * len(CHUNK_OPTIONS) + chunk_idx assigns the
    next CHUNK_OPTIONS[chunk_idx] units (clipped to remaining) to that
    vehicle as the next stage. State = Eq. 11's five components flattened:
    remaining capacity, per-vehicle (used, mem-ratio, t_cmp, t_com), and
    the partial path encoding.
    """

    def __init__(self, vehicles: Sequence[Vehicle], units: Sequence[Unit],
                 cp: CostParams, head: Optional[int] = None,
                 w=(1.0, 0.5, 0.25, 0.25)):
        self.vehicles = list(vehicles)[:N_MAX]
        if head is not None and not 0 <= head < len(self.vehicles):
            raise ValueError(
                f"head index {head} outside the fleet window of "
                f"{len(self.vehicles)} vehicles (fleets larger than "
                f"N_MAX={N_MAX} must be windowed first; see window_fleet)")
        self.units = list(units)
        self.cp = cp
        self.head = head
        self.w = w
        total_cap = sum(u.cap for u in units) or 1.0
        total_cmp = sum(u.cmp for u in units) or 1.0
        self.cap_norm = total_cap
        self.cmp_norm = total_cmp
        self.n_actions = N_MAX * len(CHUNK_OPTIONS)
        self.obs_dim = 2 + N_MAX * 5
        self.reset()

    def reset(self):
        self.next_unit = 0
        self.used = [False] * len(self.vehicles)
        self.path: List[Vehicle] = []
        self.partition: List[List[Unit]] = []
        self.done = False
        if self.head is not None:
            self._assign(self.head, self._max_units(self.head, cap_only=True,
                                                    limit=CHUNK_OPTIONS[-1]))
        return self.obs(), self.mask()

    def _max_units(self, vi, cap_only=False, limit=10 ** 9):
        v = self.vehicles[vi]
        cap, cnt = 0.0, 0
        for u in self.units[self.next_unit:]:
            if cap + u.cap > v.mem or cnt >= limit:
                break
            cap += u.cap
            cnt += 1
        return cnt

    def _assign(self, vi, count):
        count = min(count, len(self.units) - self.next_unit)
        take = self.units[self.next_unit:self.next_unit + count]
        self.next_unit += count
        self.used[vi] = True
        self.path.append(self.vehicles[vi])
        self.partition.append(take)

    def obs(self) -> np.ndarray:
        rem_cap = sum(u.cap for u in self.units[self.next_unit:]) \
            / self.cap_norm
        rem_cmp = sum(u.cmp for u in self.units[self.next_unit:]) \
            / self.cmp_norm
        feats = [rem_cap, rem_cmp]
        for i in range(N_MAX):
            if i < len(self.vehicles):
                v = self.vehicles[i]
                assigned = sum(u.cap for p, u_ in zip(self.path,
                                                      self.partition)
                               if p.vid == v.vid for u in u_) \
                    if self.used[i] else 0.0
                feats += [1.0 if self.used[i] else 0.0,
                          min(assigned / max(v.mem, 1.0), 1.0),
                          v.cmp * 1e-12, v.com * 1e-9, v.stb]
            else:
                feats += [1.0, 0.0, 0.0, 0.0, 0.0]
        return np.asarray(feats, np.float32)

    def mask(self) -> np.ndarray:
        m = np.zeros(self.n_actions, np.float32)
        if self.done or self.next_unit >= len(self.units):
            return m
        for i, v in enumerate(self.vehicles):
            if self.used[i]:
                continue
            mx = self._max_units(i)
            for j, c in enumerate(CHUNK_OPTIONS):
                if min(c, len(self.units) - self.next_unit) <= mx and mx > 0:
                    m[i * len(CHUNK_OPTIONS) + j] = 1.0
        return m

    def step(self, action: int):
        vi, ci = divmod(action, len(CHUNK_OPTIONS))
        if vi >= len(self.vehicles):
            # slot beyond the (possibly truncated) fleet: invalid action,
            # penalized like any other instead of indexing out of range
            self.done = True
            return self.obs(), self.mask(), -5.0, True
        count = CHUNK_OPTIONS[ci]
        v = self.vehicles[vi]
        count = min(count, len(self.units) - self.next_unit)
        take = self.units[self.next_unit:self.next_unit + count]
        cap = sum(u.cap for u in take)
        valid = (not self.used[vi]) and cap <= v.mem and count > 0
        w1, w2, w3, w4 = self.w
        if not valid:
            self.done = True
            return self.obs(), self.mask(), -5.0, True
        tc = sum(u.cmp for u in take) * self.cp.n_batch * self.cp.nu \
            / (v.cmp * self.cp.mu)
        tm = 2 * take[-1].com * self.cp.n_batch * self.cp.nu / v.com
        r = w1 * (-(tc + tm)) + w2 * 1.0 + w3 * 1.0 + w4 * 1.0   # Eq. 12
        self._assign(vi, count)
        finished = self.next_unit >= len(self.units)
        stuck = not finished and not self.mask().any()
        if finished:
            r -= path_time(self.path, self.partition, self.cp)   # terminal
        if stuck:
            r -= 5.0
        self.done = finished or stuck
        return self.obs(), self.mask(), r, self.done

    def result(self) -> Optional[Pipeline]:
        if self.next_unit < len(self.units):
            return None
        return Pipeline(self.path, self.partition,
                        path_time(self.path, self.partition, self.cp))


def train_policy(cluster_sampler, *, episodes: int = 800, seed: int = 0,
                 cp: Optional[CostParams] = None) -> DoubleDQN:
    """Train the phase-2 policy on clusters drawn from ``cluster_sampler()``
    -> (vehicles, units)."""
    cp = cp or CostParams()
    probe = PipelineEnv(*cluster_sampler(), cp)
    agent = DoubleDQN(DQNConfig(obs_dim=probe.obs_dim,
                                n_actions=probe.n_actions), seed=seed)
    for _ in range(episodes):
        vehicles, units = cluster_sampler()
        env = PipelineEnv(vehicles, units, cp)
        obs, mask = env.reset()
        while not env.done:
            a = agent.act(obs, mask)
            nxt, nmask, r, done = env.step(a)
            agent.record(obs, a, r, nxt, nmask, float(done))
            agent.learn()
            obs, mask = nxt, nmask
    return agent


def window_fleet(vehicles: Sequence[Vehicle], head_idx: int,
                 n_max: int = N_MAX) -> Tuple[List[Vehicle], int]:
    """Contiguous window of at most ``n_max`` vehicles containing
    ``head_idx``. Returns ``(window, head_in_window)`` with
    ``window[head_in_window] is vehicles[head_idx]`` — the policy sees a
    fleet it supports while the intended head vehicle stays the head
    (clamping the index instead would pin the WRONG vehicle as head)."""
    vehicles = list(vehicles)
    if not 0 <= head_idx < len(vehicles):
        raise ValueError(f"head_idx {head_idx} out of range "
                         f"for fleet of {len(vehicles)}")
    if len(vehicles) <= n_max:
        return vehicles, head_idx
    start = min(max(0, head_idx - n_max // 2), len(vehicles) - n_max)
    return vehicles[start:start + n_max], head_idx - start


def dqn_pipeline(agent: DoubleDQN, vehicles: Sequence[Vehicle],
                 units: Sequence[Unit], cp: CostParams,
                 head: Optional[int] = None) -> Optional[Pipeline]:
    env = PipelineEnv(vehicles, units, cp, head=head)
    obs, mask = env.reset()
    while not env.done and mask.any():
        a = agent.act(obs, mask, explore=False)
        obs, mask, _, _ = env.step(a)
    return env.result()


# --------------------------------------------------------------------------
# SWIFT: the two-phase scheduler
# --------------------------------------------------------------------------
@dataclasses.dataclass
class SwiftResult:
    initial: Optional[Pipeline]          # phase-1 quick-start pipeline
    essential: Dict[int, Pipeline]       # head vehicle id -> pipeline
    phase1_s: float
    phase2_s: float


def swift(vehicles: Sequence[Vehicle], units: Sequence[Unit], *,
          agent: Optional[DoubleDQN] = None,
          cp: Optional[CostParams] = None) -> SwiftResult:
    """Two-phase SWIFT. Phase 1 returns immediately usable pipelines;
    phase 2 builds the essential pipeline set (one per head vehicle,
    ascending stability) with the DQN, falling back to phase-1 greedy
    when the learned policy dead-ends (the paper's refinement loop)."""
    cp = cp or CostParams()
    t0 = time.perf_counter()
    initial = phase1_greedy(vehicles, units, cp)
    t1 = time.perf_counter()

    essential: Dict[int, Pipeline] = {}
    if initial is not None:
        head0 = initial.path[0].vid
        essential[head0] = initial
    rest = sorted([v for v in vehicles
                   if initial is None or v.vid != initial.path[0].vid],
                  key=lambda v: v.stb)       # ascending stability
    for v in rest:
        pipe = None
        if agent is not None:
            idx = next(i for i, w in enumerate(vehicles) if w.vid == v.vid)
            win, head = window_fleet(vehicles, idx)
            pipe = dqn_pipeline(agent, win, units, cp, head=head)
        if pipe is None:
            reordered = [v] + [w for w in sorted(vehicles,
                                                 key=lambda x: -x.stb)
                               if w.vid != v.vid]
            pipe = phase1_greedy_ordered(reordered, units, cp)
        if pipe is not None:
            essential[v.vid] = pipe
    t2 = time.perf_counter()
    return SwiftResult(initial, essential, t1 - t0, t2 - t1)


def phase1_greedy_ordered(order: Sequence[Vehicle], units: Sequence[Unit],
                          cp: CostParams) -> Optional[Pipeline]:
    remaining = list(units)
    path, partition = [], []
    for v in order:
        if not remaining:
            break
        take, cap = [], 0.0
        while remaining and cap + remaining[0].cap <= v.mem:
            cap += remaining[0].cap
            take.append(remaining.pop(0))
        if take:
            path.append(v)
            partition.append(take)
    if remaining:
        return None
    return Pipeline(path, partition, path_time(path, partition, cp))


def units_to_layer_template(pipe: Pipeline, stages: int) -> Tuple[int, ...]:
    """Map a SWIFT pipeline (unit counts per stage) onto a fixed-width SPMD
    stage template for core/pipeline.py.

    Pipelines shorter than ``stages`` pad with zero-layer stages. Pipelines
    LONGER than the SPMD width fold the overflow stages' units into the
    last SPMD stage — checked against that stage's vehicle memory — so no
    model unit is ever silently dropped (``sum(template) == len(units)``
    always holds).
    """
    counts = list(pipe.template())
    if len(counts) > stages:
        tail = [u for part in pipe.partition[stages - 1:] for u in part]
        host = pipe.path[stages - 1]
        need = sum(u.cap for u in tail)
        if need > host.mem:
            raise ValueError(
                f"cannot fold a {len(counts)}-stage pipeline into {stages} "
                f"SPMD stages: the folded tail needs {need:.3e} B but the "
                f"stage-{stages - 1} vehicle {host.vid} has {host.mem:.3e} B")
        counts = counts[:stages - 1] + [len(tail)]
    counts = counts + [0] * (stages - len(counts))
    return tuple(counts)
