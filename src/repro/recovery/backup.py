"""Edge-aided backup (paper §4.2): the edge server (master) snapshots the
merged model every ``interval`` epochs; recovery restores from the latest
snapshot and redeploys under a (possibly different) stage template.

Host-side (numpy) storage — the analogue of the edge server's disk; works
with both the tensor strategy's flat params and FHDP stage containers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


@dataclasses.dataclass
class Snapshot:
    step: int
    tree: Any
    wall_time: float


class EdgeBackup:
    def __init__(self, interval: int = 5):
        self.interval = interval
        self._latest: Optional[Snapshot] = None
        self.backups_taken = 0

    def maybe_backup(self, step: int, params) -> bool:
        """``params`` may be a pytree or a zero-arg thunk returning one —
        the thunk form defers (possibly expensive) snapshot-view work to
        the steps that actually back up."""
        if step % self.interval != 0:
            return False
        if callable(params):
            params = params()
        host = jax.tree.map(lambda x: np.asarray(x), params)
        self._latest = Snapshot(step, host, time.time())
        self.backups_taken += 1
        return True

    @property
    def latest(self) -> Optional[Snapshot]:
        return self._latest

    def restore(self):
        if self._latest is None:
            raise RuntimeError("no backup available")
        return jax.tree.map(lambda x: x, self._latest.tree), self._latest.step


def restage(merged_params, cfg, new_templates, mesh):
    """Re-deploy a merged (backup) model under a new stage template —
    recovery's 'deploy pre-generated template' step for the FHDP runtime."""
    from repro.core import pipeline as pl
    from repro.core.fhdp import _named
    import jax.numpy as jnp

    pp = pl.stage_params_from(
        jax.tree.map(jnp.asarray, merged_params), cfg, new_templates)
    spec = pl.stage_specs(mesh, jax.eval_shape(lambda: pp))
    return jax.device_put(pp, _named(mesh, spec))
