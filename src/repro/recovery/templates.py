"""Preventive pipeline-template pregeneration (paper §4.2).

For every vehicle v in a cluster, pre-compute the pipeline the cluster
would run if v departed — template generation runs concurrently with
training, so on failure the replacement deploys without replanning.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.sched.costmodel import CostParams, Unit, Vehicle
from repro.sched.swift import (DoubleDQN, Pipeline, dqn_pipeline,
                               phase1_greedy)


@dataclasses.dataclass
class TemplateSet:
    active: Pipeline
    # vid -> pipeline for the cluster WITHOUT that vehicle (None: infeasible)
    on_departure: Dict[int, Optional[Pipeline]]


def pregenerate(vehicles: Sequence[Vehicle], units: Sequence[Unit],
                cp: Optional[CostParams] = None,
                agent: Optional[DoubleDQN] = None,
                active: Optional[Pipeline] = None) -> TemplateSet:
    """Build the active pipeline plus one preventive template per potential
    departure (paper: 'pre-generates pipeline configurations for potential
    stage disconnections'). ``active`` overrides the phase-1 choice — used
    when the caller already ran full SWIFT and deployed its winner."""
    cp = cp or CostParams()
    if active is None:
        active = phase1_greedy(vehicles, units, cp)
    if active is None:
        raise ValueError("cluster cannot host the model at all")
    on_dep: Dict[int, Optional[Pipeline]] = {}
    for v in vehicles:
        rest = [w for w in vehicles if w.vid != v.vid]
        pipe = None
        if agent is not None:
            pipe = dqn_pipeline(agent, rest, units, cp)
        if pipe is None:
            pipe = phase1_greedy(rest, units, cp)
        on_dep[v.vid] = pipe
    return TemplateSet(active, on_dep)


def partition_ranges(pipe: Pipeline) -> Dict[int, tuple]:
    """vid -> (unit_start, unit_end) of its stage in the unit sequence."""
    out, off = {}, 0
    for v, units in zip(pipe.path, pipe.partition):
        out[v.vid] = (off, off + len(units))
        off += len(units)
    return out


def redistribution_bytes(old: Pipeline, new: Pipeline) -> float:
    """Bytes that must move to switch old -> new: every unit whose hosting
    vehicle changed (paper: 'distributes only modified model partitions')."""
    old_owner = {}
    off = 0
    for v, units in zip(old.path, old.partition):
        for u in units:
            old_owner[off] = (v.vid, u.cap)
            off += 1
    moved = 0.0
    off = 0
    for v, units in zip(new.path, new.partition):
        for u in units:
            owner = old_owner.get(off)
            if owner is None or owner[0] != v.vid:
                moved += u.cap
            off += 1
    return moved


def full_redistribution_bytes(pipe: Pipeline) -> float:
    return sum(u.cap for units in pipe.partition for u in units)
