"""Fault injection for the simulated vehicular cluster (paper §4.2 / §6.3).

Failures are vehicle departures/disconnects drawn from per-vehicle hazard
rates derived from dwell predictions. The simulator drives the recovery
benchmarks; the *mechanism* under test (template diff, partial
redistribution, backup restore) is the real implementation.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.sched.costmodel import Vehicle


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    time: float
    vid: int
    kind: str         # 'departure' | 'disconnect' (transient)


def sample_failures(vehicles: Sequence[Vehicle], horizon: float, *,
                    seed: int = 0, disconnect_rate: float = 0.2
                    ) -> List[FailureEvent]:
    """Departure at the (noisy) end of each dwell window; Poisson transient
    disconnects on top."""
    rng = np.random.default_rng(seed)
    events: List[FailureEvent] = []
    for v in vehicles:
        dep = v.dwl * rng.uniform(0.7, 1.1)
        if dep < horizon:
            events.append(FailureEvent(float(dep), v.vid, "departure"))
        n = rng.poisson(disconnect_rate * horizon / 3600.0)
        for t in rng.uniform(0, horizon, n):
            events.append(FailureEvent(float(t), v.vid, "disconnect"))
    return sorted(events, key=lambda e: e.time)
