"""Quick recovery orchestration (paper §4.2 + Fig. 5b).

Three strategies are modeled with the same communication substrate so their
times are comparable (the paper's 5 s vs 30 s vs 50 s):

  * ``template``  — FLAD: deploy the pre-generated template, move only the
    diff of model partitions, keep the communication stack (reassign stage
    ids). time = diff_bytes/bw + reassign overhead.
  * ``elastic``   — Elastic-TorchRun-style: keep processes, re-plan from
    scratch, redistribute every partition.
  * ``relaunch``  — tear down, re-init the stack, re-plan, redistribute
    everything, reload from backup.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence

from repro.recovery.templates import (TemplateSet, full_redistribution_bytes,
                                      pregenerate, redistribution_bytes)
from repro.sched.costmodel import CostParams, Unit, Vehicle
from repro.sched.swift import Pipeline, phase1_greedy

# fixed overheads (seconds) — calibrated to the paper's testbed numbers:
# relaunch pays full process/RPC re-init; elastic keeps processes but
# re-plans and re-establishes groups; template only reassigns stage ids.
REINIT_S = {"template": 0.5, "elastic": 8.0, "relaunch": 20.0}


@dataclasses.dataclass
class RecoveryOutcome:
    strategy: str
    ok: bool
    seconds: float
    moved_bytes: float
    replan_s: float
    new_pipeline: Optional[Pipeline]


def recover(strategy: str, templates: TemplateSet, failed_vid: int,
            vehicles: Sequence[Vehicle], units: Sequence[Unit],
            cp: Optional[CostParams] = None,
            link_bw: float = 0.125e9) -> RecoveryOutcome:
    """Execute one recovery after ``failed_vid`` departs."""
    cp = cp or CostParams()
    rest = [v for v in vehicles if v.vid != failed_vid]
    t0 = time.perf_counter()
    if strategy == "template":
        new = templates.on_departure.get(failed_vid)
        replan = time.perf_counter() - t0     # lookup only
        if new is None:
            return RecoveryOutcome(strategy, False, 0.0, 0.0, replan, None)
        moved = redistribution_bytes(templates.active, new)
    else:
        new = phase1_greedy(rest, units, cp)  # replanning from scratch
        replan = time.perf_counter() - t0
        if new is None:
            return RecoveryOutcome(strategy, False, 0.0, 0.0, replan, None)
        moved = full_redistribution_bytes(new)
    seconds = REINIT_S[strategy] + replan + moved / link_bw
    return RecoveryOutcome(strategy, True, seconds, moved, replan, new)


def run_failure_sequence(vehicles: Sequence[Vehicle], units: Sequence[Unit],
                         failures: Sequence, strategy: str,
                         cp: Optional[CostParams] = None,
                         agent=None) -> Dict:
    """Replay a failure trace; re-pregenerate templates after each accepted
    departure (the paper's concurrent template refresh)."""
    cp = cp or CostParams()
    alive = list(vehicles)
    templates = pregenerate(alive, units, cp, agent=agent)
    total_s, n_ok, n_fail = 0.0, 0, 0
    for ev in failures:
        if ev.vid not in [v.vid for v in alive]:
            continue
        out = recover(strategy, templates, ev.vid, alive, units, cp)
        if not out.ok:
            n_fail += 1
            continue
        total_s += out.seconds
        n_ok += 1
        if ev.kind == "departure":
            alive = [v for v in alive if v.vid != ev.vid]
            if len(alive) >= 2:
                try:
                    templates = pregenerate(alive, units, cp, agent=agent)
                except ValueError:
                    break
    return {"strategy": strategy, "recoveries": n_ok, "failed": n_fail,
            "total_recovery_s": total_s,
            "mean_recovery_s": total_s / max(n_ok, 1)}
