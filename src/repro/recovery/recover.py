"""Quick recovery orchestration (paper §4.2 + Fig. 5b).

Three strategies are modeled with the same communication substrate so their
times are comparable (the paper's 5 s vs 30 s vs 50 s):

  * ``template``  — FLAD: deploy the pre-generated template, move only the
    diff of model partitions, keep the communication stack (reassign stage
    ids). time = diff_bytes/bw + reassign overhead.
  * ``elastic``   — Elastic-TorchRun-style: keep processes, re-plan from
    scratch, redistribute every partition.
  * ``relaunch``  — tear down, re-init the stack, re-plan, redistribute
    everything, reload from backup.

:class:`Repartitioner` executes the ``template`` strategy FOR REAL on a
running :class:`repro.api.Session`: on a scheduled vehicle departure it
looks up the pre-generated template, merges the live stage params,
restages them under the new template, rebuilds the jitted FHDP step, and
hands the loop the swapped (step, params, opt) — the paper's 5-second
recovery executed instead of modeled.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.recovery.templates import (TemplateSet, full_redistribution_bytes,
                                      pregenerate, redistribution_bytes)
from repro.sched.costmodel import CostParams, Unit, Vehicle
from repro.sched.swift import Pipeline, phase1_greedy

# fixed overheads (seconds) — calibrated to the paper's testbed numbers:
# relaunch pays full process/RPC re-init; elastic keeps processes but
# re-plans and re-establishes groups; template only reassigns stage ids.
REINIT_S = {"template": 0.5, "elastic": 8.0, "relaunch": 20.0}


@dataclasses.dataclass
class RecoveryOutcome:
    strategy: str
    ok: bool
    seconds: float
    moved_bytes: float
    replan_s: float
    new_pipeline: Optional[Pipeline]


def recover(strategy: str, templates: TemplateSet, failed_vid: int,
            vehicles: Sequence[Vehicle], units: Sequence[Unit],
            cp: Optional[CostParams] = None,
            link_bw: float = 0.125e9) -> RecoveryOutcome:
    """Execute one recovery after ``failed_vid`` departs."""
    cp = cp or CostParams()
    rest = [v for v in vehicles if v.vid != failed_vid]
    t0 = time.perf_counter()
    if strategy == "template":
        new = templates.on_departure.get(failed_vid)
        replan = time.perf_counter() - t0     # lookup only
        if new is None:
            return RecoveryOutcome(strategy, False, 0.0, 0.0, replan, None)
        moved = redistribution_bytes(templates.active, new)
    else:
        new = phase1_greedy(rest, units, cp)  # replanning from scratch
        replan = time.perf_counter() - t0
        if new is None:
            return RecoveryOutcome(strategy, False, 0.0, 0.0, replan, None)
        moved = full_redistribution_bytes(new)
    seconds = REINIT_S[strategy] + replan + moved / link_bw
    return RecoveryOutcome(strategy, True, seconds, moved, replan, new)


# --------------------------------------------------------------------------
# Live dynamic repartitioning (scheduler -> runtime, executed not modeled)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class RepartitionEvent:
    """One executed template switch on the live runtime."""
    step: int
    vid: int
    old_template: Dict[str, tuple]
    new_template: Dict[str, tuple]
    lookup_s: float         # pre-generated template lookup (the paper's win)
    restage_s: float        # merge live stage params + restage under new
    rebuild_s: float        # rebuild the jitted FHDP step
    total_s: float          # lookup + restage + rebuild (the switch itself)
    refresh_s: float        # re-pregenerate preventive templates for the
    #                         shrunken fleet; synchronous here (the paper
    #                         overlaps it with training), so it also stalls
    #                         the loop but is NOT part of the switch time
    moved_bytes: float      # analytic diff the edge would redistribute
    params_identical: bool  # merged params bit-identical across the restage

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["old_template"] = {k: list(v) for k, v in self.old_template.items()}
        d["new_template"] = {k: list(v) for k, v in self.new_template.items()}
        return d


def fresh_zero2_opt(pp, mesh, *, fed_sgd: bool = True, step=None):
    """ZeRO-2 optimizer state for a freshly restaged param container,
    placed on the mesh. Adam moments cannot survive a template change
    (their flat shards are laid out per-stage), so they restart; the step
    counter is carried over to keep the bias-correction schedule."""
    import jax

    from repro.core import pipeline as pl
    from repro.core.fhdp import _named

    D = mesh.shape["data"]
    opt = pl.zero2_init(pp, D, sharded=fed_sgd and D > 1)
    opt = jax.device_put(
        opt, _named(mesh, pl.zero2_specs(jax.eval_shape(lambda: opt))))
    if step is not None:
        opt = dict(opt, step=jax.numpy.asarray(step))
    return opt


class Repartitioner:
    """LoopHooks.repartition hook: simulated departures -> live restages.

    ``schedule`` maps step index -> departing vehicle id. The session's
    strategy must speak the SWIFT protocol (``swift_pipeline``): expose
    ``templates``, ``active_pipeline``, ``departure_template(vid)`` and
    ``adopt_departure(vid, pipe)``.
    """

    def __init__(self, session, schedule: Dict[int, int], *,
                 log_fn: Optional[Callable] = print):
        self.session = session
        self.schedule = {int(k): int(v) for k, v in schedule.items()}
        self.events: List[RepartitionEvent] = []
        self.log_fn = log_fn

    def __call__(self, step_idx: int, step_fn, params, opt_state
                 ) -> Optional[Tuple[Callable, Any, Any]]:
        vid = self.schedule.pop(step_idx, None)
        if vid is None:
            return None
        return self.depart(step_idx, vid, params, opt_state)

    def depart(self, step_idx: int, vid: int, params, opt_state
               ) -> Tuple[Callable, Any, Any]:
        """Execute the departure of ``vid`` against the live state."""
        import jax
        import numpy as np

        from repro.core import pipeline as pl
        from repro.recovery.backup import restage

        ses = self.session
        strat = ses.strategy
        old_templates = {k: tuple(v) for k, v in strat.templates.items()}
        t0 = time.perf_counter()
        new_templates, pipe = strat.departure_template(vid)
        lookup_s = time.perf_counter() - t0
        moved = redistribution_bytes(strat.active_pipeline, pipe)

        t1 = time.perf_counter()
        merged = pl.merge_stage_params(params, old_templates)
        pp2 = restage(merged, ses.cfg, new_templates, ses.mesh)
        jax.block_until_ready(pp2)
        opt2 = fresh_zero2_opt(pp2, ses.mesh, step=opt_state["step"]
                               if isinstance(opt_state, dict)
                               and "step" in opt_state else None)
        restage_s = time.perf_counter() - t1

        t2 = time.perf_counter()
        step2 = ses.rebuild(templates=new_templates, state=(pp2, opt2))
        rebuild_s = time.perf_counter() - t2
        t3 = time.perf_counter()
        strat.adopt_departure(vid, pipe)
        refresh_s = time.perf_counter() - t3

        merged2 = pl.merge_stage_params(pp2, new_templates)
        identical = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(merged2)))
        ev = RepartitionEvent(
            step=step_idx, vid=vid, old_template=old_templates,
            new_template={k: tuple(v) for k, v in new_templates.items()},
            lookup_s=lookup_s, restage_s=restage_s, rebuild_s=rebuild_s,
            total_s=lookup_s + restage_s + rebuild_s, refresh_s=refresh_s,
            moved_bytes=moved, params_identical=identical)
        self.events.append(ev)
        if self.log_fn is not None:
            self.log_fn(
                f"[repartition] step {step_idx}: vehicle {vid} departed — "
                f"template {ev.old_template} -> {ev.new_template} in "
                f"{ev.total_s * 1e3:.1f} ms (lookup {lookup_s * 1e3:.2f} ms, "
                f"restage {restage_s * 1e3:.1f} ms, rebuild "
                f"{rebuild_s * 1e3:.1f} ms; +{refresh_s * 1e3:.1f} ms "
                f"template refresh); params identical: {identical}")
        return step2, pp2, opt2


def run_failure_sequence(vehicles: Sequence[Vehicle], units: Sequence[Unit],
                         failures: Sequence, strategy: str,
                         cp: Optional[CostParams] = None,
                         agent=None) -> Dict:
    """Replay a failure trace; re-pregenerate templates after each accepted
    departure (the paper's concurrent template refresh)."""
    cp = cp or CostParams()
    alive = list(vehicles)
    templates = pregenerate(alive, units, cp, agent=agent)
    total_s, n_ok, n_fail = 0.0, 0, 0
    for ev in failures:
        if ev.vid not in [v.vid for v in alive]:
            continue
        out = recover(strategy, templates, ev.vid, alive, units, cp)
        if not out.ok:
            n_fail += 1
            continue
        total_s += out.seconds
        n_ok += 1
        if ev.kind == "departure":
            alive = [v for v in alive if v.vid != ev.vid]
            if len(alive) >= 2:
                try:
                    templates = pregenerate(alive, units, cp, agent=agent)
                except ValueError:
                    break
    return {"strategy": strategy, "recoveries": n_ok, "failed": n_fail,
            "total_recovery_s": total_s,
            "mean_recovery_s": total_s / max(n_ok, 1)}
