"""Batching / host-side input pipeline.

Shuffled epoch iterators over in-memory datasets, client-stacked batch
assembly for FL rounds (leading [C, E, B, ...] axes expected by
core/fedavg.make_fl_round), and a double-buffered prefetch wrapper.
"""
from __future__ import annotations

import threading
import queue
from typing import Dict, Iterator, List, Sequence

import numpy as np


def batches(data: Dict[str, np.ndarray], batch_size: int, *, seed: int = 0,
            epochs: int = 1, drop_last: bool = True
            ) -> Iterator[Dict[str, np.ndarray]]:
    n = len(next(iter(data.values())))
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        perm = rng.permutation(n)
        stop = n - (n % batch_size) if drop_last else n
        for i in range(0, stop, batch_size):
            idx = perm[i:i + batch_size]
            yield {k: v[idx] for k, v in data.items()}


def client_round_batches(datasets: Sequence[Dict[str, np.ndarray]],
                         local_steps: int, batch_size: int, *,
                         round_idx: int = 0) -> Dict[str, np.ndarray]:
    """Assemble one FL round's batches: [C, E, B, ...] per key."""
    out: Dict[str, List] = {}
    for ci, data in enumerate(datasets):
        it = batches(data, batch_size, seed=round_idx * 977 + ci,
                     epochs=local_steps + 1)
        steps = [next(it) for _ in range(local_steps)]
        for k in steps[0]:
            out.setdefault(k, []).append(np.stack([s[k] for s in steps]))
    return {k: np.stack(v) for k, v in out.items()}


class Prefetcher:
    """One-element lookahead on a background thread (host->device overlap
    stand-in; on TPU this is where jax.device_put_sharded would live)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()

        def worker():
            for item in it:
                self.q.put(item)
            self.q.put(self._done)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self._done:
            raise StopIteration
        return item
