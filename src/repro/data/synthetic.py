"""Synthetic CARLA-like multimodal driving data (paper §6.1).

Generates what the stubbed frontends would emit: RGB patch features and
LiDAR pillar features, plus ground-truth waypoints and traffic-light
state, with *town-conditioned non-IID structure*:

  * each town t has a latent environment matrix E_t that colors the
    feature distribution (weather/architecture analogue);
  * the traffic-light state is a (town-rotated) linear readout of the RGB
    features — learnable, but the readout direction drifts across towns,
    so a model trained on one town underperforms on others (this is what
    FL across towns fixes in Fig. 8a);
  * waypoints follow a smooth town-biased trajectory; a red light scales
    them toward the stop line.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DrivingDataConfig:
    n_towns: int = 4
    patches: int = 128          # tokens per modality
    feature_dim: int = 256
    num_waypoints: int = 10
    num_light_classes: int = 4
    noise: float = 0.1
    seed: int = 0


class TownWorld:
    """Latent per-town generative parameters."""

    def __init__(self, cfg: DrivingDataConfig):
        rng = np.random.default_rng(cfg.seed)
        self.cfg = cfg
        f = cfg.feature_dim
        self.env = rng.normal(0, 1, (cfg.n_towns, f, f)) / np.sqrt(f)
        for t in range(cfg.n_towns):
            self.env[t] += np.eye(f) * 1.0           # keep well-conditioned
        self.light_readout = rng.normal(0, 1, (f, cfg.num_light_classes))
        # town-specific rotation of the readout (the non-IID shift)
        self.town_rot = np.stack([
            _random_rotation(f, rng, angle=0.35 * t)
            for t in range(cfg.n_towns)])
        self.heading = rng.uniform(0, 2 * np.pi, cfg.n_towns)

    def sample(self, town: int, n: int, rng) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        f, p = cfg.feature_dim, cfg.patches
        base = rng.normal(0, 1, (n, p, f)).astype(np.float32)
        rgb = base @ self.env[town].astype(np.float32)
        lidar = rng.normal(0, 1, (n, p, f)).astype(np.float32) \
            @ self.env[town].T.astype(np.float32)

        # light state: argmax of the town-rotated readout of mean rgb feats
        pooled = rgb.mean(axis=1)                                   # [n, f]
        logits = pooled @ self.town_rot[town] @ self.light_readout
        light = np.argmax(
            logits + rng.normal(0, cfg.noise, logits.shape), axis=1
        ).astype(np.int32)

        # waypoints: smooth arc along the town heading; red (class 0) stops
        tt = np.linspace(0.2, 2.0, cfg.num_waypoints)
        curv = rng.normal(0, 0.15, (n, 1))
        theta = self.heading[town] + curv * tt[None, :]
        step = np.where(light[:, None] == 0,
                        np.linspace(1, 0.05, cfg.num_waypoints)[None, :],
                        1.0) * tt[None, :]
        wps = np.stack([step * np.cos(theta), step * np.sin(theta)],
                       axis=-1).astype(np.float32)
        wps += rng.normal(0, cfg.noise * 0.1, wps.shape).astype(np.float32)
        return {"rgb": rgb, "lidar": lidar, "light": light,
                "waypoints": wps}


def _random_rotation(f: int, rng, angle: float) -> np.ndarray:
    """Rotation by `angle` in a few random 2-D planes (mild town drift)."""
    R = np.eye(f)
    for _ in range(8):
        i, j = rng.choice(f, 2, replace=False)
        c, s = np.cos(angle), np.sin(angle)
        G = np.eye(f)
        G[i, i] = c; G[i, j] = -s; G[j, i] = s; G[j, j] = c
        R = R @ G
    return R


def make_tokens(light: np.ndarray, town: int, seq_len: int, vocab: int,
                rng) -> np.ndarray:
    """Context 'instruction' tokens for the AD-LLM (navigation + notice):
    a town id token, the light state, then filler."""
    n = light.shape[0]
    toks = rng.integers(10, vocab, (n, seq_len), dtype=np.int64)
    toks[:, 0] = 1 + town
    toks[:, 1] = 5 + light
    return toks.astype(np.int32)
