"""Non-IID data partitioning across virtual vehicles (paper §6.1: '50
virtual vehicles with controlled non-IID characteristics based on CARLA
town environments').

``town_partition``: each vehicle is pinned to one town (hard non-IID).
``dirichlet_partition``: vehicle i draws its town mixture from
Dirichlet(beta) — beta -> 0 approaches hard partitioning, beta -> inf is
IID. The paper's "non-IID level 2" maps to beta ~ 0.5 here.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.data.synthetic import DrivingDataConfig, TownWorld


def dirichlet_mixtures(n_vehicles: int, n_towns: int, beta: float,
                       seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.dirichlet([beta] * n_towns, size=n_vehicles)


def vehicle_dataset(world: TownWorld, mixture: np.ndarray, n: int,
                    seed: int = 0) -> Dict[str, np.ndarray]:
    """Draw n samples for one vehicle from its town mixture."""
    rng = np.random.default_rng(seed)
    towns = rng.choice(len(mixture), size=n, p=mixture)
    parts = []
    for t in range(len(mixture)):
        cnt = int((towns == t).sum())
        if cnt:
            parts.append((t, world.sample(t, cnt, rng)))
    if not parts:
        # n == 0: an empty dataset with the right keys/trailing shapes
        parts.append((0, world.sample(0, 0, rng)))
    out: Dict[str, np.ndarray] = {}
    keys = parts[0][1].keys()
    for k in keys:
        out[k] = np.concatenate([p[1][k] for p in parts], axis=0)
    perm = rng.permutation(n)
    return {k: v[perm] for k, v in out.items()}


def fleet_datasets(cfg: DrivingDataConfig, n_vehicles: int,
                   samples_per_vehicle: int, *, beta: float = 0.5,
                   seed: int = 0) -> List[Dict[str, np.ndarray]]:
    world = TownWorld(cfg)
    mix = dirichlet_mixtures(n_vehicles, cfg.n_towns, beta, seed)
    return [vehicle_dataset(world, mix[i], samples_per_vehicle,
                            seed=seed + 1 + i)
            for i in range(n_vehicles)]
