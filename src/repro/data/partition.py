"""Non-IID data partitioning across virtual vehicles (paper §6.1: '50
virtual vehicles with controlled non-IID characteristics based on CARLA
town environments').

``town_partition``: each vehicle is pinned to one town (hard non-IID).
``dirichlet_partition``: vehicle i draws its town mixture from
Dirichlet(beta) — beta -> 0 approaches hard partitioning, beta -> inf is
IID. The paper's "non-IID level 2" maps to beta ~ 0.5 here.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.data.synthetic import DrivingDataConfig, TownWorld


def dirichlet_mixtures(n_vehicles: int, n_towns: int, beta: float,
                       seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.dirichlet([beta] * n_towns, size=n_vehicles)


def vehicle_dataset(world: TownWorld, mixture: np.ndarray, n: int,
                    seed: int = 0) -> Dict[str, np.ndarray]:
    """Draw n samples for one vehicle from its town mixture."""
    rng = np.random.default_rng(seed)
    towns = rng.choice(len(mixture), size=n, p=mixture)
    parts = []
    for t in range(len(mixture)):
        cnt = int((towns == t).sum())
        if cnt:
            parts.append((t, world.sample(t, cnt, rng)))
    if not parts:
        # n == 0: an empty dataset with the right keys/trailing shapes
        parts.append((0, world.sample(0, 0, rng)))
    out: Dict[str, np.ndarray] = {}
    keys = parts[0][1].keys()
    for k in keys:
        out[k] = np.concatenate([p[1][k] for p in parts], axis=0)
    perm = rng.permutation(n)
    return {k: v[perm] for k, v in out.items()}


def fleet_datasets(cfg: DrivingDataConfig, n_vehicles: int,
                   samples_per_vehicle: int, *, beta: float = 0.5,
                   seed: int = 0) -> List[Dict[str, np.ndarray]]:
    world = TownWorld(cfg)
    mix = dirichlet_mixtures(n_vehicles, cfg.n_towns, beta, seed)
    return [vehicle_dataset(world, mix[i], samples_per_vehicle,
                            seed=seed + 1 + i)
            for i in range(n_vehicles)]


# --------------------------------------------------------------------------
# AD-LLM pod partitions (distill_fl): region-level heterogeneity
# --------------------------------------------------------------------------
def adllm_vehicle_dataset(world: TownWorld, mixture: np.ndarray, n: int, *,
                          seq_len: int, vocab: int,
                          seed: int = 0) -> Dict[str, np.ndarray]:
    """AD-LLM training triples for one vehicle drawn from a town mixture.

    Unlike :func:`vehicle_dataset` this keeps the per-sample town
    identity: the context tokens (``make_tokens``) carry the town id the
    sample actually came from, so a language-side model can exploit the
    regional structure the waypoints depend on.

    Returns ``{"features" [n, P, F], "tokens" [n, S] int32,
    "waypoints" [n, W, 2]}``.
    """
    from repro.data.synthetic import make_tokens
    rng = np.random.default_rng(seed)
    towns = rng.choice(len(mixture), size=n, p=mixture)
    feats, toks, wps = [], [], []
    for t in range(len(mixture)):
        cnt = int((towns == t).sum())
        if not cnt:
            continue
        s = world.sample(t, cnt, rng)
        feats.append(s["rgb"])
        wps.append(s["waypoints"])
        toks.append(make_tokens(s["light"], t, seq_len, vocab, rng))
    if not feats:               # n == 0: keep keys/trailing shapes
        s = world.sample(0, 0, rng)
        feats.append(s["rgb"])
        wps.append(s["waypoints"])
        toks.append(make_tokens(s["light"], 0, seq_len, vocab, rng))
    out = {"features": np.concatenate(feats).astype(np.float32),
           "tokens": np.concatenate(toks),
           "waypoints": np.concatenate(wps).astype(np.float32)}
    perm = rng.permutation(len(out["tokens"]))
    return {k: v[perm] for k, v in out.items()}


def adllm_public_dataset(cfg: DrivingDataConfig, n: int, *, seq_len: int,
                         vocab: int, seed: int = 0) -> Dict[str, np.ndarray]:
    """IID 'public AD corpus' (uniform town mixture) — what the cloud
    warms the AD-LLM on before it freezes as the distillation teacher."""
    world = TownWorld(cfg)
    uniform = np.full((cfg.n_towns,), 1.0 / cfg.n_towns)
    return adllm_vehicle_dataset(world, uniform, n, seq_len=seq_len,
                                 vocab=vocab, seed=seed)


def pod_datasets(cfg: DrivingDataConfig, members, samples_per_vehicle: int,
                 *, seq_len: int, vocab: int, beta: float = 0.1,
                 seed: int = 0, heldout: int = 64):
    """Pod-level non-IID split for federated distillation.

    ``members``: per-edge member index arrays (a topology's
    ``member_indices``). Every vehicle in a pod draws from its **pod's**
    Dirichlet(beta) town mixture — the regional heterogeneity the CAV FL
    surveys identify — so per-pod adapters have something genuinely local
    to learn while pods still share the same underlying world.

    Returns ``(train, held, mixtures)``: ``train[c]`` is vehicle ``c``'s
    dataset, ``held[e]`` a held-out set drawn from pod ``e``'s mixture
    (fresh samples, never trained on), and ``mixtures`` the [E, n_towns]
    pod mixtures.
    """
    world = TownWorld(cfg)
    E = len(members)
    mix = dirichlet_mixtures(E, cfg.n_towns, beta, seed)
    n_clients = sum(len(m) for m in members)
    train: List[Dict[str, np.ndarray]] = [None] * n_clients
    held = []
    for e, idx in enumerate(members):
        for ci in np.asarray(idx):
            train[int(ci)] = adllm_vehicle_dataset(
                world, mix[e], samples_per_vehicle, seq_len=seq_len,
                vocab=vocab, seed=seed + 101 + int(ci))
        held.append(adllm_vehicle_dataset(
            world, mix[e], heldout, seq_len=seq_len, vocab=vocab,
            seed=seed + 7919 + e))
    return train, held, mix
