"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
swept by tests/test_kernels.py)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, scale: Optional[float] = None,
                        causal: bool = True,
                        window: Optional[int] = None,
                        q_offset: int = 0, return_lse: bool = False):
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Skv, D] (GQA when Hq > Hkv).
    Positions are absolute: q row i has position q_offset + i. With
    ``return_lse`` also returns the [B, Hq, Sq] float32 row logsumexp
    (the residual the Pallas backward kernels recompute p from)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * scale
    qp = jnp.arange(sq) + q_offset
    kp = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window is not None:
        mask &= kp[None, :] > qp[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    o = o.reshape(b, hq, sq, d).astype(q.dtype)
    if return_lse:
        lse = jax.scipy.special.logsumexp(s, axis=-1)   # [b, hkv, g, sq]
        return o, lse.reshape(b, hq, sq)
    return o


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, ctx_lens,
                               *, scale: Optional[float] = None,
                               k_scales=None, v_scales=None):
    """Oracle for the paged single-token decode kernel.

    q: [B, Hq, D]; k_pages/v_pages: [Hkv, NB, bs, D]; block_tables:
    [B, T] int32; ctx_lens: [B] int32. Gathers each request's logical KV
    view through its block table, dequantizes when scales are given,
    masks positions >= ctx_len, and runs dense softmax attention.
    Requests with ``ctx_lens == 0`` return zeros (matching the kernel's
    never-initialized accumulator path)."""
    b, hq, d = q.shape
    hkv, _, bs, _ = k_pages.shape
    g = hq // hkv
    t = block_tables.shape[1]
    scale = scale if scale is not None else d ** -0.5

    k = k_pages[:, block_tables].astype(jnp.float32)   # [Hkv, B, T, bs, D]
    v = v_pages[:, block_tables].astype(jnp.float32)
    if k_scales is not None:
        k = k * k_scales[:, block_tables]
        v = v * v_scales[:, block_tables]
    k = k.transpose(1, 0, 2, 3, 4).reshape(b, hkv, t * bs, d)
    v = v.transpose(1, 0, 2, 3, 4).reshape(b, hkv, t * bs, d)

    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k) * scale
    mask = jnp.arange(t * bs)[None, :] < ctx_lens[:, None]   # [B, T*bs]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v)
    o = jnp.where(ctx_lens[:, None, None, None] > 0, o, 0.0)
    return o.reshape(b, hq, d).astype(q.dtype)


def paged_prefill_attention_ref(q, k_pages, v_pages, block_table, q_offset,
                                ctx_len, *, scale: Optional[float] = None,
                                k_scales=None, v_scales=None):
    """Oracle for the chunked paged-prefill kernel.

    q: [Hq, C, D] (row ``c`` at absolute position ``q_offset + c``);
    k_pages/v_pages: [Hkv, NB, bs, D] pools already holding the chunk's
    own K/V; block_table: [T] int32. Gathers the request's logical KV
    view through its table, dequantizes when scales are given, masks
    causally from absolute positions (``kp <= q_offset + c`` and ``kp <
    ctx_len``), and runs dense softmax attention. Rows past ``chunk_len
    = ctx_len - q_offset`` are padding and return garbage values the
    caller discards — the comparison against the kernel slices them off.
    """
    hq, c, d = q.shape
    hkv, _, bs, _ = k_pages.shape
    g = hq // hkv
    t = block_table.shape[0]
    scale = scale if scale is not None else d ** -0.5

    k = k_pages[:, block_table].astype(jnp.float32)    # [Hkv, T, bs, D]
    v = v_pages[:, block_table].astype(jnp.float32)
    if k_scales is not None:
        k = k * k_scales[:, block_table]
        v = v * v_scales[:, block_table]
    k = k.reshape(hkv, t * bs, d)
    v = v.reshape(hkv, t * bs, d)

    qg = q.reshape(hkv, g, c, d).astype(jnp.float32)
    s = jnp.einsum("hgcd,hkd->hgck", qg, k) * scale
    qp = q_offset + jnp.arange(c)
    kp = jnp.arange(t * bs)
    mask = (kp[None, :] <= qp[:, None]) & (kp[None, :] < ctx_len)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("hgck,hkd->hgcd", p, v)
    return o.reshape(hq, c, d).astype(q.dtype)


def mlstm_chunked_ref(q, k, v, ig, lf, *, chunk: int = 64, C0=None, n0=None,
                      m0=None):
    """Stabilized mLSTM over the sequence, step-by-step (the exact
    recurrence the chunked kernel reproduces).

    q/k/v: [B, NH, S, DH] (k pre-scaled); ig/lf: [B, NH, S].
    Returns (h [B, NH, S, DH], (C, n, m) final states).
    """
    b, nh, s, dh = q.shape
    C = jnp.zeros((b, nh, dh, dh), jnp.float32) if C0 is None else C0
    n = jnp.zeros((b, nh, dh), jnp.float32) if n0 is None else n0
    m = jnp.full((b, nh), -1e30, jnp.float32) if m0 is None else m0

    def step(carry, t):
        C, n, m = carry
        q_t, k_t, v_t, i_t, lf_t = t
        m_new = jnp.maximum(lf_t + m, i_t)
        fs = jnp.exp(lf_t + m - m_new)[..., None]
        is_ = jnp.exp(i_t - m_new)[..., None]
        C = fs[..., None] * C + is_[..., None] * (v_t[..., :, None]
                                                  * k_t[..., None, :])
        n = fs * n + is_ * k_t
        num = jnp.einsum("bhij,bhj->bhi", C, q_t)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q_t)),
                          jnp.exp(-m_new))[..., None]
        return (C, n, m_new), num / den

    ts = (q.transpose(2, 0, 1, 3).astype(jnp.float32),
          k.transpose(2, 0, 1, 3).astype(jnp.float32),
          v.transpose(2, 0, 1, 3).astype(jnp.float32),
          ig.transpose(2, 0, 1).astype(jnp.float32),
          lf.transpose(2, 0, 1).astype(jnp.float32))
    (C, n, m), hs = jax.lax.scan(step, (C, n, m), ts)
    return hs.transpose(1, 2, 0, 3).astype(q.dtype), (C, n, m)


def quantize_int8_ref(x, bits):
    """Rowwise-absmax int8 stochastic quantization (oracle for
    kernels/quantize.py). x: [M, 128] float; bits: [M, 128] uint32.
    Returns (q int8 [M, 128], scale float32 [M, 1]); all-zero rows emit
    scale 0 / q 0."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=1, keepdims=True)
    safe = jnp.where(absmax > 0.0, absmax / 127.0, 1.0)
    u = bits.astype(jnp.float32) * (2.0 ** -32)
    q = jnp.clip(jnp.floor(xf / safe + u), -127.0, 127.0).astype(jnp.int8)
    scale = jnp.where(absmax > 0.0, safe, 0.0)
    return q, scale


def dequantize_int8_ref(q, scale, *, dtype=jnp.float32):
    """Inverse of :func:`quantize_int8_ref`: ``q * scale``."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def lora_matmul_ref(x, w, a, b, *, scale: float = 1.0):
    """y = x @ w + scale * (x @ a) @ b.

    x: [M, K]; w: [K, N]; a: [K, r]; b: [r, N]."""
    base = x.astype(jnp.float32) @ w.astype(jnp.float32)
    low = (x.astype(jnp.float32) @ a.astype(jnp.float32)) \
        @ b.astype(jnp.float32)
    return (base + scale * low).astype(x.dtype)
