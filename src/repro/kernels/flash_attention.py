"""Blocked online-softmax attention (flash attention) as Pallas TPU
kernels — forward AND backward.

TPU adaptation notes (vs the CUDA original): tiles live in VMEM sized for
the MXU (block dims multiples of 128 where the dtype allows); the running
(m, l, acc) statistics persist in VMEM scratch across the innermost
(sequential) KV-block grid dimension, while (batch, head, q-block) are
parallel grid dims. GQA is handled in the index map (q head h reads kv
head h // group). Causal and sliding-window masks are applied from
absolute positions, so the same kernels serve train, prefill and the
windowed long_500k path.

Backward structure (FlashAttention-2): the forward additionally emits the
per-row logsumexp ``lse = m + log(l)`` so the VJP saves ``(q, k, v, o,
lse)`` — O(S·D) residuals — instead of rematerializing the O(Sq·Skv)
score/softmax matrices. Three kernels then compute the gradients, each
recomputing ``p = exp(s - lse)`` one block at a time:

  * ``_bwd_preprocess_kernel``: ``delta = rowsum(dO * O)`` (the softmax
    Jacobian's diagonal correction), grid over q blocks.
  * ``_bwd_dkv_kernel``: grid over KV blocks (parallel) with a sequential
    inner dimension over (GQA query group x q block); dK/dV accumulate in
    float32 VMEM scratch and the query-group contributions sum into the
    shared KV head.
  * ``_bwd_dq_kernel``: grid over Q blocks (parallel) with a sequential
    inner dimension over KV blocks; dQ accumulates in VMEM scratch.

Uneven sequence lengths (e.g. vision token counts) are handled by padding
Sq/Skv up to a block multiple and masking the tail from absolute
positions (``kp < kv_len``); padded q rows carry zero cotangents, so they
contribute nothing to dK/dV and their dQ rows are sliced off.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import pallas_tpu_compiler_params

_CompilerParams = pallas_tpu_compiler_params()

NEG_INF = -1e30


def _block_and_pad(block: int, s: int) -> tuple:
    """Tile size and tail padding for a sequence length that need not be a
    multiple of the requested block (pad + mask instead of asserting)."""
    b = max(1, min(block, s))
    return b, (-s) % b


def _pad_seq(x, pad: int):
    """Zero-pad the sequence axis (axis 2 of [B, H, S, D] / [B, H, S])."""
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[2] = (0, pad)
    return jnp.pad(x, widths)


def _mask_block(qp, kp, *, causal: bool, window: Optional[int],
                kv_len: int):
    """[bq, bk] validity mask from absolute q/k positions (qp/kp are
    broadcasted iotas). ``kv_len`` masks the padded KV tail."""
    mask = kp < kv_len
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    return mask


def _block_live(qp_lo, kp_lo, *, causal: bool, window: Optional[int],
                kv_len: int, bq: int, bk: int):
    """Scalar predicate: does block [qp_lo, qp_lo+bq) x [kp_lo, kp_lo+bk)
    contain ANY unmasked (q, k) pair? Exact for causal and/or window (a
    pair with kp <= qp and kp > qp - window exists iff kp_lo <= qp_hi and
    kp_hi > qp_lo - window) — lets the grid skip ~half the tiles on the
    causal path and all but O(window/bk) per row on the windowed path."""
    live = kp_lo < kv_len
    if causal:
        live &= kp_lo <= qp_lo + bq - 1
    if window is not None:
        live &= kp_lo + bk - 1 > qp_lo - window
    return live


# ------------------------------------------------------------- forward ----
def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref,
                *, scale: float, causal: bool, window: Optional[int],
                bq: int, bk: int, q_offset: int, kv_len: int):
    kv_i = pl.program_id(3)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qp_lo = q_offset + pl.program_id(2) * bq
    kp_lo = kv_i * bk

    @pl.when(_block_live(qp_lo, kp_lo, causal=causal, window=window,
                         kv_len=kv_len, bq=bq, bk=bk))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)    # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)    # [bk, d]
        v = v_ref[0, 0]                        # [bk, d]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        qp = qp_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = kp_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = _mask_block(qp, kp, causal=causal, window=window,
                           kv_len=kv_len)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(jnp.float32), v.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kv_i == pl.num_programs(3) - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[...] + jnp.log(l)


def flash_attention(q, k, v, *, scale: Optional[float] = None,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, block_q: int = 128,
                    block_k: int = 128, return_lse: bool = False,
                    interpret: bool = False):
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Skv, D]. Returns [B, Hq, Sq, D]
    (and the float32 [B, Hq, Sq] row logsumexp when ``return_lse``)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bq, pq = _block_and_pad(block_q, sq)
    bk, pk = _block_and_pad(block_k, skv)
    q_, k_, v_ = _pad_seq(q, pq), _pad_seq(k, pk), _pad_seq(v, pk)
    spq, spk = sq + pq, skv + pk
    grid = (b, hq, spq // bq, spk // bk)

    kernel = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk,
                               q_offset=q_offset, kv_len=skv)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, qi, ki: (b_, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, spq, d), q.dtype),
            jax.ShapeDtypeStruct((b, hq, spq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q_, k_, v_)
    o, lse = o[:, :, :sq], lse[:, :, :sq]
    return (o, lse) if return_lse else o


# --------------------------------------------------------- paged decode ----
def _paged_decode_kernel(tbl_ref, ctx_ref, q_ref, k_ref, v_ref, *rest,
                         scale: float, bs: int, quantized: bool):
    """One decode token per request against a paged KV pool.

    Grid (batch, kv-head, table-slot); the innermost dimension walks the
    request's block table sequentially while (m, l, acc) persist in VMEM
    scratch — the same online-softmax recurrence as ``_fwd_kernel``, with
    the physical KV tile resolved through the scalar-prefetched block
    table (``tbl_ref[b, i]``) instead of a contiguous index map. Slots at
    or past the request's context length are dead (their table entries
    point at the reserved null block) and skip compute entirely, the
    paged analogue of ``_block_live``.
    """
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ctx = ctx_ref[b]

    @pl.when(i * bs < ctx)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # [g, d]
        k = k_ref[0, 0].astype(jnp.float32)        # [bs, d]
        v = v_ref[0, 0].astype(jnp.float32)        # [bs, d]
        if quantized:
            k = k * ks_ref[0, 0]                   # per-row absmax scales
            v = v * vs_ref[0, 0]
        g = q.shape[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kp = i * bs + jax.lax.broadcasted_iota(jnp.int32, (g, bs), 1)
        s = jnp.where(kp < ctx, s, NEG_INF)        # partial final block

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == pl.num_programs(2) - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                           scale: Optional[float] = None,
                           k_scales=None, v_scales=None,
                           interpret: bool = False):
    """Single-token decode attention over a paged KV cache.

    q: [B, Hq, D] (one query token per request); k_pages/v_pages:
    [Hkv, NB, bs, D] physical block pools; block_tables: [B, T] int32
    logical->physical maps (dead slots point at the reserved null block
    0); ctx_lens: [B] int32 visible KV length per request (requests with
    ``ctx_lens == 0`` return zeros). With ``k_scales``/``v_scales``
    ([Hkv, NB, bs, 1] float32) the pools are int8 and dequantized
    in-kernel. Returns [B, Hq, D].
    """
    b, hq, d = q.shape
    hkv, _, bs, _ = k_pages.shape
    g = hq // hkv
    t = block_tables.shape[1]
    scale = scale if scale is not None else d ** -0.5
    quantized = k_scales is not None
    qg = q.reshape(b, hkv, g, d)

    kernel = functools.partial(_paged_decode_kernel, scale=scale, bs=bs,
                               quantized=quantized)
    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda b_, h, i, tbl, ctx: (b_, h, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, i, tbl, ctx: (h, tbl[b_, i], 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda b_, h, i, tbl, ctx: (h, tbl[b_, i], 0, 0)),
    ]
    operands = [qg, k_pages, v_pages]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, 1, bs, 1),
                         lambda b_, h, i, tbl, ctx: (h, tbl[b_, i], 0, 0)),
            pl.BlockSpec((1, 1, bs, 1),
                         lambda b_, h, i, tbl, ctx: (h, tbl[b_, i], 0, 0)),
        ]
        operands += [k_scales, v_scales]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, t),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, h, i, tbl, ctx: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), ctx_lens.astype(jnp.int32), *operands)
    return o.reshape(b, hq, d)


# --------------------------------------------------------- paged prefill ---
def _paged_prefill_kernel(tbl_ref, meta_ref, q_ref, k_ref, v_ref, *rest,
                          scale: float, bs: int, chunk: int,
                          quantized: bool):
    """One prompt chunk of a single request against a paged KV pool.

    Grid (kv-head, table-slot); the innermost dimension walks the
    request's block table sequentially while (m, l, acc) persist in VMEM
    scratch — the chunked-prefill analogue of ``_paged_decode_kernel``.
    The query chunk is laid out [Hkv, G*C, D] (GQA group-major), so row
    ``r`` is chunk offset ``r % C`` at absolute position ``q_offset +
    r % C``; the causal mask is applied from those absolute positions
    against the block's absolute KV positions. The chunk's OWN K/V rows
    have already been scattered into their pool blocks before this kernel
    runs, so "prior context plus itself" is one uniform table walk —
    there is no contiguous [Smax] staging buffer anywhere. Blocks at or
    past ``ctx = q_offset + chunk_len`` are dead (their table entries
    point at the reserved null block) and skip compute entirely; rows of
    the chunk past ``chunk_len`` (last-chunk padding) are garbage by
    contract and masked down to a nonempty-but-meaningless context so
    they stay finite.
    """
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_offset = meta_ref[0]
    ctx = meta_ref[1]

    @pl.when(i * bs < ctx)
    def _compute():
        q = q_ref[0].astype(jnp.float32)           # [gc, d]
        k = k_ref[0, 0].astype(jnp.float32)        # [bs, d]
        v = v_ref[0, 0].astype(jnp.float32)        # [bs, d]
        if quantized:
            k = k * ks_ref[0, 0]                   # per-row absmax scales
            v = v * vs_ref[0, 0]
        gc = q.shape[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        r = jax.lax.broadcasted_iota(jnp.int32, (gc, bs), 0)
        qp = q_offset + r % chunk                  # absolute q position
        kp = i * bs + jax.lax.broadcasted_iota(jnp.int32, (gc, bs), 1)
        s = jnp.where((kp <= qp) & (kp < ctx), s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == pl.num_programs(1) - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_prefill_attention(q, k_pages, v_pages, block_table, q_offset,
                            ctx_len, *, scale: Optional[float] = None,
                            k_scales=None, v_scales=None,
                            interpret: bool = False):
    """Chunked-prefill attention for one request over a paged KV cache.

    q: [Hq, C, D] (a fixed-size query chunk whose row ``c`` sits at
    absolute position ``q_offset + c``); k_pages/v_pages: [Hkv, NB, bs,
    D] physical block pools ALREADY holding the chunk's own K/V rows;
    block_table: [T] int32 logical->physical map (dead slots point at the
    reserved null block 0); q_offset/ctx_len: traced int32 scalars —
    ``ctx_len = q_offset + chunk_len`` is the visible KV length, so the
    same jitted call serves every chunk of every prompt length. With
    ``k_scales``/``v_scales`` ([Hkv, NB, bs, 1] float32) the pools are
    int8 and dequantized in-kernel. Rows past ``chunk_len`` are padding
    and return garbage (finite) values. Returns [Hq, C, D].
    """
    hq, c, d = q.shape
    hkv, _, bs, _ = k_pages.shape
    g = hq // hkv
    t = block_table.shape[0]
    scale = scale if scale is not None else d ** -0.5
    quantized = k_scales is not None
    # group-major rows: [Hq, C, D] -> [Hkv, G, C, D] -> [Hkv, G*C, D]
    qg = q.reshape(hkv, g, c, d).reshape(hkv, g * c, d)
    meta = jnp.stack([jnp.asarray(q_offset, jnp.int32),
                      jnp.asarray(ctx_len, jnp.int32)])

    kernel = functools.partial(_paged_prefill_kernel, scale=scale, bs=bs,
                               chunk=c, quantized=quantized)
    in_specs = [
        pl.BlockSpec((1, g * c, d), lambda h, i, tbl, meta_: (h, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda h, i, tbl, meta_: (h, tbl[i], 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda h, i, tbl, meta_: (h, tbl[i], 0, 0)),
    ]
    operands = [qg, k_pages, v_pages]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, 1, bs, 1),
                         lambda h, i, tbl, meta_: (h, tbl[i], 0, 0)),
            pl.BlockSpec((1, 1, bs, 1),
                         lambda h, i, tbl, meta_: (h, tbl[i], 0, 0)),
        ]
        operands += [k_scales, v_scales]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(hkv, t),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, g * c, d),
                               lambda h, i, tbl, meta_: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g * c,), jnp.float32),
            pltpu.VMEM((g * c,), jnp.float32),
            pltpu.VMEM((g * c, d), jnp.float32),
        ],
    )
    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((hkv, g * c, d), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(block_table.astype(jnp.int32), meta, *operands)
    return o.reshape(hkv, g, c, d).reshape(hq, c, d)


# ------------------------------------------------------------ backward ----
def _bwd_preprocess_kernel(o_ref, do_ref, delta_ref):
    o = o_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    delta_ref[0, 0] = (o * do).sum(axis=1)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_acc, dv_acc, *,
                    scale: float, causal: bool, window: Optional[int],
                    bq: int, bk: int, q_offset: int, kv_len: int,
                    q_len: int, nqb: int):
    i = pl.program_id(3)                       # (group, q-block) sequential

    @pl.when(i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    qrow_lo = (i % nqb) * bq
    qp_lo = q_offset + qrow_lo
    kp_lo = pl.program_id(2) * bk
    live = _block_live(qp_lo, kp_lo, causal=causal, window=window,
                       kv_len=kv_len, bq=bq, bk=bk)
    live &= qrow_lo < q_len                    # skip fully padded q tiles

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)    # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)    # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)    # [bk, d]
        do = do_ref[0, 0].astype(jnp.float32)  # [bq, d]
        lse = lse_ref[0, 0]                    # [bq] f32
        delta = delta_ref[0, 0]                # [bq] f32

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qrow = qrow_lo \
            + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        qp = q_offset + qrow
        kp = kp_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = _mask_block(qp, kp, causal=causal, window=window,
                           kv_len=kv_len)
        mask &= qrow < q_len                   # padded q tail contributes 0
        s = jnp.where(mask, s, NEG_INF)

        p = jnp.exp(s - lse[:, None])          # [bq, bk], recomputed
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(i == pl.num_programs(3) - 1)
    def _done():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dq_ref, dq_acc, *,
                   scale: float, causal: bool, window: Optional[int],
                   bq: int, bk: int, q_offset: int, kv_len: int):
    kv_i = pl.program_id(3)

    @pl.when(kv_i == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    qp_lo = q_offset + pl.program_id(2) * bq
    kp_lo = kv_i * bk

    @pl.when(_block_live(qp_lo, kp_lo, causal=causal, window=window,
                         kv_len=kv_len, bq=bq, bk=bk))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qp = qp_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = kp_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = _mask_block(qp, kp, causal=causal, window=window,
                           kv_len=kv_len)
        s = jnp.where(mask, s, NEG_INF)

        p = jnp.exp(s - lse[:, None])
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * scale
        dq_acc[...] += jax.lax.dot(ds, k,
                                   preferred_element_type=jnp.float32)

    @pl.when(kv_i == pl.num_programs(3) - 1)
    def _done():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *,
                        scale: Optional[float] = None, causal: bool = True,
                        window: Optional[int] = None, q_offset: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """Gradients (dq, dk, dv) from the saved residuals ``(q, k, v, o,
    lse)`` and the output cotangent ``do`` — O(S·D) memory, no O(S²)
    temporaries."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bq, pq = _block_and_pad(block_q, sq)
    bk, pk = _block_and_pad(block_k, skv)
    q_, o_, do_ = _pad_seq(q, pq), _pad_seq(o, pq), _pad_seq(do, pq)
    lse_ = _pad_seq(lse.astype(jnp.float32), pq)
    k_, v_ = _pad_seq(k, pk), _pad_seq(v, pk)
    spq, spk = sq + pq, skv + pk
    nqb, nkb = spq // bq, spk // bk

    delta = pl.pallas_call(
        _bwd_preprocess_kernel,
        grid=(b, hq, nqb),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi: (b_, h, qi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq), lambda b_, h, qi: (b_, h, qi)),
        out_shape=jax.ShapeDtypeStruct((b, hq, spq), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(o_, do_)

    # dK/dV: grid over KV blocks; the sequential inner dim walks the GQA
    # query group x q blocks, so each group's contribution accumulates
    # into the shared KV head's scratch.
    dkv_kernel = functools.partial(
        _bwd_dkv_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, q_offset=q_offset, kv_len=skv, q_len=sq, nqb=nqb)
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid=(b, hkv, nkb, g * nqb),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda b_, h, ki, i: (b_, h * g + i // nqb,
                                               i % nqb, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, ki, i: (b_, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, ki, i: (b_, h, ki, 0)),
            pl.BlockSpec((1, 1, bq, d),
                         lambda b_, h, ki, i: (b_, h * g + i // nqb,
                                               i % nqb, 0)),
            pl.BlockSpec((1, 1, bq),
                         lambda b_, h, ki, i: (b_, h * g + i // nqb,
                                               i % nqb)),
            pl.BlockSpec((1, 1, bq),
                         lambda b_, h, ki, i: (b_, h * g + i // nqb,
                                               i % nqb)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, ki, i: (b_, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, ki, i: (b_, h, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, spk, d), k.dtype),
            jax.ShapeDtypeStruct((b, hkv, spk, d), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),
            pltpu.VMEM((bk, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q_, k_, v_, do_, lse_, delta)

    dq_kernel = functools.partial(
        _bwd_dq_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, q_offset=q_offset, kv_len=skv)
    dq = pl.pallas_call(
        dq_kernel,
        grid=(b, hq, nqb, nkb),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, qi, ki: (b_, h, qi)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, qi, ki: (b_, h, qi)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, spq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q_, k_, v_, do_, lse_, delta)

    return dq[:, :, :sq], dk[:, :, :skv], dv[:, :, :skv]
