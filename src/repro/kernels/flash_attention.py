"""Blocked online-softmax attention (flash attention) as a Pallas TPU
kernel.

TPU adaptation notes (vs the CUDA original): tiles live in VMEM sized for
the MXU (block dims multiples of 128 where the dtype allows); the running
(m, l, acc) statistics persist in VMEM scratch across the innermost
(sequential) KV-block grid dimension, while (batch, head, q-block) are
parallel grid dims. GQA is handled in the index map (q head h reads kv
head h // group). Causal and sliding-window masks are applied from
absolute positions, so the same kernel serves train, prefill and the
windowed long_500k path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import pallas_tpu_compiler_params

_CompilerParams = pallas_tpu_compiler_params()

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: Optional[int],
            bq: int, bk: int, q_offset: int):
    kv_i = pl.program_id(3)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)        # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)        # [bk, d]
    v = v_ref[0, 0]                            # [bk, d]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qp = q_offset + pl.program_id(2) * bq \
        + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kp = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p.astype(jnp.float32), v.astype(jnp.float32),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_i == pl.num_programs(3) - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...][:, None], 1e-30)
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, scale: Optional[float] = None,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: [B, Hq, Sq, D]; k/v: [B, Hkv, Skv, D]. Returns [B, Hq, Sq, D]."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    grid = (b, hq, sq // bq, skv // bk)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk,
                               q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
