"""jit'd public wrappers over the Pallas kernels.

On CPU (this container) the kernels run in interpret mode — the kernel
body executes in Python for correctness validation; on TPU backends they
compile to Mosaic. ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import lora_matmul as _lm
from repro.kernels import mlstm as _ml


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "q_offset", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, scale=None, causal=True, window=None,
                    q_offset=0, block_q=128, block_k=128, interpret=None):
    return _fa.flash_attention(q, k, v, scale=scale, causal=causal,
                               window=window, q_offset=q_offset,
                               block_q=block_q, block_k=block_k,
                               interpret=_auto_interpret(interpret))


# Differentiable wrapper: pallas_call has no autodiff rule, so the VJP
# recomputes the oracle's linearization (flash-attention backward is a
# recompute anyway; on TPU this would be the backward kernel).
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_ad(q, k, v, scale, causal, window, q_offset):
    return _fa.flash_attention(q, k, v, scale=scale, causal=causal,
                               window=window, q_offset=q_offset,
                               interpret=_auto_interpret(None))


def _fa_fwd(q, k, v, scale, causal, window, q_offset):
    out = flash_attention_ad(q, k, v, scale, causal, window, q_offset)
    return out, (q, k, v)


def _fa_bwd(scale, causal, window, q_offset, res, g):
    from repro.kernels import ref
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.flash_attention_ref(
            q_, k_, v_, scale=scale, causal=causal, window=window,
            q_offset=q_offset), q, k, v)
    return vjp(g)


flash_attention_ad.defvjp(_fa_fwd, _fa_bwd)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunked(q, k, v, ig, lf, *, chunk=64, interpret=None):
    return _ml.mlstm_chunked(q, k, v, ig, lf, chunk=chunk,
                             interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("scale", "block_m", "block_n",
                                             "block_k", "interpret"))
def lora_matmul(x, w, a, b, *, scale=1.0, block_m=256, block_n=256,
                block_k=512, interpret=None):
    return _lm.lora_matmul(x, w, a, b, scale=scale, block_m=block_m,
                           block_n=block_n, block_k=block_k,
                           interpret=_auto_interpret(interpret))
