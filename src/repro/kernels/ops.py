"""jit'd public wrappers over the Pallas kernels.

On CPU (this container) the kernels run in interpret mode — the kernel
body executes in Python for correctness validation; on TPU backends they
compile to Mosaic. ``interpret=None`` auto-detects.

Autodiff: ``flash_attention_ad`` and ``lora_matmul_ad`` carry
``custom_vjp`` rules whose backward passes are themselves kernels —
flash attention saves ``(q, k, v, o, lse)`` residuals and runs the
preprocess/dKV/dQ Pallas kernels (O(S·D) memory; no O(Sq·Skv) score
matrix is ever materialized), and the LoRA matmul's closed-form dx reuses
the fused forward kernel on transposed operands.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import lora_matmul as _lm
from repro.kernels import mlstm as _ml
from repro.kernels import quantize as _qz


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _fit_block(block: int, dim: int) -> int:
    """Largest divisor of ``dim`` that is <= ``block`` (tile clamping for
    kernels that require exact divisibility)."""
    b = max(1, min(block, dim))
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "q_offset", "block_q",
                                             "block_k", "return_lse",
                                             "interpret"))
def flash_attention(q, k, v, *, scale=None, causal=True, window=None,
                    q_offset=0, block_q=128, block_k=128, return_lse=False,
                    interpret=None):
    return _fa.flash_attention(q, k, v, scale=scale, causal=causal,
                               window=window, q_offset=q_offset,
                               block_q=block_q, block_k=block_k,
                               return_lse=return_lse,
                               interpret=_auto_interpret(interpret))


# Differentiable flash attention: the VJP runs the real backward kernels
# from the saved (q, k, v, o, lse) residuals instead of re-linearizing the
# O(S^2) reference implementation.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _fa_ad(q, k, v, scale, causal, window, q_offset, block_q, block_k,
           interpret):
    return _fa.flash_attention(q, k, v, scale=scale, causal=causal,
                               window=window, q_offset=q_offset,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


def _fa_ad_fwd(q, k, v, scale, causal, window, q_offset, block_q, block_k,
               interpret):
    o, lse = _fa.flash_attention(q, k, v, scale=scale, causal=causal,
                                 window=window, q_offset=q_offset,
                                 block_q=block_q, block_k=block_k,
                                 return_lse=True, interpret=interpret)
    return o, (q, k, v, o, lse)


def _fa_ad_bwd(scale, causal, window, q_offset, block_q, block_k,
               interpret, res, g):
    q, k, v, o, lse = res
    return _fa.flash_attention_bwd(
        q, k, v, o, lse, g, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=block_q, block_k=block_k,
        interpret=interpret)


_fa_ad.defvjp(_fa_ad_fwd, _fa_ad_bwd)


def flash_attention_ad(q, k, v, scale=None, causal=True, window=None,
                       q_offset=0, *, block_q=128, block_k=128,
                       interpret=None):
    """Differentiable flash attention (kernel forward AND backward).
    ``block_q``/``block_k`` tune the VMEM tiles of both passes."""
    scale = float(scale) if scale is not None else q.shape[-1] ** -0.5
    return _fa_ad(q, k, v, scale, causal, window, q_offset,
                  int(block_q), int(block_k), _auto_interpret(interpret))


# Serving hot path (repro.serve): single-token decode against the paged
# KV pool. No autodiff — decode never backpropagates.
@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, block_tables, ctx_lens, *,
                           scale=None, k_scales=None, v_scales=None,
                           interpret=None):
    """q: [B, Hq, D] decode queries; k_pages/v_pages: [Hkv, NB, bs, D]
    block pools; block_tables: [B, T] logical->physical maps; ctx_lens:
    [B] visible KV lengths. Pass ``k_scales``/``v_scales`` for int8
    pools (dequantized in-kernel). Returns [B, Hq, D]."""
    return _fa.paged_decode_attention(q, k_pages, v_pages, block_tables,
                                      ctx_lens, scale=scale,
                                      k_scales=k_scales, v_scales=v_scales,
                                      interpret=_auto_interpret(interpret))


# Serving hot path (repro.serve): one prompt chunk against the paged KV
# pool. q_offset/ctx_len stay traced so every chunk of every prompt
# length shares one compiled call. No autodiff — prefill never
# backpropagates.
@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_prefill_attention(q, k_pages, v_pages, block_table, q_offset,
                            ctx_len, *, scale=None, k_scales=None,
                            v_scales=None, interpret=None):
    """q: [Hq, C, D] query chunk (row c at position q_offset + c);
    k_pages/v_pages: [Hkv, NB, bs, D] block pools already holding the
    chunk's own K/V rows; block_table: [T] logical->physical map;
    q_offset/ctx_len: int32 scalars (ctx_len = q_offset + chunk_len).
    Pass ``k_scales``/``v_scales`` for int8 pools (dequantized
    in-kernel). Returns [Hq, C, D]; rows past chunk_len are garbage."""
    return _fa.paged_prefill_attention(q, k_pages, v_pages, block_table,
                                       q_offset, ctx_len, scale=scale,
                                       k_scales=k_scales,
                                       v_scales=v_scales,
                                       interpret=_auto_interpret(interpret))


# Serving hot path (repro.serve): speculative-decode verification. A
# draft window is exactly a chunk of C = k+1 decode positions attending
# through the lane's block table, so verification reuses the chunked
# prefill kernel per lane — the lane loop is static (slots is a compile
# constant) and unrolls into independent kernel calls inside one jit.
@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_verify_attention(q, k_pages, v_pages, block_tables, ctx_lens,
                           chunk_lens, *, scale=None, k_scales=None,
                           v_scales=None, interpret=None):
    """q: [B, Hq, C, D] per-lane draft-window queries (row c of lane b at
    position ctx_lens[b] + c); k_pages/v_pages: [Hkv, NB, bs, D] pools
    already holding the window's own K/V rows; block_tables: [B, T];
    ctx_lens/chunk_lens: [B] int32 (lane b's window covers positions
    [ctx_lens[b], ctx_lens[b] + chunk_lens[b])). Returns [B, Hq, C, D];
    rows at or past a lane's chunk_len are garbage."""
    outs = [
        _fa.paged_prefill_attention(
            q[b], k_pages, v_pages, block_tables[b], ctx_lens[b],
            ctx_lens[b] + chunk_lens[b], scale=scale, k_scales=k_scales,
            v_scales=v_scales, interpret=_auto_interpret(interpret))
        for b in range(q.shape[0])
    ]
    return jnp.stack(outs)


# Codec hot path (repro.comm): no custom_vjp — encode/decode runs outside
# the differentiated path, so the pair stays a plain kernel call.
@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_int8(x, bits, *, block_rows=256, interpret=None):
    """Rowwise int8 stochastic quantization of [M, 128] rows; ``bits``
    are explicit uint32 randomness (jax.random.bits) so the call is
    deterministic given its inputs."""
    return _qz.quantize_int8(x, bits, block_rows=block_rows,
                             interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("dtype", "block_rows",
                                             "interpret"))
def dequantize_int8(q, scale, *, dtype=jnp.float32, block_rows=256,
                    interpret=None):
    return _qz.dequantize_int8(q, scale, dtype=dtype,
                               block_rows=block_rows,
                               interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunked(q, k, v, ig, lf, *, chunk=64, interpret=None):
    return _ml.mlstm_chunked(q, k, v, ig, lf, chunk=chunk,
                             interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("scale", "block_m", "block_n",
                                             "block_k", "interpret"))
def lora_matmul(x, w, a, b, *, scale=1.0, block_m=256, block_n=256,
                block_k=512, interpret=None):
    return _lm.lora_matmul(x, w, a, b, scale=scale, block_m=block_m,
                           block_n=block_n, block_k=block_k,
                           interpret=_auto_interpret(interpret))


# Differentiable fused LoRA matmul: the raw pallas_call has no autodiff
# rule, so the distillation path could not differentiate through the
# fused kernel at all. Closed form for y = x@w + scale*(x@a)@b:
#   dx = g @ w^T + scale*(g @ b^T) @ a^T   (the same fused kernel, on
#                                           transposed operands)
#   dw = x^T @ g
#   da = scale * x^T @ (g @ b^T)
#   db = scale * (x @ a)^T @ g
@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _lora_ad(x, w, a, b, scale, block_m, block_n, block_k, interpret):
    return _lm.lora_matmul(x, w, a, b, scale=scale, block_m=block_m,
                           block_n=block_n, block_k=block_k,
                           interpret=interpret)


def _lora_ad_fwd(x, w, a, b, scale, block_m, block_n, block_k, interpret):
    out = _lora_ad(x, w, a, b, scale, block_m, block_n, block_k, interpret)
    return out, (x, w, a, b)


def _lora_ad_bwd(scale, block_m, block_n, block_k, interpret, res, g):
    x, w, a, b = res
    m, kdim = x.shape
    n = w.shape[1]
    dx = _lm.lora_matmul(
        g, w.T, b.T, a.T, scale=scale,
        block_m=_fit_block(block_m, m), block_n=_fit_block(block_n, kdim),
        block_k=_fit_block(block_k, n), interpret=interpret).astype(x.dtype)
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    dw = (xf.T @ gf).astype(w.dtype)
    gb = gf @ b.astype(jnp.float32).T
    da = (scale * (xf.T @ gb)).astype(a.dtype)
    xa = xf @ a.astype(jnp.float32)
    db = (scale * (xa.T @ gf)).astype(b.dtype)
    return dx, dw, da, db


_lora_ad.defvjp(_lora_ad_fwd, _lora_ad_bwd)


def lora_matmul_ad(x, w, a, b, *, scale=1.0, block_m=256, block_n=256,
                   block_k=512, interpret=None):
    """Differentiable fused LoRA matmul (closed-form VJP; dx reuses the
    fused kernel). Tiles are clamped to valid divisors of each dim."""
    m, kdim = x.shape
    n = w.shape[1]
    return _lora_ad(x, w, a, b, float(scale),
                    _fit_block(block_m, m), _fit_block(block_n, n),
                    _fit_block(block_k, kdim), _auto_interpret(interpret))
