"""Int8 stochastic quantize/dequantize Pallas kernel pair.

The uplink hot path of the :mod:`repro.comm` fabric: every FL round each
vehicle compresses its full model delta before transmission (paper §3.1
— the cloud-edge-vehicle hierarchy exists to cut communication time, and
update compression is the per-link half of that). Unfused, XLA issues
separate absmax / divide / round passes over the delta; the kernel does
one pass per tile — rowwise absmax scale, stochastic round, int8 store —
keeping the tile in VMEM throughout.

Layout contract (enforced by :func:`repro.comm.codecs.Int8Codec`): the
flat delta is reshaped to rows of 128 lanes, ``x: [M, 128]`` float, with
one float32 scale per row. Randomness comes in as explicit uint32 bits
(``jax.random.bits`` outside the kernel) so the pair is deterministic
given its inputs and runs identically under interpret mode — no
``custom_vjp`` anywhere: encode/decode is a plain function pair outside
the differentiated path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.compat import pallas_tpu_compiler_params

_CompilerParams = pallas_tpu_compiler_params()

LANES = 128          #: fixed lane width of the quantization row layout
QMAX = 127.0         #: symmetric int8 range


def _quant_kernel(x_ref, bits_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                     # [bm, 128]
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)    # [bm, 1]
    scale = jnp.where(absmax > 0.0, absmax / QMAX, 1.0)
    scale_ref[...] = jnp.where(absmax > 0.0, scale, 0.0)
    # unbiased stochastic rounding: E[floor(s + u)] = s for u ~ U[0, 1)
    u = bits_ref[...].astype(jnp.float32) * (2.0 ** -32)
    s = x / scale
    q = jnp.clip(jnp.floor(s + u), -QMAX, QMAX)
    q_ref[...] = q.astype(jnp.int8)


def _dequant_kernel(q_ref, scale_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = (q * scale_ref[...]).astype(x_ref.dtype)


def _row_blocks(m: int, block_rows: int) -> int:
    b = max(1, min(block_rows, m))
    while m % b:
        b -= 1
    return b


def quantize_int8(x, bits, *, block_rows: int = 256,
                  interpret: bool = False):
    """x: [M, 128] float; bits: [M, 128] uint32 random bits.

    Returns ``(q int8 [M, 128], scale float32 [M, 1])`` with rowwise
    symmetric absmax scales (all-zero rows emit scale 0 and q 0)."""
    m, n = x.shape
    assert n == LANES, f"quantize rows must be {LANES} lanes wide, got {n}"
    assert bits.shape == x.shape
    bm = _row_blocks(m, block_rows)
    grid = (m // bm,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda mi: (mi, 0)),
            pl.BlockSpec((bm, n), lambda mi: (mi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, n), lambda mi: (mi, 0)),
            pl.BlockSpec((bm, 1), lambda mi: (mi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int8),
            jax.ShapeDtypeStruct((m, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, bits)


def dequantize_int8(q, scale, *, dtype=jnp.float32, block_rows: int = 256,
                    interpret: bool = False):
    """Inverse of :func:`quantize_int8`: ``q * scale`` -> [M, 128]."""
    m, n = q.shape
    assert n == LANES
    assert scale.shape == (m, 1)
    bm = _row_blocks(m, block_rows)
    grid = (m // bm,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda mi: (mi, 0)),
            pl.BlockSpec((bm, 1), lambda mi: (mi, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda mi: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(q, scale)
