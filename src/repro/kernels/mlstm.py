"""Chunked mLSTM (xLSTM) as a Pallas TPU kernel.

TPU adaptation: the per-timestep recurrence (matrix memory C updated every
token) is hostile to the MXU — the chunkwise form turns intra-chunk work
into [c, c] / [c, DH] matmuls while only the inter-chunk (C, n, m) carry is
sequential, held in VMEM scratch across the innermost grid dimension.
Same stabilized math as models/recurrent.mlstm_chunk_body and
ref.mlstm_chunked_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import pallas_tpu_compiler_params

_CompilerParams = pallas_tpu_compiler_params()


def _kernel(q_ref, k_ref, v_ref, ig_ref, lf_ref, h_ref,
            c_fin_ref, n_fin_ref, m_fin_ref,
            C_ref, n_ref, m_ref, *, chunk: int, dh: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)

    q = q_ref[0, 0].astype(jnp.float32)          # [c, dh]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    ig = ig_ref[0, 0].astype(jnp.float32)        # [c]
    lf = lf_ref[0, 0].astype(jnp.float32)

    C, n, m = C_ref[...], n_ref[...][:, 0], m_ref[0, 0]

    b_ = jnp.cumsum(lf)                          # [c]
    a_ = ig - b_
    M = jax.lax.cummax(a_, axis=0)
    m_t = b_ + jnp.maximum(m, M)                 # [c]
    m_out = m_t[-1]

    pos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    D = b_[:, None] - b_[None, :] + ig[None, :] - m_t[:, None]
    D = jnp.where(kj <= pos, jnp.exp(D), 0.0)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    inter = jnp.exp(m + b_ - m_t)                # [c]
    num = jax.lax.dot(s * D, v, preferred_element_type=jnp.float32) \
        + inter[:, None] * jax.lax.dot(q, C.T,
                                       preferred_element_type=jnp.float32)
    n_t = jax.lax.dot(D, k, preferred_element_type=jnp.float32) \
        + inter[:, None] * n[None, :]
    den = jnp.maximum(jnp.abs((n_t * q).sum(axis=1)), jnp.exp(-m_t))
    h_ref[0, 0] = (num / den[:, None]).astype(h_ref.dtype)

    w_k = jnp.exp(b_[-1] - b_ + ig - m_out)      # [c]
    carry = jnp.exp(m + b_[-1] - m_out)
    C_ref[...] = carry * C + jax.lax.dot(
        (v * w_k[:, None]).T, k, preferred_element_type=jnp.float32)
    n_ref[...] = (carry * n + jax.lax.dot(
        w_k[None, :], k, preferred_element_type=jnp.float32)[0])[:, None]
    m_ref[0, 0] = m_out

    @pl.when(ci == pl.num_programs(2) - 1)
    def _done():
        c_fin_ref[0, 0] = C_ref[...]
        n_fin_ref[0, 0] = n_ref[...][:, 0]
        m_fin_ref[0, 0] = m_ref[0, 0]


def mlstm_chunked(q, k, v, ig, lf, *, chunk: int = 64,
                  interpret: bool = False):
    """q/k/v: [B, NH, S, DH] (k pre-scaled); ig/lf: [B, NH, S].
    Returns (h [B, NH, S, DH], (C [B,NH,DH,DH], n [B,NH,DH], m [B,NH]))."""
    b, nh, s, dh = q.shape
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    grid = (b, nh, s // c)

    kernel = functools.partial(_kernel, chunk=c, dh=dh)
    h, C, n, m = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, c, dh), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, 1, c, dh), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, 1, c, dh), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, 1, c), lambda b_, h_, ci: (b_, h_, ci)),
            pl.BlockSpec((1, 1, c), lambda b_, h_, ci: (b_, h_, ci)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, dh), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b_, h_, ci: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, dh), lambda b_, h_, ci: (b_, h_, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_, ci: (b_, h_)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, s, dh), q.dtype),
            jax.ShapeDtypeStruct((b, nh, dh, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, nh, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, nh), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((dh, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, ig, lf)
    return h, (C, n, m)
