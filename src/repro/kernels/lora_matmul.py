"""Fused base + low-rank matmul: y = x @ W + scale * (x @ A) @ B.

CELLAdapt's edge fine-tuning (paper §5.2) runs LoRA-adapted layers at
serving time; unfused, the low-rank path re-reads x from HBM and
materializes x@A. The kernel accumulates BOTH the base tile product and
the rank-r projection in VMEM across the K grid dimension and applies the
B projection once on the last K step — one pass over x and W.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.compat import pallas_tpu_compiler_params

_CompilerParams = pallas_tpu_compiler_params()


def _kernel(x_ref, w_ref, a_ref, b_ref, y_ref, acc_ref, xa_ref, *,
            scale: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(x, w_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)
    xa_ref[...] += jax.lax.dot(x, a_ref[...].astype(jnp.float32),
                               preferred_element_type=jnp.float32)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _done():
        low = jax.lax.dot(xa_ref[...], b_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        y_ref[...] = (acc_ref[...] + scale * low).astype(y_ref.dtype)


def lora_matmul(x, w, a, b, *, scale: float = 1.0, block_m: int = 256,
                block_n: int = 256, block_k: int = 512,
                interpret: bool = False):
    """x: [M, K]; w: [K, N]; a: [K, r]; b: [r, N] -> [M, N]."""
    m, kdim = x.shape
    n = w.shape[1]
    r = a.shape[1]
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0
    grid = (m // bm, n // bn, kdim // bk)

    kernel = functools.partial(_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((bk, r), lambda mi, ni, ki: (ki, 0)),
            pl.BlockSpec((r, bn), lambda mi, ni, ki: (0, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, a, b)
