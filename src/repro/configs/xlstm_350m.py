"""xLSTM-350M: sLSTM + mLSTM blocks in a 7:1 layout [arXiv:2405.04517]."""
from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,            # xLSTM blocks carry internal 2x expansion, no FFN
    vocab_size=50304,
    ssm=SSMConfig(state_size=0, conv_kernel=4, slstm_every=8, expand=2),
)
