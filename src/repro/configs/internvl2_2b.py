"""InternVL2-2B backbone: InternViT-300M (stubbed frontend) + InternLM2-1.8B
decoder [arXiv:2404.16821]. The language backbone consumes 256 projected
patch embeddings (prefix) + text tokens."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1e6,
    prefix_tokens=256,
    prefix_dim=1024,   # InternViT-300M feature width (stub frontend)
)
