"""Hymba-1.5B: parallel attention + mamba heads per block, ssm_state=16
[arXiv:2411.13676]."""
from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    rope_theta=1e4,
    ssm=SSMConfig(state_size=16, conv_kernel=4, expand=2),
)
