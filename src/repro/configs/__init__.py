"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full published config; ``reduced(cfg)``
returns the CPU-smoke variant (<=2 layers, d_model<=512, <=4 experts).
``input_specs(cfg, shape)`` returns jax.ShapeDtypeStruct stand-ins for every
model input of a (arch, shape) pair — no device allocation.
"""
from __future__ import annotations

import importlib

from repro.config import INPUT_SHAPES, LONG_CONTEXT_WINDOW, ModelConfig, ShapeConfig
from repro.configs.common import input_specs, reduced, state_specs  # noqa: F401

ARCH_IDS = [
    "internvl2_2b",
    "qwen2_5_32b",
    "qwen3_32b",
    "xlstm_350m",
    "qwen3_moe_30b_a3b",
    "yi_34b",
    "seamless_m4t_large_v2",
    "dbrx_132b",
    "hymba_1_5b",
    "qwen3_14b",
    # the paper's own models
    "flad_vision",
    "flad_adllm",
]


def _canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_canon(name)}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
