"""FLAD's edge AD-LLM (paper §5.2): a LLaMA-style decoder distilled from the
cloud LLM and LoRA-fine-tuned at the edge. Sized as the 'teacher' for
CELLAdapt demos; the distilled student (ADM) is `reduced()` of this."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="flad-adllm",
    family="dense",
    num_layers=16,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=4096,
    vocab_size=32000,
    rope_theta=1e4,
)
