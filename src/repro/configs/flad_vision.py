"""FLAD's own vision encoder (paper Fig. 1/3): multimodal RGB+LiDAR token
fusion transformer with waypoint + traffic-light heads. ~100M params at this
size; the model trained federatedly by FHDP in the paper's testbed."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="flad-vision",
    family="vision",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=0,
    prefix_tokens=128,     # patch/pillar tokens per modality
    prefix_dim=256,        # stub backbone feature width
    num_waypoints=10,
    num_light_classes=4,
    param_dtype="float32",
)
