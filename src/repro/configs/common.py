"""Shared helpers: reduced smoke variants and ShapeDtypeStruct input specs."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.config import (INPUT_SHAPES, LONG_CONTEXT_WINDOW, ModelConfig,
                          MoEConfig, ShapeConfig)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """CPU-smoke variant of the same family: 2 layers, d_model<=256,
    <=4 experts, tiny vocab."""
    kw = dict(
        num_layers=2,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        param_dtype="float32",
        q_chunk=64,
        kv_chunk=64,
    )
    if cfg.moe.num_experts:
        kw["moe"] = MoEConfig(num_experts=4, top_k=2, d_expert=64)
    if cfg.family == "ssm":
        kw["num_kv_heads"] = 4
        kw["ssm"] = dataclasses.replace(cfg.ssm, slstm_every=2)
    if cfg.family == "hybrid":
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_size=8)
    if cfg.family == "encdec":
        kw["enc_layers"] = 1
        kw["dec_layers"] = 1
        kw["num_kv_heads"] = 4
        kw["prefix_dim"] = 64
    if cfg.family == "vlm":
        kw["prefix_tokens"] = 8
        kw["prefix_dim"] = 64
    if cfg.family == "vision":
        kw["prefix_dim"] = 32
        kw["num_waypoints"] = cfg.num_waypoints
        kw["num_light_classes"] = cfg.num_light_classes
    return cfg.replace(name=cfg.name + "-smoke", **kw)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def effective_window(cfg: ModelConfig, shape: ShapeConfig):
    """long_500k forces sub-quadratic attention: sliding window for
    full-attention families (SSM paths are already O(1))."""
    if shape.name == "long_500k" and cfg.family not in ("ssm",):
        return LONG_CONTEXT_WINDOW
    return cfg.window


def cache_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    w = effective_window(cfg, shape)
    return min(shape.seq_len, w) if w else shape.seq_len


ENC_MEMORY_DECODE = 4096  # frames of encoder memory during enc-dec decode


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Batch ShapeDtypeStructs for train/prefill steps (decode state comes
    from :func:`state_specs`)."""
    b, s = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if cfg.family == "vision":
        p = cfg.prefix_tokens or 64
        return {
            "rgb": _sds((b, p, cfg.prefix_dim), jnp.float32),
            "lidar": _sds((b, p, cfg.prefix_dim), jnp.float32),
            "waypoints": _sds((b, cfg.num_waypoints, 2), jnp.float32),
            "light": _sds((b,), tok),
        }
    if cfg.family == "encdec":
        if shape.is_decode:
            return {"tokens": _sds((b, 1), tok)}
        half = s // 2
        return {
            "frames": _sds((b, half, cfg.prefix_dim), jnp.float32),
            "tokens": _sds((b, half), tok),
            "labels": _sds((b, half), tok),
        }
    if shape.is_decode:
        return {"tokens": _sds((b, 1), tok)}
    specs = {"tokens": _sds((b, s), tok), "labels": _sds((b, s), tok)}
    if cfg.family == "vlm":
        specs["tokens"] = _sds((b, s - cfg.prefix_tokens), tok)
        specs["labels"] = _sds((b, s - cfg.prefix_tokens), tok)
        specs["patches"] = _sds((b, cfg.prefix_tokens, cfg.prefix_dim),
                                jnp.float32)
    return specs


def state_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Decode-state ShapeDtypeStructs via eval_shape (no allocation)."""
    from repro.models import build_model
    model = build_model(cfg)
    cl = cache_len(cfg, shape)
    st = jax.eval_shape(lambda: model.init_state(shape.global_batch, cl))
    return st


def concrete_batch(cfg: ModelConfig, shape: ShapeConfig, key) -> dict:
    """Materialize a random batch matching input_specs (small shapes only)."""
    specs = input_specs(cfg, shape)
    out = {}
    for k_, v in specs.items():
        key, sub = jax.random.split(key)
        if v.dtype == jnp.int32:
            hi = cfg.num_light_classes if k_ == "light" else cfg.vocab_size
            out[k_] = jax.random.randint(sub, v.shape, 0, max(hi, 2), jnp.int32)
        else:
            out[k_] = jax.random.normal(sub, v.shape, v.dtype)
    return out
