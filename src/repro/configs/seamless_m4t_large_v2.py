"""SeamlessM4T-large-v2 backbone: enc-dec transformer [arXiv:2308.11596].
24 layers total (12 enc + 12 dec); the mel/conformer audio frontend is a stub
feeding 1024-d frame embeddings."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,
    enc_layers=12,
    dec_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    rope_theta=1e4,
    prefix_dim=1024,   # stub audio frontend feature width
)
