"""Explicit vehicle -> edge -> cloud aggregation fabric (paper §3.1).

The reproduction's FL strategies originally relied on whatever reduction
tree XLA picked for a client-axis mean; this package makes the paper's
first innovation — the cloud-edge-vehicle collaborative architecture —
an explicit runtime object:

  * :mod:`repro.comm.topology` — declarative :class:`Topology` mapping
    vehicles to edge pods to the cloud, built from the same fleet specs
    as :mod:`repro.sched.costmodel` and reusing ``Vehicle.com`` uplink
    bandwidths as link models;
  * :mod:`repro.comm.codecs` — update codecs (int8 stochastic
    quantization, top-k sparsification) with error-feedback residuals,
    the int8 hot path a Pallas kernel pair (:mod:`repro.kernels.quantize`);
  * :mod:`repro.comm.hierarchy` — two-tier weighted aggregation (edge
    partial averages, cloud merge) plus staleness-aware down-weighting of
    late edge updates for async rounds, split into per-pod
    :func:`~repro.comm.hierarchy.edge_commit` and clocked
    :func:`~repro.comm.hierarchy.cloud_merge_at` halves;
  * :mod:`repro.comm.events` — the discrete-event engine driving the
    fabric in event time: edges commit as members arrive, the cloud
    merges on a clock with observed staleness, vehicles migrate between
    pods mid-run (``Topology.reassign``) along DTMC mobility
    trajectories.

The ``hier_fl`` (synchronous) and ``async_hier_fl`` (event-driven)
strategies (:mod:`repro.api.strategies`) wire these into
:class:`repro.api.Session`.
"""
from repro.comm.topology import Topology, parse_topology  # noqa: F401
from repro.comm.codecs import (Codec, IdentityCodec, Int8Codec,  # noqa: F401
                               TopKCodec, available_codecs, get_codec)
from repro.comm.hierarchy import (cloud_merge, cloud_merge_at,  # noqa: F401
                                  edge_aggregate, edge_commit,
                                  hierarchical_mean, make_hier_round,
                                  staleness_weights)
from repro.comm.events import (AsyncHierFLEngine, ComputeModel,  # noqa: F401
                               EventQueue, FleetMobility, HierFLProgram,
                               MobilitySpec, simulate_schedule)
