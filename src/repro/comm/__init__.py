"""Explicit vehicle -> edge -> cloud aggregation fabric (paper §3.1).

The reproduction's FL strategies originally relied on whatever reduction
tree XLA picked for a client-axis mean; this package makes the paper's
first innovation — the cloud-edge-vehicle collaborative architecture —
an explicit runtime object:

  * :mod:`repro.comm.topology` — declarative :class:`Topology` mapping
    vehicles to edge pods to the cloud, built from the same fleet specs
    as :mod:`repro.sched.costmodel` and reusing ``Vehicle.com`` uplink
    bandwidths as link models;
  * :mod:`repro.comm.codecs` — update codecs (int8 stochastic
    quantization, top-k sparsification) with error-feedback residuals,
    the int8 hot path a Pallas kernel pair (:mod:`repro.kernels.quantize`);
  * :mod:`repro.comm.hierarchy` — two-tier weighted aggregation (edge
    partial averages, cloud merge) plus staleness-aware down-weighting of
    late edge updates for async rounds.

The ``hier_fl`` strategy (:mod:`repro.api.strategies`) wires all three
into :class:`repro.api.Session`.
"""
from repro.comm.topology import Topology, parse_topology  # noqa: F401
from repro.comm.codecs import (Codec, IdentityCodec, Int8Codec,  # noqa: F401
                               TopKCodec, available_codecs, get_codec)
from repro.comm.hierarchy import (cloud_merge, edge_aggregate,  # noqa: F401
                                  hierarchical_mean, make_hier_round,
                                  staleness_weights)
