"""Declarative vehicle -> edge -> cloud topology with link models.

A :class:`Topology` names which vehicles sit under which edge pod and
what the links can carry. It is built from the **same fleet specs** the
SWIFT scheduler consumes (:func:`repro.sched.costmodel.parse_fleet`):
each :class:`~repro.sched.costmodel.Vehicle`'s ``com`` bandwidth is the
vehicle -> edge uplink model, and a shared ``backhaul_bw`` models the
edge -> cloud links (paper §3.1: vehicles reach edge servers over V2X
radio; edges reach the cloud over the metro backhaul).

Round-time accounting distinguishes the two aggregation shapes:

  * :meth:`Topology.hier_round_stats` — edges reduce their members'
    updates, so the backhaul carries ONE payload per edge;
  * :meth:`Topology.flat_round_stats` — no edge aggregation (flat
    FedAvg): every vehicle's payload transits both its uplink and the
    backhaul.

Both return bytes-on-wire and a simulated round time from the link
models; the ``hier_fl`` strategy surfaces them per round through
``LoopHooks.on_round``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.sched.costmodel import Vehicle, parse_fleet, t_uplink

#: default edge -> cloud backhaul (bytes/s) — metro fiber, not V2X radio
DEFAULT_BACKHAUL_BW = 1.25e9
#: one-way edge -> cloud latency floor (s)
DEFAULT_BACKHAUL_LATENCY = 0.01


@dataclasses.dataclass(frozen=True)
class Topology:
    """Vehicles grouped under edge pods, with link bandwidths.

    Instances are immutable snapshots; the *fleet assignment over time*
    is mutable through :meth:`reassign`, which returns the successor
    topology with one vehicle moved between pods (the event engine in
    :mod:`repro.comm.events` swaps its live topology on every
    ``PodMigration`` event, so ``client_edge`` and the cached
    ``member_indices`` are recomputed for the new assignment).
    """

    vehicles: Tuple[Vehicle, ...]
    #: per-edge tuple of indices into ``vehicles``
    edges: Tuple[Tuple[int, ...], ...]
    backhaul_bw: float = DEFAULT_BACKHAUL_BW
    backhaul_latency: float = DEFAULT_BACKHAUL_LATENCY

    def __post_init__(self):
        seen = [i for members in self.edges for i in members]
        if sorted(seen) != list(range(len(self.vehicles))):
            raise ValueError(
                f"edges must partition the {len(self.vehicles)} vehicles "
                f"exactly; got memberships {self.edges}")
        if any(not members for members in self.edges):
            raise ValueError("every edge pod needs at least one vehicle")
        if self.backhaul_bw <= 0:
            raise ValueError("backhaul_bw must be positive")
        # hoisted out of the aggregation hot path: member index arrays and
        # the client->edge map are built once per topology, not per round
        member_idx = tuple(np.asarray(members, np.int32)
                           for members in self.edges)
        ce = np.empty(len(self.vehicles), np.int32)
        for e, idx in enumerate(member_idx):
            ce[idx] = e
        ce.setflags(write=False)
        object.__setattr__(self, "_member_indices", member_idx)
        object.__setattr__(self, "_client_edge", ce)

    # ---- shape -----------------------------------------------------------
    @property
    def n_clients(self) -> int:
        return len(self.vehicles)

    @property
    def n_edges(self) -> int:
        return len(self.edges)

    @property
    def member_indices(self) -> Tuple[np.ndarray, ...]:
        """Per-edge int32 index arrays into the client axis (cached)."""
        return self._member_indices

    @property
    def client_edge(self) -> np.ndarray:
        """[C] edge index of each client (client i == vehicles[i]);
        cached and read-only."""
        return self._client_edge

    # ---- validation ------------------------------------------------------
    def validate_pod_weights(self, weights) -> None:
        """Raise if any pod's member weights are degenerate (a pod whose
        members sum to zero weight would 0/0 its partial average — the
        global-sum check upstream cannot see this). Host-side numpy; call
        once at round-build time, not per invocation. Traced weights are
        skipped — the caller must validate them at build time."""
        import jax

        from repro.core.fedavg import check_weights
        try:
            w = np.asarray(weights)
        except jax.errors.ConcretizationTypeError:
            return
        for e, idx in enumerate(self.member_indices):
            try:
                check_weights(w[idx])
            except ValueError as err:
                raise ValueError(
                    f"edge pod {e} (vehicles {self.edges[e]}): {err}"
                ) from None

    # ---- transitions -----------------------------------------------------
    def reassign(self, vehicle: int, edge: int) -> "Topology":
        """The mid-run migration transition: move ``vehicle`` to ``edge``.

        Returns the successor topology (this one is unchanged); the
        source pod must keep at least one member.
        """
        if not 0 <= vehicle < self.n_clients:
            raise ValueError(f"no vehicle {vehicle} in this topology")
        if not 0 <= edge < self.n_edges:
            raise ValueError(f"no edge pod {edge} in this topology")
        src = int(self.client_edge[vehicle])
        if src == edge:
            return self
        if len(self.edges[src]) == 1:
            raise ValueError(
                f"cannot migrate vehicle {vehicle}: it is the last member "
                f"of edge pod {src}")
        edges = [tuple(i for i in members if i != vehicle)
                 for members in self.edges]
        edges[edge] = edges[edge] + (vehicle,)
        return dataclasses.replace(self, edges=tuple(edges))

    # ---- constructors ----------------------------------------------------
    @classmethod
    def from_fleet(cls, fleet, n_edges: int, *,
                   backhaul_bw: float = DEFAULT_BACKHAUL_BW,
                   backhaul_latency: float = DEFAULT_BACKHAUL_LATENCY
                   ) -> "Topology":
        """Group a fleet (any :func:`parse_fleet` form) into ``n_edges``
        contiguous pods, as even as the head count allows."""
        vehicles = tuple(parse_fleet(fleet))
        c = len(vehicles)
        if not 1 <= n_edges <= c:
            raise ValueError(
                f"need 1 <= n_edges <= {c} vehicles, got {n_edges}")
        base, extra = divmod(c, n_edges)
        edges, start = [], 0
        for e in range(n_edges):
            size = base + (1 if e < extra else 0)
            edges.append(tuple(range(start, start + size)))
            start += size
        return cls(vehicles, tuple(edges), backhaul_bw=backhaul_bw,
                   backhaul_latency=backhaul_latency)

    # ---- link timing -----------------------------------------------------
    def uplink_times(self, bytes_per_client: float) -> np.ndarray:
        """[C] seconds for each vehicle to push one payload to its edge."""
        return np.array([t_uplink(bytes_per_client, v)
                         for v in self.vehicles])

    def hier_round_stats(self, bytes_per_client: float,
                         bytes_per_edge=None) -> Dict:
        """Bytes-on-wire and simulated time for one hierarchical round.

        Each vehicle uploads its (compressed) update to its edge; each
        edge reduces and forwards ONE payload to the cloud.
        ``bytes_per_edge``: scalar or per-edge sequence (default: same
        wire format as a client payload — correct for dense codecs;
        sparse codecs pay for the support union, see
        ``Codec.edge_nbytes``). An edge's update arrives when its
        slowest member has uploaded plus the backhaul transfer; the
        round closes on the last edge.
        """
        if bytes_per_edge is None:
            bytes_per_edge = bytes_per_client
        per_edge = np.broadcast_to(
            np.asarray(bytes_per_edge, np.float64), (self.n_edges,))
        up = self.uplink_times(bytes_per_client)
        arrivals = np.array([
            up[list(members)].max()
            + per_edge[e] / self.backhaul_bw + self.backhaul_latency
            for e, members in enumerate(self.edges)])
        return {
            "uplink_bytes": int(bytes_per_client) * self.n_clients,
            "backhaul_bytes": int(per_edge.sum()),
            "edge_arrival_s": arrivals,
            "round_time_s": float(arrivals.max()),
        }

    def flat_round_stats(self, bytes_per_client: float) -> Dict:
        """The no-edge-aggregation baseline on the same physical links:
        all C payloads transit the backhaul unreduced, serialized behind
        one another on the shared link."""
        up = self.uplink_times(bytes_per_client)
        backhaul = (self.n_clients * bytes_per_client / self.backhaul_bw
                    + self.backhaul_latency)
        round_time = float(up.max() + backhaul)
        return {
            "uplink_bytes": int(bytes_per_client) * self.n_clients,
            "backhaul_bytes": int(bytes_per_client) * self.n_clients,
            "edge_arrival_s": np.full(self.n_edges, round_time),
            "round_time_s": round_time,
        }


def parse_topology(spec, *, backhaul_bw: float = DEFAULT_BACKHAUL_BW,
                   backhaul_latency: float = DEFAULT_BACKHAUL_LATENCY
                   ) -> Topology:
    """Coerce a topology declaration.

    Accepts a :class:`Topology` (passed through), an ``"E@FLEET"`` string
    — e.g. ``"2@nano*2,agx*2"`` is 2 edge pods over that 4-vehicle fleet
    — or a plain fleet spec (one edge pod over the whole fleet).
    """
    if isinstance(spec, Topology):
        return spec
    n_edges = 1
    if isinstance(spec, str) and "@" in spec:
        head, _, spec = spec.partition("@")
        try:
            n_edges = int(head)
        except ValueError:
            raise ValueError(
                f"topology spec must look like 'E@FLEET' with integer E, "
                f"got {head!r}") from None
    return Topology.from_fleet(spec, n_edges, backhaul_bw=backhaul_bw,
                               backhaul_latency=backhaul_latency)
