"""Update codecs for the uplink: what a vehicle actually transmits.

Every codec maps a flat float leaf to a wire payload and back, and
reports the payload's wire size — the quantity the topology's link
models turn into round time. Lossy codecs are used with **error
feedback**: the un-transmitted remainder of round t is added back to the
update of round t+1 (:func:`roundtrip_stacked` carries the residual
tree), so the compression error telescopes instead of accumulating —
the standard convergence fix for sparsified/quantized FL.

  ``none``  float32 passthrough (4 B/elem) — the fp32 FedAvg baseline
  ``int8``  rowwise-absmax stochastic int8 (1 B/elem + 4 B per 128-lane
            row); the quantize/dequantize hot path is the Pallas kernel
            pair in :mod:`repro.kernels.quantize`
  ``topk``  magnitude top-k sparsification (8 B per kept element:
            float32 value + int32 index)

Encode/decode is a plain function pair — no ``custom_vjp`` — because it
runs on already-computed deltas, outside the differentiated path.
"""
from __future__ import annotations

import abc
from typing import Any, Callable, Dict, Tuple, Type

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.quantize import LANES

_REGISTRY: Dict[str, Type["Codec"]] = {}


def register_codec(name: str) -> Callable[[type], type]:
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available_codecs() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_codec(name: str, **options) -> "Codec":
    """Instantiate a registered codec; unknown names list valid ones."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r}; available: "
            f"{', '.join(available_codecs())}") from None
    return cls(**options)


class Codec(abc.ABC):
    """Flat-leaf wire codec. All methods are jit-traceable."""

    name: str = ""
    #: lossless codecs skip the error-feedback residual entirely
    lossless: bool = False

    @abc.abstractmethod
    def encode(self, flat: jnp.ndarray, key) -> Dict[str, jnp.ndarray]:
        """flat float [N] -> wire payload (dict of arrays)."""

    @abc.abstractmethod
    def decode(self, payload: Dict[str, jnp.ndarray], size: int
               ) -> jnp.ndarray:
        """Wire payload -> float32 [size] (what the edge reconstructs)."""

    @abc.abstractmethod
    def nbytes(self, size: int) -> int:
        """Wire bytes for one [size] leaf (static)."""

    def edge_nbytes(self, size: int, members: int) -> int:
        """Wire bytes for an edge pod's *aggregated* update of one
        [size] leaf (``members`` vehicles in the pod). Dense formats
        aggregate to the same wire format as a client payload; sparse
        formats must pay for the support union (override)."""
        return self.nbytes(size)


@register_codec("none")
class IdentityCodec(Codec):
    """float32 passthrough — the uncompressed FedAvg wire format."""

    lossless = True

    def encode(self, flat, key):
        return {"values": flat.astype(jnp.float32)}

    def decode(self, payload, size):
        return payload["values"]

    def nbytes(self, size):
        return 4 * size


@register_codec("int8")
class Int8Codec(Codec):
    """Rowwise-absmax int8 with unbiased stochastic rounding.

    The flat leaf is packed into rows of 128 lanes (zero-padded tail)
    and handed to the Pallas kernel pair; one float32 scale per row
    rides along. ~3.9x smaller than fp32 on the wire.
    """

    def __init__(self, *, block_rows: int = 256):
        self.block_rows = block_rows

    def _rows(self, size: int) -> int:
        return -(-size // LANES)

    def encode(self, flat, key):
        rows = self._rows(flat.size)
        x = jnp.zeros((rows * LANES,), jnp.float32)
        x = x.at[:flat.size].set(flat.astype(jnp.float32))
        x = x.reshape(rows, LANES)
        bits = jax.random.bits(key, (rows, LANES), jnp.uint32)
        q, scale = ops.quantize_int8(x, bits, block_rows=self.block_rows)
        return {"q": q, "scale": scale}

    def decode(self, payload, size):
        x = ops.dequantize_int8(payload["q"], payload["scale"],
                                block_rows=self.block_rows)
        return x.reshape(-1)[:size]

    def nbytes(self, size):
        return size + 4 * self._rows(size)


@register_codec("topk")
class TopKCodec(Codec):
    """Magnitude top-k sparsification: transmit the k largest-|.| entries
    as (float32 value, int32 index) pairs; the edge scatters them into a
    zero vector. ``k_frac`` is the kept fraction (>= 1 element)."""

    def __init__(self, *, k_frac: float = 0.05):
        if not 0.0 < k_frac <= 1.0:
            raise ValueError(f"k_frac must be in (0, 1], got {k_frac}")
        self.k_frac = k_frac

    def k(self, size: int) -> int:
        return max(1, min(size, int(round(self.k_frac * size))))

    def encode(self, flat, key):
        k = self.k(flat.size)
        f = flat.astype(jnp.float32)
        _, idx = jax.lax.top_k(jnp.abs(f), k)
        return {"values": f[idx], "indices": idx.astype(jnp.int32)}

    def decode(self, payload, size):
        out = jnp.zeros((size,), jnp.float32)
        return out.at[payload["indices"]].set(payload["values"])

    def nbytes(self, size):
        return 8 * self.k(size)

    def edge_nbytes(self, size, members):
        # the pod average's support is the union of its members' top-k
        # sets — up to members*k nonzeros; past that, dense fp32 wins
        union = min(members * self.k(size), size)
        return min(8 * union, 4 * size)


# ---- tree-level error-feedback transport ---------------------------------

def tree_nbytes(codec: Codec, tree) -> int:
    """Static wire bytes for one client's update of this tree."""
    return sum(codec.nbytes(int(leaf.size))
               for leaf in jax.tree.leaves(tree))


def tree_edge_nbytes(codec: Codec, tree, members: int) -> int:
    """Static wire bytes for an edge pod's aggregated update of this
    tree (``members`` vehicles in the pod)."""
    return sum(codec.edge_nbytes(int(leaf.size), members)
               for leaf in jax.tree.leaves(tree))


def roundtrip_leaf(codec: Codec, leaf, residual, key):
    """Encode+decode one leaf with error feedback.

    Returns ``(decoded, new_residual)`` where ``decoded`` is what the
    edge reconstructs from the wire and ``new_residual`` the untransmitted
    remainder to re-inject next round (zeros for lossless codecs).
    """
    x = leaf.astype(jnp.float32) + residual
    flat = x.reshape(-1)
    decoded = codec.decode(codec.encode(flat, key), flat.size)
    decoded = decoded.reshape(leaf.shape)
    if codec.lossless:
        return decoded, jnp.zeros_like(residual)
    return decoded, x - decoded


def roundtrip_stacked(codec: Codec, stacked, residual, key):
    """Per-client wire roundtrip of a client-stacked [C, ...] tree.

    ``residual`` carries each client's error-feedback state (same
    structure, float32). Every client's leaf has the same shape, so the
    client axis is ``vmap``-ed — one traced encode/decode body per leaf
    regardless of fleet size (top-k and the quantize kernel pair both
    batch), with per-client PRNG keys.
    """
    leaves, treedef = jax.tree.flatten(stacked)
    res_leaves = jax.tree.leaves(residual)
    C = leaves[0].shape[0]
    leaf_keys = jax.random.split(key, len(leaves))
    dec_cols, res_cols = [], []
    for lk, leaf, res in zip(leaf_keys, leaves, res_leaves):
        d, r = jax.vmap(
            lambda x, rr, kk: roundtrip_leaf(codec, x, rr, kk)
        )(leaf, res, jax.random.split(lk, C))
        dec_cols.append(d)
        res_cols.append(r)
    return (jax.tree.unflatten(treedef, dec_cols),
            jax.tree.unflatten(treedef, res_cols))


def zero_residual(stacked):
    """Fresh float32 error-feedback state for a client-stacked tree."""
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), stacked)
