"""Discrete-event engine for asynchronous vehicle-edge-cloud FL.

The synchronous ``hier_fl`` round is one fused jitted function: every
vehicle trains, every pod aggregates, the cloud merges, all in lockstep.
This module inverts that control flow. A priority queue of timestamped
events drives the round:

  ``LocalStepDone``    a vehicle finished its local steps (compute-time
                       model over ``Vehicle.cmp``, optional jitter)
  ``UplinkArrived``    its coded update crossed the V2X link
                       (:func:`repro.sched.costmodel.t_uplink`)
  ``BackhaulArrived``  an edge pod's partial aggregate crossed the
                       metro backhaul to the cloud
  ``CloudDeadline``    the cloud's merge clock ticked: merge whatever
                       commits arrived, with **observed** staleness
                       lags, and re-broadcast to idle vehicles
  ``PodMigration``     a vehicle moved between edge pods
                       (:meth:`repro.comm.topology.Topology.reassign`),
                       driven by DTMC trajectories from
                       :mod:`repro.sched.mobility`

Edges commit partial aggregates (:func:`repro.comm.hierarchy
.edge_commit`) whenever their members arrive — without waiting for
stragglers when a merge clock is set — and the cloud merges commits at
deadlines (:func:`repro.comm.hierarchy.cloud_merge_at`), feeding the
observed arrival lags into the existing ``staleness_weights``.

With ``clock=None`` (infinite deadline), zero jitter, and no migrations
the engine IS the synchronous round: the cloud merges exactly when every
vehicle's update has arrived, and the piecewise-jitted computation is
bit-identical to ``make_hier_round``'s fused jit (the ``async_hier_fl``
strategy's sync-equivalence guarantee, enforced by
``tests/test_events.py``).

Event ordering ties break deterministically by ``(timestamp,
sequence-id)``: replaying a seed reproduces the exact event log and
final params on any platform.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, ClassVar, Dict, List, Optional, Tuple

import numpy as np

from repro.comm.topology import Topology
from repro.sched.costmodel import t_uplink
from repro.sched.mobility import GridWorld, make_patterns

# ---- events ---------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LocalStepDone:
    t: float
    vehicle: int
    kind: ClassVar[str] = "local_step_done"


@dataclasses.dataclass(frozen=True)
class UplinkArrived:
    t: float
    vehicle: int
    nbytes: int
    kind: ClassVar[str] = "uplink_arrived"


@dataclasses.dataclass(frozen=True)
class BackhaulArrived:
    t: float
    edge: int
    commit_id: int
    kind: ClassVar[str] = "backhaul_arrived"


@dataclasses.dataclass(frozen=True)
class CloudDeadline:
    t: float
    index: int
    kind: ClassVar[str] = "cloud_deadline"


@dataclasses.dataclass(frozen=True)
class PodMigration:
    t: float
    vehicle: int
    src: int
    dst: int
    kind: ClassVar[str] = "pod_migration"


@dataclasses.dataclass(frozen=True)
class MobilityTick:
    t: float
    index: int
    kind: ClassVar[str] = "mobility_tick"


@dataclasses.dataclass(frozen=True)
class EdgeFlush:
    t: float
    edge: int
    gen: int
    kind: ClassVar[str] = "edge_flush"


def _log_entry(ev) -> Tuple:
    d = dataclasses.asdict(ev)
    t = d.pop("t")
    return (ev.kind, t) + tuple(v for _, v in sorted(d.items()))


class EventQueue:
    """Min-heap of events keyed ``(timestamp, sequence-id)`` — identical
    timestamps pop in push order, so runs replay identically across
    platforms (heapq never compares the event payloads themselves)."""

    def __init__(self):
        self._heap: List[Tuple[float, int, object]] = []
        self._seq = 0

    def push(self, ev) -> None:
        heapq.heappush(self._heap, (ev.t, self._seq, ev))
        self._seq += 1

    def pop(self):
        if not self._heap:
            return None
        return heapq.heappop(self._heap)[2]

    def peek_t(self) -> float:
        return self._heap[0][0] if self._heap else math.inf

    def __len__(self) -> int:
        return len(self._heap)


# ---- timing models --------------------------------------------------------


@dataclasses.dataclass
class ComputeModel:
    """Per-vehicle local-round compute time: ``flops`` of one local round
    (all local steps) at the vehicle's effective throughput ``cmp * mu``
    (paper Eq. 8's utilization), times a multiplicative jitter drawn
    uniformly from ``[1, 1 + jitter]`` per (vehicle, round)."""

    flops: float
    mu: float = 0.5
    jitter: float = 0.0

    def time_s(self, vehicle, rng) -> float:
        t = self.flops / (vehicle.cmp * self.mu)
        if self.jitter > 0.0:
            t *= 1.0 + float(rng.uniform(0.0, self.jitter))
        return t


def default_compute_flops(cfg, shape, local_steps: int = 1) -> float:
    """fwd+bwd FLOPs of one local round: 6 * active params * tokens."""
    tokens = shape.global_batch * shape.seq_len * max(local_steps, 1)
    return 6.0 * cfg.active_param_count() * tokens


# ---- mobility -> migration events ----------------------------------------


@dataclasses.dataclass(frozen=True)
class MobilitySpec:
    """DTMC mobility driving ``PodMigration`` events: vehicles random-walk
    a ``size x size`` grid under :func:`repro.sched.mobility
    .make_patterns` patterns; a vehicle migrates to the nearest edge pod
    when it leaves the ``radius``-cell comm range of its current pod's
    home cell."""

    size: int = 6
    n_patterns: int = 3
    radius: int = 2
    persistence: float = 0.55
    seed: int = 0


class FleetMobility:
    """Live mobility state: one cell + pattern per vehicle, one home cell
    per edge pod (spread along the grid diagonal)."""

    def __init__(self, spec: MobilitySpec, topology: Topology):
        self.spec = spec
        self.world: GridWorld = make_patterns(
            spec.size, spec.n_patterns, seed=spec.seed,
            persistence=spec.persistence)
        E, C = topology.n_edges, topology.n_clients
        coords = (np.round(np.linspace(0, spec.size - 1, E)).astype(int)
                  if E > 1 else np.array([spec.size // 2]))
        self.edge_cells = coords * spec.size + coords
        self.patterns = np.arange(C) % spec.n_patterns
        self.cells = self.edge_cells[topology.client_edge].copy()
        self.histories: List[List[int]] = [[int(c)] for c in self.cells]

    def advance(self, vehicle: int, rng) -> int:
        c = int(rng.choice(self.world.n_cells,
                           p=self.world.patterns[self.patterns[vehicle],
                                                 self.cells[vehicle]]))
        self.cells[vehicle] = c
        self.histories[vehicle].append(c)
        return c

    def out_of_range(self, vehicle: int, edge: int) -> bool:
        return int(self.world.cell_dist(
            self.cells[vehicle], self.edge_cells[edge])) > self.spec.radius

    def nearest_edge(self, vehicle: int) -> int:
        d = self.world.cell_dist(self.cells[vehicle], self.edge_cells)
        return int(np.argmin(d))        # ties -> lowest edge index


def time_to_migration(world: GridWorld, traj, speed: float,
                      radius: int) -> float:
    """Seconds until ``traj`` leaves the ``radius``-cell comm range of
    its start cell, on the dwell-data timescale of
    :func:`repro.sched.dwell.synthetic_dwell_data` (2.0 s per newly
    entered cell at unit speed); capped at the route end. This is the
    simulated quantity the WDR-predicted dwell time upper-bounds in
    expectation (property-tested in ``tests/test_events.py``)."""
    start = int(traj[0])
    visited = {start}
    for c in traj[1:]:
        visited.add(int(c))
        if int(world.cell_dist(start, int(c))) > radius:
            break
    return len(visited) * 2.0 / speed


# ---- the jitted compute program ------------------------------------------


class HierFLProgram:
    """The jitted compute pieces of the async fabric — the same algebra
    as ``make_hier_round``, split at the event boundaries: vmapped local
    steps over the client stack, delta + codec roundtrip with error
    feedback, per-pod ``edge_commit``, clocked ``cloud_merge_at``, and
    masked row select/assign for partial-wave state updates. Composed in
    the synchronous schedule these reproduce the fused round bit for
    bit."""

    def __init__(self, cfg, shape, optimizer, codec, *, remat: bool = False):
        import jax
        import jax.numpy as jnp

        from repro.comm.codecs import roundtrip_stacked
        from repro.comm.hierarchy import cloud_merge_at, edge_commit
        from repro.core.fedavg import make_local_train
        from repro.core.steps import make_train_step

        step = make_train_step(cfg, shape, optimizer, remat=remat)
        self.local_all = jax.jit(jax.vmap(make_local_train(step)))
        self.commit = jax.jit(edge_commit)
        self.merge = jax.jit(cloud_merge_at)

        @jax.jit
        def deltas(params, base):
            return jax.tree.map(
                lambda a, g: a.astype(jnp.float32) - g, params, base)

        @jax.jit
        def roundtrip(d, residual, key):
            return roundtrip_stacked(codec, d, residual, key)

        @jax.jit
        def select_rows(new, old, mask):
            def sel(n, o):
                m = mask.reshape((-1,) + (1,) * (n.ndim - 1))
                return jnp.where(m, n, o)

            return jax.tree.map(sel, new, old)

        @jax.jit
        def assign_rows(tree, mask, flat):
            def asg(x, g):
                m = mask.reshape((-1,) + (1,) * (x.ndim - 1))
                return jnp.where(m, jnp.broadcast_to(g[None], x.shape), x)

            return jax.tree.map(asg, tree, flat)

        self.deltas = deltas
        self.roundtrip = roundtrip
        self.select_rows = select_rows
        self.assign_rows = assign_rows


@dataclasses.dataclass
class _Commit:
    partial: object               # float32 partial-average tree (or None)
    weight: object                # scalar total member weight
    vehicles: Tuple[int, ...]
    base_version: int
    base_time: float
    nbytes: int
    edge: int
    t_commit: float
    t_arrive: float = math.nan


@dataclasses.dataclass
class _Buffered:
    vehicle: int
    delta: object
    weight: float
    base_version: int
    base_time: float


# ---- the engine -----------------------------------------------------------


class AsyncHierFLEngine:
    """Event-time driver of one asynchronous hierarchical-FL fabric.

    ``clock``: cloud merge period in simulated seconds; ``None`` means
    the infinite deadline — the cloud merges exactly when every
    vehicle's update has arrived (the synchronous special case).
    ``program=None`` runs the schedule timing-only (no tensors), which
    is what ``launch/dryrun.py --async-clock`` uses.

    The engine treats :class:`Topology` as mutable over time: every
    ``PodMigration`` swaps ``self.topo`` for ``topo.reassign(vehicle,
    edge)``, so ``client_edge`` / ``member_indices`` always describe the
    live assignment.
    """

    def __init__(self, topology: Topology, bytes_per_client: int,
                 edge_nbytes_fn: Callable[[int], int], *,
                 program: Optional[HierFLProgram] = None,
                 compute: Optional[ComputeModel] = None,
                 client_weights: Optional[np.ndarray] = None,
                 clock: Optional[float] = None, decay: float = 0.5,
                 flush_every: Optional[float] = None,
                 mobility: Optional[MobilitySpec] = None,
                 migrate_every: Optional[float] = None,
                 seed: int = 0,
                 key_fn: Optional[Callable] = None,
                 tracer=None, metrics=None):
        if clock is not None and clock <= 0:
            raise ValueError(f"clock must be positive or None, got {clock}")
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        self.topo0 = topology
        self.bytes_per_client = int(bytes_per_client)
        self.edge_nbytes_fn = edge_nbytes_fn
        self.program = program
        self.compute = compute or ComputeModel(flops=1e9)
        self.client_w = (np.ones(topology.n_clients, np.float32)
                         if client_weights is None
                         else np.asarray(client_weights, np.float32))
        if self.client_w.shape != (topology.n_clients,):
            raise ValueError(
                f"client_weights has shape {self.client_w.shape}, expected "
                f"({topology.n_clients},)")
        topology.validate_pod_weights(self.client_w)
        self.clock = clock
        self.decay = decay
        self.flush_every = flush_every if flush_every is not None else clock
        self.mobility_spec = mobility
        self.migrate_every = migrate_every
        self.seed = seed
        self.key_fn = key_fn
        self.topo = topology
        self.version = 0
        #: optional :class:`repro.obs.Tracer` — sim-time spans on one
        #: track per vehicle/edge/cloud. None (the default) means no
        #: callbacks fire at all: event log, params, and metrics are
        #: bitwise those of an untraced run (tests/test_obs.py).
        self.tracer = tracer
        #: optional :class:`repro.obs.MetricsRegistry` the engine
        #: publishes wire bytes / observed staleness / migrations into
        self.metrics = metrics

    # ---- lifecycle -----------------------------------------------------
    def reset(self, client_params=None, client_opt=None,
              round_batches_fn=None) -> None:
        C = self.topo0.n_clients
        self.C = C
        self.topo = self.topo0
        self.now = 0.0
        self.queue = EventQueue()
        self.rng = np.random.default_rng(self.seed)
        self.event_log: List[Tuple] = []
        self.version = 0
        self.n_migrations = 0
        self.state = ["idle"] * C
        self._wave_open: set = set()
        self.wave_count = 0
        self._delta: List = [None] * C
        self.last_metrics: Dict[str, np.ndarray] = {}
        self.base_version = np.zeros(C, np.int64)
        self.base_time = np.zeros(C, np.float64)
        self.edge_buffers: List[List[_Buffered]] = \
            [[] for _ in range(self.topo0.n_edges)]
        self.flush_gen = [0] * self.topo0.n_edges
        self.commits: Dict[int, _Commit] = {}
        self._next_commit = 0
        self.cloud_buffer: List[int] = []
        self.bytes_up = 0
        self.bytes_backhaul = 0
        self._bytes_up_mark = 0
        self._bytes_backhaul_mark = 0
        self._batches_fn = round_batches_fn
        self.mobility = (FleetMobility(self.mobility_spec, self.topo0)
                         if self.mobility_spec is not None else None)
        self._uplink_t0 = np.zeros(C, np.float64)   # LocalStepDone times
        self._uplink_t1 = np.zeros(C, np.float64)   # UplinkArrived times
        if self.tracer is not None:
            self._declare_tracks()
        if self.program is not None:
            import jax

            from repro.comm.codecs import zero_residual
            if client_params is None:
                raise ValueError("a compute program needs client params")
            self.client_params = client_params
            self.client_opt = client_opt
            self.residual = zero_residual(client_params)
            self.global_params = jax.tree.map(lambda x: x[0], client_params)
            self.base_params = client_params
            self._key = self.key_fn() if self.key_fn is not None \
                else jax.random.PRNGKey(self.seed)
        else:
            self.client_params = client_params
            self.client_opt = client_opt
            self.global_params = None
        self._broadcast(range(C), 0.0)
        if self.clock is not None:
            self.queue.push(CloudDeadline(self.clock, 1))
        if self.mobility is not None and self.migrate_every is not None:
            self.queue.push(MobilityTick(self.migrate_every, 1))

    # ---- tracing (repro.obs) -------------------------------------------
    def _declare_tracks(self) -> None:
        from repro.obs import trace as T
        tr = self.tracer
        tr.process(T.FL_PID, "fl-fabric", sort_index=1)
        tr.track(T.FL_PID, T.CLOUD_TID, "cloud")
        for e in range(self.topo0.n_edges):
            tr.track(T.FL_PID, T.edge_tid(e), f"edge {e}")
        for i, v in enumerate(self.topo0.vehicles):
            tr.track(T.FL_PID, T.vehicle_tid(i),
                     f"vehicle {i} (vid {v.vid})")

    # ---- event dispatch ------------------------------------------------
    def handle(self, ev) -> Optional[Dict]:
        """Process one event; returns the merge record when the event
        closed a cloud round, else None."""
        self.now = ev.t
        self.event_log.append(_log_entry(ev))
        if isinstance(ev, LocalStepDone):
            return self._on_local_done(ev)
        if isinstance(ev, UplinkArrived):
            return self._on_uplink(ev)
        if isinstance(ev, BackhaulArrived):
            return self._on_backhaul(ev)
        if isinstance(ev, CloudDeadline):
            return self._on_deadline(ev)
        if isinstance(ev, EdgeFlush):
            return self._on_flush(ev)
        if isinstance(ev, MobilityTick):
            return self._on_mobility(ev)
        if isinstance(ev, PodMigration):
            return self._on_migration(ev)
        raise TypeError(f"unknown event {ev!r}")

    # ---- vehicle lifecycle ---------------------------------------------
    def _broadcast(self, vehicles, t: float) -> None:
        ids = [i for i in vehicles if self.state[i] == "idle"]
        if not ids:
            return
        if self.program is not None:
            import jax.numpy as jnp
            mask = np.zeros(self.C, bool)
            mask[ids] = True
            m = jnp.asarray(mask)
            self.client_params = self.program.assign_rows(
                self.client_params, m, self.global_params)
            self.base_params = self.program.assign_rows(
                self.base_params, m, self.global_params)
        for i in ids:
            self.base_version[i] = self.version
            self.base_time[i] = t
            self.state[i] = "computing"
            self._wave_open.add(i)
            dt = self.compute.time_s(self.topo.vehicles[i], self.rng)
            self.queue.push(LocalStepDone(t + dt, i))

    def _run_wave(self) -> None:
        members = sorted(self._wave_open)
        self._wave_open.clear()
        w = self.wave_count
        self.wave_count += 1
        if self.program is None:
            return
        # The wave always runs the full [C]-stacked vmapped computation
        # and masks non-members out afterwards: fixed shapes (one jit
        # trace) and, in the synchronous schedule where every wave is the
        # whole fleet, bit-identity with the fused round. The price is
        # O(waves * C) local steps in async mode — discarded rows for
        # idle/straggling vehicles. Gathering members into padded
        # buckets would trade that for per-bucket retraces; see the
        # ROADMAP async item.
        import jax
        import jax.numpy as jnp
        batches = self._batches_fn(w)
        self._key, sub = jax.random.split(self._key)
        params, opts, metrics = self.program.local_all(
            self.client_params, self.client_opt, batches)
        d = self.program.deltas(params, self.base_params)
        decoded, new_res = self.program.roundtrip(d, self.residual, sub)
        mask = np.zeros(self.C, bool)
        mask[members] = True
        m = jnp.asarray(mask)
        self.client_params = self.program.select_rows(
            params, self.client_params, m)
        self.client_opt = self.program.select_rows(
            opts, self.client_opt, m)
        self.residual = self.program.select_rows(
            new_res, self.residual, m)
        for i in members:
            self._delta[i] = jax.tree.map(lambda x, _i=i: x[_i], decoded)
        for k, v in metrics.items():
            arr = np.asarray(v)
            buf = self.last_metrics.setdefault(
                k, np.full(arr.shape, np.nan, np.float64))
            buf[members] = arr[members]

    def _on_local_done(self, ev: LocalStepDone) -> None:
        i = ev.vehicle
        if i in self._wave_open:
            self._run_wave()
        self.state[i] = "uplink"
        self._uplink_t0[i] = ev.t
        if self.tracer is not None:
            from repro.obs import trace as T
            from repro.obs.profile import kernel_cost_args
            self.tracer.complete(
                "compute", float(self.base_time[i]), ev.t,
                pid=T.FL_PID, tid=T.vehicle_tid(i), cat="compute",
                args=dict(kernel_cost_args(flops=self.compute.flops),
                          vehicle=i,
                          base_version=int(self.base_version[i])))
        dt = t_uplink(self.bytes_per_client, self.topo.vehicles[i])
        self.queue.push(UplinkArrived(ev.t + dt, i, self.bytes_per_client))
        return None

    # ---- edge tier ------------------------------------------------------
    def _on_uplink(self, ev: UplinkArrived) -> None:
        i = ev.vehicle
        self.bytes_up += ev.nbytes
        self.state[i] = "idle"
        e = int(self.topo.client_edge[i])
        if any(b.vehicle == i for b in self.edge_buffers[e]):
            # a fast vehicle lapped the pod's flush timer: forward the
            # current partial first so one commit never carries the same
            # member twice (which would double its aggregation weight)
            self._commit(e, ev.t)
        self._uplink_t1[i] = ev.t
        if self.tracer is not None:
            from repro.obs import trace as T
            self.tracer.complete(
                "uplink", float(self._uplink_t0[i]), ev.t,
                pid=T.FL_PID, tid=T.vehicle_tid(i), cat="comm",
                args={"vehicle": i, "edge": e, "nbytes": ev.nbytes})
        if self.metrics is not None:
            self.metrics.counter(
                "fl_uplink_bytes",
                "coded V2X uplink bytes per edge pod").inc(ev.nbytes, edge=e)
        self.edge_buffers[e].append(_Buffered(
            i, self._delta[i], float(self.client_w[i]),
            int(self.base_version[i]), float(self.base_time[i])))
        return self._edge_check(e, ev.t)

    def _edge_check(self, e: int, t: float) -> None:
        """Commit when every current member has arrived; otherwise (async
        only) arm the flush timer so stragglers cannot gate the pod."""
        buf = self.edge_buffers[e]
        if not buf:
            return None
        have = {b.vehicle for b in buf}
        if set(self.topo.edges[e]).issubset(have):
            self._commit(e, t)
        elif self.flush_every is not None and len(buf) == 1:
            self.flush_gen[e] += 1
            self.queue.push(EdgeFlush(t + self.flush_every, e,
                                      self.flush_gen[e]))
        return None

    def _on_flush(self, ev: EdgeFlush) -> None:
        if ev.gen == self.flush_gen[ev.edge] and \
                self.edge_buffers[ev.edge]:
            self._commit(ev.edge, ev.t)
        return None

    def _commit(self, e: int, t: float) -> None:
        entries = self.edge_buffers[e]
        self.edge_buffers[e] = []
        self.flush_gen[e] += 1          # invalidate any armed flush
        if len({b.vehicle for b in entries}) != len(entries):
            raise RuntimeError(
                f"edge pod {e} commit carries a duplicate member — the "
                f"weighted-mean invariant would break: {entries}")
        pos = {v: k for k, v in enumerate(self.topo.edges[e])}
        entries.sort(key=lambda b: pos.get(b.vehicle, self.C + b.vehicle))
        partial, weight = None, float(sum(b.weight for b in entries))
        if self.program is not None:
            import jax
            import jax.numpy as jnp
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs),
                                   *[b.delta for b in entries])
            w_m = jnp.asarray([b.weight for b in entries], jnp.float32)
            partial, weight = self.program.commit(stacked, w_m)
        nbytes = int(self.edge_nbytes_fn(len(entries)))
        cid = self._next_commit
        self._next_commit += 1
        self.commits[cid] = _Commit(
            partial, weight, tuple(b.vehicle for b in entries),
            min(b.base_version for b in entries),
            min(b.base_time for b in entries), nbytes, e, t)
        if self.tracer is not None:
            from repro.obs import trace as T
            for b in entries:
                # arrow from each member's uplink-span end into the
                # backhaul span that starts at the commit time
                self.tracer.flow(
                    "uplink->commit", float(self._uplink_t1[b.vehicle]),
                    T.FL_PID, T.vehicle_tid(b.vehicle),
                    t, T.FL_PID, T.edge_tid(e))
        dt = nbytes / self.topo.backhaul_bw + self.topo.backhaul_latency
        self.queue.push(BackhaulArrived(t + dt, e, cid))

    # ---- cloud tier -----------------------------------------------------
    def _on_backhaul(self, ev: BackhaulArrived) -> Optional[Dict]:
        c = self.commits[ev.commit_id]
        c.t_arrive = ev.t
        self.bytes_backhaul += c.nbytes
        if self.tracer is not None:
            from repro.obs import trace as T
            self.tracer.complete(
                "backhaul", float(c.t_commit), ev.t,
                pid=T.FL_PID, tid=T.edge_tid(c.edge), cat="comm",
                args={"edge": c.edge, "commit": ev.commit_id,
                      "nbytes": c.nbytes, "n_vehicles": len(c.vehicles),
                      "base_version": int(c.base_version)})
        if self.metrics is not None:
            self.metrics.counter(
                "fl_backhaul_bytes",
                "partial-aggregate backhaul bytes per edge pod").inc(
                    c.nbytes, edge=c.edge)
        self.cloud_buffer.append(ev.commit_id)
        if self.clock is None:
            covered = sum(len(self.commits[i].vehicles)
                          for i in self.cloud_buffer)
            if covered == self.C:       # the synchronous barrier
                return self._merge(ev.t)
        return None

    def _on_deadline(self, ev: CloudDeadline) -> Optional[Dict]:
        self.queue.push(CloudDeadline(ev.t + self.clock, ev.index + 1))
        if self.tracer is not None:
            from repro.obs import trace as T
            self.tracer.instant(
                "cloud_deadline", ev.t, pid=T.FL_PID, tid=T.CLOUD_TID,
                cat="clock", args={"index": ev.index,
                                   "pending": len(self.cloud_buffer)})
        if self.cloud_buffer:
            return self._merge(ev.t)
        self._broadcast(range(self.C), ev.t)    # restart idle vehicles
        return None

    def _merge(self, t: float) -> Dict:
        ids = sorted(self.cloud_buffer,
                     key=lambda i: (self.commits[i].edge, i))
        self.cloud_buffer = []
        commits = [self.commits.pop(i) for i in ids]
        from repro.comm.hierarchy import staleness_weights
        if self.clock is None:
            stale = np.ones(len(commits), np.float32)
            lags = np.zeros(len(commits))
        else:
            observed = np.array([c.t_arrive - c.base_time
                                 for c in commits])
            stale = staleness_weights(observed, self.clock,
                                      decay=self.decay)
            lags = np.maximum(0.0, np.ceil(observed / self.clock) - 1.0)
        if self.program is not None:
            import jax.numpy as jnp
            self.global_params = self.program.merge(
                self.global_params,
                tuple(c.partial for c in commits),
                tuple(c.weight for c in commits),
                jnp.asarray(stale))
        self.version += 1
        covered = sum(len(c.vehicles) for c in commits)
        metrics: Dict = {
            "t_sim": float(t),
            "round_version": float(self.version),
            "n_commits": float(len(commits)),
            "n_vehicles": float(covered),
            "staleness_min": float(stale.min()),
            "staleness_mean": float(stale.mean()),
            "lag_max": float(lags.max()),
            "comm_bytes_up": float(self.bytes_up - self._bytes_up_mark),
            "comm_bytes_backhaul": float(
                self.bytes_backhaul - self._bytes_backhaul_mark),
        }
        self._bytes_up_mark = self.bytes_up
        self._bytes_backhaul_mark = self.bytes_backhaul
        if self.tracer is not None:
            from repro.obs import trace as T
            self.tracer.complete(
                "merge", t, t, pid=T.FL_PID, tid=T.CLOUD_TID, cat="merge",
                args={"version": self.version, "n_commits": len(commits),
                      "n_vehicles": covered,
                      "staleness_mean": float(stale.mean()),
                      "lag_max": float(lags.max())})
            for c in commits:
                # arrow from each backhaul-span end into the merge mark
                self.tracer.flow("commit->merge", float(c.t_arrive),
                                 T.FL_PID, T.edge_tid(c.edge),
                                 t, T.FL_PID, T.CLOUD_TID)
            self.tracer.counter(
                "wire bytes", t,
                {"uplink": self.bytes_up, "backhaul": self.bytes_backhaul},
                pid=T.FL_PID)
        if self.metrics is not None:
            self.metrics.counter("fl_merges", "cloud merges").inc()
            h = self.metrics.histogram(
                "fl_observed_staleness_s",
                "commit arrival lag behind its base broadcast (sim s)")
            for c in commits:
                h.observe(float(c.t_arrive - c.base_time))
        for k, v in self.last_metrics.items():
            metrics[k] = v.copy()
        self._broadcast(range(self.C), t)
        return metrics

    # ---- mobility -------------------------------------------------------
    def _on_mobility(self, ev: MobilityTick) -> None:
        self.queue.push(MobilityTick(ev.t + self.migrate_every,
                                     ev.index + 1))
        for i in range(self.C):
            self.mobility.advance(i, self.rng)
            cur = int(self.topo.client_edge[i])
            if self.mobility.out_of_range(i, cur):
                dst = self.mobility.nearest_edge(i)
                if dst != cur and len(self.topo.edges[cur]) > 1:
                    self.queue.push(PodMigration(ev.t, i, cur, dst))
        return None

    def _on_migration(self, ev: PodMigration) -> None:
        i = ev.vehicle
        cur = int(self.topo.client_edge[i])
        if cur != ev.src or len(self.topo.edges[cur]) == 1:
            return None                 # a same-tick migration got there first
        self.topo = self.topo.reassign(i, ev.dst)
        self.n_migrations += 1
        if self.tracer is not None:
            from repro.obs import trace as T
            self.tracer.instant(
                "pod_migration", ev.t, pid=T.FL_PID, tid=T.vehicle_tid(i),
                cat="mobility", args={"src": ev.src, "dst": ev.dst})
        if self.metrics is not None:
            self.metrics.counter(
                "fl_migrations", "completed pod migrations").inc()
        # membership changed: either pod may now be complete
        self._edge_check(ev.src, ev.t)
        self._edge_check(ev.dst, ev.t)
        return None


# ---- timing-only schedule exploration (dryrun) ---------------------------


def simulate_schedule(topology: Topology, *, bytes_per_client: int = 2 ** 21,
                      clock: Optional[float] = None, decay: float = 0.5,
                      compute_flops: float = 4.7e11, jitter: float = 0.0,
                      migrate_every: Optional[float] = None,
                      mobility: Optional[MobilitySpec] = None,
                      rounds: int = 10, seed: int = 0,
                      max_events: int = 1_000_000,
                      tracer=None, metrics=None) -> Dict:
    """Run the event schedule with no tensors — merge cadence, observed
    staleness, and migration counts for a topology + clock, in
    microseconds of host time. Backs ``launch/dryrun.py --async-clock``."""
    if mobility is None and migrate_every is not None:
        mobility = MobilitySpec(seed=seed)
    engine = AsyncHierFLEngine(
        topology, bytes_per_client, lambda m: bytes_per_client,
        compute=ComputeModel(flops=compute_flops, jitter=jitter),
        clock=clock, decay=decay, mobility=mobility,
        migrate_every=migrate_every, seed=seed,
        tracer=tracer, metrics=metrics)
    engine.reset()
    merges: List[Dict] = []
    for _ in range(max_events):
        if len(merges) >= rounds:
            break
        ev = engine.queue.pop()
        if ev is None:
            raise RuntimeError(
                "event queue drained before the schedule finished — the "
                "fabric deadlocked (a pod is waiting on a member that "
                "will never arrive)")
        rec = engine.handle(ev)
        if rec is not None:
            merges.append(rec)
    if len(merges) < rounds:
        raise RuntimeError(
            f"schedule produced only {len(merges)} of {rounds} merges "
            f"within max_events={max_events} — clock too small for the "
            f"fabric's arrival rate?")
    return {
        "merges": merges,
        "sim_time_s": engine.now,
        "mean_period_s": (engine.now / len(merges)) if merges else math.inf,
        "mean_staleness": float(np.mean(
            [m["staleness_mean"] for m in merges])) if merges else 1.0,
        "n_migrations": engine.n_migrations,
        "events": len(engine.event_log),
        "event_log": engine.event_log,
    }
