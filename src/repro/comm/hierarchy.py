"""Two-tier aggregation over an explicit topology (paper §3.1, Fig. 1).

``edge_aggregate`` computes each edge pod's data-volume-weighted partial
average of its members' updates; ``cloud_merge`` combines the edge
partials, optionally down-weighting **stale** edges — the async mode
where the cloud closes a round at a deadline and late edge updates
(predicted from the link models) count for ``decay ** lag``.

With every staleness weight at 1 the two-tier weighted mean is
algebraically the flat weighted mean — ``core.fedavg.fedavg`` delegates
here when given a topology, so flat and hierarchical FedAvg are the same
math on different fabrics.

``make_hier_round`` is the full round the ``hier_fl`` strategy jits:
vmapped local steps, per-client codec roundtrip with error feedback,
edge partial averages, staleness-aware cloud merge, broadcast.

The aggregation is also available split into its event-time halves —
per-pod :func:`edge_commit` (an edge partially averages whatever
members have arrived) and clocked :func:`cloud_merge_at` (the cloud
merges the commits it holds at a deadline, with **observed** staleness
multipliers) — which the discrete-event engine in
:mod:`repro.comm.events` jits piecewise instead of as one fused round.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.codecs import Codec, roundtrip_stacked
from repro.comm.topology import Topology


def edge_commit(member_stacked, member_weights: jnp.ndarray):
    """One pod's partial aggregate: member-stacked [M, ...] tree + [M]
    weights -> (float32 partial-average tree, scalar total weight).

    This is the per-pod piece of :func:`edge_aggregate`, split out so the
    event engine (:mod:`repro.comm.events`) can jit it per pod — an edge
    commits whatever members have arrived, without waiting for the rest
    of the fleet. The returned weight is the members' total, so a
    downstream weighted merge reproduces the global weighted mean.
    """
    wm = jnp.asarray(member_weights, jnp.float32)

    def part(x):
        xm = x.astype(jnp.float32)
        wb = wm.reshape((-1,) + (1,) * (x.ndim - 1))
        return (xm * wb).sum(axis=0) / wm.sum()

    return jax.tree.map(part, member_stacked), wm.sum()


def edge_aggregate(stacked, weights: Optional[jnp.ndarray],
                   topology: Topology, *, validated: bool = False):
    """Client-stacked [C, ...] tree -> (edge-stacked [E, ...] tree,
    [E] edge weights).

    Each edge's partial average is weighted by its members' ``weights``
    (uniform when None); the returned edge weight is the members' total,
    so a downstream weighted merge reproduces the global weighted mean.
    ``validated=True`` skips the host-side per-pod degenerate-weight
    check — pass it when :meth:`Topology.validate_pod_weights` already
    ran at build time (the round builders hoist it out of the per-call
    path).
    """
    C = jax.tree.leaves(stacked)[0].shape[0]
    if C != topology.n_clients:
        raise ValueError(
            f"client axis has {C} entries but the topology declares "
            f"{topology.n_clients} vehicles")
    w = jnp.ones((C,), jnp.float32) if weights is None \
        else jnp.asarray(weights, jnp.float32)
    if weights is not None and not validated:
        topology.validate_pod_weights(w)

    member_idx = topology.member_indices
    commits = [edge_commit(jax.tree.map(lambda x: x[idx], stacked), w[idx])
               for idx in member_idx]

    edge_tree = jax.tree.map(
        lambda leaf, *parts: jnp.stack(parts).astype(leaf.dtype), stacked,
        *[c[0] for c in commits])
    edge_w = jnp.stack([c[1] for c in commits])
    return edge_tree, edge_w


def cloud_merge(edge_stacked, edge_weights: jnp.ndarray,
                staleness: Optional[jnp.ndarray] = None):
    """Edge-stacked [E, ...] tree -> global [...] tree.

    ``staleness``: optional [E] multipliers (1 = fresh); the effective
    weight of a late edge is ``edge_weight * staleness`` before
    normalization, the classic staleness-discounted async merge.
    """
    w = jnp.asarray(edge_weights, jnp.float32)
    if staleness is not None:
        w = w * jnp.asarray(staleness, jnp.float32)

    def merge(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return ((x.astype(jnp.float32) * wb).sum(axis=0)
                / w.sum()).astype(x.dtype)

    return jax.tree.map(merge, edge_stacked)


def cloud_merge_at(global_params, partials, partial_weights,
                   staleness: Optional[jnp.ndarray] = None):
    """The clocked half of the split round: merge committed edge
    partials into the current global params.

    ``partials``: sequence of float32 partial-average trees from
    :func:`edge_commit`; ``partial_weights``: their scalar weights;
    ``staleness``: optional [len(partials)] multipliers from the
    **observed** lag of each commit (1 = landed within the current
    deadline window). Returns the new global params — the merged delta
    applied on top of ``global_params``.
    """
    edge_tree = jax.tree.map(
        lambda g, *parts: jnp.stack(parts).astype(g.dtype), global_params,
        *partials)
    merged = cloud_merge(edge_tree, jnp.stack(
        [jnp.asarray(w, jnp.float32) for w in partial_weights]), staleness)
    return jax.tree.map(
        lambda g, d: (g.astype(jnp.float32) + d).astype(g.dtype),
        global_params, merged)


def pod_slice(stacked, topology: Topology):
    """Client-stacked [C, ...] tree -> edge-stacked [E, ...] tree taking
    each pod's first member.

    Valid whenever pod members hold identical state — the invariant the
    pod-broadcast rounds maintain (every member starts a round from its
    pod's shared adapter/params)."""
    idx = np.asarray([members[0] for members in topology.member_indices])
    return jax.tree.map(lambda x: x[idx], stacked)


def pod_broadcast(edge_stacked, topology: Topology):
    """Edge-stacked [E, ...] tree -> client-stacked [C, ...] tree: every
    vehicle receives its own pod's state (the personalized counterpart
    of ``core.fedavg.broadcast_round``, which sends one global tree to
    all)."""
    ce = np.asarray(topology.client_edge)
    return jax.tree.map(lambda x: x[ce], edge_stacked)


def hierarchical_mean(stacked, weights, topology: Topology,
                      staleness: Optional[jnp.ndarray] = None):
    """Explicit two-tier (edge, then cloud) weighted mean of a
    client-stacked tree — the fabric-aware form of ``fedavg``."""
    edge_tree, edge_w = edge_aggregate(stacked, weights, topology)
    return cloud_merge(edge_tree, edge_w, staleness)


def staleness_weights(arrivals, deadline: float, *,
                      decay: float = 0.5) -> np.ndarray:
    """[E] multipliers from predicted edge arrival times.

    An edge landing within the round ``deadline`` is fresh (1.0); one
    landing during the following round is one round stale (``decay``),
    and so on: ``decay ** ceil(arrival/deadline - 1)``.
    """
    if deadline <= 0:
        raise ValueError(f"deadline must be positive, got {deadline}")
    if not 0.0 < decay <= 1.0:
        raise ValueError(f"decay must be in (0, 1], got {decay}")
    lag = np.maximum(0.0, np.ceil(np.asarray(arrivals, np.float64)
                                  / deadline) - 1.0)
    return (decay ** lag).astype(np.float32)


def make_hier_round(cfg, shape, optimizer, topology: Topology,
                    codec: Codec, *, local_steps: int = 1,
                    remat: bool = False, client_weights=None,
                    staleness: Optional[np.ndarray] = None):
    """One hierarchical FL round over client-stacked params.

    hier_round(client_params, client_opt, batches, residual, key) ->
    (client_params', client_opt', metrics, residual') where ``batches``
    carry [C, E, B, ...] leaves like ``core.fedavg.make_fl_round``,
    ``residual`` is the codec's per-client error-feedback state and
    ``key`` drives the round's stochastic rounding.

    Unlike flat ``make_fl_round``, the aggregation path is the explicit
    fabric: clients transmit **deltas** (w.r.t. the round's broadcast
    params) through the codec, edges partially average the decoded
    deltas, and the cloud merges edge partials — down-weighting stale
    edges when ``staleness`` is given — before re-broadcasting.
    """
    from repro.core.fedavg import (broadcast_round, check_weights,
                                   make_local_train)
    from repro.core.steps import make_train_step

    step = make_train_step(cfg, shape, optimizer, remat=remat)
    w = None if client_weights is None else check_weights(client_weights)
    if w is not None:
        # per-pod degenerate-weight check, hoisted to build time: the
        # weights are static for the round fn's lifetime, so the per-call
        # path below runs with validated=True
        topology.validate_pod_weights(w)
    stale = None if staleness is None else \
        jnp.asarray(staleness, jnp.float32)
    local_train = make_local_train(step)

    def hier_round(client_params, client_opt, batches, residual, key):
        C = jax.tree.leaves(client_params)[0].shape[0]
        if w is not None and w.shape != (C,):
            raise ValueError(
                f"client_weights has shape {w.shape}, expected ({C},)")
        # round-start broadcast state: all clients hold the same params
        global_params = jax.tree.map(lambda x: x[0], client_params)
        params, opts, metrics = jax.vmap(local_train)(client_params,
                                                      client_opt, batches)
        deltas = jax.tree.map(
            lambda after, g: after.astype(jnp.float32) - g[None], params,
            global_params)
        decoded, residual = roundtrip_stacked(codec, deltas, residual, key)
        edge_tree, edge_w = edge_aggregate(decoded, w, topology,
                                           validated=True)
        merged = cloud_merge(edge_tree, edge_w, stale)
        new_global = jax.tree.map(
            lambda g, d: (g.astype(jnp.float32) + d).astype(g.dtype),
            global_params, merged)
        new_clients = broadcast_round(new_global, C)
        return new_clients, opts, metrics, residual

    return hier_round
