"""repro.api — the one front door to FLAD's training/serving system.

Quickstart::

    from repro.api import MeshSpec, Session

    # FHDP-train the vision encoder on a 2x4 (clients x stages) mesh
    out = Session("flad-vision", strategy="pipeline",
                  mesh=MeshSpec((2, 4))).run(steps=50)

    # one hierarchical-FedAvg round per `run` step
    Session("flad-vision", strategy="fedavg", local_steps=2).run(steps=10)

    # edge AD-LLM serving (prefill + decode, paper Fig. 2)
    Session("flad-adllm", strategy="tensor").serve(requests=3)

    # compile-only dry-run on the 256-chip production mesh
    Session("qwen3-14b", shape="train_4k", full=True,
            mesh=MeshSpec(production=True)).lower().compile()

See :mod:`repro.api.session` for the Session surface,
:mod:`repro.api.strategies` for the strategy registry, and the top-level
README for the full tour.
"""
from repro.api.mesh import AXES, MeshSpec, ensure_host_devices
from repro.api.session import Session, load_config, resolve_shape
from repro.api.strategies import (Strategy, available_strategies,
                                  get_strategy, register_strategy)
from repro.train.loop import LoopHooks

__all__ = [
    "AXES", "LoopHooks", "MeshSpec", "Session", "Strategy",
    "available_strategies", "ensure_host_devices", "get_strategy",
    "load_config", "register_strategy", "resolve_shape",
]
