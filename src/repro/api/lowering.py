"""Abstract lowering of strategy steps — the dry-run / compile-only path.

``build_lowered`` lowers the (train|prefill|decode) step for an
(arch x shape x mesh x strategy) without allocating parameters, so
production meshes (256/512 chips) can be compile-checked on a laptop.
Formerly private to ``launch/dryrun.py``; now it backs ``Session.lower``
and the dry-run launcher alike.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig
from repro.configs.common import input_specs, state_specs
from repro.core import act_sharding, sharding as shd
from repro.core.steps import (abstract_opt_state, abstract_params,
                              make_prefill_step, make_serve_step,
                              make_train_step)
from repro.train.optimizer import Adam


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


class _null_ctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


# per-arch overrides found by the §Perf hillclimb (EXPERIMENTS.md):
# qwen2.5-32b fits at accum=1 (13.8 GiB), halving FSDP re-gathers
# (collective term 24.7s -> 14.3s); yi-34b / qwen3-32b do not (16.1-18.6).
HILLCLIMBED_ACCUM = {"qwen2.5-32b": 1}


def default_grad_accum(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Smallest microbatching for which train activations fit 16 GiB HBM
    (each accumulation step re-gathers FSDP weights, so less is more)."""
    if shape.kind != "train":
        return 1
    if cfg.name in HILLCLIMBED_ACCUM:
        return HILLCLIMBED_ACCUM[cfg.name]
    if cfg.moe.num_experts and cfg.d_model >= 6144:
        return 4                       # dbrx-class
    if cfg.param_count() > 20e9 or cfg.prefix_tokens \
            or cfg.family == "encdec" or cfg.moe.num_experts:
        return 2
    return 1


def build_lowered(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                  strategy: str = "tensor", seq_shard: bool = True,
                  fsdp: bool = True, remat: bool = True,
                  grad_accum: Optional[int] = None):
    """Lower the (train|prefill|serve) step for this shape on this mesh."""
    if strategy == "pipeline":
        from repro.core.fhdp import build_pipeline_lowered
        return build_pipeline_lowered(cfg, shape, mesh, remat=remat)
    if strategy != "tensor":
        raise ValueError(
            f"build_lowered supports the 'tensor' and 'pipeline' "
            f"strategies, not {strategy!r} (FL round lowering is the "
            f"same step vmapped over clients)")

    params_abs = abstract_params(cfg)
    pspecs = shd.param_specs(mesh, params_abs, fsdp=fsdp)
    psh = _named(mesh, pspecs)
    batch_abs = input_specs(cfg, shape)
    bsh = _named(mesh, shd.batch_specs(mesh, batch_abs))

    rules = act_sharding.rules_for(mesh, shape.kind) if seq_shard else {}
    ctx = act_sharding.act_rules(**rules) if rules else _null_ctx()

    if shape.kind == "train":
        opt = Adam()
        opt_abs = abstract_opt_state(params_abs, opt)
        osh = _named(mesh, shd.param_specs(mesh, opt_abs, fsdp=fsdp))
        if grad_accum is None:
            grad_accum = default_grad_accum(cfg, shape)
        step = make_train_step(cfg, shape, opt, remat=remat,
                               grad_accum=grad_accum)
        with ctx:
            return jax.jit(step, in_shardings=(psh, osh, bsh),
                           out_shardings=(psh, osh, None),
                           donate_argnums=(0, 1)) \
                .lower(params_abs, opt_abs, batch_abs)

    st_abs = state_specs(cfg, shape)
    ssh = _named(mesh, shd.state_specs_sharding(mesh, st_abs))
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, shape)
        with ctx:
            return jax.jit(step, in_shardings=(psh, bsh, ssh),
                           out_shardings=(None, ssh),
                           donate_argnums=(2,)) \
                .lower(params_abs, batch_abs, st_abs)

    # decode: one new token against the cache/state
    step = make_serve_step(cfg, shape)
    tok_abs = input_specs(cfg, shape)["tokens"]
    tsh = _named(mesh, shd.batch_specs(mesh, {"t": tok_abs})["t"])
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    with ctx:
        return jax.jit(step,
                       in_shardings=(psh, tsh, ssh,
                                     NamedSharding(mesh, P())),
                       out_shardings=(None, ssh),
                       donate_argnums=(2,)) \
            .lower(params_abs, tok_abs, st_abs, pos_abs)
