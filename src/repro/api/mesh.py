"""Declarative mesh construction for :mod:`repro.api`.

``MeshSpec`` replaces the per-launcher ``--mesh 2,4`` string parsing and
manual ``XLA_FLAGS`` device forcing. A spec is plain data: it can be built
before jax touches any device, so the host-device forcing (needed for CPU
testing of multi-client meshes) happens at exactly the right moment —
before the first backend init — no matter which entrypoint runs first.

FLAD axis mapping (see :mod:`repro.launch.mesh`): ``pod`` = cloud regions,
``data`` = vehicles / edge FL clients, ``model`` = intra-cluster
pipeline/tensor ranks.
"""
from __future__ import annotations

import dataclasses
import math
import os
import re
from typing import Optional, Sequence, Tuple, Union

AXES = ("pod", "data", "model")

_FORCE_RE = re.compile(r"--xla_force_host_platform_device_count=(\d+)")

# set once the first mesh is built (jax locks the device count at first
# backend init; after that forcing is verification-only)
_devices_locked = False


def ensure_host_devices(n: int) -> None:
    """Force at least ``n`` host (CPU) devices before the first backend init.

    Safe to call repeatedly and on real accelerators: the flag only affects
    the host platform, and once jax has initialized this degrades to an
    assertion that enough devices exist.
    """
    global _devices_locked
    if n <= 0:
        return
    if not _devices_locked:
        flags = os.environ.get("XLA_FLAGS", "")
        m = _FORCE_RE.search(flags)
        current = int(m.group(1)) if m else 0
        if current < n:
            flags = _FORCE_RE.sub("", flags).strip()
            os.environ["XLA_FLAGS"] = (
                flags + f" --xla_force_host_platform_device_count={n}"
            ).strip()
    import jax

    have = len(jax.devices())
    _devices_locked = True
    if have < n:
        raise RuntimeError(
            f"need {n} devices, have {have}; jax locks the device count at "
            f"first backend use — build the Session/MeshSpec (or call "
            f"ensure_host_devices) before any other jax device access")


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Declarative mesh: dims + axis names + (optional) device forcing.

    ``dims``     trailing-aligned against ``(pod, data, model)`` unless
                 ``axes`` is given: ``(2, 4)`` -> data=2, model=4.
    ``devices``  None (default) forces ``prod(dims)`` host devices on CPU;
                 0 disables forcing (use whatever jax already has);
                 N forces at least N.
    ``production``/``multi_pod`` select the deployment meshes from
                 :func:`repro.launch.mesh.make_production_mesh`.
    """

    dims: Tuple[int, ...] = (2, 4)
    axes: Optional[Tuple[str, ...]] = None
    devices: Optional[int] = None
    production: bool = False
    multi_pod: bool = False

    @classmethod
    def parse(cls, spec: Union["MeshSpec", str, Sequence[int], None], *,
              devices: Optional[int] = None) -> "MeshSpec":
        """Coerce ``--mesh``-style input ('2,4', (2, 4), MeshSpec, None)."""
        if spec is None:
            return cls(devices=devices)
        if isinstance(spec, MeshSpec):
            return spec if devices is None else \
                dataclasses.replace(spec, devices=devices)
        try:
            if isinstance(spec, str):
                dims = tuple(int(x) for x in spec.split(","))
            else:
                dims = tuple(int(x) for x in spec)
        except (TypeError, ValueError):
            raise ValueError(
                f"mesh spec {spec!r}: expected comma-separated ints like "
                f"'2,4' (data,model) or '2,4,4' (pod,data,model)") from None
        if not 1 <= len(dims) <= len(AXES):
            raise ValueError(f"mesh dims {dims}: want 1..{len(AXES)} axes")
        return cls(dims=dims, devices=devices)

    @property
    def size(self) -> int:
        if self.production:
            from repro.launch.mesh import PRODUCTION_SHAPES
            return math.prod(PRODUCTION_SHAPES[self.multi_pod])
        return math.prod(self.dims)

    @property
    def axis_names(self) -> Tuple[str, ...]:
        if self.production:
            return AXES if self.multi_pod else AXES[1:]
        return self.axes or AXES[-len(self.dims):]

    def build(self):
        """Materialize the jax Mesh (forcing host devices if requested)."""
        from repro.launch.mesh import make_mesh, make_production_mesh

        force = self.size if self.devices is None else self.devices
        ensure_host_devices(force)
        if self.production:
            return make_production_mesh(multi_pod=self.multi_pod)
        return make_mesh(tuple(self.dims), self.axis_names)
