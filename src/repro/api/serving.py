"""Prefill + decode serving path (paper Fig. 2 inference procedure).

Vehicles send vision features to the edge; the edge AD-LLM prefills the
feature+instruction context once and then decodes waypoint tokens against
the KV cache. :func:`serve_requests` is the batched request driver behind
``Session.serve`` — the logic formerly hand-wired in ``launch/serve.py``.

Throughput is reported two ways: ``tokens_per_s`` spans every request
batch (the first one pays jit compilation, so the number is pessimistic
and hardware-dependent), while ``warm_tokens_per_s`` is timed from the
second batch onward — the steady-state figure the serving benchmarks
compare against. With a single batch there is no warm region and
``warm_tokens_per_s`` falls back to the cold number.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig


def _make_sampler(sampling: str, temperature: float):
    """sampler(logits [B, 1, V], key) -> [B, 1] int32. The greedy path
    ignores its key so the legacy key-split sequence (and therefore the
    generated streams) stays bit-identical."""
    if sampling == "greedy":
        def sample(logits, key):
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    elif sampling == "temperature":
        t = float(temperature)

        def sample(logits, key):
            return jax.random.categorical(
                key, logits / t, axis=-1).astype(jnp.int32)
    else:
        raise ValueError(f"unknown sampling {sampling!r} "
                         "(greedy|temperature)")
    return sample


def serve_requests(cfg: ModelConfig, *, batch: int = 8, context: int = 64,
                   decode_steps: int = 16, requests: int = 3,
                   params=None, key=None, sampling: str = "greedy",
                   temperature: float = 1.0,
                   log_fn: Optional[Callable] = print) -> Dict:
    """Serve ``requests`` batches: one prefill + ``decode_steps`` decodes.

    ``params`` defaults to a fresh ``model.init`` (smoke serving); pass the
    merged params of a trained session to serve a real model. Returns the
    generated sequences plus token-throughput accounting (cold and warm).

    ``sampling="temperature"`` draws each step's tokens from the scaled
    softmax using a dedicated key stream folded from the request key —
    the greedy path performs exactly the legacy key operations, so greedy
    output is bit-identical to pre-sampling builds.
    """
    from repro.core.steps import make_prefill_step, make_serve_step
    from repro.models import build_model

    shape = ShapeConfig("serve", context + decode_steps, batch, "decode")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0) if key is None else key
    if params is None:
        key, init_key = jax.random.split(key)
        params = model.init(init_key)
    prefill = jax.jit(make_prefill_step(cfg, shape))
    serve = jax.jit(make_serve_step(cfg, shape))
    sample = _make_sampler(sampling, temperature)

    sequences = []
    total_toks = 0
    warm_toks = 0
    warm_dt = 0.0
    t0 = time.time()
    for r in range(requests):
        t_req = time.time()
        key, k1 = jax.random.split(key)
        ctx = jax.random.randint(k1, (batch, context), 0,
                                 cfg.vocab_size, jnp.int32)
        state = model.init_state(batch, shape.seq_len)
        req = {"tokens": ctx}
        if cfg.family == "encdec":
            req = {"frames": jax.random.normal(
                k1, (batch, context, cfg.prefix_dim)), "tokens": ctx}
        logits, state = prefill(params, req, state)
        tok = sample(logits[:, -1:], jax.random.fold_in(k1, 0))
        out = [tok]
        for i in range(decode_steps):
            logits, state = serve(params, tok, state, context + i)
            tok = sample(logits[:, -1:], jax.random.fold_in(k1, i + 1))
            out.append(tok)
        seqs = jnp.concatenate(out, axis=1)
        seqs.block_until_ready()
        sequences.append(seqs)
        total_toks += int(seqs.size)
        if r > 0:                      # batch 0 pays jit compilation
            warm_toks += int(seqs.size)
            warm_dt += time.time() - t_req
        if log_fn:
            log_fn(f"[serve] request batch {r}: generated {seqs.shape} "
                   f"first row: {seqs[0, :8].tolist()}")
    dt = time.time() - t0
    warm_tps = (warm_toks / warm_dt) if warm_dt > 0 else total_toks / dt
    if log_fn:
        log_fn(f"[serve] {total_toks} tokens in {dt:.2f}s "
               f"({total_toks / dt:.1f} tok/s incl. compile, "
               f"{warm_tps:.1f} tok/s warm)")
    return {"sequences": sequences, "total_tokens": total_toks,
            "seconds": dt, "tokens_per_s": total_toks / dt,
            "warm_tokens_per_s": warm_tps}
