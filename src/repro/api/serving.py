"""Prefill + decode serving path (paper Fig. 2 inference procedure).

Vehicles send vision features to the edge; the edge AD-LLM prefills the
feature+instruction context once and then decodes waypoint tokens against
the KV cache. :func:`serve_requests` is the batched request driver behind
``Session.serve`` — the logic formerly hand-wired in ``launch/serve.py``.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig


def serve_requests(cfg: ModelConfig, *, batch: int = 8, context: int = 64,
                   decode_steps: int = 16, requests: int = 3,
                   params=None, key=None,
                   log_fn: Optional[Callable] = print) -> Dict:
    """Serve ``requests`` batches: one prefill + ``decode_steps`` decodes.

    ``params`` defaults to a fresh ``model.init`` (smoke serving); pass the
    merged params of a trained session to serve a real model. Returns the
    generated sequences plus token-throughput accounting.
    """
    from repro.core.steps import make_prefill_step, make_serve_step
    from repro.models import build_model

    shape = ShapeConfig("serve", context + decode_steps, batch, "decode")
    model = build_model(cfg)
    key = jax.random.PRNGKey(0) if key is None else key
    if params is None:
        key, init_key = jax.random.split(key)
        params = model.init(init_key)
    prefill = jax.jit(make_prefill_step(cfg, shape))
    serve = jax.jit(make_serve_step(cfg, shape))

    sequences = []
    total_toks = 0
    t0 = time.time()
    for r in range(requests):
        key, k1 = jax.random.split(key)
        ctx = jax.random.randint(k1, (batch, context), 0,
                                 cfg.vocab_size, jnp.int32)
        state = model.init_state(batch, shape.seq_len)
        req = {"tokens": ctx}
        if cfg.family == "encdec":
            req = {"frames": jax.random.normal(
                k1, (batch, context, cfg.prefix_dim)), "tokens": ctx}
        logits, state = prefill(params, req, state)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [tok]
        for i in range(decode_steps):
            logits, state = serve(params, tok, state, context + i)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(tok)
        seqs = jnp.concatenate(out, axis=1)
        sequences.append(seqs)
        total_toks += int(seqs.size)
        if log_fn:
            log_fn(f"[serve] request batch {r}: generated {seqs.shape} "
                   f"first row: {seqs[0, :8].tolist()}")
    dt = time.time() - t0
    if log_fn:
        log_fn(f"[serve] {total_toks} tokens in {dt:.2f}s "
               f"({total_toks / dt:.1f} tok/s incl. compile)")
    return {"sequences": sequences, "total_tokens": total_toks,
            "seconds": dt, "tokens_per_s": total_toks / dt}
