"""Strategy protocol + registry — the pluggable heart of :mod:`repro.api`.

A :class:`Strategy` owns the three things every FLAD execution mode needs:

  * ``init(cfg, shape, mesh, key) -> (params_like, opt_like)`` — materialize
    trainable state on the mesh in the strategy's layout;
  * ``make_step(cfg, shape, mesh) -> step`` — the jitted
    ``(params, opt, batch) -> (params, opt, metrics)`` update (a whole
    FedAvg round for the ``round``-loop strategies);
  * its sharding specs (:meth:`Strategy.param_specs`) and a
    :meth:`Strategy.merge_params` view collapsing the layout back to flat
    model params (for backup / eval / serving).

Registered strategies:

  ``tensor``          datacenter-style SPMD baseline (FedSGD gradient mean)
  ``pipeline``        FHDP — FL data columns x pipeline stages (paper §4)
  ``fedavg``          hierarchical FedAvg over client-stacked flat params
  ``fl_pipeline``     FedAvg rounds of FHDP-pipelined local steps (Fig. 1)
  ``swift_pipeline``  FHDP whose stage templates come from the SWIFT
                      scheduler over a declared heterogeneous fleet, with
                      pre-generated departure templates for live dynamic
                      repartitioning (paper §4.1.3 + §4.2)
  ``hier_fl``         FedAvg rounds over the explicit vehicle->edge->cloud
                      fabric (:mod:`repro.comm`): compressed uplinks
                      (int8 / top-k codecs with error feedback), edge
                      partial averages, staleness-aware cloud merge, and
                      per-round bytes-on-wire + simulated round time from
                      the topology's link models
  ``async_hier_fl``   the same fabric driven in event time
                      (:mod:`repro.comm.events`): edges commit partial
                      aggregates as members arrive, the cloud merges on a
                      configurable clock with **observed** staleness lags,
                      and vehicles migrate between edge pods mid-run; with
                      an infinite deadline, zero jitter, and no migrations
                      it reproduces ``hier_fl`` bit for bit
  ``distill_fl``      federated personalized distillation (paper
                      §3.3/§5.2): a frozen cloud AD-LLM teaches per-pod
                      LoRA students on non-IID pod partitions, and only
                      (A, B) adapter deltas ride the ``hier_fl`` fabric —
                      orders of magnitude fewer uplink bytes per round

New execution modes plug in via :func:`register_strategy` instead of
another bespoke launcher.
"""
from __future__ import annotations

import abc
import math
from typing import Any, Callable, Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ShapeConfig
from repro.configs.common import concrete_batch

_REGISTRY: Dict[str, Type["Strategy"]] = {}


def register_strategy(name: str) -> Callable[[type], type]:
    """Class decorator adding a Strategy to the registry under ``name``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_strategy(name: str, **options) -> "Strategy":
    """Instantiate a registered strategy; unknown names list valid ones."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; available: "
            f"{', '.join(available_strategies())}") from None
    return cls(**options)


def _fl_client_count(mesh) -> int:
    """Clients = product of the FL axes present on the mesh (pod x data)."""
    return math.prod(mesh.shape[a] for a in ("pod", "data")
                     if a in mesh.shape)


def _stacked_batch(cfg, shape, key, lead: Tuple[int, ...]):
    """Synthetic batch with extra leading axes (clients/local-steps)."""
    n = math.prod(lead)
    keys = jax.random.split(key, n)
    parts = [concrete_batch(cfg, shape, k) for k in keys]
    return jax.tree.map(
        lambda *xs: jnp.stack(xs).reshape(lead + xs[0].shape), *parts)


class Strategy(abc.ABC):
    """One way to realize FLAD training on a mesh (see module docstring)."""

    name: str = ""
    #: which driver Session.run uses: "step" -> train_loop, "round" -> fl_loop
    loop: str = "step"

    def __init__(self, *, learning_rate: float = 1e-3):
        self.learning_rate = learning_rate

    @abc.abstractmethod
    def init(self, cfg: ModelConfig, shape: ShapeConfig, mesh, key
             ) -> Tuple[Any, Any]:
        """Materialize (params_like, opt_like) in this strategy's layout."""

    @abc.abstractmethod
    def make_step(self, cfg: ModelConfig, shape: ShapeConfig, mesh
                  ) -> Callable:
        """Jitted (params, opt, batch) -> (params, opt, metrics)."""

    def param_specs(self, cfg: ModelConfig, mesh):
        """PartitionSpec tree for this strategy's parameter layout."""
        raise NotImplementedError(f"{self.name} has no sharding specs")

    def merge_params(self, state, cfg: Optional[ModelConfig] = None):
        """Collapse strategy state to flat model params (backup/eval view)."""
        return state[0]

    def default_batch(self, cfg: ModelConfig, shape: ShapeConfig, mesh, key):
        """One synthetic batch/round-batch matching ``make_step``'s input."""
        return concrete_batch(cfg, shape, key)


@register_strategy("tensor")
class TensorStrategy(Strategy):
    """SPMD data/tensor-parallel baseline; FedSGD via implicit grad mean."""

    loop = "step"

    def __init__(self, *, learning_rate: float = 1e-3, remat: bool = True,
                 grad_accum: int = 1, fsdp: bool = True):
        super().__init__(learning_rate=learning_rate)
        self.remat = remat
        self.grad_accum = grad_accum
        self.fsdp = fsdp

    def _optimizer(self):
        from repro.train.optimizer import Adam
        return Adam(lr=self.learning_rate)

    def init(self, cfg, shape, mesh, key):
        from repro.models import build_model
        model = build_model(cfg)
        params = model.init(key)
        return params, self._optimizer().init(params)

    def make_step(self, cfg, shape, mesh):
        from repro.core.steps import make_train_step
        return jax.jit(make_train_step(cfg, shape, self._optimizer(),
                                       remat=self.remat,
                                       grad_accum=self.grad_accum))

    def param_specs(self, cfg, mesh):
        from repro.core import sharding as shd
        from repro.core.steps import abstract_params
        return shd.param_specs(mesh, abstract_params(cfg), fsdp=self.fsdp)


@register_strategy("pipeline")
class PipelineStrategy(Strategy):
    """FHDP: FL columns (data axis) x pipeline stages (model axis)."""

    loop = "step"

    def __init__(self, *, learning_rate: float = 1e-3, remat: bool = True,
                 templates: Optional[Dict] = None,
                 microbatches: Optional[int] = None):
        super().__init__(learning_rate=learning_rate)
        self.remat = remat
        self.templates = templates
        self.microbatches = microbatches
        self.helpers: Optional[Dict] = None

    def resolve_templates(self, cfg, mesh) -> Dict:
        """Stage templates are shared by init and make_step — pin them."""
        if self.templates is None:
            from repro.core import pipeline as pl
            self.templates = pl.make_templates(cfg, mesh.shape["model"])
        return self.templates

    def init(self, cfg, shape, mesh, key):
        from repro.core.fhdp import init_fhdp
        pp, opt, templates = init_fhdp(
            cfg, mesh, key, templates=self.resolve_templates(cfg, mesh))
        self.templates = templates
        return pp, opt

    def make_step(self, cfg, shape, mesh):
        from repro.core import pipeline as pl
        step, h = pl.make_fhdp_train_step(
            cfg, shape, mesh, learning_rate=self.learning_rate,
            remat=self.remat, templates=self.resolve_templates(cfg, mesh),
            microbatches=self.microbatches)
        self.helpers = h
        return jax.jit(step)

    def param_specs(self, cfg, mesh):
        if self.helpers is None:
            raise RuntimeError(
                "pipeline sharding specs come from the step builder; call "
                "make_step (or Session.build) first")
        return self.helpers["pspec"]

    def merge_params(self, state, cfg=None):
        from repro.core import pipeline as pl
        return pl.merge_stage_params(state[0], self.templates)


@register_strategy("swift_pipeline")
class SwiftPipelineStrategy(PipelineStrategy):
    """FHDP with SWIFT-scheduled stage templates + live repartitioning.

    Closes the scheduler -> runtime loop: model units come from the cost
    model (:func:`repro.sched.costmodel.model_units`), SWIFT schedules the
    declared heterogeneous ``fleet`` over them, the winning pipeline is
    bridged to a per-stack stage template for the FHDP step, and departure
    templates are pre-generated (paper §4.2) so a mid-run vehicle
    departure swaps templates via :class:`repro.recovery.recover
    .Repartitioner` instead of replanning.

    ``fleet``: "nano*4,agx*2"-style preset string, spec dicts, or
    :class:`~repro.sched.costmodel.Vehicle` list (see ``parse_fleet``).
    """

    loop = "step"

    def __init__(self, *, learning_rate: float = 1e-3, remat: bool = True,
                 microbatches: Optional[int] = None,
                 fleet="nano*4,agx*2", seq_len: int = 512,
                 cost=None, agent=None):
        super().__init__(learning_rate=learning_rate, remat=remat,
                         templates=None, microbatches=microbatches)
        from repro.sched.costmodel import CostParams, parse_fleet
        self.vehicles = parse_fleet(fleet)
        self.seq_len = seq_len
        self.cost = cost or CostParams()
        self.agent = agent
        self.units = None
        self.swift_result = None
        self.active_pipeline = None
        self.template_set = None
        self._cfg = None
        self._stages: Optional[int] = None

    # ---- scheduling -------------------------------------------------------
    def schedule(self, cfg: ModelConfig, stages: int):
        """Run SWIFT once over (fleet x model units) and pre-generate the
        departure templates; cached for the strategy's lifetime."""
        if self.swift_result is not None:
            return self.swift_result
        from repro.core.pipeline import get_adapter
        from repro.recovery.templates import TemplateSet, pregenerate
        from repro.sched.costmodel import model_units
        from repro.sched.swift import swift, units_to_layer_template
        self._cfg, self._stages = cfg, stages
        n_units = sum(get_adapter(cfg).counts(cfg).values())
        self.units = model_units(cfg, seq_len=self.seq_len,
                                 num_units=n_units)
        self.swift_result = swift(self.vehicles, self.units,
                                  agent=self.agent, cp=self.cost)
        candidates = [self.swift_result.initial] \
            + list(self.swift_result.essential.values())
        feasible = []
        for pipe in candidates:
            if pipe is None:
                continue
            try:
                units_to_layer_template(pipe, stages)
            except ValueError:
                continue        # cannot fold onto this SPMD width
            feasible.append(pipe)
        if not feasible:
            raise ValueError(
                f"SWIFT found no pipeline for {len(self.vehicles)} vehicles "
                f"x {len(self.units)} units that maps onto {stages} SPMD "
                f"stages; grow the fleet's memory or the mesh's model axis")
        self.active_pipeline = min(feasible, key=lambda p: p.time)
        try:
            ts = pregenerate(
                self.vehicles, self.units, self.cost, agent=self.agent,
                active=self.active_pipeline)
            on_dep = self._foldable_only(ts.on_departure)
        except ValueError:
            on_dep = {}
        self.template_set = TemplateSet(self.active_pipeline, on_dep)
        return self.swift_result

    def _foldable_only(self, on_departure):
        """Drop (-> None) departure pipelines that cannot fold onto the
        SPMD width NOW, so an unrecoverable departure is reported as 'no
        feasible template' up front instead of crashing mid-training."""
        from repro.sched.swift import units_to_layer_template
        out = {}
        for vid, pipe in on_departure.items():
            if pipe is not None:
                try:
                    units_to_layer_template(pipe, self._stages)
                except ValueError:
                    pipe = None
            out[vid] = pipe
        return out

    def resolve_templates(self, cfg, mesh) -> Dict:
        if self.templates is None:
            from repro.core.pipeline import template_from_sequence
            from repro.sched.swift import units_to_layer_template
            stages = mesh.shape["model"]
            self.schedule(cfg, stages)
            seq = units_to_layer_template(self.active_pipeline, stages)
            self.templates = template_from_sequence(cfg, seq)
        return self.templates

    # ---- live-repartition protocol (recovery.recover.Repartitioner) -------
    def departure_template(self, vid: int):
        """(per-stack templates, pipeline) pre-generated for ``vid``'s
        departure — the paper's template lookup, no replanning."""
        if self.template_set is None:
            raise RuntimeError("schedule() has not run; build the session "
                               "(resolve_templates) first")
        pipe = self.template_set.on_departure.get(vid)
        if pipe is None:
            raise ValueError(
                f"no feasible pre-generated template for the departure of "
                f"vehicle {vid} (remaining fleet cannot host the model)")
        from repro.core.pipeline import template_from_sequence
        from repro.sched.swift import units_to_layer_template
        seq = units_to_layer_template(pipe, self._stages)
        return template_from_sequence(self._cfg, seq), pipe

    def adopt_departure(self, vid: int, pipe) -> None:
        """Commit a departure: shrink the fleet, promote ``pipe`` to
        active, and refresh the preventive templates for the remaining
        fleet (the paper's concurrent template regeneration)."""
        from repro.recovery.templates import TemplateSet, pregenerate
        self.vehicles = [v for v in self.vehicles if v.vid != vid]
        self.active_pipeline = pipe
        on_dep = {}
        if len(self.vehicles) >= 2:
            try:
                on_dep = self._foldable_only(
                    pregenerate(self.vehicles, self.units, self.cost,
                                agent=self.agent, active=pipe).on_departure)
            except ValueError:
                on_dep = {}
        self.template_set = TemplateSet(pipe, on_dep)


def _abstract_init(cfg):
    from repro.core.steps import abstract_params
    return abstract_params(cfg)


@register_strategy("fedavg")
class FedAvgStrategy(Strategy):
    """Hierarchical FedAvg over client-stacked flat params (paper §3.1)."""

    loop = "round"

    def __init__(self, *, learning_rate: float = 1e-3, local_steps: int = 1,
                 clients: int = 0, remat: bool = False,
                 client_weights: Optional[Any] = None):
        super().__init__(learning_rate=learning_rate)
        self.local_steps = local_steps
        self.clients = clients
        self.remat = remat
        #: [C] aggregation weights (paper: data-volume weighted); None=mean
        self.client_weights = client_weights

    def _optimizer(self):
        from repro.train.optimizer import Adam
        return Adam(lr=self.learning_rate)

    def n_clients(self, mesh) -> int:
        if self.clients:
            return self.clients
        if not any(a in mesh.shape for a in ("pod", "data")):
            raise ValueError(
                f"fedavg derives the client count from the mesh's FL axes "
                f"(pod/data) but this mesh only has {tuple(mesh.shape)}; "
                f"pass clients=N or use a mesh with a 'data' axis")
        return _fl_client_count(mesh)

    def init(self, cfg, shape, mesh, key):
        from repro.core.fedavg import stack_clients
        from repro.models import build_model
        opt = self._optimizer()
        params0 = build_model(cfg).init(key)
        cp = stack_clients(params0, self.n_clients(mesh))
        return cp, jax.vmap(opt.init)(cp)

    def make_step(self, cfg, shape, mesh):
        from repro.core.fedavg import make_fl_round
        return jax.jit(make_fl_round(cfg, shape, self._optimizer(),
                                     local_steps=self.local_steps,
                                     remat=self.remat,
                                     client_weights=self.client_weights))

    def param_specs(self, cfg, mesh):
        from repro.core.fedavg import client_specs
        return client_specs(mesh, _abstract_init(cfg))

    def merge_params(self, state, cfg=None):
        from repro.core.fedavg import fedavg
        w = None if self.client_weights is None else \
            jnp.asarray(self.client_weights, jnp.float32)
        return fedavg(state[0], weights=w)

    def default_batch(self, cfg, shape, mesh, key):
        return _stacked_batch(cfg, shape, key,
                              (self.n_clients(mesh), self.local_steps))


@register_strategy("hier_fl")
class HierFLStrategy(FedAvgStrategy):
    """FedAvg rounds over the explicit comm fabric (paper §3.1, Fig. 1).

    Clients transmit round deltas through a lossy ``codec`` (with
    error-feedback residuals), edge pods partially average the decoded
    updates, and the cloud merges edge partials — down-weighting edges
    the link models predict to miss the round deadline when
    ``async_decay`` is set. Bytes-on-wire and the simulated round time
    ride along in every round's metrics (and reach
    ``LoopHooks.on_round``).

    ``topology``: a :class:`repro.comm.Topology` or an ``"E@FLEET"``
    spec like ``"2@nano*2,agx*2"`` (2 edge pods over that fleet);
    the client count comes from the topology's vehicle head count.
    ``codec``: ``none`` | ``int8`` | ``topk`` (see
    :mod:`repro.comm.codecs`), options via ``codec_options``.
    """

    loop = "round"

    def __init__(self, *, learning_rate: float = 1e-3, local_steps: int = 1,
                 remat: bool = False, topology="2@nano*2,agx*2",
                 codec: str = "none",
                 codec_options: Optional[Dict] = None,
                 client_weights: Optional[Any] = None,
                 async_decay: Optional[float] = None,
                 async_deadline: Optional[float] = None,
                 seed: int = 0):
        from repro.comm.codecs import Codec, get_codec
        from repro.comm.topology import parse_topology
        self.topology = parse_topology(topology)
        super().__init__(learning_rate=learning_rate,
                         local_steps=local_steps,
                         clients=self.topology.n_clients, remat=remat,
                         client_weights=client_weights)
        self.codec = codec if isinstance(codec, Codec) \
            else get_codec(codec, **(codec_options or {}))
        if async_deadline is not None and async_decay is None:
            raise ValueError(
                "async_deadline only affects the staleness-aware async "
                "merge; set async_decay to enable it")
        self.async_decay = async_decay
        self.async_deadline = async_deadline
        #: fallback PRNG seed for the codec's stochastic rounding when
        #: make_step runs without init(); under Session the stream is
        #: derived from the session's init key (Session(seed=...))
        self.seed = seed
        self.comm_stats: Optional[Dict] = None
        self._residual = None
        self._key = None

    def _wire_tree(self, cfg):
        """The abstract tree whose bytes ride the uplink (full params
        here; ``distill_fl`` overrides with the LoRA factor tree)."""
        return _abstract_init(cfg)

    def _round_stats(self, cfg) -> Dict:
        """Static per-round wire accounting from the link models."""
        from repro.comm.codecs import tree_edge_nbytes, tree_nbytes
        from repro.comm.hierarchy import staleness_weights
        ptree = self._wire_tree(cfg)
        per_client = tree_nbytes(self.codec, ptree)
        per_edge = [tree_edge_nbytes(self.codec, ptree, len(members))
                    for members in self.topology.edges]
        stats = self.topology.hier_round_stats(per_client, per_edge)
        stats["bytes_per_client"] = per_client
        if self.async_decay is not None:
            # async mode: the cloud closes the round at the deadline
            # (default: the median edge arrival) and discounts the rest
            deadline = self.async_deadline \
                if self.async_deadline is not None \
                else float(np.median(stats["edge_arrival_s"]))
            stats["staleness"] = staleness_weights(
                stats["edge_arrival_s"], deadline,
                decay=self.async_decay)
            stats["round_time_s"] = deadline
        else:
            stats["staleness"] = None
        return stats

    def init(self, cfg, shape, mesh, key):
        state = super().init(cfg, shape, mesh, key)
        self._residual = None           # fresh error-feedback state
        # derive the codec's rounding stream from the init key so runs
        # are seedable through Session(seed=...) and re-inits restart it
        self._key = jax.random.fold_in(key, 1)
        return state

    def make_step(self, cfg, shape, mesh):
        from repro.comm.codecs import zero_residual
        from repro.comm.hierarchy import make_hier_round

        stats = self._round_stats(cfg)
        self.comm_stats = stats
        hier_round = jax.jit(make_hier_round(
            cfg, shape, self._optimizer(), self.topology, self.codec,
            local_steps=self.local_steps, remat=self.remat,
            client_weights=self.client_weights,
            staleness=stats["staleness"]))
        wire_metrics = {
            "comm_bytes_up": float(stats["uplink_bytes"]),
            "comm_bytes_backhaul": float(stats["backhaul_bytes"]),
            "sim_round_s": float(stats["round_time_s"]),
        }

        def round_fn(client_params, client_opt, batches):
            if self._key is None:
                self._key = jax.random.PRNGKey(self.seed)
            if self._residual is None:
                self._residual = zero_residual(client_params)
            self._key, sub = jax.random.split(self._key)
            client_params, client_opt, metrics, self._residual = \
                hier_round(client_params, client_opt, batches,
                           self._residual, sub)
            return client_params, client_opt, dict(metrics, **wire_metrics)

        return round_fn

    def merge_params(self, state, cfg=None):
        from repro.core.fedavg import fedavg
        w = None if self.client_weights is None else \
            jnp.asarray(self.client_weights, jnp.float32)
        return fedavg(state[0], weights=w, topology=self.topology)


@register_strategy("async_hier_fl")
class AsyncHierFLStrategy(HierFLStrategy):
    """Event-driven hierarchical FL (paper §3.1's *parallelized
    collaborative training*): the comm fabric of ``hier_fl`` driven by
    the discrete-event engine in :mod:`repro.comm.events`.

    ``clock``: cloud merge period in simulated seconds — ``None`` is the
    infinite deadline, i.e. the synchronous special case, guaranteed
    bit-identical to ``hier_fl`` (same topology/codec/seed, zero jitter,
    no migrations). With a finite clock, edge pods flush partial
    aggregates instead of waiting for stragglers and the cloud
    down-weights late commits by ``decay ** observed_lag`` — the lag is
    what actually happened on the simulated links, not a prediction.
    ``compute_flops`` sizes the per-vehicle compute-time model (default:
    a 6*params*tokens estimate from the config); ``compute_jitter`` adds
    up-to-that-fraction uniform slowdown per (vehicle, round).
    ``migrate_every`` turns on DTMC mobility: every that-many simulated
    seconds each vehicle takes one grid step and migrates to the nearest
    edge pod when it leaves its pod's comm radius.
    """

    loop = "async"

    def __init__(self, *, learning_rate: float = 1e-3, local_steps: int = 1,
                 remat: bool = False, topology="2@nano*2,agx*2",
                 codec: str = "none",
                 codec_options: Optional[Dict] = None,
                 client_weights: Optional[Any] = None,
                 clock: Optional[float] = None, decay: float = 0.5,
                 flush_every: Optional[float] = None,
                 compute_flops: Optional[float] = None,
                 compute_jitter: float = 0.0,
                 migrate_every: Optional[float] = None,
                 mobility: Optional[Any] = None,
                 sim_seed: int = 0, seed: int = 0):
        super().__init__(learning_rate=learning_rate,
                         local_steps=local_steps, remat=remat,
                         topology=topology, codec=codec,
                         codec_options=codec_options,
                         client_weights=client_weights, seed=seed)
        self.clock = clock
        self.decay = decay
        self.flush_every = flush_every
        self.compute_flops = compute_flops
        self.compute_jitter = compute_jitter
        self.migrate_every = migrate_every
        self.mobility = mobility
        self.sim_seed = sim_seed
        self.engine = None

    def make_step(self, cfg, shape, mesh):
        from repro.comm.codecs import tree_edge_nbytes, tree_nbytes
        from repro.comm.events import (AsyncHierFLEngine, ComputeModel,
                                       HierFLProgram, MobilitySpec,
                                       default_compute_flops)

        self.comm_stats = self._round_stats(cfg)    # predicted, for info
        program = HierFLProgram(cfg, shape, self._optimizer(), self.codec,
                                remat=self.remat)
        ptree = _abstract_init(cfg)
        flops = self.compute_flops if self.compute_flops is not None \
            else default_compute_flops(cfg, shape, self.local_steps)
        mobility = self.mobility
        if mobility is None and self.migrate_every is not None:
            mobility = MobilitySpec(seed=self.sim_seed)
        self.engine = AsyncHierFLEngine(
            self.topology, tree_nbytes(self.codec, ptree),
            lambda m: tree_edge_nbytes(self.codec, ptree, m),
            program=program,
            compute=ComputeModel(flops=flops, jitter=self.compute_jitter),
            client_weights=self.client_weights,
            clock=self.clock, decay=self.decay,
            flush_every=self.flush_every, mobility=mobility,
            migrate_every=self.migrate_every, seed=self.sim_seed,
            key_fn=self._take_run_key)
        return self.engine

    def _take_run_key(self):
        """The engine's codec-rounding stream: the first run replays
        ``hier_fl``'s exact stream (fold_in(init_key, 1)); later runs
        advance so they do not reuse it."""
        if self._key is None:
            self._key = jax.random.PRNGKey(self.seed)
        k = self._key
        self._key = jax.random.fold_in(k, 0x517)
        return k

    def merge_params(self, state, cfg=None):
        if self.engine is not None and self.engine.version > 0:
            return self.engine.global_params
        return super().merge_params(state, cfg)


@register_strategy("distill_fl")
class DistillFLStrategy(HierFLStrategy):
    """Federated personalized distillation (paper §3.3/§5.2): the cloud
    AD-LLM teaches per-pod LoRA students and **only adapter deltas ride
    the fabric**.

    ``init`` warms the AD-LLM on public (IID) driving data
    (``warmup_steps`` supervised waypoint steps), freezes it as the
    teacher/backbone, and hands every vehicle the same zero-initialized
    (A, B) factor tree. Each round (see
    :func:`repro.distill.federated.make_distill_round`) the vmapped
    students take ``local_steps`` KD steps on their pod's non-IID
    partition — the fused base+low-rank kernel, never merged weights —
    then factor deltas go through the codec (error feedback and all),
    pods partially average, and the cloud merge is **blended** back per
    pod: ``mix=1`` is global FedAvg-of-adapters, ``mix=0`` fully local,
    in between pods keep a personalized adapter while sharing global
    structure.

    State is ``({"base": frozen params, "factors": [C, ...] factor
    tree}, client opt)``; ``merge_params`` gives the global view (base +
    cloud-merged adapter) and :meth:`pod_params` the per-pod
    personalized model that ``Session.serve(pod=e)`` hands to the
    serving tier.
    """

    loop = "distill"

    def __init__(self, *, learning_rate: float = 1e-2,
                 local_steps: int = 1, topology="2@nano*2,agx*2",
                 codec: str = "int8",
                 codec_options: Optional[Dict] = None,
                 client_weights: Optional[Any] = None,
                 async_decay: Optional[float] = None,
                 async_deadline: Optional[float] = None, seed: int = 0,
                 lora_rank: int = 4, lora_alpha: Optional[float] = None,
                 lora_targets: Optional[Tuple[str, ...]] = None,
                 kd_weight: float = 0.3, kd_temp: float = 2.0,
                 logit_weight: float = 0.1, mix: float = 0.5,
                 warmup_steps: int = 20, warmup_lr: float = 1e-3,
                 feature_dim: int = 32, feature_tokens: int = 8,
                 num_waypoints: int = 6, n_towns: int = 4,
                 samples_per_vehicle: int = 256, heldout: int = 64,
                 beta: float = 0.1, data_seed: int = 0):
        from repro.distill.lora import DEFAULT_TARGETS, LoRAConfig
        super().__init__(learning_rate=learning_rate,
                         local_steps=local_steps, topology=topology,
                         codec=codec, codec_options=codec_options,
                         client_weights=client_weights,
                         async_decay=async_decay,
                         async_deadline=async_deadline, seed=seed)
        self.lora_cfg = LoRAConfig(
            rank=lora_rank,
            alpha=float(lora_alpha if lora_alpha is not None
                        else 2 * lora_rank),
            targets=tuple(lora_targets or DEFAULT_TARGETS))
        self.kd_weight = kd_weight
        self.kd_temp = kd_temp
        self.logit_weight = logit_weight
        self.mix = mix
        self.warmup_steps = warmup_steps
        self.warmup_lr = warmup_lr
        self.feature_dim = feature_dim
        self.feature_tokens = feature_tokens
        self.num_waypoints = num_waypoints
        self.n_towns = n_towns
        self.samples_per_vehicle = samples_per_vehicle
        self.heldout = heldout
        self.beta = beta
        self.data_seed = data_seed
        self.warmup_history: Optional[list] = None
        self._base = None
        self._data = None
        self._round_ctr = 0

    # ---- configs / data ---------------------------------------------------
    def adllm_cfg(self, cfg):
        """The AD-LLM view of the session config (prefix features +
        waypoint head); the base ``cfg`` still drives serving."""
        from repro.distill.celladapt import adllm_config
        if cfg.family != "dense":
            raise ValueError(
                f"distill_fl needs a dense AD-LLM config, got family "
                f"{cfg.family!r}")
        return adllm_config(cfg, feature_dim=self.feature_dim,
                            feature_tokens=self.feature_tokens,
                            num_waypoints=self.num_waypoints)

    def _driving_cfg(self):
        from repro.data.synthetic import DrivingDataConfig
        return DrivingDataConfig(n_towns=self.n_towns,
                                 patches=self.feature_tokens,
                                 feature_dim=self.feature_dim,
                                 num_waypoints=self.num_waypoints,
                                 seed=self.data_seed)

    def datasets(self, cfg, shape):
        """(per-vehicle train sets, per-pod held-out sets, pod
        mixtures) — built once per strategy lifetime."""
        if self._data is None:
            from repro.data.partition import pod_datasets
            acfg = self.adllm_cfg(cfg)
            self._data = pod_datasets(
                self._driving_cfg(), self.topology.member_indices,
                self.samples_per_vehicle, seq_len=shape.seq_len,
                vocab=acfg.vocab_size, beta=self.beta,
                seed=self.data_seed, heldout=self.heldout)
        return self._data

    # ---- wire accounting: only the factor tree rides the uplink -----------
    def _wire_tree(self, cfg):
        from repro.distill.celladapt import init_adllm
        from repro.distill.lora import init_lora
        acfg = self.adllm_cfg(cfg)
        params = jax.eval_shape(lambda k: init_adllm(k, acfg),
                                jax.random.PRNGKey(0))
        return jax.eval_shape(
            lambda k: init_lora(k, params, self.lora_cfg),
            jax.random.PRNGKey(0))

    # ---- strategy protocol ------------------------------------------------
    def init(self, cfg, shape, mesh, key):
        from repro.core.fedavg import stack_clients
        from repro.data.partition import adllm_public_dataset
        from repro.data.pipeline import batches as data_batches
        from repro.distill.celladapt import init_adllm
        from repro.distill.federated import warmup_base
        from repro.distill.lora import init_lora
        acfg = self.adllm_cfg(cfg)
        kb, kl = jax.random.split(key)
        base = init_adllm(kb, acfg)
        if self.warmup_steps:
            pub = adllm_public_dataset(
                self._driving_cfg(),
                max(self.warmup_steps * shape.global_batch,
                    shape.global_batch),
                seq_len=shape.seq_len, vocab=acfg.vocab_size,
                seed=self.data_seed + 31)
            it = data_batches(pub, shape.global_batch, seed=self.data_seed,
                              epochs=self.warmup_steps)
            warm = [{k: jnp.asarray(v) for k, v in b.items()}
                    for _, b in zip(range(self.warmup_steps), it)]
            base, self.warmup_history = warmup_base(base, acfg, warm,
                                                    lr=self.warmup_lr)
        factors = init_lora(kl, base, self.lora_cfg)
        cf = stack_clients(factors, self.topology.n_clients)
        client_opt = jax.vmap(self._optimizer().init)(cf)
        self._base = base
        self._residual = None
        self._key = jax.random.fold_in(key, 1)
        self._round_ctr = 0
        return ({"base": base, "factors": cf}, client_opt)

    def make_step(self, cfg, shape, mesh):
        from repro.comm.codecs import zero_residual
        from repro.distill.federated import make_distill_round

        stats = self._round_stats(cfg)
        self.comm_stats = stats
        distill_round = jax.jit(make_distill_round(
            self.adllm_cfg(cfg), self._optimizer(), self.topology,
            self.codec, lora_cfg=self.lora_cfg,
            local_steps=self.local_steps, kd_weight=self.kd_weight,
            kd_temp=self.kd_temp, logit_weight=self.logit_weight,
            mix=self.mix, client_weights=self.client_weights,
            staleness=stats["staleness"]))
        wire_metrics = {
            "comm_bytes_up": float(stats["uplink_bytes"]),
            "comm_bytes_backhaul": float(stats["backhaul_bytes"]),
            "sim_round_s": float(stats["round_time_s"]),
        }

        def round_fn(client_factors, client_opt, batches, base):
            if self._key is None:
                self._key = jax.random.PRNGKey(self.seed)
            if self._residual is None:
                self._residual = zero_residual(client_factors)
            self._key, sub = jax.random.split(self._key)
            client_factors, client_opt, metrics, self._residual = \
                distill_round(client_factors, client_opt, batches, base,
                              self._residual, sub)
            return client_factors, client_opt, dict(metrics,
                                                    **wire_metrics)

        return round_fn

    def param_specs(self, cfg, mesh):
        raise NotImplementedError(
            "distill_fl state is host-driven (frozen base + "
            "client-stacked adapters); it has no mesh sharding specs")

    def _unpack(self, params_like):
        if isinstance(params_like, dict) and "base" in params_like \
                and "factors" in params_like:
            return params_like["base"], params_like["factors"]
        if self._base is None:
            raise RuntimeError(
                "distill_fl has no frozen base yet; init the session "
                "(build/run) before asking for a merged view")
        return self._base, params_like

    def merge_params(self, state, cfg=None):
        """Global view: base + cloud-merged (hierarchical-mean) adapter."""
        from repro.comm.hierarchy import hierarchical_mean
        from repro.distill.lora import merge_lora
        base, factors = self._unpack(state[0])
        w = None if self.client_weights is None else \
            jnp.asarray(self.client_weights, jnp.float32)
        gf = hierarchical_mean(factors, w, self.topology)
        return merge_lora(base, gf, self.lora_cfg)

    def teacher_params(self, state=None):
        """The frozen cloud teacher (warmed-up base, no adapter).

        This is the verify-side target for speculative decoding: pod
        students draft against the teacher they were distilled from, so
        pod-matched drafts accept more than the global-average draft.
        ``state`` is accepted for signature symmetry with
        :meth:`pod_params` but only the frozen base is consulted."""
        if state is not None:
            base, _ = self._unpack(state[0])
            return base
        if self._base is None:
            raise RuntimeError(
                "distill_fl has no frozen base yet; init the session "
                "(build/run) before asking for the teacher")
        return self._base

    def pod_params(self, state, pod: int):
        """Pod ``pod``'s personalized model: base + that pod's adapter
        folded in (the serving handoff)."""
        from repro.distill.lora import merge_lora
        base, factors = self._unpack(state[0])
        members = self.topology.member_indices
        if not 0 <= pod < len(members):
            raise ValueError(
                f"pod {pod} out of range for {len(members)} edge pods")
        idx = np.asarray(members[pod])
        pf = jax.tree.map(
            lambda x: x[idx].astype(jnp.float32).mean(axis=0), factors)
        return merge_lora(base, pf, self.lora_cfg)

    def default_batch(self, cfg, shape, mesh, key):
        from repro.data.pipeline import client_round_batches
        train, _, _ = self.datasets(cfg, shape)
        b = client_round_batches(train, self.local_steps,
                                 shape.global_batch,
                                 round_idx=self._round_ctr)
        self._round_ctr += 1
        return {k: jnp.asarray(v) for k, v in b.items()}


@register_strategy("fl_pipeline")
class FLPipelineStrategy(PipelineStrategy):
    """FedAvg rounds of FHDP-pipelined local steps (paper Fig. 1)."""

    loop = "round"

    def __init__(self, *, learning_rate: float = 1e-3, local_steps: int = 1,
                 remat: bool = True, templates: Optional[Dict] = None,
                 microbatches: Optional[int] = None):
        super().__init__(learning_rate=learning_rate, remat=remat,
                         templates=templates, microbatches=microbatches)
        self.local_steps = local_steps

    def init(self, cfg, shape, mesh, key):
        from repro.core.fhdp import init_fhdp
        pp, opt, templates = init_fhdp(
            cfg, mesh, key, templates=self.resolve_templates(cfg, mesh),
            fed_sgd=False)
        self.templates = templates
        return pp, opt

    def make_step(self, cfg, shape, mesh):
        from repro.core.fhdp import make_fl_pipeline_round
        fl_round, h = make_fl_pipeline_round(
            cfg, shape, mesh, local_steps=self.local_steps,
            learning_rate=self.learning_rate, remat=self.remat,
            templates=self.resolve_templates(cfg, mesh),
            microbatches=self.microbatches)
        self.helpers = h
        return jax.jit(fl_round)

    def default_batch(self, cfg, shape, mesh, key):
        return _stacked_batch(cfg, shape, key, (self.local_steps,))
