"""``Session`` — the single way to stand up FLAD work.

A Session composes:

  * a model config (``arch`` name; the CPU-smoke REDUCED variant unless
    ``full=True`` selects the published scale),
  * a :class:`repro.api.MeshSpec` (declarative mesh + device forcing),
  * a registered :class:`repro.api.Strategy` (``tensor``, ``pipeline``,
    ``fedavg``, ``fl_pipeline``, ``swift_pipeline``),
  * :class:`repro.train.loop.LoopHooks` (log / edge backup / checkpoint),

and exposes the four FLAD entrypoints behind one object::

    from repro.api import Session

    out = Session("flad-vision", strategy="pipeline").run(steps=50)
    Session("flad-adllm").serve(requests=3)
    Session("qwen3-14b", shape="train_4k",
            mesh=MeshSpec(production=True)).lower().compile()

Every launcher, example, benchmark, and smoke script routes through here;
new backends / strategies / schedulers plug into the registry instead of
growing another bespoke launcher.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, Optional, Tuple, Union

from repro.config import INPUT_SHAPES, ModelConfig, ShapeConfig
from repro.api.mesh import MeshSpec
from repro.api.strategies import Strategy, get_strategy


def load_config(arch: str, *, full: bool = False) -> ModelConfig:
    """Resolve an arch name to its ModelConfig — the CPU-smoke REDUCED
    variant by default; ``full=True`` gives the published scale."""
    from repro.configs import get_config
    from repro.configs.common import reduced
    cfg = get_config(arch)
    return cfg if full else reduced(cfg)


def resolve_shape(shape: Union[ShapeConfig, str, None], *,
                  default_batch: int = 8,
                  kind: str = "train") -> Optional[ShapeConfig]:
    """Accept a ShapeConfig, a named shape, 'SEQxBATCH', or None."""
    if shape is None or isinstance(shape, ShapeConfig):
        return shape
    if shape in INPUT_SHAPES:
        return INPUT_SHAPES[shape]
    s, b = (int(x) for x in shape.lower().split("x"))
    return ShapeConfig("cli", s, b, kind)


class Session:
    """One FLAD workload: config x shape x mesh x strategy x hooks."""

    def __init__(self, arch: Optional[str] = None, *,
                 cfg: Optional[ModelConfig] = None,
                 full: bool = False,
                 shape: Union[ShapeConfig, str, None] = None,
                 mesh=None,
                 strategy: Union[str, Strategy] = "pipeline",
                 learning_rate: float = 1e-3,
                 seed: int = 0,
                 hooks=None,
                 **strategy_options):
        if cfg is None:
            cfg = load_config(arch or "flad-vision", full=full)
        self.cfg = cfg
        if isinstance(mesh, MeshSpec):
            self._mesh = None
            self.mesh_spec = mesh
        elif _is_mesh(mesh):
            self._mesh = mesh
            self.mesh_spec = MeshSpec(dims=tuple(mesh.devices.shape),
                                      axes=tuple(mesh.axis_names),
                                      devices=0)
        else:
            self._mesh = None
            self.mesh_spec = MeshSpec.parse(mesh)
        self.seed = seed
        self.hooks = hooks
        if isinstance(strategy, Strategy):
            if strategy_options:
                raise ValueError(
                    f"strategy options {sorted(strategy_options)} are "
                    f"ignored when passing a Strategy instance; set them "
                    f"on the instance or pass the strategy by name")
            self.strategy = strategy
        else:
            self.strategy = get_strategy(strategy,
                                         learning_rate=learning_rate,
                                         **strategy_options)
        self._shape_arg = shape
        self._built: Optional[Tuple[Callable, Any]] = None
        self.state: Optional[Tuple[Any, Any]] = None
        self.history: list = []

    # ---- lazy device-touching pieces ----------------------------------
    @property
    def mesh(self):
        if self._mesh is None:
            self._mesh = self.mesh_spec.build()
        return self._mesh

    @property
    def shape(self) -> ShapeConfig:
        resolved = resolve_shape(self._shape_arg)
        if resolved is None:
            resolved = ShapeConfig("session", 128,
                                   2 * self.mesh_spec.size, "train")
        self._shape_arg = resolved
        return resolved

    @property
    def model(self):
        """The flat (un-pipelined) model — for eval / serving views."""
        from repro.models import build_model
        return build_model(self.cfg)

    def prng(self, salt: int = 0):
        import jax
        return jax.random.PRNGKey(self.seed + salt)

    # ---- strategy plumbing --------------------------------------------
    def build(self, key=None, *, init: bool = True
              ) -> Tuple[Callable, Optional[Tuple[Any, Any]]]:
        """(step_fn, state): the strategy's jitted step + materialized
        state on this session's mesh. Cached; ``init`` only runs once.
        ``init=False`` skips state materialization (state is None) — used
        when the caller supplies its own state, e.g. after recovery."""
        if key is not None and self._built is not None \
                and self._built[1] is not None:
            raise ValueError(
                "state is already materialized; the key passed to build() "
                "would be silently ignored (build with the key first, or "
                "pass state=... to run())")
        if self._built is None:
            mesh = self.mesh
            step = self.strategy.make_step(self.cfg, self.shape, mesh)
            self._built = (step, None)
        if init and self._built[1] is None:
            state = self.strategy.init(self.cfg, self.shape, self.mesh,
                                       self.prng() if key is None else key)
            self._built = (self._built[0], state)
            self.state = state
        return self._built

    @property
    def step_fn(self) -> Callable:
        return self.build()[0]

    def rebuild(self, *, templates=None, state=None) -> Callable:
        """Drop the cached jitted step and rebuild it — the runtime half of
        live dynamic repartitioning. ``templates`` replaces a
        template-bearing strategy's stage templates first; ``state``
        becomes the session state (default: keep the current state — the
        session is never silently re-initialized)."""
        if templates is not None:
            if not hasattr(self.strategy, "templates"):
                raise ValueError(
                    f"strategy {self.strategy.name!r} has no stage "
                    f"templates to replace")
            self.strategy.templates = {k: tuple(v)
                                       for k, v in templates.items()}
        step = self.strategy.make_step(self.cfg, self.shape, self.mesh)
        if state is not None:
            self.state = state
        self._built = (step, self.state)
        return step

    def _checkpoint_meta(self) -> dict:
        """Sidecar metadata for checkpoints: enough to restage the raw
        (stage/client-stacked) container later."""
        meta = {"strategy": self.strategy.name, "arch": self.cfg.name}
        templates = getattr(self.strategy, "templates", None)
        if templates:
            meta["templates"] = {k: list(v) for k, v in templates.items()}
        return meta

    def param_specs(self):
        return self.strategy.param_specs(self.cfg, self.mesh)

    def merged_params(self, state=None):
        """Flat model params view of the current (or given) state."""
        state = state if state is not None else self.state
        if state is None:
            raise RuntimeError("no state yet; call build()/run() first")
        return self.strategy.merge_params(state, self.cfg)

    def default_batches(self, salt: int = 1) -> Iterator:
        """Endless synthetic batches matching the strategy's step input."""
        import jax
        key = self.prng(salt)
        while True:
            key, sub = jax.random.split(key)
            yield self.strategy.default_batch(self.cfg, self.shape,
                                              self.mesh, sub)

    # ---- drivers ------------------------------------------------------
    def run(self, steps: int, *, state=None, batches=None,
            hooks=None, trace=None, metrics=None, profile=None) -> Dict:
        """Train for ``steps`` steps (or FL rounds, for ``round``-loop
        strategies) and return the loop output (+ final ``state``).

        ``batches``: an iterator of step batches, or for round strategies a
        ``fn(round_idx) -> round_batch``; defaults to synthetic data.

        Observability (:mod:`repro.obs`): ``trace`` — a Tracer or a path —
        records the event engine's sim-time spans (async strategies only:
        the sim clock lives there); ``metrics`` — a MetricsRegistry or a
        path — collects every loop's scalar metrics plus the engine's
        fabric counters; ``profile`` — a :class:`repro.obs.ProfileOptions`
        — wraps the loop in a ``jax.profiler`` capture. Paths are written
        when the loop returns (``out["trace_path"]`` /
        ``out["metrics_path"]``). All three default off and add zero work
        when off.
        """
        import dataclasses

        from repro.obs import (MetricsRegistry, profiled, resolve_tracer)
        from repro.train.loop import (LoopHooks, async_fl_loop, fl_loop,
                                      train_loop)

        tracer, trace_path = resolve_tracer(trace)
        if tracer is not None and self.strategy.loop != "async":
            raise ValueError(
                f"trace= needs an async strategy (the event engine owns "
                f"the simulated clock); {self.strategy.name!r} runs a "
                f"{self.strategy.loop!r} loop — pass metrics= instead")
        if isinstance(metrics, str):
            registry, metrics_path = MetricsRegistry(), metrics
        else:
            registry, metrics_path = metrics, None
        step, init_state = self.build(init=state is None)
        if state is not None:
            init_state = state
        hooks = hooks or self.hooks or (
            LoopHooks(log_every=1) if self.strategy.loop in ("round",
                                                             "async",
                                                             "distill")
            else LoopHooks())
        if hooks.backup is not None and hooks.backup_view is None:
            # default the edge snapshot to the merged flat model, the form
            # recovery's restage() redeploys under a new template
            hooks = dataclasses.replace(
                hooks, backup_view=lambda p: self.strategy.merge_params(
                    (p, None), self.cfg))
        if hooks.checkpoint_path and hooks.checkpoint_meta is None:
            # record the live layout next to structured checkpoints (bound
            # method, so a mid-run repartition is reflected at save time)
            hooks = dataclasses.replace(
                hooks, checkpoint_meta=self._checkpoint_meta)
        if tracer is not None and hooks.tracer is None:
            hooks = dataclasses.replace(hooks, tracer=tracer)
        if registry is not None and hooks.metrics is None:
            hooks = dataclasses.replace(hooks, metrics=registry)
        params, opt = init_state
        if self.strategy.loop in ("round", "async", "distill"):
            if batches is None:
                it = self.default_batches()
                round_fn = lambda r: next(it)          # noqa: E731
            elif callable(batches):
                round_fn = batches
            else:
                if self.strategy.loop == "async" and \
                        hasattr(batches, "__len__"):
                    # the event engine consumes one batch per broadcast
                    # WAVE, and async waves outnumber cloud merges — a
                    # finite per-round list would StopIteration mid-run,
                    # so cycle it instead
                    import itertools
                    batches = itertools.cycle(batches)
                round_fn = lambda r, _it=iter(batches): next(_it)  # noqa: E731
            loop = async_fl_loop if self.strategy.loop == "async" \
                else fl_loop
            loop_kw = {}
            client_like = params
            if self.strategy.loop == "distill":
                # student/teacher split: the loop carries only the
                # trainable adapters; the frozen base rides along as the
                # per-round teacher and rejoins the state afterwards
                loop_kw["teacher"] = params["base"]
                client_like = params["factors"]
            with profiled(profile):
                out = loop(step, client_like, opt, round_fn, rounds=steps,
                           hooks=hooks, **loop_kw)
            if self.strategy.loop == "distill":
                out["client_params"] = {"base": params["base"],
                                        "factors": out["client_params"]}
            self.state = (out["client_params"], out["client_opt"])
        else:
            it = iter(batches) if batches is not None \
                else self.default_batches()
            with profiled(profile):
                out = train_loop(step, params, opt, it, steps=steps,
                                 hooks=hooks)
            self.state = (out["params"], out["opt_state"])
        # a live repartition may have swapped the jitted step mid-loop
        self._built = (out.get("step_fn", step), self.state)
        self.history.extend(out["history"])
        if trace_path is not None:
            out["trace_path"] = tracer.save(trace_path)
        if metrics_path is not None:
            out["metrics_path"] = registry.save(metrics_path)
        return out

    def serve(self, *, requests: int = 3, batch: int = 8, context: int = 64,
              decode_steps: int = 16, params=None, scheduler: str = "legacy",
              sampling: str = "greedy", temperature: float = 1.0,
              pod: Optional[int] = None, trace=None,
              speculative: bool = False,
              draft_pod: Optional[int] = None, log_fn=print,
              **serve_options) -> Dict:
        """Batched prefill+decode serving (paper Fig. 2); uses the trained
        session params when available, else a fresh init.

        ``scheduler="legacy"`` is the static-batch driver of
        :func:`repro.api.serving.serve_requests` (bit-identical to prior
        builds under greedy sampling). ``scheduler="continuous"`` routes
        through the paged-KV continuous-batching tier of
        :mod:`repro.serve` — ``requests`` becomes the trace length,
        ``batch`` the number of lanes, ``context`` the monolithic prefill
        bucket, and extra ``serve_options`` (``block_size``, ``cache``,
        ``fleet``, ``prefill``/``prefill_chunk`` for chunked paged
        prefill (the default) vs the monolithic baseline,
        ``prefix_cache`` for pod prefix-block sharing, ...) pass straight
        to :func:`repro.serve.serve_continuous`. ``trace`` (a
        :class:`repro.obs.Tracer` or a path) records the final warm
        pass's queue/lane spans on the simulated clock — continuous
        scheduler only; the legacy driver has no sim clock.

        ``pod``: serve edge pod ``pod``'s **personalized** model — the
        strategy's ``pod_params`` view (``distill_fl``: base weights with
        that pod's LoRA adapter folded in via ``merge_lora``) instead of
        the global merge.

        ``speculative``: draft-verify speculative decoding (continuous
        scheduler, greedy only; streams stay bit-identical). The draft
        model defaults to the target weights (self-draft); pass
        ``draft_pod`` to draft with pod ``draft_pod``'s distilled
        student — same base weights, that pod's LoRA factors merged in,
        no second checkpoint (``distill_fl`` only). ``draft_k`` and
        ``preemption`` ride through ``serve_options``."""
        self.mesh  # force device setup once, like every other entrypoint
        if pod is not None:
            if params is not None:
                raise ValueError("pass either params or pod, not both")
            if not hasattr(self.strategy, "pod_params"):
                raise ValueError(
                    f"strategy {self.strategy.name!r} has no per-pod "
                    f"personalized view (pod= needs distill_fl)")
            if self.state is None:
                raise RuntimeError("no state yet; run() before serving "
                                   "a personalized pod model")
            params = self.strategy.pod_params(self.state, pod)
        if params is None and self.state is not None:
            params = self.merged_params()
        if draft_pod is not None and not speculative:
            raise ValueError("draft_pod= needs speculative=True")
        if speculative:
            if scheduler != "continuous":
                raise ValueError("speculative decoding needs "
                                 "scheduler='continuous'")
            serve_options["speculative"] = True
            if draft_pod is not None:
                if not hasattr(self.strategy, "pod_params"):
                    raise ValueError(
                        f"strategy {self.strategy.name!r} has no per-pod "
                        f"student to draft with (draft_pod= needs "
                        f"distill_fl)")
                if self.state is None:
                    raise RuntimeError(
                        "no state yet; run() before drafting with a "
                        "distilled pod student")
                serve_options["draft_params"] = self.strategy.pod_params(
                    self.state, draft_pod)
        if scheduler == "continuous":
            from repro.serve import serve_continuous
            return serve_continuous(self.cfg, params=params,
                                    seed=self.seed, slots=batch,
                                    max_context=context,
                                    num_requests=requests,
                                    sampling=sampling,
                                    temperature=temperature,
                                    trace=trace,
                                    log_fn=log_fn, **serve_options)
        if trace is not None:
            raise ValueError(
                "trace= needs scheduler='continuous' (the legacy static "
                "driver has no simulated clock to put spans on)")
        if scheduler != "legacy":
            raise ValueError(f"unknown scheduler {scheduler!r} "
                             "(legacy|continuous)")
        from repro.api.serving import serve_requests
        return serve_requests(self.cfg, batch=batch, context=context,
                              decode_steps=decode_steps, requests=requests,
                              params=params, key=self.prng(2),
                              sampling=sampling, temperature=temperature,
                              log_fn=log_fn, **serve_options)

    def lower(self, **kw):
        """Compile-only dry-run lowering of this session's step (no
        allocation); see :func:`repro.api.lowering.build_lowered`."""
        from repro.api.lowering import build_lowered
        return build_lowered(self.cfg, self.shape, self.mesh,
                             strategy=self.strategy.name, **kw)


def _is_mesh(obj) -> bool:
    return obj is not None and hasattr(obj, "axis_names") \
        and hasattr(getattr(obj, "devices", None), "shape")
