"""Configuration dataclasses for the FLAD-JAX framework.

Every architecture in ``repro.configs`` instantiates a :class:`ModelConfig`;
input shapes are :class:`ShapeConfig`; mesh/runtime knobs live in
:class:`RunConfig`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    d_expert: int = 0          # per-expert FFN hidden size
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """State for xLSTM / Mamba-style recurrent paths."""
    state_size: int = 16       # per-head recurrent state dim (mamba N)
    conv_kernel: int = 4       # depthwise conv width (mamba)
    slstm_every: int = 0       # xlstm: 1-in-k blocks are sLSTM (0 = none)
    expand: int = 2            # mamba inner expansion


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: MoEConfig = MoEConfig()
    ssm: SSMConfig = SSMConfig()
    # encoder-decoder split (family == 'encdec'); num_layers = enc + dec
    enc_layers: int = 0
    dec_layers: int = 0
    # sliding-window attention (None = full attention). Set per-run for the
    # long_500k shape; window caches keep decode memory bounded.
    window: Optional[int] = None
    # multimodal stub frontend: number of prefix embedding tokens fed by
    # input_specs() (vlm patch embeddings / audio frame embeddings)
    prefix_tokens: int = 0
    prefix_dim: int = 0
    # extra task heads for the FLAD vision encoder
    num_waypoints: int = 0
    num_light_classes: int = 0
    param_dtype: str = "bfloat16"
    # attention impl: 'auto' picks chunked for long sequences
    q_chunk: int = 512
    kv_chunk: int = 1024
    # Pallas flash-attention VMEM tile sizes (fwd + bwd kernels); callers
    # may override per-call via blocks.attention(block_q=..., block_k=...)
    attn_block_q: int = 128
    attn_block_k: int = 128

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (used by SWIFT's memory model & rooflines) ----
    def param_count(self) -> int:
        d, hd = self.d_model, self.hd
        nq, nkv = self.num_heads, self.num_kv_heads
        V = self.vocab_size
        emb = V * d
        out = 0 if self.tie_embeddings else V * d

        def attn_params() -> int:
            p = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
            if self.qkv_bias:
                p += (nq + 2 * nkv) * hd
            if self.qk_norm:
                p += 2 * hd
            return p + 2 * d  # two RMSNorm vectors per block

        def ffn_params() -> int:
            if self.moe.num_experts:
                e = self.moe.num_experts
                return d * e + e * 3 * d * self.moe.d_expert
            return 3 * d * self.d_ff  # SwiGLU

        def mlstm_params() -> int:
            di = self.ssm.expand * d
            # in-proj (x,z), out-proj, q/k/v projections, gates, conv
            return d * 2 * di + di * d + 3 * di * di + 2 * di + d

        def block_params() -> int:
            if self.family == "ssm":
                return mlstm_params() + ffn_params() + 2 * d
            if self.family == "hybrid":
                return attn_params() + mlstm_params() + ffn_params()
            return attn_params() + ffn_params()

        n = self.num_layers * block_params() + emb + out + d
        if self.family == "encdec":
            # decoder blocks additionally carry cross-attention
            n += self.dec_layers * (d * nq * hd + 2 * d * nkv * hd + nq * hd * d + d)
        if self.prefix_tokens:
            n += self.prefix_dim * d  # projector
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of num_experts)."""
        if not self.moe.num_experts:
            return self.param_count()
        e, k = self.moe.num_experts, self.moe.top_k
        full = self.param_count()
        expert_p = self.num_layers * e * 3 * self.d_model * self.moe.d_expert
        return full - expert_p + expert_p * k // e


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Sliding window used when a full-attention architecture runs long_500k.
LONG_CONTEXT_WINDOW = 8192


@dataclasses.dataclass(frozen=True)
class RunConfig:
    arch: str = "flad-vision"
    shape: str = "train_4k"
    strategy: str = "tensor"     # tensor | pipeline  (pipeline == FHDP)
    multi_pod: bool = False
    microbatches: int = 8        # pipeline microbatching
    remat: str = "block"         # none | block  (activation checkpointing)
    learning_rate: float = 3e-4
    seed: int = 0


# ---- TPU v5e hardware model (roofline + SWIFT cost model constants) ----
@dataclasses.dataclass(frozen=True)
class HardwareConfig:
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link
    hbm_bytes: float = 16 * 2 ** 30  # per chip (v5e: 16 GiB)
    vmem_bytes: float = 128 * 2**20


TPU_V5E = HardwareConfig()
