"""CELLAdapt — Cloud-Edge LLM Adaptation (paper §3.3 / §5.2).

The AD-LLM is a decoder LM that consumes vision-encoder features (prefix
embeddings) plus context tokens (navigation/notice instructions) and
regresses future waypoints from its final hidden states.

Pipeline (paper Fig. 1):
  1. cloud: distill the general LLM into the AD-LLM on public AD data;
  2. edge: distill AD-LLM (teacher, LLaMA-7B in the paper) into the compact
     ADM (student, LLaMA-3B) with an L1 loss on waypoint outputs;
  3. edge: LoRA-fine-tune on the region's vehicle features (personalize).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distill.lora import LoRAConfig, init_lora, make_lora_loss, merge_lora
from repro.models import blocks as B
from repro.models import lm
from repro.train.optimizer import Adam


def adllm_config(base: ModelConfig, *, feature_dim: int = 256,
                 feature_tokens: int = 64, num_waypoints: int = 10
                 ) -> ModelConfig:
    return base.replace(prefix_tokens=feature_tokens,
                        prefix_dim=feature_dim,
                        num_waypoints=num_waypoints)


def init_adllm(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    params = lm.init(k1, cfg)
    params["wp_head"] = B.init_linear(k2, cfg.d_model,
                                      cfg.num_waypoints * 2, cfg.dtype,
                                      bias=True)
    return params


def adllm_waypoints(params, cfg: ModelConfig, features, tokens,
                    window=None) -> jnp.ndarray:
    """features: [B, P, F] vision-encoder output; tokens: [B, S] context.
    Returns waypoints [B, W, 2] regressed from the last hidden state."""
    x, _, _ = lm.forward(params, cfg, tokens, prefix_embeds=features,
                         window=window, hidden_only=True)
    h = x[:, -1]
    wp = B.linear(params["wp_head"], h).astype(jnp.float32)
    return wp.reshape(h.shape[0], cfg.num_waypoints, 2)


def waypoint_l1(pred, target) -> jnp.ndarray:
    return jnp.abs(pred - target).mean()


# --------------------------------------------------------------------------
# Step 2: edge knowledge distillation (teacher AD-LLM -> student ADM)
# --------------------------------------------------------------------------
def make_distill_step(tcfg: ModelConfig, scfg: ModelConfig, *,
                      lr: float = 1e-3):
    """L1 alignment of student waypoints to teacher waypoints (paper: 'the
    L1-norm loss is adopted to align the outputs (i.e., waypoints) of the
    teacher and student models')."""
    opt = Adam(lr=lr)

    def loss_fn(sp, tp, batch):
        t_wp = jax.lax.stop_gradient(
            adllm_waypoints(tp, tcfg, batch["features"], batch["tokens"]))
        s_wp = adllm_waypoints(sp, scfg, batch["features"], batch["tokens"])
        return waypoint_l1(s_wp, t_wp)

    @jax.jit
    def step(sp, opt_state, tp, batch):
        loss, grads = jax.value_and_grad(loss_fn)(sp, tp, batch)
        sp, opt_state = opt.update(grads, opt_state, sp)
        return sp, opt_state, loss

    return step, opt


# --------------------------------------------------------------------------
# Step 3: edge LoRA fine-tuning on regional features
# --------------------------------------------------------------------------
def make_finetune_step(cfg: ModelConfig, params, *,
                       lora_cfg: Optional[LoRAConfig] = None,
                       lr: float = 1e-3):
    """LoRA fine-tune of the AD-LLM against ground-truth waypoints from the
    region's vehicles. Only the factors train (0.1–1% of params, §2.5)."""
    lora_cfg = lora_cfg or LoRAConfig()
    key = jax.random.PRNGKey(0)
    lora = init_lora(key, params, lora_cfg)
    opt = Adam(lr=lr)

    def loss_fn(lora, batch):
        merged = merge_lora(params, lora, lora_cfg)
        wp = adllm_waypoints(merged, cfg, batch["features"], batch["tokens"])
        return waypoint_l1(wp, batch["waypoints"])

    @jax.jit
    def step(lora, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(lora, batch)
        lora, opt_state = opt.update(grads, opt_state, lora)
        return lora, opt_state, loss

    return step, lora, opt


# --------------------------------------------------------------------------
# Step 1: cloud distillation of a general LLM into the AD-LLM
# --------------------------------------------------------------------------
def make_cloud_distill_step(gcfg: ModelConfig, acfg: ModelConfig, *,
                            lr: float = 1e-3, temp: float = 2.0):
    """Token-level KD (KL on soft logits) from the general LLM to the
    AD-LLM on public AD corpora — the cloud-side abstraction step."""
    opt = Adam(lr=lr)

    def loss_fn(ap, gp, batch):
        g_logits, _, _ = lm.forward(gp, gcfg, batch["tokens"])
        a_logits, _, _ = lm.forward(ap, acfg, batch["tokens"])
        gt = jax.nn.log_softmax(
            jax.lax.stop_gradient(g_logits) / temp, axis=-1)
        at = jax.nn.log_softmax(a_logits / temp, axis=-1)
        return (jnp.exp(gt) * (gt - at)).sum(-1).mean() * temp * temp

    @jax.jit
    def step(ap, opt_state, gp, batch):
        loss, grads = jax.value_and_grad(loss_fn)(ap, gp, batch)
        ap, opt_state = opt.update(grads, opt_state, ap)
        return ap, opt_state, loss

    return step, opt
