"""LoRA adapters over parameter pytrees (paper §2.5/§5.2: PEFT makes
on-vehicle/edge personalization feasible under memory constraints).

``init_lora`` creates {path: (A, B)} factors for every 2-D weight whose
leaf name matches ``targets``; ``merge_lora`` returns params with
w + scale * A @ B folded in (for inference/serving); ``apply_lora`` keeps
the factors separate so only (A, B) receive gradients during fine-tuning.
The fused base+low-rank matmul lives in kernels/lora_matmul and is
differentiable through ``ops.lora_matmul_ad``'s closed-form custom_vjp
(``apply_lora`` routes through it) — one pass over x and W, and the
merged weight is never materialized.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

DEFAULT_TARGETS = ("wq", "wk", "wv", "wo")


def _leaf_name(path) -> str:
    for e in reversed(path):
        if isinstance(e, jax.tree_util.DictKey):
            return e.key
    return ""


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 8
    alpha: float = 16.0
    targets: Tuple[str, ...] = DEFAULT_TARGETS

    @property
    def scale(self) -> float:
        return self.alpha / self.rank


def init_lora(key, params, cfg: LoRAConfig):
    """Factor pytree with the same structure as ``params``; non-target
    leaves hold None.

    Raises ``ValueError`` when no leaf matches ``cfg.targets`` — an
    all-None factor tree would make fine-tuning a silent no-op (zero
    trainable parameters, zero gradients, unchanged model)."""
    leaves = jax.tree_util.tree_leaves_with_path(params)
    keys = jax.random.split(key, max(len(leaves), 1))

    def make(i, path, leaf):
        if _leaf_name(path) in cfg.targets and leaf.ndim >= 2:
            din, dout = leaf.shape[-2], leaf.shape[-1]
            lead = leaf.shape[:-2]
            a = jax.random.normal(keys[i], lead + (din, cfg.rank)) \
                * din ** -0.5
            b = jnp.zeros(lead + (cfg.rank, dout))
            return {"A": a.astype(jnp.float32), "B": b}
        return None

    out = []
    for i, (path, leaf) in enumerate(leaves):
        out.append(make(i, path, leaf))
    if all(f is None for f in out):
        adaptable = sorted({_leaf_name(path) for path, leaf in leaves
                            if hasattr(leaf, "ndim") and leaf.ndim >= 2})
        raise ValueError(
            f"LoRA targets {tuple(cfg.targets)} match no parameter leaf — "
            f"fine-tuning would be a no-op (zero trainable factors); "
            f"adaptable 2-D leaf names in this tree: {adaptable}")
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, out)


def merge_lora(params, lora, cfg: LoRAConfig):
    """w + scale * A @ B for every adapted leaf (batched over leading
    stack dims)."""
    def merge(p, f):
        if f is None:
            return p
        delta = jnp.einsum("...ir,...ro->...io", f["A"], f["B"]) * cfg.scale
        return (p.astype(jnp.float32) + delta).astype(p.dtype)

    return jax.tree.map(merge, params, lora,
                        is_leaf=lambda x: x is None
                        or (isinstance(x, dict) and "A" in x))


def lora_linear(x, w, factors, scale: float, *, interpret=None):
    """Adapted linear ``x @ w + scale * (x @ A) @ B`` through the fused
    Pallas kernel — differentiable (closed-form custom_vjp), so LoRA
    fine-tuning can run the fused path instead of merging, and only the
    factors' cotangents are nonzero where the optimizer masks the base.

    x: [..., K]; w: [K, N]; factors: {"A": [K, r], "B": [r, N]}. This is
    the hot path the adapted model forward (``lm.forward(lora=...)``)
    routes every target projection through; ``apply_lora`` is the
    LoRAConfig-taking wrapper.
    """
    from repro.kernels import ops
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = ops.lora_matmul_ad(x2, w, factors["A"].astype(w.dtype),
                           factors["B"].astype(w.dtype),
                           scale=scale, interpret=interpret)
    return y.reshape(lead + (w.shape[-1],))


def apply_lora(x, w, factors, cfg: LoRAConfig, *, interpret=None):
    """``lora_linear`` with the scale taken from a :class:`LoRAConfig`."""
    return lora_linear(x, w, factors, cfg.scale, interpret=interpret)


def lora_param_count(lora) -> int:
    return sum(x.size for x in jax.tree.leaves(lora))


def make_lora_loss(loss_fn: Callable, params, cfg: LoRAConfig):
    """loss over the factors only: lora_loss(lora, batch)."""
    def lora_loss(lora, batch):
        return loss_fn(merge_lora(params, lora, cfg), batch)

    return lora_loss
