"""Federated personalized distillation (paper §3.3/§5.2 as a strategy).

The cloud AD-LLM is warmed once on public (IID) driving data and then
frozen as the **teacher**; each vehicle trains a LoRA **student** — the
same base weights plus per-pod (A, B) factors — on its pod's non-IID
partition. The student loss combines the task term with the CELLAdapt
distillation terms:

    L = L1(student_wp, ground truth)
      + kd_weight * ( L1(student_wp, teacher_wp)
                      + logit_weight * KL(teacher || student) @ kd_temp )

The student forward never materializes merged weights: every adapted
projection routes through the fused base+low-rank kernel
(``ops.lora_matmul_ad``) via ``lm.forward(lora=...)``, and only the
factor deltas ride the comm fabric — codec roundtrips with error
feedback, per-pod edge partial averages, and a staleness-aware cloud
merge, exactly the ``hier_fl`` fabric but orders of magnitude fewer
bytes per round.

Aggregation keeps personalization: pods do NOT collapse to one global
adapter. Each round ends with

    pod_adapter' = (1 - mix) * (pod_adapter + pod_delta)
                 + mix * cloud_merge(all pods)

so ``mix=1`` recovers fully-global FedAvg-of-adapters and ``mix=0`` is
fully-local per-pod training; in between the cloud shares structure
while each region keeps its own head start (the per-edge personalization
win measured in BENCH_distill.json).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.codecs import Codec, roundtrip_stacked
from repro.comm.hierarchy import (cloud_merge, edge_aggregate, pod_broadcast,
                                  pod_slice)
from repro.comm.topology import Topology
from repro.config import ModelConfig
from repro.distill.celladapt import waypoint_l1
from repro.distill.lora import LoRAConfig
from repro.models import blocks as B
from repro.models import lm
from repro.train.optimizer import Adam


def _hidden(params, cfg: ModelConfig, batch, *, lora=None,
            lora_scale: float = 1.0):
    h, _, _ = lm.forward(params, cfg, batch["tokens"],
                         prefix_embeds=batch["features"], hidden_only=True,
                         lora=lora, lora_scale=lora_scale)
    return h


def _waypoints(params, cfg: ModelConfig, h):
    wp = B.linear(params["wp_head"], h[:, -1]).astype(jnp.float32)
    return wp.reshape(h.shape[0], cfg.num_waypoints, 2)


def _logits(params, cfg: ModelConfig, h):
    if cfg.tie_embeddings:
        return B.unembed(params["embed"], h).astype(jnp.float32)
    return B.linear(params["head"], h).astype(jnp.float32)


def make_student_loss(acfg: ModelConfig, lora_cfg: LoRAConfig, *,
                      kd_weight: float = 0.3, kd_temp: float = 2.0,
                      logit_weight: float = 0.1):
    """loss(factors, base, batch) -> (loss, metrics) for one LoRA student.

    Only ``factors`` is differentiated; ``base`` is both the student's
    frozen backbone and — run without the adapter — the teacher."""

    def loss_fn(factors, base, batch):
        h = _hidden(base, acfg, batch, lora=factors,
                    lora_scale=lora_cfg.scale)
        s_wp = _waypoints(base, acfg, h)
        task = waypoint_l1(s_wp, batch["waypoints"])
        th = jax.lax.stop_gradient(_hidden(base, acfg, batch))
        t_wp = _waypoints(base, acfg, th)
        align = waypoint_l1(s_wp, t_wp)
        gt = jax.nn.log_softmax(_logits(base, acfg, th) / kd_temp, axis=-1)
        at = jax.nn.log_softmax(_logits(base, acfg, h) / kd_temp, axis=-1)
        kl = (jnp.exp(gt) * (gt - at)).sum(-1).mean() * kd_temp * kd_temp
        loss = task + kd_weight * (align + logit_weight * kl)
        return loss, {"loss": loss, "task_l1": task, "kd_l1": align,
                      "kd_kl": kl}

    return loss_fn


def make_distill_round(acfg: ModelConfig, optimizer: Adam,
                       topology: Topology, codec: Codec, *,
                       lora_cfg: LoRAConfig, local_steps: int = 1,
                       kd_weight: float = 0.3, kd_temp: float = 2.0,
                       logit_weight: float = 0.1, mix: float = 0.5,
                       client_weights=None,
                       staleness: Optional[np.ndarray] = None):
    """One federated-distillation round over client-stacked LoRA factors.

    distill_round(client_factors, client_opt, batches, base, residual,
    key) -> (client_factors', client_opt', metrics, residual').

    ``batches`` carry [C, E, B, ...] leaves; ``base`` is the frozen
    teacher/backbone (shared by all students — vmapped with
    ``in_axes=None``); ``residual`` is the codec's per-client
    error-feedback state over the **factor** tree. Pod members start each
    round from their pod's shared adapter, so client deltas are w.r.t.
    their own pod — ``pod_slice``/``pod_broadcast`` carry the per-pod
    state across the round while ``cloud_merge`` supplies the ``mix``
    share of global structure.
    """
    from repro.core.fedavg import check_weights

    loss_fn = make_student_loss(acfg, lora_cfg, kd_weight=kd_weight,
                                kd_temp=kd_temp, logit_weight=logit_weight)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def local_train(factors, opt_state, batches, base):
        def body(carry, batch):
            f, o = carry
            (_, metrics), grads = grad_fn(f, base, batch)
            f, o = optimizer.update(grads, o, f)
            return (f, o), metrics

        (factors, opt_state), ms = jax.lax.scan(
            body, (factors, opt_state), batches)
        return factors, opt_state, jax.tree.map(lambda m: m[-1], ms)

    w = None if client_weights is None else check_weights(client_weights)
    if w is not None:
        topology.validate_pod_weights(w)
    stale = None if staleness is None else jnp.asarray(staleness,
                                                       jnp.float32)
    if not 0.0 <= mix <= 1.0:
        raise ValueError(f"mix must be in [0, 1], got {mix}")

    def distill_round(client_factors, client_opt, batches, base,
                      residual, key):
        start = client_factors
        factors, opts, metrics = jax.vmap(
            local_train, in_axes=(0, 0, 0, None))(client_factors,
                                                  client_opt, batches, base)
        # adapter-only uplink: factor deltas w.r.t. the round's pod state
        deltas = jax.tree.map(
            lambda a, s: a.astype(jnp.float32) - s.astype(jnp.float32),
            factors, start)
        decoded, residual = roundtrip_stacked(codec, deltas, residual, key)
        edge_delta, edge_w = edge_aggregate(decoded, w, topology,
                                            validated=True)
        pod_start = pod_slice(start, topology)
        pod_partial = jax.tree.map(
            lambda s, d: s.astype(jnp.float32) + d, pod_start, edge_delta)
        global_f = cloud_merge(pod_partial, edge_w, stale)
        pod_new = jax.tree.map(
            lambda p, g: (1.0 - mix) * p + mix * g[None],
            pod_partial, global_f)
        new_clients = pod_broadcast(pod_new, topology)
        return new_clients, opts, metrics, residual

    return distill_round


def warmup_base(params, acfg: ModelConfig, batches, *, lr: float = 1e-3):
    """Supervised waypoint warmup of the full AD-LLM on pooled public
    data — the cloud stage that trains ``wp_head`` (and settles the
    backbone) before it freezes as the distillation teacher. Returns
    (params, per-step losses)."""
    opt = Adam(lr=lr)

    def loss_fn(p, batch):
        wp = _waypoints(p, acfg, _hidden(p, acfg, batch))
        return waypoint_l1(wp, batch["waypoints"])

    @jax.jit
    def step(p, o, batch):
        loss, grads = jax.value_and_grad(loss_fn)(p, batch)
        p, o = opt.update(grads, o, p)
        return p, o, loss

    o = opt.init(params)
    losses = []
    for b in batches:
        params, o, loss = step(params, o, b)
        losses.append(float(loss))
    return params, losses


def greedy_agreement(target, draft, cfg: ModelConfig, tokens, *,
                     draft_lora=None, lora_scale: float = 1.0) -> float:
    """Teacher-forced greedy next-token agreement of ``draft`` with
    ``target`` over ``tokens`` [B, S] — the analytical predictor of
    speculative-decode acceptance.

    Both models see the same ground-truth prefixes, so a position counts
    as agreeing iff the draft's greedy token equals the target's at that
    prefix — exactly the event the serving tier's greedy exact-match
    verifier accepts. A pod student scored against the teacher it was
    distilled from should agree more often on its own pod's traffic than
    the global-average adapter does; the specdec bench reports this
    number next to the acceptance rate the scheduler actually measured.

    ``draft_lora`` runs the draft as base + factors through the fused
    kernel (no merged weights); otherwise ``draft`` is a full param tree.
    """
    toks = jnp.asarray(tokens, jnp.int32)
    tl, _, _ = lm.forward(target, cfg, toks)
    dl, _, _ = lm.forward(draft, cfg, toks, lora=draft_lora,
                          lora_scale=lora_scale)
    return float((tl.argmax(-1) == dl.argmax(-1)).mean())


def waypoint_eval(base, acfg: ModelConfig, data, *, lora=None,
                  lora_scale: float = 1.0) -> float:
    """Mean waypoint L1 of (base [+ adapter]) over a held-out dataset."""
    batch = {"features": jnp.asarray(data["features"]),
             "tokens": jnp.asarray(data["tokens"]),
             "waypoints": jnp.asarray(data["waypoints"])}
    h = _hidden(base, acfg, batch, lora=lora, lora_scale=lora_scale)
    wp = _waypoints(base, acfg, h)
    return float(waypoint_l1(wp, batch["waypoints"]))
