"""Training launcher.

On the container (CPU) this runs REDUCED variants on a small forced-host
mesh; on a real TPU slice the same flags drive the full configs on the
production mesh. The FHDP strategy is the paper's system; ``tensor`` is
the datacenter-style baseline.

  PYTHONPATH=src python -m repro.launch.train --arch flad-vision \
      --strategy pipeline --steps 50 --devices 8 --mesh 2,4
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="flad-vision")
    ap.add_argument("--shape", default=None, help="named shape or 'SEQxBATCH'")
    ap.add_argument("--strategy", default="pipeline",
                    choices=["tensor", "pipeline"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU testing)")
    ap.add_argument("--mesh", default="2,4", help="data,model (or pod,data,model)")
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (TPU scale)")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + f" --xla_force_host_platform_device_count={args.devices}").strip()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import INPUT_SHAPES, ShapeConfig
    from repro.configs import get_config
    from repro.configs.common import concrete_batch, reduced
    from repro.core import pipeline as pl
    from repro.core import sharding as shd
    from repro.core.steps import make_train_step
    from repro.launch.mesh import _mk
    from repro.models import build_model
    from repro.recovery.backup import EdgeBackup
    from repro.train.loop import train_loop
    from repro.train.optimizer import Adam

    dims = tuple(int(x) for x in args.mesh.split(","))
    mesh = _mk(dims, ("pod", "data", "model")[-len(dims):])
    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    if args.shape and args.shape in INPUT_SHAPES:
        shape = INPUT_SHAPES[args.shape]
    elif args.shape:
        s, b = (int(x) for x in args.shape.split("x"))
        shape = ShapeConfig("cli", s, b, "train")
    else:
        shape = ShapeConfig("cli", 128, int(np.prod(dims)) * 2, "train")

    key = jax.random.PRNGKey(args.seed)
    rngs = iter(jax.random.split(key, args.steps + 10))

    def batch_iter():
        while True:
            yield concrete_batch(cfg, shape, next(rngs))

    backup = EdgeBackup(interval=10)
    if args.strategy == "pipeline":
        from repro.core.fhdp import init_fhdp
        step, h = pl.make_fhdp_train_step(cfg, shape, mesh,
                                          learning_rate=args.lr)
        pp, opt, _ = init_fhdp(cfg, mesh, key)
        out = train_loop(jax.jit(step), pp, opt, batch_iter(),
                         steps=args.steps, backup=backup,
                         checkpoint_path=args.checkpoint,
                         checkpoint_every=50 if args.checkpoint else 0)
    else:
        model = build_model(cfg)
        opt = Adam(lr=args.lr)
        params = model.init(key)
        opt_state = opt.init(params)
        step = jax.jit(make_train_step(cfg, shape, opt))
        out = train_loop(step, params, opt_state, batch_iter(),
                         steps=args.steps, backup=backup,
                         checkpoint_path=args.checkpoint,
                         checkpoint_every=50 if args.checkpoint else 0)
    last = out["history"][-1]
    print(f"[train] done: {last}")


if __name__ == "__main__":
    main()
