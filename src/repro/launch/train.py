"""Training launcher — a thin CLI over :class:`repro.api.Session`.

On the container (CPU) this runs REDUCED variants on a small forced-host
mesh; on a real TPU slice the same flags drive the full configs on the
production mesh. The FHDP strategy is the paper's system; ``tensor`` is
the datacenter-style baseline; ``fedavg``/``fl_pipeline`` run FedAvg
rounds instead of steps. All wiring (mesh, devices, strategy, hooks)
lives in :mod:`repro.api` — this file only parses flags.

  PYTHONPATH=src python -m repro.launch.train --arch flad-vision \
      --strategy pipeline --steps 50 --devices 8 --mesh 2,4
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="flad-vision")
    ap.add_argument("--shape", default=None, help="named shape or 'SEQxBATCH'")
    ap.add_argument("--strategy", default="pipeline",
                    choices=["tensor", "pipeline", "fedavg", "fl_pipeline",
                             "swift_pipeline", "hier_fl", "async_hier_fl",
                             "distill_fl"])
    ap.add_argument("--steps", type=int, default=50,
                    help="train steps (FL strategies: rounds)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--local-steps", type=int, default=1,
                    help="local steps per FL round (fedavg/fl_pipeline)")
    ap.add_argument("--fleet", default="nano*4,agx*2",
                    help="heterogeneous fleet spec for swift_pipeline, "
                         "e.g. 'nano*4,nx*2,agx'")
    ap.add_argument("--topology", default="2@nano*2,agx*2",
                    help="hier_fl vehicle->edge->cloud topology: "
                         "'E@FLEET', e.g. '2@nano*2,agx*2' = 2 edge pods "
                         "over that fleet")
    ap.add_argument("--codec", default="none",
                    choices=["none", "int8", "topk"],
                    help="hier_fl uplink codec (update compression)")
    ap.add_argument("--async-decay", type=float, default=None,
                    help="hier_fl: staleness decay per missed round "
                         "deadline (enables the predicted-staleness "
                         "merge); async_hier_fl: the observed-staleness "
                         "decay (default 0.5)")
    ap.add_argument("--async-clock", type=float, default=None,
                    help="async_hier_fl: cloud merge period in simulated "
                         "seconds (default: infinite deadline — the "
                         "synchronous special case)")
    ap.add_argument("--migrate-every", type=float, default=None,
                    help="async_hier_fl: simulated seconds per mobility "
                         "step; vehicles migrate between edge pods when "
                         "they leave their pod's comm radius")
    ap.add_argument("--compute-jitter", type=float, default=0.0,
                    help="async_hier_fl: per-(vehicle, round) uniform "
                         "compute slowdown fraction")
    ap.add_argument("--lora-rank", type=int, default=4,
                    help="distill_fl: LoRA rank of the per-pod adapters")
    ap.add_argument("--kd-weight", type=float, default=0.3,
                    help="distill_fl: weight of the teacher-distillation "
                         "terms in the student loss")
    ap.add_argument("--mix", type=float, default=0.5,
                    help="distill_fl: per-round blend toward the cloud "
                         "merge (1 = global FedAvg-of-adapters, 0 = "
                         "fully local per-pod adapters)")
    ap.add_argument("--distill-warmup", type=int, default=20,
                    help="distill_fl: supervised warmup steps for the "
                         "cloud AD-LLM before it freezes as the teacher")
    ap.add_argument("--depart", default=None, metavar="STEP:VID",
                    help="swift_pipeline: simulate vehicle VID departing "
                         "after step STEP (live template repartition)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU testing)")
    ap.add_argument("--mesh", default="2,4", help="data,model (or pod,data,model)")
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (TPU scale)")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="async_hier_fl: write a Perfetto-loadable "
                         "sim-time trace (repro.obs) to PATH")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write a repro.obs metrics-registry snapshot "
                         "(JSON) to PATH")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.api import LoopHooks, MeshSpec, Session
    from repro.recovery.backup import EdgeBackup

    options = {}
    fl = args.strategy in ("fedavg", "fl_pipeline", "hier_fl",
                           "async_hier_fl", "distill_fl")
    if fl:
        options["local_steps"] = args.local_steps
    if args.strategy == "swift_pipeline":
        options["fleet"] = args.fleet
    if args.strategy == "hier_fl":
        options.update(topology=args.topology, codec=args.codec,
                       async_decay=args.async_decay)
    if args.strategy == "async_hier_fl":
        options.update(topology=args.topology, codec=args.codec,
                       clock=args.async_clock,
                       migrate_every=args.migrate_every,
                       compute_jitter=args.compute_jitter)
        if args.async_decay is not None:
            options["decay"] = args.async_decay
    if args.strategy == "distill_fl":
        options.update(topology=args.topology, codec=args.codec,
                       async_decay=args.async_decay,
                       lora_rank=args.lora_rank,
                       kd_weight=args.kd_weight, mix=args.mix,
                       warmup_steps=args.distill_warmup)
    session = Session(
        args.arch, full=args.full, shape=args.shape,
        mesh=MeshSpec.parse(args.mesh, devices=args.devices or None),
        strategy=args.strategy, learning_rate=args.lr, seed=args.seed,
        hooks=LoopHooks(log_every=1 if fl else 10,
                        backup=EdgeBackup(interval=10),
                        checkpoint_path=args.checkpoint,
                        checkpoint_every=50 if args.checkpoint else 0),
        **options)
    if args.depart:
        if args.strategy != "swift_pipeline":
            raise SystemExit("--depart requires --strategy swift_pipeline")
        import dataclasses

        from repro.recovery.recover import Repartitioner
        step_s, vid_s = args.depart.split(":")
        session.hooks = dataclasses.replace(
            session.hooks,
            repartition=Repartitioner(session, {int(step_s): int(vid_s)}))
    out = session.run(args.steps, trace=args.trace, metrics=args.metrics)
    last = out["history"][-1]
    print(f"[train] done: {last}")
    if args.trace:
        print(f"[train] trace written to {out['trace_path']} "
              f"(load at https://ui.perfetto.dev)")
    if args.metrics:
        print(f"[train] metrics snapshot written to {out['metrics_path']}")


if __name__ == "__main__":
    main()
