"""Serving launcher (paper Fig. 2 inference procedure) — a thin CLI over
:meth:`repro.api.Session.serve`.

Vehicles send vision-encoder features to the edge; the edge AD-LLM
prefills the feature+instruction context and decodes waypoint tokens /
regresses waypoints, returned to the vehicle's PID controller. The
batched prefill/decode driver lives in :mod:`repro.api.serving`.

  PYTHONPATH=src python -m repro.launch.serve --arch flad-adllm \
      --batch 8 --decode-steps 16
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="flad-adllm")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--context", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--requests", type=int, default=3,
                    help="number of request batches to serve")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.api import MeshSpec, Session

    session = Session(args.arch, full=args.full, strategy="tensor",
                      seed=args.seed,
                      mesh=MeshSpec((1,), axes=("data",),
                                    devices=args.devices or 0))
    session.serve(requests=args.requests, batch=args.batch,
                  context=args.context, decode_steps=args.decode_steps)


if __name__ == "__main__":
    main()
