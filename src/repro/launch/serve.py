"""Serving launcher (paper Fig. 2 inference procedure).

Vehicles send vision-encoder features to the edge; the edge AD-LLM
prefills the feature+instruction context and decodes waypoint tokens /
regresses waypoints, returned to the vehicle's PID controller. This
driver batches requests, runs prefill once and decode steps against the
KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch flad-adllm \
      --batch 8 --decode-steps 16
"""
import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="flad-adllm")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--context", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--requests", type=int, default=3,
                    help="number of request batches to serve")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + f" --xla_force_host_platform_device_count={args.devices}").strip()

    import jax
    import jax.numpy as jnp

    from repro.config import ShapeConfig
    from repro.configs import get_config
    from repro.configs.common import reduced
    from repro.core.steps import make_prefill_step, make_serve_step
    from repro.models import build_model

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    shape = ShapeConfig("serve", args.context + args.decode_steps,
                        args.batch, "decode")
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    prefill = jax.jit(make_prefill_step(cfg, shape))
    serve = jax.jit(make_serve_step(cfg, shape))

    total_toks = 0
    t0 = time.time()
    for r in range(args.requests):
        key, k1 = jax.random.split(key)
        ctx = jax.random.randint(k1, (args.batch, args.context), 0,
                                 cfg.vocab_size, jnp.int32)
        state = model.init_state(args.batch, shape.seq_len)
        batch = {"tokens": ctx}
        if cfg.family == "encdec":
            batch = {"frames": jax.random.normal(
                k1, (args.batch, args.context, cfg.prefix_dim)),
                "tokens": ctx}
        logits, state = prefill(params, batch, state)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [tok]
        for i in range(args.decode_steps):
            logits, state = serve(params, tok, state, args.context + i)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(tok)
        seqs = jnp.concatenate(out, axis=1)
        total_toks += int(seqs.size)
        print(f"[serve] request batch {r}: generated {seqs.shape} "
              f"first row: {seqs[0, :8].tolist()}")
    dt = time.time() - t0
    print(f"[serve] {total_toks} tokens in {dt:.2f}s "
          f"({total_toks / dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
