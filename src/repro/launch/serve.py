"""Serving launcher (paper Fig. 2 inference procedure) — a thin CLI over
:meth:`repro.api.Session.serve`.

Vehicles send vision-encoder features to the edge; the edge AD-LLM
prefills the feature+instruction context and decodes waypoint tokens /
regresses waypoints, returned to the vehicle's PID controller. The
batched prefill/decode driver lives in :mod:`repro.api.serving`; the
paged-KV continuous-batching tier (``--scheduler continuous``) lives in
:mod:`repro.serve`.

  PYTHONPATH=src python -m repro.launch.serve --arch flad-adllm \
      --batch 8 --decode-steps 16
  PYTHONPATH=src python -m repro.launch.serve --arch flad-adllm \
      --scheduler continuous --slots 4 --cache int8 --fleet nano*2,agx*2
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="flad-adllm")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--context", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=16,
                    help="decode steps per batch (legacy scheduler)")
    ap.add_argument("--requests", type=int, default=3,
                    help="request batches (legacy) / trace length "
                         "(continuous)")
    ap.add_argument("--scheduler", choices=("legacy", "continuous"),
                    default="legacy")
    ap.add_argument("--slots", type=int, default=0,
                    help="continuous-batching lanes (default: --batch)")
    ap.add_argument("--block-size", type=int, default=8,
                    help="KV block size in tokens (continuous)")
    ap.add_argument("--cache", choices=("fp32", "int8"), default="fp32",
                    help="paged KV-cache storage mode (continuous)")
    ap.add_argument("--prefill", choices=("chunked", "monolithic"),
                    default="chunked",
                    help="prompt prefill path: paged chunks interleaved "
                         "with decode, or the bucketed monolithic "
                         "baseline (continuous)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="tokens per prefill chunk (continuous, chunked)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share pod prompt-prefix KV blocks across "
                         "requests (continuous, chunked prefill only)")
    ap.add_argument("--fleet", default="nano*2,agx*2",
                    help="vehicle fleet spec for the load generator "
                         "(continuous)")
    ap.add_argument("--speculative", action="store_true",
                    help="draft-verify speculative decoding (continuous, "
                         "greedy; streams stay bit-identical)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="draft tokens proposed per lane per step "
                         "(with --speculative)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Perfetto-loadable sim-time trace of "
                         "the final warm pass to PATH (continuous)")
    ap.add_argument("--sampling", choices=("greedy", "temperature"),
                    default="greedy")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.api import MeshSpec, Session

    session = Session(args.arch, full=args.full, strategy="tensor",
                      seed=args.seed,
                      mesh=MeshSpec((1,), axes=("data",),
                                    devices=args.devices or 0))
    kw = {}
    if args.scheduler == "continuous":
        kw = dict(block_size=args.block_size, cache=args.cache,
                  fleet=args.fleet, prefill=args.prefill,
                  prefill_chunk=args.prefill_chunk,
                  prefix_cache=args.prefix_cache, trace=args.trace,
                  speculative=args.speculative, draft_k=args.draft_k)
    elif args.trace:
        raise SystemExit("--trace requires --scheduler continuous")
    elif args.speculative:
        raise SystemExit("--speculative requires --scheduler continuous")
    report = session.serve(requests=args.requests,
                           batch=args.slots or args.batch,
                           context=args.context,
                           decode_steps=args.decode_steps,
                           scheduler=args.scheduler, sampling=args.sampling,
                           temperature=args.temperature, **kw)
    if args.trace:
        print(f"[serve] trace written to {report['trace_path']} "
              f"(load at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
