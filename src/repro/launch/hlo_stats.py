"""Parse compiled HLO for roofline inputs.

``collective_bytes(hlo_text)`` sums the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute in
the (post-SPMD, per-device) module — ``cost_analysis`` does not report
collective traffic, so this is the collective roofline term's numerator.

``count_ops`` tallies op kinds (used to spot remat-duplicated compute and
layout-change churn during perf iterations).
"""
from __future__ import annotations

import math
import re
from collections import Counter
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%name = TYPE op-name(` — TYPE may be a tuple `(bf16[..], ...)`.
_LINE_RE = re.compile(
    r"=\s+(?P<type>\([^)]*\)|\S+)\s+"
    r"(?P<op>" + "|".join(_COLLECTIVES) + r")(?P<suffix>-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict[str, dict]:
    """Per-collective-kind {count, bytes} from per-device HLO text."""
    out: Dict[str, dict] = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for m in _LINE_RE.finditer(hlo_text):
        if m.group("suffix") == "-start":
            continue  # avoid double counting async pairs (tuple holds both)
        op = m.group("op")
        out[op]["count"] += 1
        out[op]["bytes"] += _type_bytes(m.group("type"))
    return out


def collective_bytes(hlo_text: str) -> int:
    return sum(v["bytes"] for v in collective_stats(hlo_text).values())


_OP_RE = re.compile(r"=\s+(?:\([^)]*\)|\S+)\s+([a-z][a-z0-9-]*)\(")


def count_ops(hlo_text: str) -> Counter:
    return Counter(m.group(1) for m in _OP_RE.finditer(hlo_text))


def fusion_flops_fallback(cost: dict) -> float:
    return float(cost.get("flops", 0.0))


def roofline_terms(*, flops: float, hbm_bytes: float, coll_bytes: float,
                   hw) -> dict:
    """Three per-chip roofline terms in seconds (inputs are per-device)."""
    return {
        "compute_s": flops / hw.peak_flops,
        "memory_s": hbm_bytes / hw.hbm_bw,
        "collective_s": coll_bytes / hw.ici_bw,
    }


def dominant(terms: dict) -> str:
    return max(terms, key=lambda k: terms[k]).replace("_s", "")
