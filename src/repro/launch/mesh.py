"""Mesh factories.

``make_production_mesh`` builds the target deployment mesh:
  single-pod : (16, 16)    axes (data, model)   = 256 chips (one v5e pod)
  multi-pod  : (2, 16, 16) axes (pod, data, model) = 512 chips

FLAD mapping: ``pod`` = cloud regions, ``data`` = vehicles/edge clients,
``model`` = intra-cluster pipeline/tensor ranks.

Functions (never module-level constants) so importing this module does not
touch jax device state — the dry-run must set XLA_FLAGS before first init.
Prefer the declarative :class:`repro.api.MeshSpec` front end, which also
handles host-device forcing.
"""
from __future__ import annotations

import jax


def _mk(shape, axes):
    # axis_types only exists on newer jax; older versions default to Auto
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


#: public alias used by repro.api.MeshSpec
make_mesh = _mk


#: deployment shapes, keyed by multi_pod (shared with repro.api.MeshSpec)
PRODUCTION_SHAPES = {False: (16, 16), True: (2, 16, 16)}


def make_production_mesh(*, multi_pod: bool = False):
    shape = PRODUCTION_SHAPES[multi_pod]
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh over forced host devices for CPU tests."""
    if pod:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))


def require_host_devices(n: int) -> None:
    """Assert the forced-host-platform device count is available."""
    have = len(jax.devices())
    if have < n:
        raise RuntimeError(
            f"need {n} devices, have {have}; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            f"the first jax import")
