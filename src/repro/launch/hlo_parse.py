"""Trip-count-aware cost extraction from optimized HLO text.

``xla::HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports) visits a
``while`` body ONCE — every jax.lax.scan (layer stacks, KV-chunk loops,
microbatch pipelines) is undercounted by its trip count, and collectives
inside scanned FSDP layers vanish from naive text sums. This module parses
the per-device HLO, recovers static trip counts from loop conditions, and
walks the call graph multiplying costs through nested loops.

Reported:
  * ``flops``            — dot/convolution FLOPs (dominant; elementwise ops
                           are ignored and that is documented in §Roofline)
  * ``bytes``            — operand+result bytes per instruction (HBM-traffic
                           proxy, same definition cost_analysis uses)
  * ``collectives``      — per-kind {count, bytes} with loop multipliers
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_CFG = re.compile(r'known_trip_count.{0,8}?"n"\s*:\s*"?(\d+)')

_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")
_INSTR_START = re.compile(r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s")

_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONSTANT = re.compile(r"constant\((-?\d+)\)")
_DIRECTION = re.compile(r"direction=(\w+)")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute", "ragged-all-to-all")

# ops with no real memory traffic of their own
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "opt-barrier", "copy-start", "copy-done"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr]
    order: List[str]


def _operand_names(line: str) -> List[str]:
    """Names inside the top-level operand parens of an instruction line."""
    i = line.find("(")
    if i < 0:
        return []
    depth = 0
    j = i
    for j in range(i, len(line)):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
            if depth == 0:
                break
    return re.findall(r"%([\w.\-]+)", line[i:j + 1])


def _logical_lines(text: str):
    """Join wrapped instruction lines (the HLO printer wraps long tuples)."""
    buf: Optional[str] = None
    for raw in text.splitlines():
        s = raw.strip()
        starts_new = (_INSTR_START.match(raw) or s == "}" or
                      (raw.rstrip().endswith("{") and " = " not in raw))
        if starts_new:
            if buf is not None:
                yield buf
            buf = raw
        elif buf is not None and s:
            buf += " " + s
        elif s:
            yield raw
    if buf is not None:
        yield buf


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in _logical_lines(text):
        if cur is None:
            if raw.rstrip().endswith("{") and " = " not in raw:
                m = _COMP_HDR.match(raw)
                if m:
                    cur = Computation(m.group(1), {}, [])
                    if raw.lstrip().startswith("ENTRY"):
                        entry = cur.name
                continue
        else:
            if raw.strip() == "}" or raw.rstrip().endswith("} // %" + cur.name):
                comps[cur.name] = cur
                cur = None
                continue
            m = _INSTR.match(raw)
            if m:
                name, type_str, op = m.group(1), m.group(2), m.group(3)
                body = raw[m.end(3):]
                cur.instrs[name] = Instr(name, type_str, op,
                                         _operand_names(body), raw)
                cur.order.append(name)
    if cur is not None:  # unterminated (defensive)
        comps[cur.name] = cur
    return comps, entry


def _trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Recover the static trip count from a loop condition computation."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    # find the compare; resolve its constant operand (possibly via fusion)
    def find_constant(comp: Computation, name: str) -> Optional[int]:
        ins = comp.instrs.get(name)
        if ins is None:
            return None
        if ins.op == "constant":
            m = _CONSTANT.search(ins.line)
            return int(m.group(1)) if m else None
        return None

    def scan_comp(comp: Computation) -> Optional[Tuple[str, int]]:
        for nm in comp.order:
            ins = comp.instrs[nm]
            if ins.op == "compare":
                d = _DIRECTION.search(ins.line)
                direction = d.group(1) if d else "LT"
                for op_name in ins.operands:
                    c = find_constant(comp, op_name)
                    if c is None and op_name in comps.get(
                            "", Computation("", {}, [])).instrs:
                        pass
                    if c is not None:
                        return direction, c
            elif ins.op == "fusion":
                m = _CALLS.search(ins.line)
                if m and m.group(1) in comps:
                    # constants may be passed as fusion operands
                    inner = scan_comp(comps[m.group(1)])
                    if inner and inner[1] is not None:
                        return inner
                    # compare inside, constant outside: check operands
                    for op_name in ins.operands:
                        c = find_constant(comp, op_name)
                        if c is not None:
                            icomp = comps[m.group(1)]
                            for nm2 in icomp.order:
                                if icomp.instrs[nm2].op == "compare":
                                    d = _DIRECTION.search(icomp.instrs[nm2].line)
                                    return (d.group(1) if d else "LT", c)
        return None

    got = scan_comp(cond)
    if not got:
        return 1
    direction, c = got
    if direction in ("LT", "GT"):
        return max(int(c), 1)
    if direction in ("LE", "GE"):
        return max(int(c) + 1, 1)
    return 1


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_elems = _shape_elems(ins.type_str)
    m = _LHS_CDIMS.search(ins.line)
    contracted = 1
    if m and ins.operands:
        lhs = comp.instrs.get(ins.operands[0])
        if lhs is not None:
            dims = _shape_dims(lhs.type_str)
            for d in (int(x) for x in m.group(1).split(",") if x):
                if d < len(dims):
                    contracted *= dims[d]
    return 2.0 * out_elems * contracted


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collectives: Optional[dict] = None
    op_counts: Optional[Counter] = None

    def __post_init__(self):
        if self.collectives is None:
            self.collectives = {k: {"count": 0, "bytes": 0.0}
                                for k in COLLECTIVE_OPS}
        if self.op_counts is None:
            self.op_counts = Counter()


_TRANSCENDENTAL = {"exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                   "sine", "cosine", "logistic", "exponential-minus-one"}


def _instr_bytes(comp: Computation, ins: Instr) -> float:
    """Approximate HBM traffic of one instruction: result + operand bytes,
    EXCEPT pass-through operands of in-place updates. A loop-carried
    dynamic-update-slice (KV-cache writes, scan ys) lists the full buffer
    as operand AND result while XLA aliases them — counting both charges a
    32k-entry cache 48 layers x 3 GB per decode step (measured 300x
    overcount). When an operand's byte size equals the result's, we charge
    the remaining (update-sized) operands twice (read-modify-write) and
    skip the aliased buffer."""
    res = float(_shape_bytes(ins.type_str))
    ops = []
    for nm in ins.operands:
        o = comp.instrs.get(nm)
        if o is not None and o.op not in ("tuple",):
            ops.append(float(_shape_bytes(o.type_str)))
    if ins.op in ("fusion", "dynamic-update-slice") and ops:
        passthrough = [b for b in ops if b == res]
        if passthrough:
            others = sum(b for b in ops if b != res)
            return 2.0 * others + (res if others == 0 else others)
    return res + sum(ops)


def accumulate(comps: Dict[str, Computation], name: str, mult: float,
               cost: Cost, fused: bool = False) -> None:
    comp = comps.get(name)
    if comp is None:
        return
    for nm in comp.order:
        ins = comp.instrs[nm]
        op = ins.op
        if op in _FREE_OPS:
            continue
        cost.op_counts[op] += mult
        if op == "while":
            body = _BODY.search(ins.line)
            cfg = _TRIP_CFG.search(ins.line)  # XLA-annotated trip count
            if cfg:
                trips = int(cfg.group(1))
            else:
                cond = _COND.search(ins.line)
                trips = _trip_count(comps, cond.group(1)) if cond else 1
            if body:
                accumulate(comps, body.group(1), mult * max(trips, 1), cost)
            continue
        if op in ("call", "conditional", "async-start"):
            m = _TO_APPLY.search(ins.line) or _CALLS.search(ins.line)
            if m:
                accumulate(comps, m.group(1), mult, cost)
            continue
        if op == "fusion":
            # count dot/collective flops inside; bytes from the fusion itself
            cost.bytes += mult * _instr_bytes(comp, ins)
            m = _CALLS.search(ins.line)
            if m:
                accumulate(comps, m.group(1), mult, cost, fused=True)
            continue
        base = op.replace("-start", "").replace("-done", "")
        if base in COLLECTIVE_OPS:
            if op.endswith("-start"):
                continue  # tuple holds both operand+result; count done/plain
            cost.collectives[base]["count"] += mult
            cost.collectives[base]["bytes"] += mult * _shape_bytes(ins.type_str)
            cost.bytes += mult * _instr_bytes(comp, ins)
            continue
        if op in ("dot", "convolution"):
            cost.flops += mult * _dot_flops(comp, ins)
        if op in _TRANSCENDENTAL:
            cost.transcendentals += mult * _shape_elems(ins.type_str)
        if not fused:
            cost.bytes += mult * _instr_bytes(comp, ins)
        elif op in ("dot", "convolution"):
            cost.bytes += mult * _instr_bytes(comp, ins)


def module_cost(text: str) -> Cost:
    comps, entry = parse_module(text)
    cost = Cost()
    if entry is None:
        # fall back: accumulate every computation named like an entry
        entry = next(iter(comps)) if comps else None
    if entry is not None:
        accumulate(comps, entry, 1.0, cost)
    return cost


def collective_bytes_total(cost: Cost) -> float:
    return sum(v["bytes"] for v in cost.collectives.values())
