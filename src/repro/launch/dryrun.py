import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run: ``.lower().compile()`` every (arch × shape × mesh).

The two lines above run before any other import — jax locks the device count
on first backend init, and the production meshes need 512 host placeholders.
Do NOT set this flag globally; tests and benches see one device.

Per pair this records: compile success, ``memory_analysis`` (fits/overflow),
``cost_analysis`` FLOPs/bytes (per-device, post-SPMD), the collective
schedule parsed from compiled HLO, and the three roofline terms.

The lowering itself lives in :mod:`repro.api.lowering` (also reachable as
``Session.lower()``); this launcher adds the sweep + HLO analysis.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out results/dryrun.json
"""
import argparse
import json
import time
import traceback
from typing import Optional

from repro.api.lowering import build_lowered, default_grad_accum  # noqa: F401
from repro.api.mesh import MeshSpec
from repro.config import INPUT_SHAPES, TPU_V5E
from repro.configs import ARCH_IDS, get_config
from repro.launch import hlo_parse, hlo_stats

ASSIGNED = [a for a in ARCH_IDS if not a.startswith("flad_")]


def analyze(compiled, mesh, hw=TPU_V5E) -> dict:
    """Roofline inputs from the compiled per-device module.

    Primary source: the trip-count-aware HLO walk (hlo_parse) — XLA's own
    ``cost_analysis`` counts while bodies once, undercounting every scanned
    layer stack. The raw XLA numbers are kept alongside for reference.
    """
    out = {}
    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        out["xla_flops"] = float(cost.get("flops", 0.0))
        out["xla_bytes"] = float(cost.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        out["cost_error"] = repr(e)
    try:
        mem = compiled.memory_analysis()
        out["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)}
        out["peak_bytes"] = (out["memory"].get("argument_size_in_bytes", 0)
                             + out["memory"].get("output_size_in_bytes", 0)
                             + out["memory"].get("temp_size_in_bytes", 0)
                             - out["memory"].get("alias_size_in_bytes", 0))
        out["fits_hbm"] = out["peak_bytes"] <= hw.hbm_bytes
    except Exception as e:  # pragma: no cover
        out["memory_error"] = repr(e)
    try:
        txt = compiled.as_text()
        cost = hlo_parse.module_cost(txt)
        out["flops"] = cost.flops
        out["hbm_bytes"] = cost.bytes
        out["collectives"] = {k: v for k, v in cost.collectives.items()
                              if v["count"]}
        out["collective_bytes"] = hlo_parse.collective_bytes_total(cost)
        out["top_ops"] = dict(cost.op_counts.most_common(12))
    except Exception as e:  # pragma: no cover
        out["hlo_error"] = repr(e)
        out["flops"] = out.get("xla_flops", 0.0)
        out["hbm_bytes"] = out.get("xla_bytes", 0.0)
        out["collective_bytes"] = 0
    out["roofline"] = hlo_stats.roofline_terms(
        flops=out.get("flops", 0.0), hbm_bytes=out.get("hbm_bytes", 0.0),
        coll_bytes=out.get("collective_bytes", 0), hw=hw)
    out["dominant"] = hlo_stats.dominant(out["roofline"])
    return out


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             strategy: str = "tensor", seq_shard: bool = True,
             fsdp: bool = True, remat: bool = True, verbose: bool = True,
             grad_accum: Optional[int] = None,
             keep_compiled: bool = False) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    dbg = os.environ.get("DRYRUN_MESH")  # e.g. "4,4" or "2,2,4" for debugging
    spec = MeshSpec.parse(dbg, devices=0) if dbg \
        else MeshSpec(production=True, multi_pod=multi_pod, devices=0)
    mesh = spec.build()
    rec = {"arch": arch, "shape": shape_name, "strategy": strategy,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "multi_pod": multi_pod, "seq_shard": seq_shard, "fsdp": fsdp}
    t0 = time.time()
    try:
        lowered = build_lowered(cfg, shape, mesh, strategy=strategy,
                                seq_shard=seq_shard, fsdp=fsdp, remat=remat,
                                grad_accum=grad_accum)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        rec.update(analyze(compiled, mesh))
        n = cfg.param_count()
        rec["params"] = n
        rec["active_params"] = cfg.active_param_count()
        # useful-model-FLOPs ratio (per device, fwd+bwd for train)
        tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
        mult = 6 if shape.kind == "train" else 2
        model_flops = mult * cfg.active_param_count() * tokens
        per_dev = model_flops / mesh.devices.size
        rec["model_flops_per_dev"] = per_dev
        rec["useful_ratio"] = (per_dev / rec["flops"]) if rec.get("flops") \
            else None
        rec["ok"] = True
        if keep_compiled:
            rec["_compiled"] = compiled
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    if verbose:
        status = "OK " if rec["ok"] else "FAIL"
        extra = ""
        if rec["ok"]:
            r = rec["roofline"]
            extra = (f" lower={rec['lower_s']}s compile={rec['compile_s']}s "
                     f"compute={r['compute_s']:.4f}s mem={r['memory_s']:.4f}s "
                     f"coll={r['collective_s']:.4f}s dom={rec['dominant']}")
        else:
            extra = " " + rec["error"][:200]
        print(f"[dryrun] {status} {arch:22s} {shape_name:12s} "
              f"{rec['mesh']:8s}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--strategy", default="tensor",
                    choices=["tensor", "pipeline"])
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--async-clock", type=float, default=None,
                    help="dry-run the async event schedule (timing only, "
                         "no tensors): cloud merge period in simulated "
                         "seconds; 0 = infinite deadline (sync)")
    ap.add_argument("--migrate-every", type=float, default=None,
                    help="async schedule dry-run: simulated seconds per "
                         "DTMC mobility step")
    ap.add_argument("--compute-jitter", type=float, default=0.0,
                    help="async schedule dry-run: per-round uniform "
                         "compute slowdown fraction")
    ap.add_argument("--topology", default="2@nano*2,agx*2",
                    help="async schedule dry-run topology spec")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="with --async-clock: write a Perfetto-loadable "
                         "sim-time trace of the dry-run schedule to PATH")
    args = ap.parse_args()

    if args.trace is not None and args.async_clock is None:
        raise SystemExit("--trace requires --async-clock (the timing-only "
                         "schedule is what gets traced)")
    if args.async_clock is not None:
        # timing-only event-schedule exploration: no params, no lowering —
        # the event engine runs with program=None
        from repro.comm.events import simulate_schedule
        from repro.comm.topology import parse_topology
        from repro.obs import resolve_tracer
        tracer, trace_path = resolve_tracer(args.trace)
        topo = parse_topology(args.topology)
        stats = simulate_schedule(
            topo, clock=args.async_clock or None,
            jitter=args.compute_jitter,
            migrate_every=args.migrate_every, tracer=tracer)
        print(f"[dryrun] async schedule {args.topology}: "
              f"{len(stats['merges'])} merges in "
              f"{stats['sim_time_s']:.3f}s simulated "
              f"(period {stats['mean_period_s']:.3f}s, mean staleness "
              f"{stats['mean_staleness']:.3f}, "
              f"{stats['n_migrations']} migrations, "
              f"{stats['events']} events)")
        if trace_path is not None:
            tracer.save(trace_path)
            print(f"[dryrun] trace written to {trace_path} "
                  f"(load at https://ui.perfetto.dev)")
        if not (args.arch or args.all):
            return

    archs = ASSIGNED if (args.all or not args.arch) else [args.arch.replace("-", "_").replace(".", "_")]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                records.append(run_pair(
                    arch, shape, multi_pod=mp, strategy=args.strategy,
                    seq_shard=not args.no_seq_shard, fsdp=not args.no_fsdp))
    n_ok = sum(r["ok"] for r in records)
    print(f"[dryrun] {n_ok}/{len(records)} pairs lowered+compiled")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1, default=str)
        print(f"[dryrun] wrote {args.out}")
    if n_ok != len(records):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
