"""Jittable step builders for the tensor (baseline) strategy.

``make_train_step``  — loss + grad + optimizer update (train shapes)
``make_prefill_step``— context ingestion into the decode state
``make_serve_step``  — one-token decode against a KV cache / SSM state

The FHDP (FL × pipeline) strategy lives in :mod:`repro.core.fhdp`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.configs.common import effective_window
from repro.models import build_model
from repro.train.optimizer import Adam


def make_train_step(cfg: ModelConfig, shape: ShapeConfig,
                    optimizer: Optional[Adam] = None, *, remat: bool = True,
                    grad_accum: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``grad_accum > 1`` scans over microbatches of the global batch,
    accumulating gradients — divides activation memory by the accumulation
    factor at the cost of re-gathering FSDP-sharded weights per microbatch.
    """
    model = build_model(cfg)
    opt = optimizer or Adam()
    window = effective_window(cfg, shape)

    def loss_fn(p, b):
        return model.loss(p, b, remat=remat, window=window)

    if grad_accum <= 1:
        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, dict(metrics, loss=loss)

        return train_step

    def train_step(params, opt_state, batch):
        def split(x):
            a = grad_accum
            return x.reshape((a, x.shape[0] // a) + x.shape[1:])

        mbs = jax.tree.map(split, batch)

        def body(acc, mb):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / grad_accum,
                acc, grads)
            return acc, (loss, metrics)

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        grads, (losses, metrics) = jax.lax.scan(body, zeros, mbs)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = jax.tree.map(lambda x: x.mean(), metrics)
        return params, opt_state, dict(metrics, loss=losses.mean())

    return train_step


def make_loss_fn(cfg: ModelConfig, shape: ShapeConfig, *, remat: bool = True):
    model = build_model(cfg)
    window = effective_window(cfg, shape)

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=remat, window=window)

    return loss_fn


def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig):
    model = build_model(cfg)
    window = effective_window(cfg, shape)

    def prefill_step(params, batch, state):
        if cfg.family in ("ssm",):
            return model.prefill(params, batch, state)
        return model.prefill(params, batch, state, window=window)

    return prefill_step


def make_serve_step(cfg: ModelConfig, shape: ShapeConfig):
    """serve_step(params, tokens [B,1], state, pos) -> (logits, state)."""
    model = build_model(cfg)
    window = effective_window(cfg, shape)

    def serve_step(params, tokens, state, pos):
        return model.decode_step(params, tokens, state, pos, window=window)

    if cfg.family == "ssm":
        def serve_step(params, tokens, state, pos):   # noqa: F811
            return model.decode_step(params, tokens, state, pos)

    return serve_step


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocation."""
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def abstract_opt_state(params_shape, optimizer: Optional[Adam] = None):
    opt = optimizer or Adam()
    return jax.eval_shape(opt.init, params_shape)
