"""Hierarchical federated averaging (paper §3.1).

Vehicle → edge → cloud aggregation realized on the mesh:
  * clients are slices of the ``data`` axis (paper: vehicles under one edge);
  * edge aggregation   = mean over ``data`` within a pod;
  * cloud aggregation  = mean over ``pod`` across pods.

Two operating modes:

1. **Client-stacked params** (faithful FL): params carry a leading client
   axis sharded over ``data`` (and ``pod``); each client runs E local steps
   with zero cross-client traffic, then :func:`fedavg` averages parameters.
   FedAvg with E=1 local SGD step == data-parallel SGD; E>1 is true FedAvg.

2. **Gradient mean** (FedSGD / the tensor baseline): a plain psum-mean of
   grads over (pod, data) — what ``pjit`` does implicitly when the loss is a
   global-batch mean.

The vehicle -> edge -> cloud fabric itself lives in :mod:`repro.comm`:
pass ``topology=`` to :func:`fedavg` for the explicit two-tier (edge
partial-average, cloud merge) aggregation over declared link models, or
use the ``hier_fl`` strategy for the full compressed, staleness-aware
round. Without a topology this module's mean is a *flat* client-axis
reduction — whatever tree XLA picks, with no link costs attached.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import sharding as shd


def stack_clients(params, n_clients: int):
    """Replicate params into a leading client axis [C, ...]."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape), params)


def client_specs(mesh: Mesh, params_shape, *, fsdp: bool = True):
    """PartitionSpecs for client-stacked params: leading axis over the
    combined data axes, trailing dims per the tensor rules."""
    base = shd.param_specs(mesh, params_shape, fsdp=fsdp)
    dp = shd.batch_axes(mesh)

    def add_leading(spec):
        return P(dp, *spec)

    return jax.tree.map(add_leading, base,
                        is_leaf=lambda x: isinstance(x, P))


def check_weights(weights) -> jnp.ndarray:
    """Validate aggregation weights: a degenerate vector (all-zero,
    negative, or non-finite sum) would silently NaN the global params
    through the normalizing division. Raises when the sum is concrete;
    traced weights must be validated by the caller at build time."""
    w = jnp.asarray(weights, jnp.float32)
    try:
        total = float(w.sum())
    except jax.errors.ConcretizationTypeError:
        return w
    if not math.isfinite(total) or total <= 0.0:
        raise ValueError(
            f"degenerate aggregation weights (sum={total}): the "
            f"normalizing division would NaN the global params; weights "
            f"must be finite with a positive sum")
    return w


def fedavg(client_params, *, weights: Optional[jnp.ndarray] = None,
           topology=None):
    """Average client-stacked params [C, ...] -> global params [...].

    ``weights``: optional [C] client weights (paper: data-volume weighted).
    ``topology``: optional :class:`repro.comm.Topology` — aggregate over
    the explicit vehicle -> edge -> cloud fabric (edge partial averages,
    then the cloud merge) instead of a flat client-axis mean. Without
    it, the mean is flat: XLA picks some reduction tree, but nothing
    models the paper's edge tier, link costs, or compression — that is
    what :mod:`repro.comm` and the ``hier_fl`` strategy provide.
    """
    if weights is not None:
        weights = check_weights(weights)
    if topology is not None:
        from repro.comm.hierarchy import hierarchical_mean
        return hierarchical_mean(client_params, weights, topology)
    if weights is None:
        return jax.tree.map(lambda x: x.mean(axis=0), client_params)
    w = weights / weights.sum()

    def wmean(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
        return (x.astype(jnp.float32) * wb).sum(axis=0).astype(x.dtype)

    return jax.tree.map(wmean, client_params)


def broadcast_round(global_params, n_clients: int):
    """Cloud -> edge -> vehicle model distribution for the next round."""
    return stack_clients(global_params, n_clients)


def make_local_train(step):
    """One client's E local steps via ``lax.scan``: (params, opt_state,
    steps_batches) -> (params', opt_state', last-step metrics). The
    round builders (:func:`make_fl_round` here, ``make_hier_round`` in
    :mod:`repro.comm.hierarchy`) vmap this over the client axis — one
    definition of the local-training contract for both fabrics."""

    def local_train(params, opt_state, steps_batches):
        def body(carry, batch):
            p, o = carry
            p, o, m = step(p, o, batch)
            return (p, o), m

        (params, opt_state), ms = jax.lax.scan(body, (params, opt_state),
                                               steps_batches)
        return params, opt_state, jax.tree.map(lambda x: x[-1], ms)

    return local_train


def make_fl_round(cfg, shape, optimizer, *, local_steps: int = 1,
                  remat: bool = True, client_weights=None):
    """One FL round over client-stacked params.

    fl_round(client_params, client_opt, batches) -> (client_params',
    client_opt', metrics) where ``batches`` carry a leading client axis and a
    second local-step axis: pytree leaves [C, E, B_local, ...].

    ``client_weights``: optional [C] weights for the aggregation — the
    paper's data-volume-weighted averaging (w_i ∝ local sample count);
    None keeps the uniform mean.

    Local steps run under ``jax.vmap`` over the client axis — with the client
    axis sharded over ``data`` this is embarrassingly parallel (no
    collectives until :func:`fedavg`).
    """
    from repro.core.steps import make_train_step
    step = make_train_step(cfg, shape, optimizer, remat=remat)
    w = None if client_weights is None else check_weights(client_weights)
    local_train = make_local_train(step)

    def fl_round(client_params, client_opt, batches):
        C = jax.tree.leaves(client_params)[0].shape[0]
        if w is not None and w.shape != (C,):
            raise ValueError(
                f"client_weights has shape {w.shape}, expected ({C},) to "
                f"match the client axis")
        params, opts, metrics = jax.vmap(local_train)(client_params,
                                                      client_opt, batches)
        avg = fedavg(params, weights=w)
        new_clients = broadcast_round(avg, C)
        return new_clients, opts, metrics

    return fl_round
