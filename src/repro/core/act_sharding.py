"""Activation-sharding constraints at block boundaries.

At train shapes the per-layer residual stream dominates HBM (scan-of-remat
saves one [B/dp, S, d] tensor per layer); sharding its sequence dim over the
``model`` axis (Megatron sequence parallelism) divides that footprint by the
tensor-parallel degree. XLA/GSPMD inserts the required gathers around the
head-sharded attention/FFN matmuls.

The rules are installed for the duration of a trace (``lower()`` runs the
tracing synchronously), so jitted functions capture them:

    with act_rules(batch_axes=("data",), seq_axis="model"):
        lowered = jax.jit(step, ...).lower(...)

Models call :func:`constrain` on the residual stream between blocks; with no
rules installed it is the identity, so tests and single-device runs are
unaffected.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ActRules:
    batch_axes: Optional[Tuple[str, ...]] = None  # residual dim 0
    seq_axis: Optional[str] = None                # residual dim 1
    # mesh axis sizes for divisibility checks (avoids lowering failures on
    # odd dims)
    batch_size_div: int = 1
    seq_div: int = 1
    mesh: object = None          # explicit Mesh -> NamedSharding constraints


_RULES: Optional[ActRules] = None


@contextlib.contextmanager
def act_rules(*, batch_axes=None, seq_axis=None, batch_div=1, seq_div=1,
              mesh=None):
    global _RULES
    prev = _RULES
    _RULES = ActRules(tuple(batch_axes) if batch_axes else None, seq_axis,
                      batch_div, seq_div, mesh)
    try:
        yield
    finally:
        _RULES = prev


def rules_for(mesh, shape_kind: str = "train"):
    """Standard rules for a production mesh: batch over the data axes,
    sequence over ``model`` for train/prefill activations."""
    names = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in names)
    bdiv = 1
    for a in batch:
        bdiv *= mesh.shape[a]
    seq = "model" if "model" in names and shape_kind != "decode" else None
    return dict(batch_axes=batch, seq_axis=seq, batch_div=bdiv,
                seq_div=mesh.shape.get("model", 1) if seq else 1, mesh=mesh)


def current() -> Optional[ActRules]:
    return _RULES


def constrain(x: jax.Array, seq_dim: int = 1) -> jax.Array:
    """Constrain ``x``: dim 0 over the batch axes, ``seq_dim`` over the
    sequence axis (Megatron-SP layout). Identity when no rules installed or
    dims do not divide."""
    r = _RULES
    if r is None or x.ndim <= seq_dim:
        return x
    b = r.batch_axes if (r.batch_axes and x.shape[0] % r.batch_size_div == 0) \
        else None
    s = r.seq_axis if (r.seq_axis and x.shape[seq_dim] % r.seq_div == 0) \
        else None
    if b is None and s is None:
        return x
    spec = [b] + [None] * (x.ndim - 1)
    if s is not None:
        spec[seq_dim] = s
    spec = P(*spec)
    if r.mesh is not None:
        from jax.sharding import NamedSharding
        spec = NamedSharding(r.mesh, spec)
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_map(x: jax.Array, dims: dict) -> jax.Array:
    """Constrain arbitrary dims: {dim: 'batch'|'seq'} (seq = tensor axis)."""
    r = _RULES
    if r is None:
        return x
    spec = [None] * x.ndim
    ok = False
    for d, kind in dims.items():
        if d >= x.ndim:
            continue
        if kind == "batch" and r.batch_axes \
                and x.shape[d] % r.batch_size_div == 0:
            spec[d] = r.batch_axes
            ok = True
        elif kind == "seq" and r.seq_axis and x.shape[d] % r.seq_div == 0:
            spec[d] = r.seq_axis
            ok = True
    if not ok:
        return x
    sp = P(*spec)
    if r.mesh is not None:
        from jax.sharding import NamedSharding
        sp = NamedSharding(r.mesh, sp)
    return jax.lax.with_sharding_constraint(x, sp)


def constrain_vocab(x: jax.Array) -> jax.Array:
    """Constrain logits [B, s, V]: batch over data axes, vocab over the
    tensor axis (vocab-parallel CE)."""
    r = _RULES
    if r is None or x.ndim != 3:
        return x
    b = r.batch_axes if (r.batch_axes and x.shape[0] % r.batch_size_div == 0) \
        else None
    v = r.seq_axis if (r.seq_axis and x.shape[2] % r.seq_div == 0) else None
    if b is None and v is None:
        return x
    spec = P(b, None, v)
    if r.mesh is not None:
        from jax.sharding import NamedSharding
        spec = NamedSharding(r.mesh, spec)
    return jax.lax.with_sharding_constraint(x, spec)
