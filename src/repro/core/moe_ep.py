"""Expert-parallel MoE dispatch via shard_map + all_to_all.

GSPMD cannot partition a scatter into an [E, cap, d] buffer that is sharded
on both dims (it falls back to "involuntary full rematerialization" —
measured 10-40x collective/memory inflation on the 16x16 mesh). This module
does what a datacenter MoE does explicitly:

  * tokens stay on their (data, seq) shard; routing + capacity are LOCAL;
  * each device builds its [E, cap_loc, d] send buffer and ``all_to_all``s
    expert slabs along the ``model`` axis (experts are sharded over
    ``model``, paper-analogue: per-cluster expert placement);
  * expert FFN runs on [E_loc, world*cap_loc, d]; the inverse all_to_all
    returns outputs; the combine is local.

Expert weights are [E, d, de] sharded (model, data, -): the d shards are
all-gathered over ``data`` once per layer inside the block.

Differentiable end-to-end (all_to_all/gather transposes), so it drops into
the jit train step as a shard_map island.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import compat
from repro.config import ModelConfig


def _local_dispatch(xt, logits, e: int, k: int, cap: int):
    """Local top-k routing + capacity assignment (argsort ranking).

    xt: [t, d]; logits: [t, E]. Returns (buf [E, cap, d], slot [t*k],
    keep [t*k], gate_vals [t, k], probs [t, E], gate_idx [t, k]).
    """
    t, d = xt.shape
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                        1e-9)
    flat_e = gate_idx.reshape(t * k)
    order = jnp.argsort(flat_e)
    starts = jnp.searchsorted(flat_e[order], jnp.arange(e))
    pos_sorted = jnp.arange(t * k) - starts[flat_e[order]]
    pos = jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)
    buf = jnp.zeros((e * cap + 1, d), xt.dtype)
    xk = jnp.repeat(xt, k, axis=0)
    buf = buf.at[slot].set(xk, mode="drop")
    return buf[:-1].reshape(e, cap, d), slot, keep, gate_vals, probs, gate_idx


def moe_block_ep(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                 mesh: Mesh, seq_sharded: bool = True):
    """Drop-in replacement for blocks.moe_block under a production mesh."""
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    de = cfg.moe.d_expert
    ep = mesh.shape["model"]
    assert e % ep == 0, (e, ep)
    e_loc = e // ep
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bdiv = 1
    for a in batch_axes:
        bdiv *= mesh.shape[a]
    b, s, d = x.shape
    seq_sharded = seq_sharded and s % ep == 0
    b_sh = batch_axes if (batch_axes and b % bdiv == 0) else None
    t_loc = (b // (bdiv if b_sh else 1)) * (s // (ep if seq_sharded else 1))
    cap = max(int(math.ceil(t_loc * k * cfg.moe.capacity_factor / e)),
              min(t_loc, k))

    xspec = P(b_sh, "model" if seq_sharded else None, None)
    wspec_i = P("model", "data" if "data" in mesh.axis_names else None, None)
    wspec_o = P("model", None, "data" if "data" in mesh.axis_names else None)

    def body(router, wi, wg, wo, xl):
        bl, sl, _ = xl.shape
        t = bl * sl
        xt = xl.reshape(t, d)
        # gather the d-shards of this rank's experts (once per layer)
        if "data" in mesh.axis_names and wi.shape[1] != d:
            wi = lax.all_gather(wi, "data", axis=1, tiled=True)
            wg = lax.all_gather(wg, "data", axis=1, tiled=True)
        if "data" in mesh.axis_names and wo.shape[2] != d:
            wo = lax.all_gather(wo, "data", axis=2, tiled=True)

        logits = xt.astype(jnp.float32) @ router
        buf, slot, keep, gate_vals, probs, gate_idx = _local_dispatch(
            xt, logits, e, k, cap)

        # exchange expert slabs along the model axis
        send = buf.reshape(ep, e_loc, cap, d)
        recv = lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                              tiled=False)          # [ep, e_loc, cap, d]
        expert_in = recv.transpose(1, 0, 2, 3).reshape(e_loc, ep * cap, d)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, wg)) \
            * jnp.einsum("ecd,edf->ecf", expert_in, wi)
        out = jnp.einsum("ecf,efd->ecd", h, wo)     # [e_loc, ep*cap, d]

        back = out.reshape(e_loc, ep, cap, d).transpose(1, 0, 2, 3)
        got = lax.all_to_all(back, "model", split_axis=0, concat_axis=0,
                             tiled=False)           # [ep, e_loc, cap, d]
        flat = jnp.concatenate(
            [got.reshape(e * cap, d),
             jnp.zeros((1, d), got.dtype)], axis=0)
        picked = flat[slot].reshape(t, k, d)
        w = jnp.where(keep.reshape(t, k), gate_vals, 0.0).astype(picked.dtype)
        yl = jnp.einsum("tkd,tk->td", picked, w,
                        preferred_element_type=jnp.float32)
        yl = yl.reshape(bl, sl, d).astype(xl.dtype)

        # Switch-style load-balance + router-z aux (local means, averaged
        # across the mesh so every rank sees the same scalar)
        me = probs.mean(0)
        ce = jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32).mean(0)
        aux = e * jnp.sum(me * ce) * cfg.moe.aux_loss_weight
        zl = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) \
            * cfg.moe.router_z_weight
        aux = lax.pmean(aux + zl, mesh.axis_names)
        return yl, aux

    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(), wspec_i, wspec_i, wspec_o, xspec),
        out_specs=(xspec, P()),
        check_vma=False)
    return fn(p["router"], p["wi"], p["wg"], p["wo"], x)
