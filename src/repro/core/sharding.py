"""Sharding rules for the tensor (baseline) distribution strategy.

Axis conventions (see launch/mesh.py):
  * ``data``  — FL-client / data-parallel axis (paper: vehicles under an edge);
                also used as the FSDP axis for parameters/optimizer state.
  * ``model`` — tensor-parallel axis (heads / d_ff / experts / vocab);
                the FHDP *pipeline* strategy reuses this axis for stages.
  * ``pod``   — cloud-level axis (multi-pod only). Parameters are replicated
                across pods; gradients/params are reduced over it (the
                paper's cloud aggregation).

Every rule validates divisibility against the actual mesh before applying —
odd vocabularies (92553, 32001, 256206) and small head counts degrade to
replication per-dimension instead of failing to lower.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL = "model"
FSDP = "data"          # parameter-sharding axis (ZeRO-3 style)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _fit(mesh: Mesh, dim_size: int, axis):
    """Return axis if dim_size divides evenly over it, else None."""
    return axis if axis and dim_size % _axis_size(mesh, axis) == 0 else None


def batch_axes(mesh: Mesh):
    """The combined data-parallel axes: ('pod','data') or ('data',)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# --------------------------------------------------------------------------
# Parameter rules, keyed by the *leaf name* (last DictKey in the tree path).
# Each value is a spec over the TRAILING dims; leading stack dims (layers,
# super-blocks, experts-as-leading in xlstm) are replicated unless the rule
# consumes them.
# --------------------------------------------------------------------------
# (trailing_spec, ) entries use: 'T' tensor axis, 'F' fsdp axis, None repl.
_TRAILING_RULES = {
    # embeddings / heads
    "table": (None, "T"),           # [V, d]  d on model (psum on unembed)
    # attention
    "wq": ("F", "T"),               # [d, nq*hd]
    "wk": ("F", "T"),
    "wv": ("F", "T"),
    "wo": ("T", "F"),               # [nq*hd, d] (also mlp wo [f, d])
    "bq": ("T",),
    "bk": ("T",),
    "bv": ("T",),
    "q_norm": (None,),
    "k_norm": (None,),
    # mlp
    "wi": ("F", "T"),               # [d, f]
    "wg": ("F", "T"),
    # norms
    "scale": (None,),
    "ln": (None,),
    "gn": (None,),
    "enc_ln": (None,),
    # recurrent cells
    "w_in": ("F", "T"),             # [d, 2di]
    "conv": (None, "T"),            # [K, di]
    "w_if": ("F", None),            # [di, 2nh]
    "b_if": (None,),
    "w_out": ("T", "F"),            # [di, d]
    "w_dt1": ("F", None),
    "w_dt2": (None, "T"),
    "b_dt": ("T",),
    "wB": ("T", None),              # [di, N]
    "wC": ("T", None),
    "A_log": ("T", None),
    "D": ("T",),
    # slstm
    "r": (None, None, "T"),         # [nh, dh, 4dh]
    # generic linear
    "w": ("F", "T"),                # head [d, V]: vocab-parallel logits
    "b": (None,),
    # vision / vlm extras
    "modality_emb": (None, None),
    "queries": (None, None),
}

# MoE expert tensors carry a leading expert dim -> expert parallelism on the
# tensor axis (paper: per-cluster expert placement analogue).
_MOE_RULES = {
    "router": (None, None),
    "wi": ("T", "F", None),         # [E, d, de]
    "wg": ("T", "F", None),
    "wo": ("T", None, "F"),         # [E, de, d]
}


def _resolve(mesh: Mesh, shape, trailing, *, fsdp: bool):
    """Build a full PartitionSpec: replicate leading stack dims, apply the
    trailing rule with per-dim divisibility checks."""
    n = len(shape)
    k = len(trailing)
    if k > n:                       # e.g. scalar-ish leaves
        trailing = trailing[-n:]
        k = len(trailing)
    spec = [None] * (n - k)
    for dim, rule in zip(shape[n - k:], trailing):
        ax = None
        if rule == "T":
            ax = _fit(mesh, dim, MODEL)
        elif rule == "F" and fsdp:
            ax = _fit(mesh, dim, FSDP)
        spec.append(ax)
    return P(*spec)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return entry.key
    return ""


def _in_moe(path) -> bool:
    return any(isinstance(e, jax.tree_util.DictKey) and e.key == "moe"
               for e in path)


def param_specs(mesh: Mesh, params_shape, *, fsdp: bool = True):
    """PartitionSpec pytree for a parameter (or optimizer-state) pytree.

    ``params_shape``: pytree of ShapeDtypeStruct / arrays (shapes are enough).
    ``fsdp=False`` keeps parameters replicated over the data axis (used for
    low-latency decode where per-layer all-gathers would serialize).
    """
    def rule(path, leaf):
        name = _leaf_name(path)
        table = _MOE_RULES if (_in_moe(path) and name in _MOE_RULES) \
            else _TRAILING_RULES
        trailing = table.get(name)
        if trailing is None:
            return P()
        return _resolve(mesh, leaf.shape, trailing, fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


# --------------------------------------------------------------------------
# Batch / decode-state rules
# --------------------------------------------------------------------------
def batch_specs(mesh: Mesh, batch_shape):
    """Shard the leading (global-batch) dim of every input over the combined
    data axes."""
    dp = batch_axes(mesh)

    def rule(path, leaf):
        if leaf.shape and leaf.shape[0] % _axis_size(mesh, dp) == 0:
            return P(dp)
        return P()

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


# Decode-state leaves by name. KV caches: [(L,) B, nkv, S, hd] — batch on
# data, head_dim on model (always 16-divisible across the assigned archs;
# falls back to replication when not). SSM states shard batch + inner dim.
_STATE_RULES = {
    # trailing [B, nkv, S, hd]: batch over data, cache SEQUENCE over the
    # tensor axis (KV-parallel decode: per-shard partial softmax + combine;
    # hd-sharding forces an involuntary resharding of every cache update
    # against the attention einsum's layout)
    "k": ("D", None, "T", None),
    "v": ("D", None, "T", None),
    "pos": (None,),
    "C": ("D", None, "T", None),       # mlstm [B, nh, dh, dh]
    "n": ("D", None, "T"),
    "m": ("D", None),
    "h": ("D", "T"),                   # mamba [B, di, N] -> wait h is [B,di,N]
    "c": ("D", None, "T"),             # slstm [B, nh, dh]
    "conv": ("D", None, "T"),          # [B, K-1, di]
    # enc-dec cross-attention memory (tuple under this key): [L,B,nkv,S,hd]
    "cross_kv": ("D", None, None, "T"),
}
# mamba h [B, di, N]: trailing rule length 2 would hit (di, N); use explicit
_STATE_RULES_3D = {"h": ("D", "T", None)}


def state_specs_sharding(mesh: Mesh, state_shape):
    dp = batch_axes(mesh)

    def rule(path, leaf):
        name = _leaf_name(path)
        trailing = None
        if name in _STATE_RULES_3D and len(leaf.shape) >= 3:
            # disambiguate mamba h [B,di,N] vs slstm h [B,nh,dh]
            trailing = _STATE_RULES_3D[name] if leaf.shape[-1] <= 64 \
                else _STATE_RULES.get(name)
        if trailing is None:
            trailing = _STATE_RULES.get(name)
        if trailing is None:
            return P()
        shape = leaf.shape
        n, k = len(shape), len(trailing)
        if k > n:
            trailing = trailing[-n:]
            k = n
        spec = [None] * (n - k)
        for dim, r in zip(shape[n - k:], trailing):
            ax = None
            if r == "T":
                ax = _fit(mesh, dim, MODEL)
            elif r == "D":
                ax = _fit(mesh, dim, dp)
            spec.append(ax)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, state_shape)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
