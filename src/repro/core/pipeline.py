"""FHDP intra-cluster pipeline parallelism (paper §4, Fig. 3).

The ``model`` mesh axis hosts the pipeline stages of one vehicle cluster;
``data`` (and ``pod``) hosts FL clients/regions. A GPipe-style microbatch
schedule runs as a single ``lax.scan`` over ticks inside ``shard_map``, with
``jax.lax.ppermute`` moving activations along the stage ring — the
TPU-idiomatic realization of the paper's RPC pipeline (DESIGN.md §2).

Paper-faithful elements:
  * **Every rank feeds data** (the paper's dynamic stage-exchange fix for
    non-i.i.d. utilization in classic HDP): the batch is sharded over *all*
    mesh axes including ``model``; each rank embeds its own samples locally
    and only the *embeddings* are gathered to feed the pipeline head (raw
    sensor inputs never leave their rank — the paper's privacy analogue;
    labels do move to the loss stage, as in any intra-cluster pipeline).
  * **Unequal stage templates** (SWIFT output, Eq. 11): layers are stacked
    to ``[S, Lmax, ...]`` with a per-slot validity mask, so heterogeneous
    partitions lower as one SPMD program.
  * **Stage rotation** (§4 "vehicles systematically rotate through pipeline
    stages"): :func:`rotate_stages` rolls stage ownership around the ring;
    under SPMD the data-utilization benefit is inherent (all ranks always
    contribute samples), so rotation exercises the mechanism the paper needs
    on heterogeneous hardware.

Memory: optimizer state is ZeRO-2 sharded over ``data`` (flattened
reduce-scatter / all-gather update). The paper's Eq. (6) c1 grows clusters
until the memory constraint holds; on a fixed mesh the analogous lever is
sharding optimizer state (and, for MoE, expert weights) over ``data``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import compat
from repro.config import ModelConfig, ShapeConfig
from repro.models import blocks as B


# --------------------------------------------------------------------------
# Stage templates
# --------------------------------------------------------------------------
def balanced_template(num_layers: int, stages: int) -> Tuple[int, ...]:
    """Even split; first ``num_layers % stages`` stages get one extra."""
    base, rem = divmod(num_layers, stages)
    return tuple(base + (1 if s < rem else 0) for s in range(stages))


def template_offsets(template: Sequence[int]) -> Tuple[int, ...]:
    off, out = 0, []
    for c in template:
        out.append(off)
        off += c
    return tuple(out)


def stack_stages(blocks, template: Sequence[int]):
    """[L, ...] stacked blocks -> ([S, Lmax, ...] padded, mask [S, Lmax]).

    Padded slots repeat layer 0 (their values are masked out), so the
    lowering stays uniform across stages.
    """
    S = len(template)
    lmax = max(max(template), 1)
    offsets = template_offsets(template)
    idx, mask = [], []
    for s in range(S):
        idx.append([offsets[s] + i if i < template[s] else 0
                    for i in range(lmax)])
        mask.append([i < template[s] for i in range(lmax)])
    idx = jnp.asarray(idx)
    mask = jnp.asarray(mask, jnp.bool_)
    return jax.tree.map(lambda x: x[idx], blocks), mask


def rotate_stages(stage_tree, shift: int):
    """Roll stage ownership around the ring (paper's stage rotation)."""
    return jax.tree.map(lambda x: jnp.roll(x, shift, axis=0), stage_tree)


# --------------------------------------------------------------------------
# Family adapters
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FamilyAdapter:
    stack_order: Tuple[str, ...]
    split: Callable      # params -> (shared, {name: [L, ...]})
    counts: Callable     # cfg -> {name: L}
    embed: Callable      # (shared, batch, cfg) -> act dict (incl. 'aux')
    block: Callable      # (stack, layer_params, act, cfg, window, shared) -> act
    loss: Callable       # (shared, act, batch_mb, cfg) -> (loss_sum, n, metrics)


def _ce_sum(shared, x, labels):
    from repro.train.losses import chunked_ce
    w = shared["head"]["w"] if "head" in shared else shared["embed"]["table"].T
    loss, metrics = chunked_ce(x, w, labels, seq_chunk=512)
    n = jnp.asarray(labels.size, jnp.float32)
    return loss * n, n, metrics


def _aux0(x):
    return jnp.zeros((x.shape[0],), jnp.float32)


# ---- decoder LM (dense / moe / vlm) ----
def _lm_split(params):
    return ({k: v for k, v in params.items() if k != "blocks"},
            {"blocks": params["blocks"]})


def _lm_embed(shared, batch, cfg):
    x = B.embed(shared["embed"], batch["tokens"])
    if cfg.prefix_tokens and "patches" in batch:
        pfx = B.linear(shared["projector"], batch["patches"].astype(x.dtype))
        x = jnp.concatenate([pfx, x], axis=1)
    return {"x": x, "aux": _aux0(x)}


def _lm_block(stack, lp, act, cfg, window, shared=None):
    from repro.models.lm import apply_block
    pos = jnp.arange(act["x"].shape[1], dtype=jnp.int32)
    out, _, aux = apply_block(lp, act["x"], cfg, positions=pos, window=window)
    return dict(act, x=out, aux=act["aux"] + aux / act["aux"].shape[0])


def _lm_loss(shared, act, batch, cfg):
    x = act["x"]
    if cfg.prefix_tokens and x.shape[1] > batch["labels"].shape[1]:
        x = x[:, x.shape[1] - batch["labels"].shape[1]:]
    x = B.rms_norm(shared["ln_f"], x, cfg.norm_eps)
    ls, n, metrics = _ce_sum(shared, x, batch["labels"])
    return ls + act["aux"].sum() * n / act["aux"].shape[0], n, metrics


# ---- xLSTM (stage unit = super-block) ----
def _xlstm_split(params):
    return ({k: v for k, v in params.items() if k not in ("mlstm", "slstm")},
            {"mlstm": params["mlstm"], "slstm": params["slstm"]})


def _tok_embed(shared, batch, cfg):
    x = B.embed(shared["embed"], batch["tokens"])
    return {"x": x, "aux": _aux0(x)}


def _xlstm_block(stack, lp, act, cfg, window, shared=None):
    from repro.models import recurrent as R
    x = act["x"]
    if stack == "mlstm":
        def body(h, p):
            y, _ = R.apply_mlstm_seq(p, h, cfg, state=None)
            return h + y, None
        x, _ = lax.scan(body, x, lp)
    else:
        y, _ = R.apply_slstm_seq(lp, x, cfg, state=None)
        x = x + y
    return dict(act, x=x)


def _head_ce_loss(shared, act, batch, cfg):
    x = B.rms_norm(shared["ln_f"], act["x"], cfg.norm_eps)
    return _ce_sum(shared, x, batch["labels"])


# ---- Hymba hybrid ----
def _hymba_block(stack, lp, act, cfg, window, shared=None):
    from repro.models.hymba import apply_block
    pos = jnp.arange(act["x"].shape[1], dtype=jnp.int32)
    out, _, _ = apply_block(lp, act["x"], cfg, positions=pos, window=window)
    return dict(act, x=out)


# ---- encoder-decoder: enc stack then dec stack, memory frozen in-band ----
def _encdec_split(params):
    return ({k: v for k, v in params.items()
             if k not in ("enc_blocks", "dec_blocks")},
            {"enc": params["enc_blocks"], "dec": params["dec_blocks"]})


def _encdec_embed(shared, batch, cfg):
    enc = B.linear(shared["frontend"], batch["frames"].astype(cfg.dtype))
    dec = B.embed(shared["embed"], batch["tokens"])
    return {"enc": enc, "dec": dec, "mem": jnp.zeros_like(enc),
            "enc_done": jnp.zeros((enc.shape[0],), jnp.float32),
            "aux": _aux0(enc)}


def _encdec_block(stack, lp, act, cfg, window, shared=None):
    pos_e = jnp.arange(act["enc"].shape[1], dtype=jnp.int32)
    pos_d = jnp.arange(act["dec"].shape[1], dtype=jnp.int32)
    if stack == "enc":
        h = act["enc"]
        a, _ = B.attention(lp["attn"], B.rms_norm(lp["ln1"], h, cfg.norm_eps),
                           cfg, positions=pos_e, causal=False, window=window)
        h = h + a
        h = h + B.mlp(lp["ffn"], B.rms_norm(lp["ln2"], h, cfg.norm_eps))
        return dict(act, enc=h)
    # decoder block; the first one freezes the (enc_ln'd) encoder memory
    done = act["enc_done"][:, None, None] > 0
    enc_out = B.rms_norm(shared["enc_ln"], act["enc"], cfg.norm_eps) \
        if shared is not None else act["enc"]
    mem = jnp.where(done, act["mem"], enc_out)
    h = act["dec"]
    a, _ = B.attention(lp["attn"], B.rms_norm(lp["ln1"], h, cfg.norm_eps),
                       cfg, positions=pos_d, causal=True, window=window)
    h = h + a
    nkv, hd = cfg.num_kv_heads, cfg.hd
    bsz, sm, _ = mem.shape
    ck = (mem @ lp["xattn"]["wk"]).reshape(bsz, sm, nkv, hd).transpose(0, 2, 1, 3)
    cv = (mem @ lp["xattn"]["wv"]).reshape(bsz, sm, nkv, hd).transpose(0, 2, 1, 3)
    xa, _ = B.attention(lp["xattn"], B.rms_norm(lp["ln_x"], h, cfg.norm_eps),
                        cfg, positions=pos_d, cross_kv=(ck, cv),
                        cross_pos=jnp.arange(sm, dtype=jnp.int32), causal=False)
    h = h + xa
    h = h + B.mlp(lp["ffn"], B.rms_norm(lp["ln2"], h, cfg.norm_eps))
    return dict(act, dec=h, mem=mem,
                enc_done=jnp.ones_like(act["enc_done"]))


def _encdec_loss(shared, act, batch, cfg):
    x = B.rms_norm(shared["ln_f"], act["dec"], cfg.norm_eps)
    return _ce_sum(shared, x, batch["labels"])


# ---- the paper's vision encoder ----
def _vision_embed(shared, batch, cfg):
    rgb = B.linear(shared["rgb_proj"], batch["rgb"].astype(cfg.dtype))
    lid = B.linear(shared["lidar_proj"], batch["lidar"].astype(cfg.dtype))
    x = jnp.concatenate([rgb + shared["modality_emb"][0],
                         lid + shared["modality_emb"][1]], axis=1)
    return {"x": x, "aux": _aux0(x)}


def _vision_block(stack, lp, act, cfg, window, shared=None):
    pos = jnp.arange(act["x"].shape[1], dtype=jnp.int32)
    h = act["x"]
    a, _ = B.attention(lp["attn"], B.rms_norm(lp["ln1"], h, cfg.norm_eps),
                       cfg, positions=pos, causal=False)
    h = h + a
    h = h + B.mlp(lp["ffn"], B.rms_norm(lp["ln2"], h, cfg.norm_eps))
    return dict(act, x=h)


def _vision_loss(shared, act, batch, cfg):
    feats = B.rms_norm(shared["ln_f"], act["x"], cfg.norm_eps)
    b = feats.shape[0]
    q = jnp.broadcast_to(shared["queries"][None],
                         (b,) + shared["queries"].shape)
    nkv, hd = cfg.num_kv_heads, cfg.hd
    k = (feats @ shared["dec_attn"]["wk"]).reshape(
        b, -1, nkv, hd).transpose(0, 2, 1, 3)
    v = (feats @ shared["dec_attn"]["wv"]).reshape(
        b, -1, nkv, hd).transpose(0, 2, 1, 3)
    qpos = jnp.arange(q.shape[1], dtype=jnp.int32)
    dec, _ = B.attention(shared["dec_attn"],
                         B.rms_norm(shared["dec_ln"], q, cfg.norm_eps), cfg,
                         positions=qpos, cross_kv=(k, v),
                         cross_pos=jnp.arange(feats.shape[1], dtype=jnp.int32),
                         causal=False)
    dec = dec + q
    wp = B.linear(shared["wp_head"],
                  dec[:, :cfg.num_waypoints]).astype(jnp.float32)
    light = B.linear(shared["light_head"], dec[:, -1]).astype(jnp.float32)
    l1 = jnp.abs(wp - batch["waypoints"]).mean()
    logp = jax.nn.log_softmax(light)
    ce = -jnp.take_along_axis(logp, batch["light"][:, None], axis=-1).mean()
    n = jnp.asarray(b, jnp.float32)
    return (l1 + ce) * n, n, {"l1": l1, "ce": ce}


def get_adapter(cfg: ModelConfig) -> FamilyAdapter:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return FamilyAdapter(("blocks",), _lm_split,
                             lambda c: {"blocks": c.num_layers},
                             _lm_embed, _lm_block, _lm_loss)
    if fam == "ssm":
        from repro.models.xlstm import _layout

        def counts(c):
            n_super, _ = _layout(c)
            return {"mlstm": n_super, "slstm": n_super}

        return FamilyAdapter(("mlstm", "slstm"), _xlstm_split, counts,
                             _tok_embed, _xlstm_block, _head_ce_loss)
    if fam == "hybrid":
        return FamilyAdapter(("blocks",), _lm_split,
                             lambda c: {"blocks": c.num_layers},
                             _tok_embed, _hymba_block, _head_ce_loss)
    if fam == "encdec":
        return FamilyAdapter(("enc", "dec"), _encdec_split,
                             lambda c: {"enc": c.enc_layers,
                                        "dec": c.dec_layers},
                             _encdec_embed, _encdec_block, _encdec_loss)
    if fam == "vision":
        return FamilyAdapter(("blocks",), _lm_split,
                             lambda c: {"blocks": c.num_layers},
                             _vision_embed, _vision_block, _vision_loss)
    raise ValueError(fam)


# --------------------------------------------------------------------------
# Stage-stacked parameter container
# --------------------------------------------------------------------------
def template_from_sequence(cfg: ModelConfig, seq: Sequence[int]
                           ) -> Dict[str, Tuple[int, ...]]:
    """Split a flat per-stage layer-count template over the model's stacks.

    ``seq[s]`` counts layers of the concatenated stack sequence (the
    adapter's ``stack_order`` concatenation) assigned to stage ``s`` — the
    form SWIFT's :func:`repro.sched.swift.units_to_layer_template` emits.
    Raises if the sequence does not cover the model exactly (a template
    that drops or invents layers must never reach the runtime).
    """
    adapter = get_adapter(cfg)
    counts = adapter.counts(cfg)
    total = sum(counts.values())
    seq = tuple(int(c) for c in seq)
    if sum(seq) != total:
        raise ValueError(
            f"stage template {seq} covers {sum(seq)} layers but the model "
            f"has {total} ({counts}); refusing to drop/invent layers")
    offs = template_offsets(seq)
    out, start = {}, 0
    for name in adapter.stack_order:
        L = counts[name]
        out[name] = tuple(
            max(0, min(offs[s] + seq[s], start + L) - max(offs[s], start))
            for s in range(len(seq)))
        start += L
    return out


def make_templates(cfg: ModelConfig, stages: int,
                   template: Optional[Dict[str, Sequence[int]]] = None
                   ) -> Dict[str, Tuple[int, ...]]:
    """Per-stack stage templates. The default splits the concatenated layer
    sequence (stack_order concatenation) evenly across stages. Custom
    templates come from SWIFT (sched/swift.py)."""
    if template is not None:
        return {k: tuple(v) for k, v in template.items()}
    adapter = get_adapter(cfg)
    total = sum(adapter.counts(cfg).values())
    return template_from_sequence(cfg, balanced_template(total, stages))


def _abstract_params_thunk(cfg: ModelConfig):
    from repro.models import build_model
    model = build_model(cfg)
    return lambda: model.init(jax.random.PRNGKey(0))


def stage_params_from(params, cfg: ModelConfig,
                      templates: Dict[str, Sequence[int]]):
    """Full params -> {'shared', 'stacks': {name: [S, Lmax, ...]},
    'masks': {name: [S, Lmax]}} container."""
    adapter = get_adapter(cfg)
    shared, stacks = adapter.split(params)
    out_stacks, masks = {}, {}
    for name, blocks in stacks.items():
        st, mask = stack_stages(blocks, templates[name])
        out_stacks[name] = st
        masks[name] = mask
    return {"shared": shared, "stacks": out_stacks, "masks": masks}


_STACK_TO_PARAM = {"blocks": "blocks", "enc": "enc_blocks",
                   "dec": "dec_blocks", "mlstm": "mlstm", "slstm": "slstm"}


def merge_stage_params(pp, templates: Dict[str, Sequence[int]]):
    """Inverse of :func:`stage_params_from` (used by recovery/backup)."""
    merged = dict(pp["shared"])
    for name, st in pp["stacks"].items():
        tmpl = templates[name]

        def unstack(x):
            parts = [x[s, :tmpl[s]] for s in range(len(tmpl)) if tmpl[s]]
            return jnp.concatenate(parts, axis=0)

        merged[_STACK_TO_PARAM.get(name, name)] = jax.tree.map(unstack, st)
    return merged


def stage_specs(mesh: Mesh, pp_shape) -> Any:
    """Stacks and masks sharded over ``model`` on the stage dim; shared
    params replicated."""
    def spec(path, leaf):
        keys = [e.key for e in path if isinstance(e, jax.tree_util.DictKey)]
        if keys and keys[0] in ("stacks", "masks"):
            return P("model", *([None] * (len(leaf.shape) - 1)))
        return P()

    return jax.tree_util.tree_map_with_path(spec, pp_shape)


# --------------------------------------------------------------------------
# ZeRO-2 optimizer state (flattened, data-sharded Adam moments)
# --------------------------------------------------------------------------
def _flat_shard(n: int, d: int) -> int:
    return (n + d - 1) // d


def zero2_init(pp, data_size: int, sharded: bool = True):
    """Adam moments, flattened per LOCAL leaf.

    Stage stacks keep their leading stage dim (sharded over ``model``);
    within a stage the flat moments are split over ``data`` when
    ``sharded=True`` (ZeRO-2 — valid when gradients are synchronized every
    step) or kept whole per column for FedAvg local steps (columns
    diverge, so moments cannot be sharded across them). Global layouts:
      stacks : [S, D, n]  with P('model', 'data')
      shared : [D, n]     with P('data')
    """
    def shard(path, x):
        keys = [e.key for e in path if isinstance(e, jax.tree_util.DictKey)]
        staged = bool(keys) and keys[0] == "stacks"
        if not jnp.issubdtype(x.dtype, jnp.inexact):
            return jnp.zeros(((x.shape[0], data_size, 0) if staged
                              else (data_size, 0)), jnp.float32)
        if staged:
            n_loc = x.size // x.shape[0]
            n = _flat_shard(n_loc, data_size) if sharded else n_loc
            return jnp.zeros((x.shape[0], data_size, n), jnp.float32)
        n = _flat_shard(x.size, data_size) if sharded else x.size
        return jnp.zeros((data_size, n), jnp.float32)

    return {"step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map_with_path(shard, pp),
            "v": jax.tree_util.tree_map_with_path(shard, pp)}


def zero2_specs(opt_shape):
    def spec(leaf):
        if leaf.shape == ():
            return P()
        if len(leaf.shape) == 3:
            return P("model", "data", None)
        return P("data", None)

    return jax.tree.map(spec, opt_shape)


# --------------------------------------------------------------------------
# The pipelined train step
# --------------------------------------------------------------------------
def make_fhdp_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
                         microbatches: Optional[int] = None,
                         templates: Optional[Dict[str, Sequence[int]]] = None,
                         learning_rate: float = 3e-4,
                         remat: bool = True,
                         window: Optional[int] = None,
                         fed_sgd: bool = True):
    """Build the FHDP pipelined train step.

    Returns ``(step, helpers)``; ``step(pp, opt, batch) -> (pp, opt,
    metrics)`` over the stage-param container (:func:`stage_params_from`)
    laid out per :func:`stage_specs`.

    ``fed_sgd=True`` synchronizes gradients across FL clients every step
    (equivalent to FL with one local step); ``fed_sgd=False`` runs local
    steps with NO cross-client sync — parameters diverge per data column
    and are averaged by :func:`fedavg_stage_params` at round boundaries
    (true FedAvg, paper §3.1).
    """
    adapter = get_adapter(cfg)
    S = mesh.shape["model"]
    D = mesh.shape["data"]
    pods = mesh.shape.get("pod", 1)
    Bg = shape.global_batch
    B_col = Bg // (D * pods)             # per-pipeline-column batch
    assert Bg % (D * pods) == 0, (Bg, D, pods)
    # microbatch geometry: one microbatch per rank when the column batch
    # allows; columns smaller than the stage count run a partial stream.
    if microbatches:
        M = microbatches
        assert M <= S or M % S == 0, (M, S)
        assert B_col % M == 0, (B_col, M)
        mb = B_col // M
    else:
        mb = max(1, B_col // S)
        M = B_col // mb
    share = (max(M // S, 1)) * mb        # samples each rank embeds
    templates = templates or make_templates(cfg, S)
    lr = learning_rate
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    all_axes = batch_axes + ("model",)
    label_keys = ("labels", "waypoints", "light")

    def device_fn(pp, opt, batch):
        r = lax.axis_index("model")

        def local_loss(pp):
            shared = pp["shared"]
            stacks = jax.tree.map(lambda x: x[0], pp["stacks"])
            masks = {k: v[0] for k, v in pp["masks"].items()}

            def mb_slice(tree, m, size=None):
                sz = size or mb
                return jax.tree.map(
                    lambda x: lax.dynamic_slice_in_dim(x, m, sz, 0), tree)

            # every rank embeds its own share of the column batch; only the
            # resulting features are gathered to feed the pipeline head.
            start = jnp.minimum(r * share, B_col - share)
            my = mb_slice(batch, start, share)
            act0 = adapter.embed(shared, my, cfg)
            gath = lambda x: lax.all_gather(x, "model", axis=0, tiled=True)
            act_all = jax.tree.map(gath, act0)  # rows m*mb..: microbatch m
            lbl_all = {k: v for k, v in batch.items() if k in label_keys}

            def apply_stage(act):
                for name in adapter.stack_order:
                    stack, mask = stacks[name], masks[name]

                    def body(a, xs):
                        lp, valid = xs
                        out = adapter.block(name, lp, a, cfg, window, shared)
                        a2 = jax.tree.map(
                            lambda o, i: jnp.where(valid, o, i), out, a)
                        return a2, None

                    if remat:  # per-layer remat inside the stage
                        body = jax.checkpoint(
                            body,
                            policy=jax.checkpoint_policies.nothing_saveable)
                    act, _ = lax.scan(body, act, (stack, mask))
                return act

            zero_act = jax.tree.map(
                lambda x: jnp.zeros((mb,) + x.shape[1:], x.dtype), act0)
            perm = [(i, (i + 1) % S) for i in range(S)]
            T = M + S - 1

            def tick(carry, t):
                act_in = carry
                head_in = mb_slice(act_all, jnp.clip(t, 0, M - 1) * mb)
                inp = jax.tree.map(lambda h, a: jnp.where(r == 0, h, a),
                                   head_in, act_in)
                out = apply_stage(inp)
                nxt = jax.tree.map(lambda x: lax.ppermute(x, "model", perm),
                                   out)
                # emit the (masked) final-stage activation of microbatch t-r
                fin = jax.tree.map(
                    lambda x: jnp.where(r == S - 1, x, jnp.zeros_like(x)),
                    out)
                return nxt, fin

            tick_fn = jax.checkpoint(tick) if remat else tick
            _, fins = lax.scan(tick_fn, zero_act, jnp.arange(T))

            # The loss was previously computed inside every tick on every
            # rank (S*T redundant head+CE evaluations — measured 4x whole-
            # step FLOP inflation at 16 stages). Instead: broadcast the
            # final-stage microbatch activations once (masked psum) and let
            # every rank evaluate the loss for its own 1/S of microbatches.
            fins = jax.tree.map(lambda x: x[S - 1:], fins)   # ticks -> mb
            fins = jax.tree.map(lambda x: lax.psum(x, "model"), fins)
            per = max(M // S, 1)
            lo = jnp.minimum(r * per, M - per)

            def mb_loss(c, i):
                loss, cnt = c
                m_idx = lo + i
                act_m = jax.tree.map(
                    lambda x: lax.dynamic_slice_in_dim(x, m_idx, 1, 0)[0],
                    fins)
                lsum, n, _ = adapter.loss(shared, act_m,
                                          mb_slice(lbl_all, m_idx * mb), cfg)
                # ranks whose slot is clamped (M < S) recompute a duplicate
                # microbatch — mask them out of the psum
                keep = (r * per + i < M).astype(jnp.float32)
                return (loss + lsum * keep, cnt + n * keep), None

            (loss, cnt), _ = lax.scan(
                mb_loss, (jnp.zeros((), jnp.float32),
                          jnp.zeros((), jnp.float32)), jnp.arange(per))

            loss = lax.psum(loss, "model") / jnp.maximum(
                lax.psum(cnt, "model"), 1.0)
            if fed_sgd and batch_axes:
                loss = lax.pmean(loss, batch_axes)
            return loss

        loss, grads = jax.value_and_grad(local_loss, allow_int=True)(pp)

        def sync(path, g):
            if not jnp.issubdtype(g.dtype, jnp.inexact):
                return g
            keys = [e.key for e in path
                    if isinstance(e, jax.tree_util.DictKey)]
            if keys and keys[0] == "shared":
                return lax.psum(g, all_axes if (fed_sgd and batch_axes)
                                else ("model",))
            if keys and keys[0] == "stacks" and fed_sgd and batch_axes:
                return lax.psum(g, batch_axes)
            return g

        grads = jax.tree_util.tree_map_with_path(sync, grads)

        # ZeRO-2 Adam on flattened shards
        step = opt["step"] + 1
        b1, b2, eps = 0.9, 0.95, 1e-8
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        zero2 = fed_sgd and D > 1

        def upd(p, g, m, v):
            # all sizes are LOCAL: p/g are this rank's stage view, m/v the
            # flat (possibly data-sharded) moment shards
            n = p.size
            mf, vf = m.reshape(-1), v.reshape(-1)
            shard = mf.size
            if zero2:
                # reduce-scatter grads IN THE GRAD DTYPE (padding the
                # embedding to full float32 costs GiB-scale temps), then
                # update the local shard and all-gather in param dtype
                gf = jnp.pad(g.reshape(-1), (0, shard * D - n))
                gl = lax.psum_scatter(gf.reshape(D, shard), "data",
                                      scatter_dimension=0, tiled=False
                                      ).astype(jnp.float32)
            else:
                gl = g.astype(jnp.float32).reshape(-1)
            m2 = b1 * mf + (1 - b1) * gl
            v2 = b2 * vf + (1 - b2) * gl * gl
            u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            if zero2:
                pf = jnp.pad(p.reshape(-1), (0, shard * D - n))
                pl = lax.dynamic_slice_in_dim(
                    pf, lax.axis_index("data") * shard, shard
                ).astype(jnp.float32) - lr * u
                pg = lax.all_gather(pl.astype(p.dtype), "data", axis=0,
                                    tiled=True)[:n].astype(jnp.float32)
            else:
                pg = p.astype(jnp.float32).reshape(-1) - lr * u
            return (pg.reshape(p.shape).astype(p.dtype),
                    m2.reshape(m.shape), v2.reshape(v.shape))

        flat_p, tdef = jax.tree_util.tree_flatten(pp)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(opt["m"])
        flat_v = tdef.flatten_up_to(opt["v"])
        new_p, new_m, new_v = [], [], []
        for p_, g_, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v):
            if not jnp.issubdtype(p_.dtype, jnp.inexact):
                new_p.append(p_); new_m.append(m_); new_v.append(v_)
                continue
            p2, m2, v2 = upd(p_, g_, m_, v_)
            new_p.append(p2); new_m.append(m2); new_v.append(v2)
        pp2 = jax.tree_util.tree_unflatten(tdef, new_p)
        opt2 = {"step": step,
                "m": jax.tree_util.tree_unflatten(tdef, new_m),
                "v": jax.tree_util.tree_unflatten(tdef, new_v)}
        return pp2, opt2, {"loss": loss}

    # ---- shard_map wiring ----
    pp_abs = jax.eval_shape(
        lambda: stage_params_from(_abstract_params_thunk(cfg)(), cfg,
                                  templates))
    pspec = stage_specs(mesh, pp_abs)
    opt_abs = jax.eval_shape(
        functools.partial(zero2_init, data_size=D,
                          sharded=fed_sgd and D > 1), pp_abs)
    ospec = zero2_specs(opt_abs)
    from repro.configs.common import input_specs
    batch_abs = input_specs(cfg, shape)
    bspec = jax.tree.map(
        lambda x: P(batch_axes, *([None] * (len(x.shape) - 1))), batch_abs)

    step = compat.shard_map(device_fn, mesh=mesh,
                         in_specs=(pspec, ospec, bspec),
                         out_specs=(pspec, ospec, P()),
                         check_vma=False)

    helpers = {"templates": templates, "pp_abs": pp_abs, "opt_abs": opt_abs,
               "pspec": pspec, "ospec": ospec, "bspec": bspec,
               "microbatches": M, "mb": mb, "batch_abs": batch_abs}
    return step, helpers


def fedavg_stage_params(pp, mesh: Mesh):
    """Round-boundary FedAvg for ``fed_sgd=False`` training: average the
    (diverged) per-column parameters over the FL axes — edge aggregation
    over ``data`` then cloud aggregation over ``pod`` (paper Fig. 1)."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def avg(pp):
        return jax.tree.map(
            lambda x: lax.pmean(x, batch_axes)
            if jnp.issubdtype(x.dtype, jnp.inexact) else x, pp)

    spec = stage_specs(mesh, jax.eval_shape(lambda: pp))
    return compat.shard_map(avg, mesh=mesh, in_specs=(spec,), out_specs=spec,
                         check_vma=False)(pp)
