"""jax version compatibility shims.

The codebase targets the modern ``jax.shard_map`` / ``check_vma`` API;
older jax (< 0.5) only ships ``jax.experimental.shard_map`` with the
``check_rep`` spelling. Route every call through :func:`shard_map` so the
whole stack (pipeline, MoE EP, mesh factories) runs on both.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def pallas_tpu_compiler_params():
    """The pallas TPU CompilerParams class (jax < 0.5 spells it
    TPUCompilerParams)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:
        raise RuntimeError(
            "jax.experimental.pallas.tpu has neither CompilerParams nor "
            "TPUCompilerParams; unsupported jax version")
    return cls
