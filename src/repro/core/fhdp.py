"""FHDP = FL (over data/pod) x pipeline (over model) — step builders.

This is the paper's headline technique packaged for the launcher:
  * :func:`build_pipeline_lowered` — dry-run entry (lower the pipelined
    train step for a production mesh without allocating anything).
  * :func:`init_fhdp` — materialize stage-stacked params + ZeRO-2 opt state
    on a real mesh (tests / examples).
  * :func:`make_fl_pipeline_round` — E local pipelined steps per FL client
    column with no cross-client sync, then hierarchical FedAvg
    (vehicle -> edge -> cloud, paper Fig. 1).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig
from repro.configs.common import effective_window, input_specs
from repro.core import pipeline as pl


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_pipeline_lowered(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                           *, remat: bool = True,
                           microbatches: Optional[int] = None,
                           templates: Optional[Dict] = None):
    """Lower the FHDP pipelined train step (dry-run; no allocation)."""
    if shape.kind != "train":
        raise ValueError(
            "FHDP pipelines the training path (the paper serves via the "
            "edge AD-LLM, not a pipelined decoder); use strategy=tensor "
            "for prefill/decode shapes")
    window = effective_window(cfg, shape)
    step, h = pl.make_fhdp_train_step(
        cfg, shape, mesh, remat=remat, window=window,
        microbatches=microbatches, templates=templates)
    return jax.jit(step,
                   in_shardings=(_named(mesh, h["pspec"]),
                                 _named(mesh, h["ospec"]),
                                 _named(mesh, h["bspec"])),
                   out_shardings=(_named(mesh, h["pspec"]),
                                  _named(mesh, h["ospec"]), None)) \
        .lower(h["pp_abs"], h["opt_abs"], h["batch_abs"])


def init_fhdp(cfg: ModelConfig, mesh: Mesh, key, *,
              templates: Optional[Dict] = None, fed_sgd: bool = True):
    """Materialize (pp, opt) on the mesh with the pipeline layout."""
    from repro.models import build_model
    model = build_model(cfg)
    S = mesh.shape["model"]
    D = mesh.shape["data"]
    templates = templates or pl.make_templates(cfg, S)
    params = model.init(key)
    pp = pl.stage_params_from(params, cfg, templates)
    opt = pl.zero2_init(pp, D, sharded=fed_sgd and D > 1)
    pp_sh = _named(mesh, pl.stage_specs(mesh, jax.eval_shape(lambda: pp)))
    opt_sh = _named(mesh, pl.zero2_specs(jax.eval_shape(lambda: opt)))
    pp = jax.device_put(pp, pp_sh)
    opt = jax.device_put(opt, opt_sh)
    return pp, opt, templates


def make_fl_pipeline_round(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                           *, local_steps: int = 1,
                           templates: Optional[Dict] = None,
                           learning_rate: float = 3e-4,
                           remat: bool = True,
                           microbatches: Optional[int] = None):
    """One FedAvg round of FHDP: each data column (FL client cluster) runs
    ``local_steps`` pipelined steps on its own batches with NO cross-client
    traffic, then parameters are hierarchically averaged (edge = ``data``,
    cloud = ``pod``)."""
    window = effective_window(cfg, shape)
    step, h = pl.make_fhdp_train_step(
        cfg, shape, mesh, remat=remat, window=window, fed_sgd=False,
        learning_rate=learning_rate, microbatches=microbatches,
        templates=templates)

    def fl_round(pp, opt, batches):
        # batches: pytree with leading local-step axis [E, B, ...]
        def body(carry, batch):
            pp, opt = carry
            pp, opt, metrics = step(pp, opt, batch)
            return (pp, opt), metrics

        (pp, opt), ms = jax.lax.scan(body, (pp, opt), batches)
        pp = pl.fedavg_stage_params(pp, mesh)
        return pp, opt, jax.tree.map(lambda x: x[-1], ms)

    return fl_round, h
