"""FLAD's own vision encoder (paper §4.1.3 "Complexity of Vision Encoder").

DAG: RGB backbone + LiDAR backbone -> transformer encoder (multimodal token
fusion) -> query-based decoder heads (waypoints, traffic light, BEV logits).
The conv/PointPillar frontends are stubs per the carve-out: synthetic data
supplies patch/pillar features; the model owns projectors and everything
after. This is the model trained federatedly by FHDP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import blocks as B


def init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 9)
    lkeys = jax.random.split(ks[0], cfg.num_layers)
    from repro.models.encdec import init_enc_block
    d = cfg.d_model
    nq = cfg.num_waypoints + 1  # waypoint queries + 1 traffic-light query
    return {
        "rgb_proj": B.init_linear(ks[1], cfg.prefix_dim, d, cfg.dtype),
        "lidar_proj": B.init_linear(ks[2], cfg.prefix_dim, d, cfg.dtype),
        "modality_emb": (jax.random.normal(ks[3], (2, d)) * 0.02).astype(cfg.dtype),
        "blocks": jax.vmap(lambda k: init_enc_block(k, cfg))(lkeys),
        "ln_f": B.init_rmsnorm(d, cfg.dtype),
        "queries": (jax.random.normal(ks[4], (nq, d)) * 0.02).astype(cfg.dtype),
        "dec_attn": B.init_attention(ks[5], cfg, cross=True),
        "dec_ln": B.init_rmsnorm(d, cfg.dtype),
        "wp_head": B.init_linear(ks[6], d, 2, cfg.dtype, bias=True),
        "light_head": B.init_linear(ks[7], d, cfg.num_light_classes, cfg.dtype,
                                    bias=True),
    }


def forward(params, cfg: ModelConfig, batch, **_):
    """batch: {'rgb': [B,Pr,F], 'lidar': [B,Pl,F]} ->
    {'waypoints': [B,W,2], 'light_logits': [B,C], 'features': [B,P,d]}."""
    rgb = B.linear(params["rgb_proj"], batch["rgb"].astype(cfg.dtype))
    lid = B.linear(params["lidar_proj"], batch["lidar"].astype(cfg.dtype))
    x = jnp.concatenate([rgb + params["modality_emb"][0],
                         lid + params["modality_emb"][1]], axis=1)
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(h, lp):
        a, _ = B.attention(lp["attn"], B.rms_norm(lp["ln1"], h, cfg.norm_eps),
                           cfg, positions=pos, causal=False,
                           positions_contiguous=True)
        h = h + a
        h = h + B.mlp(lp["ffn"], B.rms_norm(lp["ln2"], h, cfg.norm_eps))
        return h, None

    x, _ = jax.lax.scan(body, x, params["blocks"])
    feats = B.rms_norm(params["ln_f"], x, cfg.norm_eps)

    b = feats.shape[0]
    q = jnp.broadcast_to(params["queries"][None], (b,) + params["queries"].shape)
    nkv, hd = cfg.num_kv_heads, cfg.hd
    k = (feats @ params["dec_attn"]["wk"]).reshape(
        b, -1, nkv, hd).transpose(0, 2, 1, 3)
    v = (feats @ params["dec_attn"]["wv"]).reshape(
        b, -1, nkv, hd).transpose(0, 2, 1, 3)
    qpos = jnp.arange(q.shape[1], dtype=jnp.int32)
    dec, _ = B.attention(params["dec_attn"], B.rms_norm(params["dec_ln"], q,
                                                        cfg.norm_eps),
                         cfg, positions=qpos, cross_kv=(k, v), cross_pos=pos,
                         causal=False)
    dec = dec + q
    wp = B.linear(params["wp_head"], dec[:, :cfg.num_waypoints]).astype(jnp.float32)
    light = B.linear(params["light_head"], dec[:, -1]).astype(jnp.float32)
    return {"waypoints": wp, "light_logits": light, "features": feats}


def loss_fn(params, cfg: ModelConfig, batch):
    out = forward(params, cfg, batch)
    l1 = jnp.abs(out["waypoints"] - batch["waypoints"]).mean()
    logp = jax.nn.log_softmax(out["light_logits"])
    ce = -jnp.take_along_axis(logp, batch["light"][:, None], axis=-1).mean()
    return l1 + ce, {"l1": l1, "ce": ce,
                     "acc": (out["light_logits"].argmax(-1) == batch["light"])
                     .mean()}
