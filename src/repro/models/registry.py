"""Uniform model interface over all families.

``build_model(cfg)`` returns a :class:`Model` whose members are plain
functions (easy to ``jax.jit`` / ``shard_map`` / pipeline-partition).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import blocks as B
from repro.models import encdec, hymba, lm, vision_encoder, xlstm


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]          # (params, batch) -> (loss, metrics)
    init_state: Callable[..., Any]    # (batch, cache_len) -> decode state
    prefill: Callable[..., Any]       # (params, batch, state) -> (logits, state)
    decode_step: Callable[..., Any]   # (params, tokens, state, pos) -> (logits, state)


def _ce_loss(logits, labels, aux):
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = -ll.mean()
    return loss + aux, {"ce": loss, "aux": aux,
                        "acc": (logits.argmax(-1) == labels).mean()}


def _hidden_ce(params, x, labels, aux):
    """Chunked CE from final hidden states (never builds [B,S,V] logits)."""
    from repro.train.losses import chunked_ce, head_weight
    loss, metrics = chunked_ce(x, head_weight(params), labels)
    metrics = dict(metrics, aux=aux)
    return loss + aux, metrics


# --------------------------------------------------------- dense/moe/vlm ----
def _build_lm(cfg: ModelConfig) -> Model:
    is_vlm = cfg.family == "vlm"

    def loss(params, batch, *, remat=True, window=None):
        pfx = batch.get("patches") if is_vlm else None
        x, _, aux = lm.forward(params, cfg, batch["tokens"],
                               prefix_embeds=pfx, window=window,
                               remat=remat, hidden_only=True)
        return _hidden_ce(params, x, batch["labels"], aux)

    def init_state(batch: int, cache_len: int):
        return {"caches": lm.init_cache(cfg, batch, cache_len)}

    def prefill(params, batch, state, *, window=None):
        pfx = batch.get("patches") if is_vlm else None
        logits, caches, _ = lm.forward(params, cfg, batch["tokens"],
                                       caches=state["caches"],
                                       prefix_embeds=pfx, window=window,
                                       logits_slice=1)
        return logits, {"caches": caches}

    def decode_step(params, tokens, state, pos, *, window=None):
        positions = jnp.full((1,), pos, jnp.int32)
        logits, caches, _ = lm.forward(params, cfg, tokens,
                                       positions=positions,
                                       caches=state["caches"], window=window)
        return logits, {"caches": caches}

    return Model(cfg, lambda key: lm.init(key, cfg), loss, init_state,
                 prefill, decode_step)


# ------------------------------------------------------------------ ssm ----
def _build_xlstm(cfg: ModelConfig) -> Model:
    def loss(params, batch, *, remat=True, window=None):
        x, _, aux = xlstm.forward(params, cfg, batch["tokens"],
                                  hidden_only=True, remat=remat)
        return _hidden_ce(params, x, batch["labels"], aux)

    def init_state(batch: int, cache_len: int):
        return xlstm.init_state(cfg, batch)

    def prefill(params, batch, state):
        logits, st, _ = xlstm.forward(params, cfg, batch["tokens"],
                                      states=state, logits_slice=1)
        return logits, st

    def decode_step(params, tokens, state, pos, *, window=None):
        logits, st, _ = xlstm.forward(params, cfg, tokens, states=state,
                                      step=True)
        return logits, st

    return Model(cfg, lambda key: xlstm.init(key, cfg), loss, init_state,
                 prefill, decode_step)


# --------------------------------------------------------------- hybrid ----
def _build_hymba(cfg: ModelConfig) -> Model:
    def loss(params, batch, *, remat=True, window=None):
        x, _, aux = hymba.forward(params, cfg, batch["tokens"],
                                  window=window, hidden_only=True,
                                  remat=remat)
        return _hidden_ce(params, x, batch["labels"], aux)

    def init_state(batch: int, cache_len: int):
        return hymba.init_state(cfg, batch, cache_len)

    def prefill(params, batch, state, *, window=None):
        logits, st, _ = hymba.forward(params, cfg, batch["tokens"],
                                      states=state, window=window,
                                      logits_slice=1)
        return logits, st

    def decode_step(params, tokens, state, pos, *, window=None):
        positions = jnp.full((1,), pos, jnp.int32)
        logits, st, _ = hymba.forward(params, cfg, tokens,
                                      positions=positions, states=state,
                                      window=window, step=True)
        return logits, st

    return Model(cfg, lambda key: hymba.init(key, cfg), loss, init_state,
                 prefill, decode_step)


# --------------------------------------------------------------- encdec ----
def _build_encdec(cfg: ModelConfig) -> Model:
    def loss(params, batch, *, remat=True, window=None):
        memory = encdec.encode(params, cfg, batch["frames"], window=window,
                               remat=remat)
        cross = encdec.make_cross_kv(params, cfg, memory)
        x, _ = encdec.decode(params, cfg, batch["tokens"], cross,
                             window=window, hidden_only=True, remat=remat)
        return _hidden_ce(params, x, batch["labels"],
                          jnp.zeros((), jnp.float32))

    def init_state(batch: int, cache_len: int):
        # cross_kv is overwritten by prefill; zeros let a raw decode lower.
        from repro.configs.common import ENC_MEMORY_DECODE
        nkv, hd = cfg.num_kv_heads, cfg.hd
        ck = jnp.zeros((cfg.dec_layers, batch, nkv, ENC_MEMORY_DECODE, hd),
                       cfg.dtype)
        return {"caches": encdec.init_cache(cfg, batch, cache_len),
                "cross_kv": (ck, ck)}

    def prefill(params, batch, state, *, window=None):
        memory = encdec.encode(params, cfg, batch["frames"], window=window)
        cross = encdec.make_cross_kv(params, cfg, memory)
        logits, caches = encdec.decode(params, cfg, batch["tokens"], cross,
                                       caches=state["caches"], window=window,
                                       logits_slice=1)
        return logits, {"caches": caches, "cross_kv": cross}

    def decode_step(params, tokens, state, pos, *, window=None):
        positions = jnp.full((1,), pos, jnp.int32)
        logits, caches = encdec.decode(params, cfg, tokens, state["cross_kv"],
                                       positions=positions,
                                       caches=state["caches"], window=window)
        return logits, {"caches": caches, "cross_kv": state["cross_kv"]}

    return Model(cfg, lambda key: encdec.init(key, cfg), loss, init_state,
                 prefill, decode_step)


# --------------------------------------------------------------- vision ----
def _build_vision(cfg: ModelConfig) -> Model:
    def loss(params, batch, *, remat=True, window=None):
        return vision_encoder.loss_fn(params, cfg, batch)

    def unsupported(*a, **k):
        raise NotImplementedError("vision encoder has no decode path")

    return Model(cfg, lambda key: vision_encoder.init(key, cfg), loss,
                 lambda b, c: {}, unsupported, unsupported)


MODEL_BUILDERS = {
    "dense": _build_lm,
    "moe": _build_lm,
    "vlm": _build_lm,
    "ssm": _build_xlstm,
    "hybrid": _build_hymba,
    "encdec": _build_encdec,
    "vision": _build_vision,
}


def build_model(cfg: ModelConfig) -> Model:
    return MODEL_BUILDERS[cfg.family](cfg)
