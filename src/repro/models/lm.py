"""Decoder-only LM covering the dense / moe / vlm families.

Layers are parameter-stacked on axis 0 and executed with ``jax.lax.scan`` so
the lowered HLO stays O(1) in depth (critical for 512-device dry-run compiles
and for pipeline-stage slicing in FHDP).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import blocks as B


def init_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": B.init_rmsnorm(cfg.d_model, cfg.dtype),
        "attn": B.init_attention(k1, cfg),
        "ln2": B.init_rmsnorm(cfg.d_model, cfg.dtype),
    }
    if cfg.moe.num_experts:
        p["moe"] = B.init_moe(k2, cfg)
    else:
        p["ffn"] = B.init_mlp(k2, cfg)
    return p


def _factors_present(sub) -> bool:
    """True iff a LoRA factor subtree carries any actual {"A", "B"}
    factors (None everywhere = unadapted)."""
    if sub is None:
        return False
    leaves = jax.tree_util.tree_leaves(
        sub, is_leaf=lambda v: isinstance(v, dict) and "A" in v)
    return any(isinstance(leaf, dict) for leaf in leaves)


def apply_block(p: dict, x, cfg: ModelConfig, *, positions, cache=None,
                window=None, use_chunked=None, positions_contiguous=None,
                lora=None, lora_scale: float = 1.0):
    attn_lora = None if lora is None else lora.get("attn")
    ffn_lora = None if lora is None else lora.get("ffn")
    a, new_cache = B.attention(p["attn"], B.rms_norm(p["ln1"], x, cfg.norm_eps),
                               cfg, positions=positions, cache=cache,
                               window=window, use_chunked=use_chunked,
                               positions_contiguous=positions_contiguous,
                               lora=attn_lora, lora_scale=lora_scale)
    x = x + a
    h = B.rms_norm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        if _factors_present(None if lora is None else lora.get("moe")):
            raise NotImplementedError(
                "LoRA factors on MoE expert weights are not supported by "
                "the fused adapted forward; restrict LoRAConfig.targets "
                "to the attention/MLP projections")
        from repro.core import act_sharding
        r = act_sharding.current()
        if r is not None and r.mesh is not None \
                and "model" in getattr(r.mesh, "axis_names", ()):
            from repro.core.moe_ep import moe_block_ep
            f, aux = moe_block_ep(p["moe"], h, cfg, mesh=r.mesh,
                                  seq_sharded=r.seq_axis is not None)
        else:
            f, aux = B.moe_block(p["moe"], h, cfg)
    else:
        f, aux = B.mlp(p["ffn"], h, lora=ffn_lora, lora_scale=lora_scale), \
            jnp.zeros((), jnp.float32)
    return x + f, new_cache, aux


def init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.num_layers)
    params = {
        "embed": B.init_embedding(ks[1], cfg.vocab_size, cfg.d_model, cfg.dtype),
        "blocks": jax.vmap(lambda k: init_block(k, cfg))(layer_keys),
        "ln_f": B.init_rmsnorm(cfg.d_model, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = B.init_linear(ks[2], cfg.d_model, cfg.vocab_size,
                                       cfg.dtype)
    if cfg.prefix_tokens:  # vlm projector (stub ViT output -> d_model)
        params["projector"] = B.init_linear(ks[3], cfg.prefix_dim, cfg.d_model,
                                            cfg.dtype)
    return params


def _scan_blocks(params, x, cfg: ModelConfig, *, positions, caches=None,
                 window=None, remat=False, use_chunked=None,
                 positions_contiguous=None, lora=None, lora_scale=1.0):
    """Run the stacked block pytree over x. caches: stacked kv cache or None.

    ``lora`` is the layer-stacked factor subtree for ``params["blocks"]``
    (or None): scan slices the leading layer axis of each (A, B) factor
    exactly like the block weights, and None (unadapted) leaves are empty
    pytree nodes that cost nothing.
    """
    from repro.core.act_sharding import constrain

    def body(carry, layer):
        h = carry
        lp, lc, lf = layer
        out, new_cache, aux = apply_block(
            lp, h, cfg, positions=positions, cache=lc, window=window,
            use_chunked=use_chunked,
            positions_contiguous=positions_contiguous,
            lora=lf, lora_scale=lora_scale)
        return constrain(out), (new_cache, aux)

    fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body
    xs = (params["blocks"], caches, lora)
    x, (new_caches, auxs) = jax.lax.scan(fn, x, xs)
    return x, new_caches, auxs.sum()


def forward(params, cfg: ModelConfig, tokens, *, positions=None, caches=None,
            prefix_embeds=None, window=None, remat=False, use_chunked=None,
            logits_slice: Optional[int] = None, hidden_only: bool = False,
            lora=None, lora_scale: float = 1.0):
    """tokens: [B, S] int32. Returns (logits [B, S(, V)], new_caches, aux).

    ``lora``: optional factor pytree from ``distill.lora.init_lora`` (same
    structure as ``params``). Factors on the block stack run through the
    fused base+low-rank kernel without materializing merged weights; the
    base stays frozen, so grads w.r.t. ``lora`` are the adapter-only
    update federated distillation ships upstream.
    """
    lora_blocks = None
    if lora is not None:
        extra = {k: v for k, v in lora.items() if k != "blocks"}
        if _factors_present(extra):
            bad = sorted(k for k, v in extra.items() if _factors_present(v))
            raise NotImplementedError(
                f"LoRA factors outside the block stack are not supported "
                f"by the fused forward (got factors under {bad}); adapt "
                f"only block projections or fold with merge_lora instead")
        lora_blocks = lora.get("blocks")
    x = B.embed(params["embed"], tokens)
    npfx = 0
    if prefix_embeds is not None:
        pfx = B.linear(params["projector"], prefix_embeds.astype(x.dtype))
        x = jnp.concatenate([pfx, x], axis=1)
        npfx = pfx.shape[1]
    pos_contig = True if positions is None else None
    if positions is None:
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, new_caches, aux = _scan_blocks(params, x, cfg, positions=positions,
                                      caches=caches, window=window,
                                      remat=remat, use_chunked=use_chunked,
                                      positions_contiguous=pos_contig,
                                      lora=lora_blocks, lora_scale=lora_scale)
    x = B.rms_norm(params["ln_f"], x, cfg.norm_eps)
    if npfx:
        x = x[:, npfx:]
    if logits_slice is not None:
        x = x[:, -logits_slice:]
    if hidden_only:
        return x, new_caches, aux
    if cfg.tie_embeddings:
        logits = B.unembed(params["embed"], x)
    else:
        logits = B.linear(params["head"], x).astype(jnp.float32)
    return logits, new_caches, aux


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    return B.init_kv_cache(cfg, batch, cache_len, stacked=cfg.num_layers)
