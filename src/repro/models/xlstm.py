"""xLSTM stack (sLSTM + mLSTM blocks), arXiv:2405.04517.

Layout: ``slstm_every``-sized super-blocks, each = (slstm_every - 1) mLSTM
blocks followed by one sLSTM block (the xLSTM[7:1] pattern for
slstm_every=8). Parameters are stacked [n_super, k, ...] so a two-level scan
keeps HLO size depth-independent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import blocks as B
from repro.models import recurrent as R


def _layout(cfg: ModelConfig):
    k = cfg.ssm.slstm_every or cfg.num_layers
    assert cfg.num_layers % k == 0, (cfg.num_layers, k)
    return cfg.num_layers // k, k - 1  # (n_super, mlstm_per_super)


def init(key, cfg: ModelConfig) -> dict:
    n_super, n_m = _layout(cfg)
    ks = jax.random.split(key, 4)
    mkeys = jax.random.split(ks[0], n_super * max(n_m, 1)).reshape(
        n_super, max(n_m, 1), 2)
    skeys = jax.random.split(ks[1], n_super)
    params = {
        "embed": B.init_embedding(ks[2], cfg.vocab_size, cfg.d_model, cfg.dtype),
        "mlstm": jax.vmap(jax.vmap(lambda k_: R.init_mlstm(k_, cfg)))(mkeys),
        "slstm": jax.vmap(lambda k_: R.init_slstm(k_, cfg))(skeys),
        "ln_f": B.init_rmsnorm(cfg.d_model, cfg.dtype),
        "head": B.init_linear(ks[3], cfg.d_model, cfg.vocab_size, cfg.dtype),
    }
    return params


def init_state(cfg: ModelConfig, batch: int) -> dict:
    n_super, n_m = _layout(cfg)

    def stack(fn, outer, inner=None):
        one = fn(cfg, batch)
        reps = (outer,) if inner is None else (outer, inner)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, reps + x.shape).copy(), one)

    return {"mlstm": stack(R.init_mlstm_state, n_super, max(n_m, 1)),
            "slstm": stack(R.init_slstm_state, n_super)}


def _super_block(params, x, cfg, states, step: bool):
    mp, sp = params
    ms, ss = states

    def m_body(h, layer):
        lp, lst = layer
        if step:
            y, nst = R.apply_mlstm_step(lp, h, lst, cfg)
        else:
            y, nst = R.apply_mlstm_seq(lp, h, cfg, state=lst)
        return h + y, nst

    if not step:
        # per-layer remat: one mLSTM layer's chunk carries ([nc,B,NH,DH,DH]
        # f32) at a time during backward, not all 7 at once
        m_body = jax.checkpoint(
            m_body, policy=jax.checkpoint_policies.nothing_saveable)
    x, new_ms = jax.lax.scan(m_body, x, (mp, ms))
    if step:
        y, new_ss = R.apply_slstm_step(sp, x, ss, cfg)
    else:
        y, new_ss = R.apply_slstm_seq(sp, x, cfg, state=ss)
    return x + y, (new_ms, new_ss)


def forward(params, cfg: ModelConfig, tokens, *, states=None, step=False,
            logits_slice=None, hidden_only=False, remat=False, **_):
    x = B.embed(params["embed"], tokens)
    if states is None:
        states = init_state(cfg, tokens.shape[0])

    from repro.core.act_sharding import constrain

    def body(h, layer):
        (mp, sp), (ms, ss) = layer
        h, (nms, nss) = _super_block((mp, sp), h, cfg, (ms, ss), step)
        return constrain(h), (nms, nss)

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (new_m, new_s) = jax.lax.scan(
        body, x, ((params["mlstm"], params["slstm"]),
                  (states["mlstm"], states["slstm"])))
    x = B.rms_norm(params["ln_f"], x, cfg.norm_eps)
    if logits_slice is not None:
        x = x[:, -logits_slice:]
    if hidden_only:
        return x, {"mlstm": new_m, "slstm": new_s}, jnp.zeros((), jnp.float32)
    logits = B.linear(params["head"], x).astype(jnp.float32)
    return logits, {"mlstm": new_m, "slstm": new_s}, jnp.zeros((), jnp.float32)
